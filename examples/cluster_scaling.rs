//! Cluster-scaling sweep (a runnable miniature of the paper's Fig 11).
//!
//! Sweeps the number of FPGA boards, running the event-driven raw algorithm
//! on a panel sized to the boards' hardware threads (DES at reduced scale)
//! and the analytic model at the paper's full scale, printing the speedup
//! trend against the measured x86 baseline.
//!
//! ```bash
//! cargo run --release --example cluster_scaling -- 1 2 4 8
//! ```

use poets_impute::bench::{FigOpts, X86Cost, fig11};

fn main() {
    let boards: Vec<usize> = std::env::args()
        .skip(1)
        .map(|a| a.parse().expect("board counts must be integers"))
        .collect();
    let boards = if boards.is_empty() {
        vec![1, 2, 4, 8]
    } else {
        boards
    };

    eprintln!("calibrating x86 baseline throughput...");
    let x86 = X86Cost::measure_default();
    let opts = FigOpts {
        des_states_per_board: 96,
        des_targets: 10,
        full_targets: 10_000,
        skip_des: false,
        seed: 11,
    };
    let report = fig11(&boards, &opts, &x86);
    println!("{}", report.render());
    println!(
        "(DES columns: exact simulation at reduced scale; full columns: \
         analytic model at paper scale with 10,000 targets)"
    );

    // The paper's qualitative claim: speedup grows with hardware.
    let s: Vec<f64> = report.rows.iter().map(|r| r.full_speedup).collect();
    if s.windows(2).all(|w| w[1] > w[0]) {
        println!("shape check: monotone speedup growth over boards ✓");
    } else {
        println!("shape check FAILED: {s:?}");
    }
}
