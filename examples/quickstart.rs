//! Quickstart: impute one small synthetic workload through the session API
//! on two compute planes and watch the answers agree.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use poets_impute::session::{EngineSpec, ImputeSession, Workload, max_abs_dosage_diff};
use poets_impute::util::table::fmt_secs;
use poets_impute::workload::panelgen::PanelConfig;

fn main() {
    // 1. A small reference panel and three target haplotypes, generated with
    //    the paper's recipe (diallelic, 5% MAF, 1-in-10 markers annotated).
    let cfg = PanelConfig {
        n_hap: 16,
        n_mark: 101,
        maf: 0.05,
        annot_ratio: 0.1,
        seed: 42,
        ..PanelConfig::default()
    };
    let workload = Workload::synthetic(&cfg, 3);
    println!(
        "panel: {} haplotypes x {} markers; {} targets, {} annotated markers each",
        workload.panel().n_hap(),
        workload.panel().n_mark(),
        workload.n_targets(),
        workload.targets()[0].n_annotated()
    );

    // 2. The x86-style baseline (paper §6.1: three nested loops).
    let baseline = ImputeSession::new(workload.clone())
        .engine(EngineSpec::Baseline)
        .run()
        .expect("baseline plane");

    // 3. The event-driven plane on a simulated 2-board POETS cluster
    //    (paper §5: one vertex per HMM state, α/β waves, posterior unicast).
    let event = ImputeSession::new(workload)
        .engine(EngineSpec::Event)
        .boards(2)
        .states_per_thread(8)
        .run()
        .expect("event plane");
    let metrics = event.metrics.as_ref().expect("event plane reports metrics");
    println!(
        "event-driven run: {} steps, {} events, simulated wall-clock {}",
        metrics.steps,
        metrics.copies_delivered,
        fmt_secs(event.sim_seconds.expect("event plane reports sim time"))
    );

    // 4. Agreement + accuracy against the withheld truth (scored by the
    //    session because the synthetic workload retains truth).
    let max_diff = max_abs_dosage_diff(&baseline.dosages, &event.dosages);
    println!("max |dosage difference| baseline vs event-driven: {max_diff:.2e}");
    assert!(max_diff < 1e-3, "engines disagree!");

    let agg = event.accuracy.expect("synthetic workload has truth");
    println!(
        "imputation accuracy on masked markers: concordance {:.3}, dosage r² {:.3}",
        agg.concordance, agg.dosage_r2
    );
}
