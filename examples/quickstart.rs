//! Quickstart: impute one small synthetic workload three ways and watch the
//! answers agree.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use poets_impute::imputation::app::{RawAppConfig, run_raw};
use poets_impute::model::accuracy;
use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::util::table::fmt_secs;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn main() {
    // 1. A small reference panel and three target haplotypes, generated with
    //    the paper's recipe (diallelic, 5% MAF, 1-in-10 markers annotated).
    let cfg = PanelConfig {
        n_hap: 16,
        n_mark: 101,
        maf: 0.05,
        annot_ratio: 0.1,
        seed: 42,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(7);
    let cases = generate_targets(&panel, &cfg, 3, &mut rng);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
    println!(
        "panel: {} haplotypes x {} markers; {} targets, {} annotated markers each",
        panel.n_hap(),
        panel.n_mark(),
        targets.len(),
        targets[0].n_annotated()
    );

    // 2. The x86-style baseline (paper §6.1: three nested loops).
    let baseline = Baseline::default();
    let want: Vec<ImputeOut<f32>> =
        baseline.impute_batch(&panel, &targets, Method::DenseThreeLoop);

    // 3. The event-driven algorithm on a simulated 2-board POETS cluster
    //    (paper §5: one vertex per HMM state, α/β waves, posterior unicast).
    let app = RawAppConfig {
        cluster: ClusterConfig::with_boards(2),
        states_per_thread: 8,
        ..RawAppConfig::default()
    };
    let event = run_raw(&panel, &targets, &app);
    println!(
        "event-driven run: {} steps, {} events, simulated wall-clock {}",
        event.metrics.steps,
        event.metrics.copies_delivered,
        fmt_secs(event.sim_seconds)
    );

    // 4. Agreement + accuracy against the withheld truth.
    let mut max_diff = 0.0f32;
    for (t, out) in want.iter().enumerate() {
        for m in 0..panel.n_mark() {
            max_diff = max_diff.max((out.dosage[m] - event.dosages[t][m]).abs());
        }
    }
    println!("max |dosage difference| baseline vs event-driven: {max_diff:.2e}");
    assert!(max_diff < 1e-3, "engines disagree!");

    let accs: Vec<_> = cases
        .iter()
        .zip(&event.dosages)
        .map(|(c, d)| accuracy::score(d, &c.truth, &c.masked))
        .collect();
    let agg = accuracy::aggregate(&accs);
    println!(
        "imputation accuracy on masked markers: concordance {:.3}, dosage r² {:.3}",
        agg.concordance, agg.dosage_r2
    );
}
