//! Soft-scheduling tuner (a runnable miniature of the paper's Fig 12).
//!
//! Sweeps states-per-hardware-thread on the full simulated cluster via the
//! analytic model (and optionally the DES at reduced scale), locating the
//! optimum the paper reports at ≈10 states/thread for 10,000 targets.
//!
//! ```bash
//! cargo run --release --example softsched_tuning
//! cargo run --release --example softsched_tuning -- --des
//! ```

use poets_impute::bench::{FigOpts, X86Cost, fig12};

fn main() {
    let with_des = std::env::args().any(|a| a == "--des");
    eprintln!("calibrating x86 baseline throughput...");
    let x86 = X86Cost::measure_default();
    let opts = FigOpts {
        des_states_per_board: 96,
        des_targets: 10,
        full_targets: 10_000,
        skip_des: !with_des,
        seed: 12,
    };
    let spt = [1usize, 2, 5, 10, 20, 40];
    let report = fig12(&spt, &opts, &x86);
    println!("{}", report.render());

    let best = report
        .rows
        .iter()
        .max_by(|a, b| a.full_speedup.partial_cmp(&b.full_speedup).unwrap())
        .unwrap();
    println!(
        "optimal soft-scheduling at {} states/thread (paper: ~10) — \
         speedup {:.0}x vs this host's baseline",
        best.x, best.full_speedup
    );
}
