//! Real-data windows: ingest the tiny VCF fixture, bit-pack it, and impute
//! mosaic targets window-by-window on two compute planes.
//!
//! ```bash
//! cargo run --release --example vcf_windows
//! ```

use poets_impute::genomics::packed::PackedPanel;
use poets_impute::genomics::vcf;
use poets_impute::genomics::window::{WindowPlan, run_windowed};
use poets_impute::serve::PanelRegistry;
use poets_impute::session::{EngineSpec, ImputeSession, Workload, max_abs_dosage_diff};

const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/data/tiny.vcf");

fn main() {
    // 1. Ingest: phased bi-allelic VCF → panel + site metadata.
    let parsed = vcf::load(FIXTURE).expect("fixture parses");
    let first = &parsed.sites[0];
    let last = parsed.sites.last().unwrap();
    println!(
        "ingested {FIXTURE}:\n  {} sites x {} haplotypes on chromosome {} ({}..{})",
        parsed.panel.n_mark(),
        parsed.panel.n_hap(),
        first.chrom,
        first.pos,
        last.pos
    );

    // 2. Bit-pack at 1 bit/allele and round-trip through the .ppnl format.
    let packed = PackedPanel::from_vcf(&parsed);
    let raw = parsed.panel.n_hap() * parsed.panel.n_mark();
    println!(
        "  packed alleles: {} B vs {} B unpacked ({:.1}x smaller), {} B on disk",
        packed.packed_allele_bytes(),
        raw,
        raw as f64 / packed.packed_allele_bytes() as f64,
        packed.encode().len()
    );
    let ppnl = std::env::temp_dir().join("vcf_windows_example.ppnl");
    let ppnl = ppnl.to_str().unwrap().to_string();
    packed.write(&ppnl).expect("write .ppnl");

    // 3. Resolve it like `impute --panel packed:...` / a serve request would,
    //    and mint mosaic targets from the panel itself (truth retained).
    let registry = PanelRegistry::new();
    let panel = registry.resolve(&format!("packed:{ppnl}")).expect("resolve");
    let _ = std::fs::remove_file(&ppnl);
    let cases = panel.mosaic_targets(3, 0.25, 7).expect("mint targets");
    let workload = Workload::from_shared_cases(panel.panel_arc(), cases).expect("workload");

    // 4. Window the marker axis (length 30, overlap 20 — window edges land
    //    on the fixture's recombination-hotspot gaps, at markers the 1-in-4
    //    chip grid leaves unobserved) and run two planes.
    let plan = WindowPlan::new(workload.panel().n_mark(), 30, 20).expect("plan");
    println!("  {} windows:", plan.len());
    for w in plan.windows() {
        println!(
            "    [{:2}, {:2})  core [{:2}, {:2})",
            w.start, w.end, w.core_start, w.core_end
        );
    }
    let baseline = run_windowed(&workload, &plan, |s| s.engine(EngineSpec::Baseline))
        .expect("baseline plane");
    let event = run_windowed(&workload, &plan, |s| {
        s.engine(EngineSpec::Event).boards(1).states_per_thread(8)
    })
    .expect("event plane");

    // 5. Stitched dosages agree across planes and with the unwindowed run.
    let cross = max_abs_dosage_diff(&baseline.dosages, &event.dosages);
    let full = ImputeSession::new(workload)
        .engine(EngineSpec::Baseline)
        .run()
        .expect("unwindowed baseline");
    let drift = max_abs_dosage_diff(&baseline.dosages, &full.dosages);
    println!(
        "windowed baseline vs event: max |Δdosage| = {cross:.2e}\n\
         windowed vs unwindowed baseline: max |Δdosage| = {drift:.2e}"
    );
    assert!(cross <= 1e-3, "planes disagree");
    assert!(drift <= 1e-4, "windowing drifted from the full run");
    let acc = event.accuracy.expect("mosaic targets retain truth");
    println!(
        "imputation accuracy on masked markers: concordance {:.3}, dosage r² {:.3}",
        acc.concordance, acc.dosage_r2
    );
}
