//! End-to-end driver: upscale a GWAS-style cohort on the simulated POETS
//! cluster — the repository's headline validation run (EXPERIMENTS.md §E2E).
//!
//! A chromosome-1-like reference panel is generated with the paper's §6.2
//! recipe; a cohort of target haplotypes (drawn from the Li & Stephens
//! mosaic process, truth withheld) is imputed through the session API on
//! every available compute plane:
//!
//! 1. x86-style dense baseline (the paper's comparison point),
//! 2. event-driven raw plane on the simulated cluster (paper §5.2),
//! 3. event-driven + linear interpolation (paper §5.3),
//! 4. the AOT JAX/Pallas artifact through PJRT (the XLA compute plane),
//!
//! and the run reports accuracy against the withheld truth, message
//! statistics, simulated POETS wall-clock and host wall-clock.
//!
//! ```bash
//! make artifacts && cargo run --release --example gwas_upscale
//! ```

use poets_impute::bench::X86Cost;
use poets_impute::model::baseline::Method;
use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::util::table::{Table, fmt_count, fmt_secs};
use poets_impute::workload::panelgen::PanelConfig;

fn add_row(table: &mut Table, name: &str, report: &ImputeReport) {
    let acc = report.accuracy.expect("synthetic workload has truth");
    table.row(vec![
        name.into(),
        fmt_secs(report.host_seconds),
        report.sim_seconds.map_or("-".into(), fmt_secs),
        report
            .metrics
            .as_ref()
            .map_or("-".into(), |m| fmt_count(m.copies_delivered)),
        format!("{:.4}", acc.concordance),
        format!("{:.4}", acc.dosage_r2),
    ]);
}

fn main() {
    // Chromosome-1-like slice at canonical H=64 so the XLA plane can join:
    // 64 haplotypes x 500 markers = 32,000 HMM states, 1-in-10 annotated.
    let cfg = PanelConfig {
        n_hap: 64,
        n_mark: 500,
        maf: 0.05,
        annot_ratio: 0.1,
        seed: 1000,
        ..PanelConfig::default()
    };
    let workload = Workload::synthetic(&cfg, 24);
    println!(
        "== GWAS upscale: {}x{} panel ({} states), {} targets, ratio 1/10 ==\n",
        workload.panel().n_hap(),
        workload.panel().n_mark(),
        fmt_count(workload.panel().n_states() as u64),
        workload.n_targets()
    );

    let session = |engine: EngineSpec, spt: usize| {
        ImputeSession::new(workload.clone())
            .engine(engine)
            .boards(8)
            .states_per_thread(spt)
            .run()
    };

    let mut table = Table::new(&[
        "engine",
        "host time",
        "poets sim",
        "events",
        "concordance",
        "dosage r2",
    ]);

    // 1. Dense baseline.
    let dense = session(EngineSpec::Baseline, 4).expect("baseline plane");
    add_row(&mut table, "x86 dense baseline", &dense);

    // 2. Event-driven raw on 8 boards.
    let raw = session(EngineSpec::Event, 4).expect("event plane");
    add_row(&mut table, "event-driven raw", &raw);

    // 3. Event-driven + linear interpolation (one section vertex per thread).
    let itp = session(EngineSpec::Interp, 1).expect("interp plane");
    add_row(&mut table, "event-driven interp", &itp);

    // 4. XLA artifact plane (AOT JAX/Pallas via PJRT), if artifacts exist.
    match session(EngineSpec::Xla, 4) {
        Ok(xla) => add_row(&mut table, "XLA artifact (Pallas)", &xla),
        Err(e) => println!("XLA plane skipped: {e} (run `make artifacts`)"),
    }

    println!("{}", table.render());

    // Message economics (the paper's §6.3 argument in one line):
    let raw_m = raw.metrics.as_ref().expect("event plane reports metrics");
    let itp_m = itp.metrics.as_ref().expect("interp plane reports metrics");
    println!(
        "message reduction raw -> interp: {:.1}x (sends {} -> {})",
        raw_m.sends as f64 / itp_m.sends as f64,
        fmt_count(raw_m.sends),
        fmt_count(itp_m.sends),
    );
    let raw_sim = raw.sim_seconds.expect("event plane reports sim time");
    let itp_sim = itp.sim_seconds.expect("interp plane reports sim time");
    println!("simulated speedup interp vs raw: {:.1}x", raw_sim / itp_sim);

    // Simulated POETS vs measured baseline: the figure currency.
    let x86 = X86Cost::measure_raw_batch(
        workload.panel(),
        workload.targets(),
        Method::DenseThreeLoop,
    );
    println!(
        "this-host x86 dense {} vs simulated POETS raw {} -> speedup {:.1}x",
        fmt_secs(x86),
        fmt_secs(raw_sim),
        x86 / raw_sim
    );
}
