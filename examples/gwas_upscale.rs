//! End-to-end driver: upscale a GWAS-style cohort on the simulated POETS
//! cluster — the repository's headline validation run (EXPERIMENTS.md §E2E).
//!
//! A chromosome-1-like reference panel is generated with the paper's §6.2
//! recipe; a cohort of target haplotypes (drawn from the Li & Stephens
//! mosaic process, truth withheld) is imputed four ways:
//!
//! 1. x86-style dense baseline (the paper's comparison point),
//! 2. event-driven raw model on the simulated cluster (paper §5.2),
//! 3. event-driven + linear interpolation (paper §5.3),
//! 4. the AOT JAX/Pallas artifact through PJRT (the XLA compute plane),
//!
//! and the run reports accuracy against the withheld truth, message
//! statistics, simulated POETS wall-clock and host wall-clock.
//!
//! ```bash
//! make artifacts && cargo run --release --example gwas_upscale
//! ```

use poets_impute::bench::X86Cost;
use poets_impute::imputation::app::{RawAppConfig, run_raw};
use poets_impute::imputation::interp_app::run_interp;
use poets_impute::model::accuracy::{self, Accuracy};
use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::params::ModelParams;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::runtime::{Runtime, XlaImputer};
use poets_impute::util::rng::Rng;
use poets_impute::util::table::{Table, fmt_count, fmt_secs};
use poets_impute::util::timed;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn score(
    dosages: &[Vec<f32>],
    cases: &[poets_impute::workload::panelgen::TargetCase],
) -> Accuracy {
    let accs: Vec<_> = cases
        .iter()
        .zip(dosages)
        .map(|(c, d)| accuracy::score(d, &c.truth, &c.masked))
        .collect();
    accuracy::aggregate(&accs)
}

fn main() {
    // Chromosome-1-like slice at canonical H=64 so the XLA plane can join:
    // 64 haplotypes x 500 markers = 32,000 HMM states, 1-in-10 annotated.
    let cfg = PanelConfig {
        n_hap: 64,
        n_mark: 500,
        maf: 0.05,
        annot_ratio: 0.1,
        seed: 1000,
        ..PanelConfig::default()
    };
    let n_targets = 24;
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(99);
    let cases = generate_targets(&panel, &cfg, n_targets, &mut rng);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
    println!(
        "== GWAS upscale: {}x{} panel ({} states), {} targets, ratio 1/10 ==\n",
        panel.n_hap(),
        panel.n_mark(),
        fmt_count(panel.n_states() as u64),
        n_targets
    );

    let mut table = Table::new(&[
        "engine",
        "host time",
        "poets sim",
        "events",
        "concordance",
        "dosage r2",
    ]);

    // 1. Dense baseline.
    let b = Baseline::default();
    let (dense, t_dense) = timed(|| {
        b.impute_batch::<f32>(&panel, &targets, Method::DenseThreeLoop)
            .into_iter()
            .map(|o: ImputeOut<f32>| o.dosage)
            .collect::<Vec<_>>()
    });
    let a = score(&dense, &cases);
    table.row(vec![
        "x86 dense baseline".into(),
        fmt_secs(t_dense),
        "-".into(),
        "-".into(),
        format!("{:.4}", a.concordance),
        format!("{:.4}", a.dosage_r2),
    ]);

    // 2. Event-driven raw on 8 boards.
    let app = RawAppConfig {
        cluster: ClusterConfig::with_boards(8),
        states_per_thread: 4,
        ..RawAppConfig::default()
    };
    let (raw, t_raw) = timed(|| run_raw(&panel, &targets, &app));
    let a = score(&raw.dosages, &cases);
    table.row(vec![
        "event-driven raw".into(),
        fmt_secs(t_raw),
        fmt_secs(raw.sim_seconds),
        fmt_count(raw.metrics.copies_delivered),
        format!("{:.4}", a.concordance),
        format!("{:.4}", a.dosage_r2),
    ]);

    // 3. Event-driven + linear interpolation (one section vertex per thread).
    let app_itp = RawAppConfig {
        states_per_thread: 1,
        ..app
    };
    let (itp, t_itp) = timed(|| run_interp(&panel, &targets, &app_itp));
    let a = score(&itp.dosages, &cases);
    table.row(vec![
        "event-driven interp".into(),
        fmt_secs(t_itp),
        fmt_secs(itp.sim_seconds),
        fmt_count(itp.metrics.copies_delivered),
        format!("{:.4}", a.concordance),
        format!("{:.4}", a.dosage_r2),
    ]);

    // 4. XLA artifact plane (AOT JAX/Pallas via PJRT), if artifacts exist.
    match Runtime::open_default() {
        Ok(rt) => {
            let mut imputer = XlaImputer::new(rt, ModelParams::default());
            let (xla, t_xla) = timed(|| imputer.impute_batch(&panel, &targets));
            match xla {
                Ok(xla) => {
                    let a = score(&xla, &cases);
                    table.row(vec![
                        "XLA artifact (Pallas)".into(),
                        fmt_secs(t_xla),
                        "-".into(),
                        "-".into(),
                        format!("{:.4}", a.concordance),
                        format!("{:.4}", a.dosage_r2),
                    ]);
                }
                Err(e) => println!("XLA plane skipped: {e}"),
            }
        }
        Err(e) => println!("XLA plane skipped: {e} (run `make artifacts`)"),
    }

    println!("{}", table.render());

    // Message economics (the paper's §6.3 argument in one line):
    println!(
        "message reduction raw -> interp: {:.1}x (sends {} -> {})",
        raw.metrics.sends as f64 / itp.metrics.sends as f64,
        fmt_count(raw.metrics.sends),
        fmt_count(itp.metrics.sends),
    );
    println!(
        "simulated speedup interp vs raw: {:.1}x",
        raw.sim_seconds / itp.sim_seconds
    );

    // Simulated POETS vs measured baseline: the figure currency.
    let x86 = X86Cost::measure_raw_batch(&panel, &targets, Method::DenseThreeLoop);
    println!(
        "this-host x86 dense {} vs simulated POETS raw {} -> speedup {:.1}x",
        fmt_secs(x86),
        fmt_secs(raw.sim_seconds),
        x86 / raw.sim_seconds
    );
}
