//! Serve roundtrip: stand up the multi-tenant imputation service, hit it
//! with a burst of concurrent clients, and verify every answer against a
//! direct single-request session run.
//!
//! ```bash
//! cargo run --release --example serve_roundtrip
//! ```

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use poets_impute::serve::{
    CoalescePolicy, ImputeRequest, PanelRegistry, ServeConfig, Service,
};
use poets_impute::session::{EngineSpec, ImputeSession, Workload};

const PANEL: &str = "synth:hap=16,mark=101,annot=0.1,seed=42";
const CLIENTS: usize = 4;

fn main() {
    // 1. A registry with one cached synthetic panel.  Every request names
    //    the panel; the service shares the single in-memory copy.
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).expect("valid synth spec");
    println!(
        "registry: panel {:?} ({} haplotypes x {} markers)",
        panel.name(),
        panel.panel().n_hap(),
        panel.panel().n_mark()
    );

    // 2. The service: two pool workers, coalescing on with a 20ms linger so
    //    this burst of tiny requests visibly merges into shared batches.
    let cfg = ServeConfig::default().workers(2).coalesce(CoalescePolicy {
        max_batch_targets: 32,
        max_linger: Duration::from_millis(20),
    });
    let app = cfg.app.clone();
    let mapping = cfg.mapping;
    let service = Service::start(Arc::clone(&registry), cfg);

    // 3. Concurrent closed-loop clients with disjoint target sets.
    let reports: Vec<_> = thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let service = &service;
                let targets = panel
                    .synthetic_targets(2, 1000 + c as u64)
                    .expect("synthetic panel has a recipe");
                s.spawn(move || {
                    service
                        .submit_wait(ImputeRequest::new(PANEL, EngineSpec::Rank1, targets))
                        .expect("rank1 plane is always available")
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect()
    });

    // 4. Every served answer is bit-identical to a direct session run of
    //    the same request (coalescing preserves request boundaries).
    for (c, report) in reports.iter().enumerate() {
        let direct = ImputeSession::new(
            Workload::from_shared(
                panel.panel_arc(),
                panel.synthetic_targets(2, 1000 + c as u64).unwrap(),
            )
            .unwrap(),
        )
        .engine(EngineSpec::Rank1)
        .app_config(app.clone())
        .mapping(mapping)
        .run()
        .unwrap();
        assert_eq!(
            report.dosages(),
            &direct.dosages[..],
            "served != direct for client {c}"
        );
        println!(
            "client {c}: request {} served in batch {} (width {}, queue wait {:.2}ms) — \
             matches the direct session bit-for-bit",
            report.request_id,
            report.batch_id,
            report.coalesce_width,
            report.queue_wait_seconds * 1e3
        );
    }

    let stats = service.shutdown();
    println!(
        "service: {} accepted, {} completed over {} engine batches (mean width {:.2})",
        stats.accepted,
        stats.completed,
        stats.batches,
        stats.mean_batch_width()
    );
}
