"""AOT pipeline tests: HLO-text emission, manifest format, numeric sanity.

These run the same lowering path as `make artifacts` but into a temp dir with
a trimmed shape menu, then execute the lowered computation through jax to show
the HLO is a faithful export (the Rust-side load/execute is covered by
rust/tests/runtime_artifacts.rs).
"""

from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from .conftest import make_problem


def test_to_hlo_text_contains_entry():
    spec = jax.ShapeDtypeStruct((8,), jnp.float32)
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[8]" in text


def test_emitter_writes_manifest(tmp_path):
    em = aot.Emitter(str(tmp_path))
    em.emit(
        "double_m8",
        lambda x: (x * 2.0,),
        {"x": aot.f32(8)},
        {"y": aot.f32(8)},
    )
    em.finish()
    manifest = (tmp_path / "manifest.tsv").read_text().strip().split("\n")
    assert len(manifest) == 1
    cols = manifest[0].split("\t")
    assert cols[0] == "double_m8"
    assert cols[1] == "double_m8.hlo.txt"
    assert cols[2] == "in:x:float32:8"
    assert cols[3] == "out:y:float32:8"
    assert (tmp_path / "double_m8.hlo.txt").exists()


def test_repo_artifacts_exist_and_match_manifest():
    """`make artifacts` must have produced every manifest entry."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.tsv")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built (run `make artifacts`)")
    for line in open(manifest):
        cols = line.strip().split("\t")
        path = os.path.join(art, cols[1])
        assert os.path.exists(path), f"missing artifact {cols[1]}"
        head = open(path).read(4096)
        assert "ENTRY" in head or "HloModule" in head


def test_lowered_raw_pipeline_numerics():
    """Lowered-and-reimported HLO text is checked indirectly: the jitted fn the
    text was lowered from must agree with the interpreted pipeline."""
    h, m = 16, 32
    p = make_problem(51, h, m)
    jitted = jax.jit(lambda tau, emis, alleles: model.impute_raw(tau, emis, alleles))
    got = np.asarray(jitted(p["tau"], p["emis"], p["alleles_mh"]))
    want = np.asarray(model.impute_raw(p["tau"], p["emis"], p["alleles_mh"]))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_canonical_shape_menu_is_padable():
    """Every raw shape must be reachable by padding: H and M nondecreasing."""
    hs = sorted(h for h, _ in aot.RAW_SHAPES)
    ms = sorted(m for _, m in aot.RAW_SHAPES)
    assert hs == [h for h, _ in aot.RAW_SHAPES]
    assert ms == [m for _, m in aot.RAW_SHAPES]
    assert all(h >= 2 for h in hs) and all(m >= 2 for m in ms)
