"""L2 pipeline tests: model.py against the oracles, batching, interpolation."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .conftest import make_problem

SWEEP = dict(max_examples=15, deadline=None)


@given(seed=st.integers(0, 2**31 - 1), n_hap=st.integers(2, 24), n_mark=st.integers(2, 48))
@settings(**SWEEP)
def test_impute_raw_matches_ref(seed, n_hap, n_mark):
    p = make_problem(seed, n_hap, n_mark)
    want = np.asarray(ref.impute(p["tau"], p["emis"], jnp.asarray(p["panel"])))
    got = np.asarray(model.impute_raw(p["tau"], p["emis"], p["alleles_mh"]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_emissions_match_ref(small_problem):
    p = small_problem
    got = np.asarray(model.emissions(p["alleles_mh"], jnp.asarray(p["obs"])))
    want = np.asarray(ref.emission_probs(jnp.asarray(p["panel"]), jnp.asarray(p["obs"])))
    np.testing.assert_allclose(got, want, rtol=0)


def test_impute_batch_matches_per_target():
    """The vmapped batch path must agree with single-target calls."""
    p = make_problem(21, 16, 32)
    rng = np.random.default_rng(21)
    batch = 5
    obs_batch = np.where(
        rng.random((batch, 32)) < 0.3,
        (rng.random((batch, 32)) < 0.5).astype(np.int32),
        np.int32(-1),
    )
    got = np.asarray(model.impute_batch(p["tau"], jnp.asarray(obs_batch), p["alleles_mh"]))
    for b in range(batch):
        want = np.asarray(model.impute_obs(p["tau"], jnp.asarray(obs_batch[b]), p["alleles_mh"]))
        np.testing.assert_allclose(got[b], want, rtol=1e-5)


def test_impute_batch_jits():
    import jax

    p = make_problem(22, 8, 16)
    obs = jnp.zeros((3, 16), jnp.int32)
    fn = jax.jit(model.impute_batch)
    out = np.asarray(fn(p["tau"], obs, p["alleles_mh"]))
    assert out.shape == (3, 16)
    assert np.isfinite(out).all()


def test_posterior_states_normalised(small_problem):
    p = small_problem
    post = np.asarray(model.posterior_states(p["tau"], p["emis"]))
    np.testing.assert_allclose(post.sum(axis=1), np.ones(post.shape[0]), rtol=1e-4)


def test_impute_interp_pipeline_end_to_end():
    """Full interp pipeline (anchor HMM inside) vs a hand-assembled reference."""
    k, n_hap, m = 6, 12, 24
    p = make_problem(31, n_hap, k)
    rng = np.random.default_rng(31)
    left = np.minimum(np.arange(m) * (k - 1) // m, k - 2).astype(np.int32)
    frac = rng.random(m).astype(np.float32)
    alleles = (rng.random((m, n_hap)) < 0.4).astype(np.float32)

    got = np.asarray(
        model.impute_interp(p["tau"], p["emis"], jnp.asarray(left),
                            jnp.asarray(frac), jnp.asarray(alleles))
    )
    post_k = np.asarray(model.posterior_states(p["tau"], p["emis"]))
    blend = np.asarray(
        ref.interp_posteriors(jnp.asarray(post_k), jnp.asarray(left), jnp.asarray(frac))
    )
    want = (blend * alleles).sum(axis=1) / blend.sum(axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_observed_markers_dominate_dosage():
    """At an annotated marker the dosage must be pulled toward the observed
    allele (posterior mass concentrates on matching haplotypes)."""
    p = make_problem(41, 16, 30, annot_ratio=0.5)
    dos = np.asarray(model.impute_obs(p["tau"], jnp.asarray(p["obs"]), p["alleles_mh"]))
    obs = p["obs"]
    panel = p["panel"]
    for m in np.nonzero(obs >= 0)[0]:
        # Skip monomorphic columns — nothing to discriminate.
        if panel[:, m].min() == panel[:, m].max():
            continue
        freq = panel[:, m].mean()
        if obs[m] == 1:
            assert dos[m] > freq - 1e-6
        else:
            assert dos[m] < freq + 1e-6
