"""Cross-checks between the two independent oracles (dense vs rank-1).

The dense O(H^2) form is a literal transcription of the paper's equations; the
rank-1 form is what every production path (Pallas kernels, Rust baseline, Rust
event-driven vertices) implements.  Agreement here is the root of the whole
correctness argument.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from .conftest import make_problem

SMALL = dict(max_examples=25, deadline=None)


@given(seed=st.integers(0, 2**31 - 1), n_hap=st.integers(2, 24), n_mark=st.integers(2, 40))
@settings(**SMALL)
def test_dense_vs_rank1_forward(seed, n_hap, n_mark):
    p = make_problem(seed, n_hap, n_mark)
    dense = np.asarray(ref.dense_forward(p["tau"], p["emis"]))
    r1 = np.asarray(ref.rank1_forward(p["tau"], p["emis"]))
    np.testing.assert_allclose(dense, r1, rtol=1e-4, atol=1e-7)


@given(seed=st.integers(0, 2**31 - 1), n_hap=st.integers(2, 24), n_mark=st.integers(2, 40))
@settings(**SMALL)
def test_dense_vs_rank1_backward(seed, n_hap, n_mark):
    p = make_problem(seed, n_hap, n_mark)
    dense = np.asarray(ref.dense_backward(p["tau"], p["emis"]))
    r1 = np.asarray(ref.rank1_backward(p["tau"], p["emis"]))
    np.testing.assert_allclose(dense, r1, rtol=1e-4, atol=1e-7)


def test_transition_rows_sum_to_one():
    for tau in [0.0, 0.1, 0.5, 1.0]:
        a = np.asarray(ref.dense_transition(jnp.float64(tau), 8, jnp.float64))
        np.testing.assert_allclose(a.sum(axis=1), np.ones(8), rtol=1e-12)


def test_initialisation_follows_algorithm1(small_problem):
    p = small_problem
    alphas = np.asarray(ref.rank1_forward(p["tau"], p["emis"]))
    betas = np.asarray(ref.rank1_backward(p["tau"], p["emis"]))
    h = p["panel"].shape[0]
    np.testing.assert_allclose(alphas[0], np.full(h, 1.0 / h), rtol=1e-6)
    np.testing.assert_allclose(betas[-1], np.ones(h), rtol=0)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SMALL)
def test_posterior_columns_normalised(seed):
    p = make_problem(seed, 10, 20)
    post = np.asarray(
        ref.posterior(
            ref.rank1_forward(p["tau"], p["emis"]),
            ref.rank1_backward(p["tau"], p["emis"]),
        )
    )
    np.testing.assert_allclose(post.sum(axis=1), np.ones(post.shape[0]), rtol=1e-4)
    assert (post >= 0).all()


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SMALL)
def test_dosage_bounded(seed):
    p = make_problem(seed, 10, 20)
    dos = np.asarray(ref.impute(p["tau"], p["emis"], jnp.asarray(p["panel"])))
    assert (dos >= -1e-6).all() and (dos <= 1 + 1e-6).all()


def test_forward_backward_likelihood_consistency(small_problem):
    """sum_h alpha_m(h) beta_m(h) is the sequence likelihood — constant in m."""
    p = small_problem
    alphas = np.asarray(ref.rank1_forward(p["tau"], p["emis"]), dtype=np.float64)
    betas = np.asarray(ref.rank1_backward(p["tau"], p["emis"]), dtype=np.float64)
    lik = (alphas * betas).sum(axis=1)
    np.testing.assert_allclose(lik, lik[0] * np.ones_like(lik), rtol=1e-4)


def test_perfect_copy_recovers_reference_haplotype():
    """A target that copies reference haplotype 0 exactly, fully observed,
    should be imputed back to haplotype 0's alleles with high confidence."""
    rng = np.random.default_rng(3)
    n_hap, n_mark = 16, 40
    panel = (rng.random((n_hap, n_mark)) < 0.5).astype(np.int8)
    obs = panel[0].astype(np.int32)  # fully observed copy of hap 0
    d = np.full(n_mark, 1e-7)
    d[0] = 0
    tau = ref.tau_from_distance(jnp.asarray(d), n_hap)
    emis = ref.emission_probs(jnp.asarray(panel), jnp.asarray(obs))
    dos = np.asarray(ref.impute(tau, emis, jnp.asarray(panel)))
    hard = (dos > 0.5).astype(np.int8)
    np.testing.assert_array_equal(hard, panel[0])


def test_no_observations_gives_allele_frequency_posterior():
    """With zero annotated markers every state stays equally likely, so the
    dosage must equal the panel's per-column allele frequency."""
    rng = np.random.default_rng(4)
    n_hap, n_mark = 12, 20
    panel = (rng.random((n_hap, n_mark)) < 0.4).astype(np.int8)
    obs = np.full(n_mark, -1, dtype=np.int32)
    d = np.full(n_mark, 1e-7)
    d[0] = 0
    tau = ref.tau_from_distance(jnp.asarray(d), n_hap)
    emis = ref.emission_probs(jnp.asarray(panel), jnp.asarray(obs))
    dos = np.asarray(ref.impute(tau, emis, jnp.asarray(panel)))
    np.testing.assert_allclose(dos, panel.mean(axis=0), rtol=1e-4, atol=1e-6)


def test_tau_formula():
    """Eq (1) spot-check."""
    t = float(ref.tau_from_distance(jnp.float64(1e-6), 100, ne=50_000.0))
    assert t == pytest.approx(1.0 - np.exp(-4 * 50_000 * 1e-6 / 100), rel=1e-9)


def test_emission_matrix_values(small_problem):
    p = small_problem
    emis = np.asarray(p["emis"])
    obs = p["obs"]
    panel = p["panel"]
    for m in range(len(obs)):
        for h in range(panel.shape[0]):
            if obs[m] < 0:
                assert emis[m, h] == 1.0
            elif panel[h, m] == obs[m]:
                assert emis[m, h] == pytest.approx(1 - ref.DEFAULT_ERR)
            else:
                assert emis[m, h] == pytest.approx(ref.DEFAULT_ERR)
