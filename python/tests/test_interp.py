"""Linear-interpolation kernel vs reference (paper §5.3 / Fig 10)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.interp import interp_dosage
from .conftest import make_problem

SWEEP = dict(max_examples=20, deadline=None)


def make_anchors(seed: int, k: int, n_hap: int, m: int):
    rng = np.random.default_rng(seed)
    post = rng.random((k, n_hap)).astype(np.float32)
    post /= post.sum(axis=1, keepdims=True)
    left = rng.integers(0, k - 1, m).astype(np.int32)
    frac = rng.random(m).astype(np.float32)
    alleles = (rng.random((m, n_hap)) < 0.4).astype(np.float32)
    return post, left, frac, alleles


@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 12),
       n_hap=st.integers(2, 16), m=st.integers(1, 40))
@settings(**SWEEP)
def test_interp_kernel_matches_ref(seed, k, n_hap, m):
    post, left, frac, alleles = make_anchors(seed, k, n_hap, m)
    want_post = np.asarray(
        ref.interp_posteriors(jnp.asarray(post), jnp.asarray(left), jnp.asarray(frac))
    )
    want = (want_post * alleles).sum(axis=1) / want_post.sum(axis=1)
    got = np.asarray(
        interp_dosage(jnp.asarray(post), jnp.asarray(left), jnp.asarray(frac),
                      jnp.asarray(alleles))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_interp_endpoints_exact():
    """frac=0 reproduces the left anchor, frac=1 the right anchor."""
    post, _, _, alleles = make_anchors(1, 4, 8, 2)
    left = np.array([1, 1], dtype=np.int32)
    frac = np.array([0.0, 1.0], dtype=np.float32)
    got = np.asarray(
        interp_dosage(jnp.asarray(post), jnp.asarray(left), jnp.asarray(frac),
                      jnp.asarray(alleles))
    )
    want0 = (post[1] * alleles[0]).sum() / post[1].sum()
    want1 = (post[2] * alleles[1]).sum() / post[2].sum()
    np.testing.assert_allclose(got, [want0, want1], rtol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SWEEP)
def test_interp_dosage_bounded(seed):
    post, left, frac, alleles = make_anchors(seed, 6, 10, 30)
    got = np.asarray(
        interp_dosage(jnp.asarray(post), jnp.asarray(left), jnp.asarray(frac),
                      jnp.asarray(alleles))
    )
    assert (got >= -1e-6).all() and (got <= 1 + 1e-6).all()


def test_interp_normalised_anchors_stay_normalised():
    """A blend of two normalised columns is normalised: sum(lerp) == 1, so the
    kernel's defensive normalisation must be a no-op."""
    post, left, frac, _ = make_anchors(2, 5, 8, 20)
    blend = np.asarray(
        ref.interp_posteriors(jnp.asarray(post), jnp.asarray(left), jnp.asarray(frac))
    )
    np.testing.assert_allclose(blend.sum(axis=1), np.ones(20), rtol=1e-5)


def test_interp_rejects_single_anchor():
    post = np.ones((1, 4), dtype=np.float32)
    with pytest.raises(ValueError):
        interp_dosage(jnp.asarray(post), jnp.zeros(4, jnp.int32),
                      jnp.zeros(4, jnp.float32), jnp.ones((4, 4), jnp.float32))


def test_interp_against_full_hmm_is_close_on_smooth_problem():
    """On a problem whose posteriors vary smoothly (tiny genetic distances),
    interpolating from 1-in-4 anchors must track the full HMM dosage closely —
    the paper's 'negligible impact on accuracy' claim, in miniature."""
    p = make_problem(seed=9, n_hap=16, n_mark=33, annot_ratio=0.0)
    # Annotate only the anchor columns so the emission term is 1 elsewhere.
    anchors = np.arange(0, 33, 4)
    obs = np.full(33, -1, dtype=np.int32)
    rng = np.random.default_rng(0)
    obs[anchors] = (rng.random(len(anchors)) < 0.5).astype(np.int32)
    emis = ref.emission_probs(jnp.asarray(p["panel"]), jnp.asarray(obs))
    full = np.asarray(ref.impute(p["tau"], emis, jnp.asarray(p["panel"])))

    # Anchor subproblem: accumulated tau between anchors.
    post = ref.posterior(ref.rank1_forward(p["tau"], emis),
                         ref.rank1_backward(p["tau"], emis))
    post_k = jnp.asarray(np.asarray(post)[anchors])
    left = np.minimum(np.arange(33) // 4, len(anchors) - 2).astype(np.int32)
    frac = ((np.arange(33) % 4) / 4.0).astype(np.float32)
    frac[anchors[-1]:] = (np.arange(33)[anchors[-1]:] - anchors[-2]) / 4.0
    got = np.asarray(
        interp_dosage(post_k, jnp.asarray(left), jnp.asarray(frac),
                      p["alleles_mh"])
    )
    # Anchor columns themselves must be (nearly) exact.
    np.testing.assert_allclose(got[anchors[:-1]], full[anchors[:-1]], atol=5e-3)
    # Intermediate columns track the full model.
    assert np.abs(got - full).mean() < 0.05
