"""Pallas kernels vs the pure-jnp oracles (the core L1 correctness signal).

Hypothesis sweeps shapes, block sizes and dtypes; every property asserts
allclose against ref.py.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.common import pick_block_m, vmem_bytes_estimate
from compile.kernels.ls_bwd import ls_backward
from compile.kernels.ls_fwd import ls_forward
from compile.kernels.posterior import posterior_dosage
from .conftest import make_problem

SWEEP = dict(max_examples=20, deadline=None)


@given(
    seed=st.integers(0, 2**31 - 1),
    n_hap=st.integers(2, 32),
    n_mark=st.integers(2, 64),
)
@settings(**SWEEP)
def test_forward_kernel_matches_ref(seed, n_hap, n_mark):
    p = make_problem(seed, n_hap, n_mark)
    want = np.asarray(ref.rank1_forward(p["tau"], p["emis"]))
    got = np.asarray(ls_forward(p["tau"], p["emis"]))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


@given(
    seed=st.integers(0, 2**31 - 1),
    n_hap=st.integers(2, 32),
    n_mark=st.integers(2, 64),
)
@settings(**SWEEP)
def test_backward_kernel_matches_ref(seed, n_hap, n_mark):
    p = make_problem(seed, n_hap, n_mark)
    want = np.asarray(ref.rank1_backward(p["tau"], p["emis"]))
    got = np.asarray(ls_backward(p["tau"], p["emis"]))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("block_m", [1, 2, 4, 8, 24])
def test_forward_block_size_invariance(block_m):
    """Result must not depend on the VMEM tiling choice."""
    p = make_problem(11, 16, 24)
    want = np.asarray(ls_forward(p["tau"], p["emis"], block_m=24))
    got = np.asarray(ls_forward(p["tau"], p["emis"], block_m=block_m))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("block_m", [1, 2, 4, 8, 24])
def test_backward_block_size_invariance(block_m):
    p = make_problem(12, 16, 24)
    want = np.asarray(ls_backward(p["tau"], p["emis"], block_m=24))
    got = np.asarray(ls_backward(p["tau"], p["emis"], block_m=block_m))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(seed=st.integers(0, 2**31 - 1), n_hap=st.integers(2, 24), n_mark=st.integers(2, 48))
@settings(**SWEEP)
def test_posterior_kernel_matches_ref(seed, n_hap, n_mark):
    p = make_problem(seed, n_hap, n_mark)
    alphas = ref.rank1_forward(p["tau"], p["emis"])
    betas = ref.rank1_backward(p["tau"], p["emis"])
    want = np.asarray(ref.dosage(ref.posterior(alphas, betas), jnp.asarray(p["panel"])))
    got = np.asarray(posterior_dosage(alphas, betas, p["alleles_mh"]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_posterior_dosage_bounded(small_problem):
    p = small_problem
    alphas = ref.rank1_forward(p["tau"], p["emis"])
    betas = ref.rank1_backward(p["tau"], p["emis"])
    dos = np.asarray(posterior_dosage(alphas, betas, p["alleles_mh"]))
    assert (dos >= -1e-6).all() and (dos <= 1 + 1e-6).all()


def test_kernels_reject_bad_block():
    p = make_problem(1, 4, 10)
    with pytest.raises(ValueError):
        ls_forward(p["tau"], p["emis"], block_m=3)
    with pytest.raises(ValueError):
        ls_backward(p["tau"], p["emis"], block_m=4)


@given(m=st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_pick_block_m_divides(m):
    bm = pick_block_m(m)
    assert m % bm == 0 and 1 <= bm <= 128


def test_pick_block_m_rejects_nonpositive():
    with pytest.raises(ValueError):
        pick_block_m(0)


def test_vmem_estimate_within_budget():
    """The default tiling must stay within a 16 MiB VMEM budget at the largest
    canonical shape (H=1024) — the claim documented in DESIGN.md §Perf."""
    assert vmem_bytes_estimate(128, 1024) < 16 * 2**20 // 2


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_forward_kernel_dtypes(dtype):
    p = make_problem(5, 8, 16)
    tau = p["tau"].astype(dtype)
    emis = p["emis"].astype(dtype)
    got = ls_forward(tau, emis)
    assert got.dtype == dtype
    want = np.asarray(ref.rank1_forward(tau, emis))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)
