"""Shared fixtures/strategies for the kernel test-suite."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# The f64 oracle comparisons (and the dtype-sweep tests) need real float64.
jax.config.update("jax_enable_x64", True)

from compile.kernels import ref


def make_problem(seed: int, n_hap: int, n_mark: int, annot_ratio: float = 0.3,
                 maf: float = 0.25, dtype=np.float32):
    """Random Li & Stephens problem instance mirroring workload/panelgen.rs."""
    rng = np.random.default_rng(seed)
    panel = (rng.random((n_hap, n_mark)) < maf).astype(np.int8)
    obs = np.where(
        rng.random(n_mark) < annot_ratio,
        (rng.random(n_mark) < 0.5).astype(np.int32),
        np.int32(-1),
    )
    d = rng.uniform(1e-8, 2e-6, n_mark).astype(np.float64)
    d[0] = 0.0
    tau = np.asarray(ref.tau_from_distance(jnp.asarray(d), n_hap), dtype=dtype)
    emis = np.asarray(
        ref.emission_probs(jnp.asarray(panel), jnp.asarray(obs)), dtype=dtype
    )
    return {
        "panel": panel,
        "obs": obs,
        "tau": jnp.asarray(tau),
        "emis": jnp.asarray(emis),
        "alleles_mh": jnp.asarray(panel.T.astype(dtype)),
    }


@pytest.fixture
def small_problem():
    return make_problem(seed=7, n_hap=12, n_mark=24)
