"""L2 — the imputation compute graph in JAX, calling the Pallas kernels.

This module is the build-time definition of everything the Rust coordinator
executes through PJRT:

* :func:`impute_raw`        — raw Li & Stephens pipeline for one target.
* :func:`impute_batch`      — the same, vmapped over a batch of targets
                              (batching is how the AOT artifact amortises
                              dispatch on the Rust hot path).
* :func:`forward` / :func:`backward` — the individual sweeps, exported so the
                              coordinator can drive column-block execution.
* :func:`impute_interp`     — HMM at annotated anchors + linear interpolation
                              everywhere else (paper §5.3).

Everything here is jit-able with static shapes; `aot.py` lowers a fixed menu
of shapes to HLO text for the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.interp import interp_dosage
from .kernels.ls_bwd import ls_backward
from .kernels.ls_fwd import ls_forward
from .kernels.posterior import posterior_dosage

DEFAULT_ERR = ref.DEFAULT_ERR
DEFAULT_NE = ref.DEFAULT_NE


def emissions(alleles_mh: jnp.ndarray, obs: jnp.ndarray, err: float = DEFAULT_ERR) -> jnp.ndarray:
    """Emission matrix [M, H] from column-major alleles [M, H] and obs [M]."""
    obs_f = obs.astype(alleles_mh.dtype)[:, None]
    match = jnp.where(alleles_mh == obs_f, 1.0 - err, err)
    return jnp.where(obs[:, None] < 0, jnp.ones_like(match), match)


def forward(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """Forward sweep [M, H] (Pallas)."""
    return ls_forward(tau, emis)


def backward(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """Backward sweep [M, H] (Pallas)."""
    return ls_backward(tau, emis)


def impute_raw(tau: jnp.ndarray, emis: jnp.ndarray, alleles_mh: jnp.ndarray) -> jnp.ndarray:
    """Raw-model dosage [M] for one target haplotype."""
    alphas = ls_forward(tau, emis)
    betas = ls_backward(tau, emis)
    return posterior_dosage(alphas, betas, alleles_mh)


def impute_obs(tau: jnp.ndarray, obs: jnp.ndarray, alleles_mh: jnp.ndarray,
               err: float = DEFAULT_ERR) -> jnp.ndarray:
    """Raw-model dosage [M] straight from observations (fused emission)."""
    return impute_raw(tau, emissions(alleles_mh, obs, err), alleles_mh)


def impute_batch(tau: jnp.ndarray, obs_batch: jnp.ndarray, alleles_mh: jnp.ndarray,
                 err: float = DEFAULT_ERR) -> jnp.ndarray:
    """Dosage [B, M] for a batch of target haplotypes ``obs_batch [B, M]``."""
    return jax.vmap(lambda o: impute_obs(tau, o, alleles_mh, err))(obs_batch)


def posterior_states(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """Column-normalised posteriors [M, H] (used as interpolation anchors)."""
    alphas = ls_forward(tau, emis)
    betas = ls_backward(tau, emis)
    p = alphas * betas
    return p / jnp.sum(p, axis=1, keepdims=True)


def impute_interp(
    tau_k: jnp.ndarray,
    emis_k: jnp.ndarray,
    left: jnp.ndarray,
    frac: jnp.ndarray,
    alleles_all: jnp.ndarray,
) -> jnp.ndarray:
    """Interpolated dosage [M] over the full marker grid.

    ``tau_k``/``emis_k`` [K]/[K, H] — the annotated-anchor subproblem, with
    ``tau_k`` already built from *accumulated* genetic distance between
    adjacent anchors (paper Fig 10); ``left``/``frac`` [M] — anchor index and
    blend fraction per output marker; ``alleles_all`` [M, H].
    """
    post_k = posterior_states(tau_k, emis_k)
    return interp_dosage(post_k, left, frac, alleles_all)
