"""Pallas backward-sweep kernel for the Li & Stephens HMM (paper eq. (5)).

Same blocking strategy as :mod:`ls_fwd` but the grid walks marker blocks from
right to left (via a reversing ``index_map``) and the columns inside each block
are scanned in reverse.  The recurrence consumes the tau/emission of the *next*
column, so the caller passes the sequences pre-shifted by one
(``tau_s[m] = tau[m+1]``, ``emis_s[m] = emis[m+1]``; the last entries are
padding and never read), keeping every Ref access block-local.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import pick_block_m


def _bwd_kernel(tau_s_ref, emis_s_ref, out_ref, carry_ref, *, block_m: int, n_hap: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # Paper Algorithm 1 line 2: beta <- 1 at the final column.
        carry_ref[...] = jnp.ones((n_hap,), dtype=out_ref.dtype)

    def column(k, beta):
        j = block_m - 1 - k  # scan columns right-to-left inside the block
        is_last = (i == 0) & (k == 0)
        t = tau_s_ref[j]
        e = emis_s_ref[j, :]
        g = e * beta
        s = jnp.sum(g)
        stepped = (1.0 - t) * g + t * s / n_hap
        prev = jnp.where(is_last, beta, stepped)
        pl.store(out_ref, (j, slice(None)), prev)
        return prev

    carry_ref[...] = lax.fori_loop(0, block_m, column, carry_ref[...])


def ls_backward(tau: jnp.ndarray, emis: jnp.ndarray, block_m: int | None = None) -> jnp.ndarray:
    """All backward variables ``[M, H]`` from ``tau [M]`` and ``emis [M, H]``."""
    m_total, n_hap = emis.shape
    bm = block_m or pick_block_m(m_total)
    if m_total % bm != 0:
        raise ValueError(f"block_m={bm} must divide M={m_total}")
    nblk = m_total // bm
    # Shift so the kernel reads next-column tau/emis at the current index.
    tau_s = jnp.concatenate([tau[1:], jnp.zeros((1,), tau.dtype)])
    emis_s = jnp.concatenate([emis[1:], jnp.ones((1, n_hap), emis.dtype)], axis=0)
    kernel = functools.partial(_bwd_kernel, block_m=bm, n_hap=n_hap)
    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (nblk - 1 - i,)),
            pl.BlockSpec((bm, n_hap), lambda i: (nblk - 1 - i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n_hap), lambda i: (nblk - 1 - i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_total, n_hap), emis.dtype),
        scratch_shapes=[pltpu.VMEM((n_hap,), emis.dtype)],
        interpret=True,
    )(tau_s, emis_s)
