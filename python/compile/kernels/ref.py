"""Pure-jnp oracles for the Li & Stephens imputation HMM.

Two independent references are provided and cross-checked against each other in
the test-suite:

* ``dense_*`` — the textbook O(H^2 M) formulation with explicit transition
  matrices, literally transcribing equations (1)-(7) of the paper.
* ``rank1_*`` — the O(H M) formulation exploiting the structure of the
  Li & Stephens transition matrix ``a_ij = tau/H + (1-tau) * delta_ij``
  (a rank-1 update of a scaled identity).  This is the recurrence the Pallas
  kernels implement and the event-driven Rust vertices accumulate.

Conventions (identical across Python and Rust):

* ``panel``   int8/float [H, M]  — reference panel alleles (diallelic: 0/1).
* ``obs``     int       [M]     — target haplotype observation per marker:
                                   -1 = unannotated, 0/1 = observed allele.
* ``tau``     float     [M]     — recombination factor per column transition;
                                   ``tau[0]`` is unused (there is no transition
                                   into the first column) and kept for shape
                                   regularity.  ``tau[m]`` governs the
                                   transition from column ``m-1`` to ``m``.
* ``emis``    float     [M, H]  — emission ``b_h(O_m)``: 1 where ``obs`` is -1,
                                   ``1-err`` on allele match, ``err`` on
                                   mismatch (paper eq. (6)/(7), err = 1e-4).
* alpha/beta initialisation follows the paper's Algorithm 1 exactly:
  ``alpha[0, :] = 1/H`` (no emission applied at the first column) and
  ``beta[M-1, :] = 1``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

DEFAULT_ERR = 1e-4
DEFAULT_NE = 50_000.0


def tau_from_distance(d: jnp.ndarray, n_hap: int, ne: float = DEFAULT_NE) -> jnp.ndarray:
    """Paper eq. (1): ``tau_m = 1 - exp(-4 Ne d_m / |H|)``."""
    return 1.0 - jnp.exp(-4.0 * ne * d / float(n_hap))


def emission_probs(panel: jnp.ndarray, obs: jnp.ndarray, err: float = DEFAULT_ERR) -> jnp.ndarray:
    """Emission matrix [M, H] from panel [H, M] and observations [M].

    Paper eq. (6)/(7): ``1 - err`` on match, ``err`` on mismatch, and the term
    "falls out" (probability 1) when the marker is unannotated (obs == -1).
    """
    panel_mt = panel.T.astype(jnp.float32)  # [M, H]
    obs_f = obs.astype(jnp.float32)[:, None]  # [M, 1]
    match = jnp.where(panel_mt == obs_f, 1.0 - err, err)
    return jnp.where(obs[:, None] < 0, 1.0, match)


# ---------------------------------------------------------------------------
# Dense O(H^2 M) oracle
# ---------------------------------------------------------------------------

def dense_transition(tau_m: jnp.ndarray, n_hap: int, dtype=jnp.float32) -> jnp.ndarray:
    """Explicit [H, H] transition matrix for one column step.

    ``a_ij = tau/H + (1 - tau) * delta_ij`` — paper eqs. (2)/(3): the diagonal
    holds ``(1 - tau) + tau/H`` (stay), off-diagonals ``tau/H`` (jump).
    """
    eye = jnp.eye(n_hap, dtype=dtype)
    return (tau_m / n_hap).astype(dtype) + (1.0 - tau_m).astype(dtype) * eye


def dense_forward(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """All forward variables, [M, H]; paper eq. (4)."""
    m_total, n_hap = emis.shape
    alpha0 = jnp.full((n_hap,), 1.0 / n_hap, dtype=emis.dtype)

    def step(alpha, inputs):
        tau_m, emis_m = inputs
        a = dense_transition(tau_m, n_hap, emis.dtype)
        nxt = (alpha @ a) * emis_m
        return nxt, nxt

    _, rest = lax.scan(step, alpha0, (tau[1:], emis[1:]))
    return jnp.concatenate([alpha0[None, :], rest], axis=0)


def dense_backward(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """All backward variables, [M, H]; paper eq. (5)."""
    m_total, n_hap = emis.shape
    beta_last = jnp.ones((n_hap,), dtype=emis.dtype)

    def step(beta, inputs):
        tau_m, emis_m = inputs  # tau/emis of the *next* column (m+1)
        a = dense_transition(tau_m, n_hap, emis.dtype)
        prev = a @ (emis_m * beta)
        return prev, prev

    _, rest = lax.scan(step, beta_last, (tau[1:][::-1], emis[1:][::-1]))
    return jnp.concatenate([rest[::-1], beta_last[None, :]], axis=0)


# ---------------------------------------------------------------------------
# Rank-1 O(H M) oracle (the recurrence the kernels and Rust vertices use)
# ---------------------------------------------------------------------------

def rank1_forward(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """Forward via ``alpha' = ((1-tau) alpha + tau * mean-sum) * emis``.

    ``sum_i alpha_m(i) a_ij = (1-tau) alpha_m(j) + (tau/H) sum_i alpha_m(i)``.
    """
    m_total, n_hap = emis.shape
    alpha0 = jnp.full((n_hap,), 1.0 / n_hap, dtype=emis.dtype)

    def step(alpha, inputs):
        tau_m, emis_m = inputs
        s = jnp.sum(alpha)
        nxt = ((1.0 - tau_m) * alpha + tau_m * s / n_hap) * emis_m
        return nxt, nxt

    _, rest = lax.scan(step, alpha0, (tau[1:], emis[1:]))
    return jnp.concatenate([alpha0[None, :], rest], axis=0)


def rank1_backward(tau: jnp.ndarray, emis: jnp.ndarray) -> jnp.ndarray:
    """Backward via ``beta = (1-tau) g + tau * mean(g)`` with ``g = emis*beta'``."""
    m_total, n_hap = emis.shape
    beta_last = jnp.ones((n_hap,), dtype=emis.dtype)

    def step(beta, inputs):
        tau_m, emis_m = inputs
        g = emis_m * beta
        s = jnp.sum(g)
        prev = (1.0 - tau_m) * g + tau_m * s / n_hap
        return prev, prev

    _, rest = lax.scan(step, beta_last, (tau[1:][::-1], emis[1:][::-1]))
    return jnp.concatenate([rest[::-1], beta_last[None, :]], axis=0)


# ---------------------------------------------------------------------------
# Posterior / dosage / interpolation
# ---------------------------------------------------------------------------

def posterior(alphas: jnp.ndarray, betas: jnp.ndarray) -> jnp.ndarray:
    """Column-normalised posterior state probabilities [M, H]."""
    p = alphas * betas
    return p / jnp.sum(p, axis=1, keepdims=True)


def dosage(post: jnp.ndarray, panel: jnp.ndarray) -> jnp.ndarray:
    """Allele-1 dosage per marker: posterior mass summed by allele label.

    This is the paper's "summed based on their base labels" step; for diallelic
    data the major/minor decision is ``dosage > 0.5``.
    """
    return jnp.sum(post * panel.T.astype(post.dtype), axis=1)


def impute(tau: jnp.ndarray, emis: jnp.ndarray, panel: jnp.ndarray) -> jnp.ndarray:
    """Full raw-model pipeline → dosage [M] (rank-1 reference path)."""
    alphas = rank1_forward(tau, emis)
    betas = rank1_backward(tau, emis)
    return dosage(posterior(alphas, betas), panel)


def interp_posteriors(post_k: jnp.ndarray, left: jnp.ndarray, frac: jnp.ndarray) -> jnp.ndarray:
    """Linear interpolation of per-state posteriors between annotated columns.

    ``post_k`` [K, H] — posteriors at the K annotated (HMM-evaluated) columns;
    ``left``   [M]    — for each output marker, index of the annotated column
                        at-or-left of it (clamped to K-2 so ``left+1`` is valid);
    ``frac``   [M]    — fractional genetic distance covered, 0 at the left
                        anchor, 1 at the right anchor (paper Fig 10).
    """
    lo = post_k[left]          # [M, H]
    hi = post_k[left + 1]      # [M, H]
    return lo + frac[:, None] * (hi - lo)
