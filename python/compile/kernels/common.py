"""Shared helpers for the Pallas kernels.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is both the correctness path and the
form that lowers into plain HLO for the Rust runtime.  Block shapes are still
chosen as if targeting a real TPU (VMEM budgeting is documented per kernel and
estimated in DESIGN.md §Perf) so the structure is hardware-honest.
"""

from __future__ import annotations

# VMEM on current TPUs is ~16 MiB/core; we budget half of it for the streaming
# operand (emis block) and pick the marker-block size accordingly at H=1024/f32.
DEFAULT_BLOCK_M = 128


def pick_block_m(m_total: int, preferred: int = DEFAULT_BLOCK_M) -> int:
    """Largest divisor of ``m_total`` that is ≤ ``preferred``.

    Pallas BlockSpecs require the grid to tile the array exactly; rather than
    pad (which would corrupt a carried scan) we shrink the block.  Worst case
    (prime M) degenerates to 1-column blocks — correct, just more grid steps.
    """
    if m_total <= 0:
        raise ValueError(f"m_total must be positive, got {m_total}")
    for cand in range(min(preferred, m_total), 0, -1):
        if m_total % cand == 0:
            return cand
    return 1


def vmem_bytes_estimate(block_m: int, n_hap: int, dtype_bytes: int = 4, n_hbuf: int = 3) -> int:
    """Rough per-grid-step VMEM footprint of a forward/backward block.

    ``n_hbuf`` [M_blk, H] buffers (emis in, alphas out, plus double-buffering)
    plus the [H] carry and [M_blk] tau vector.
    """
    return n_hbuf * block_m * n_hap * dtype_bytes + n_hap * dtype_bytes + block_m * dtype_bytes
