"""Pallas posterior/dosage kernel.

Fuses the three tail stages of the pipeline — ``p = alpha*beta``, per-column
normalisation, and the allele-label accumulation (the paper's "summed based on
their base labels", the job of the bottom-row vertices in the event-driven
graph) — into one pass over ``[block_m, H]`` tiles so the posterior matrix is
never materialised in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import pick_block_m


def _post_kernel(alpha_ref, beta_ref, allele_ref, dosage_ref, *, eps: float):
    p = alpha_ref[...] * beta_ref[...]
    tot = jnp.sum(p, axis=1)
    hit = jnp.sum(p * allele_ref[...], axis=1)
    dosage_ref[...] = hit / jnp.maximum(tot, eps)


def posterior_dosage(
    alphas: jnp.ndarray,
    betas: jnp.ndarray,
    alleles: jnp.ndarray,
    block_m: int | None = None,
    eps: float = 1e-38,
) -> jnp.ndarray:
    """Allele-1 dosage ``[M]`` from ``alphas/betas/alleles`` all ``[M, H]``."""
    m_total, n_hap = alphas.shape
    bm = block_m or pick_block_m(m_total)
    if m_total % bm != 0:
        raise ValueError(f"block_m={bm} must divide M={m_total}")
    spec_mh = pl.BlockSpec((bm, n_hap), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_post_kernel, eps=eps),
        grid=(m_total // bm,),
        in_specs=[spec_mh, spec_mh, spec_mh],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m_total,), alphas.dtype),
        interpret=True,
    )(alphas, betas, alleles.astype(alphas.dtype))
