"""Pallas linear-interpolation kernel (paper §5.3, Fig 10).

The HMM is evaluated only at the K annotated columns; every intermediate
column's per-state posterior is a linear blend of its two anchors, apportioned
by fractional genetic distance, and immediately reduced to an allele dosage
with that column's own panel alleles.

The anchor matrix ``post_k [K, H]`` is small (K = M/upscale) and kept fully
resident per grid step; output columns are produced in ``[block_m]`` tiles with
dynamic anchor gathers (`pl.load` with a computed row index).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import pick_block_m


def _interp_kernel(postk_ref, left_ref, frac_ref, allele_ref, dosage_ref, *, block_m: int, eps: float):
    def column(j, _):
        li = left_ref[j]
        lo = pl.load(postk_ref, (li, slice(None)))
        hi = pl.load(postk_ref, (li + 1, slice(None)))
        p = lo + frac_ref[j] * (hi - lo)
        tot = jnp.sum(p)
        hit = jnp.sum(p * allele_ref[j, :])
        pl.store(dosage_ref, (j,), hit / jnp.maximum(tot, eps))
        return 0

    lax.fori_loop(0, block_m, column, 0)


def interp_dosage(
    post_k: jnp.ndarray,
    left: jnp.ndarray,
    frac: jnp.ndarray,
    alleles: jnp.ndarray,
    block_m: int | None = None,
    eps: float = 1e-38,
) -> jnp.ndarray:
    """Dosage ``[M]`` interpolated from anchor posteriors ``post_k [K, H]``.

    ``left [M]`` int32 anchor indices (≤ K-2), ``frac [M]`` blend fractions,
    ``alleles [M, H]`` panel alleles at every output column.
    """
    k_total, n_hap = post_k.shape
    m_total = left.shape[0]
    if k_total < 2:
        raise ValueError("need at least two anchor columns to interpolate")
    bm = block_m or pick_block_m(m_total)
    if m_total % bm != 0:
        raise ValueError(f"block_m={bm} must divide M={m_total}")
    kernel = functools.partial(_interp_kernel, block_m=bm, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(m_total // bm,),
        in_specs=[
            pl.BlockSpec((k_total, n_hap), lambda i: (0, 0)),  # anchors resident
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, n_hap), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m_total,), post_k.dtype),
        interpret=True,
    )(post_k, left, frac, alleles.astype(post_k.dtype))
