"""Pallas forward-sweep kernel for the Li & Stephens HMM (paper eq. (4)).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the Li & Stephens
transition matrix is ``tau/H + (1-tau)·I`` — rank-1 plus diagonal — so the
O(H²) per-column matmul collapses to an O(H) FMA plus one reduction.  Nothing
is left for the MXU; the kernel is VPU-bound.  The HBM↔VMEM schedule the paper
expressed with events is expressed here with a BlockSpec grid over marker
blocks: each grid step streams one ``[block_m, H]`` tile of emissions into
VMEM, scans its columns sequentially carrying the live alpha vector in a VMEM
scratch buffer, and writes one ``[block_m, H]`` tile of alphas back out.

The carried scratch persists across grid steps (the grid dimension is
sequential), which is what makes a *scan* expressible as a grid at all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import pick_block_m


def _fwd_kernel(tau_ref, emis_ref, out_ref, carry_ref, *, block_m: int, n_hap: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # Paper Algorithm 1 line 2: alpha <- 1/|H| at the first column.
        carry_ref[...] = jnp.full((n_hap,), 1.0 / n_hap, dtype=out_ref.dtype)

    def column(j, alpha):
        is_first = (i == 0) & (j == 0)
        t = tau_ref[j]
        e = emis_ref[j, :]
        s = jnp.sum(alpha)
        stepped = ((1.0 - t) * alpha + t * s / n_hap) * e
        nxt = jnp.where(is_first, alpha, stepped)
        pl.store(out_ref, (j, slice(None)), nxt)
        return nxt

    carry_ref[...] = lax.fori_loop(0, block_m, column, carry_ref[...])


def ls_forward(tau: jnp.ndarray, emis: jnp.ndarray, block_m: int | None = None) -> jnp.ndarray:
    """All forward variables ``[M, H]`` from ``tau [M]`` and ``emis [M, H]``."""
    m_total, n_hap = emis.shape
    bm = block_m or pick_block_m(m_total)
    if m_total % bm != 0:
        raise ValueError(f"block_m={bm} must divide M={m_total}")
    grid = (m_total // bm,)
    kernel = functools.partial(_fwd_kernel, block_m=bm, n_hap=n_hap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, n_hap), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n_hap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_total, n_hap), emis.dtype),
        scratch_shapes=[pltpu.VMEM((n_hap,), emis.dtype)],
        interpret=True,
    )(tau, emis)
