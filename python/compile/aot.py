"""AOT pipeline: lower the L2 graph to HLO *text* artifacts for the Rust runtime.

HLO text — NOT ``lowered.compile().serialize()`` or serialized HloModuleProto —
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py.

Artifacts are emitted for a fixed menu of canonical shapes; the Rust runtime
pads a request up to the nearest canonical shape (padding rows of the panel
with copies of row 0 and extra markers with tau=0/emis=1 is mathematically
inert — verified in rust/tests/runtime_artifacts.rs).

A TSV manifest (``manifest.tsv``) describes each artifact's entry signature so
the Rust side needs no JSON machinery:

    name<TAB>file<TAB>in:NAME:DTYPE:d0xd1<TAB>...<TAB>out:NAME:DTYPE:d0xd1
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (H, M) menu for the single-target raw pipeline and the sweeps.
# H must match workloads exactly (1/|H| is baked into the HLO); M pads up.
RAW_SHAPES = [(16, 32), (64, 128), (64, 512), (256, 512)]
# (B, H, M) menu for the batched pipeline (the Rust hot path).
BATCH_SHAPES = [(8, 64, 128), (16, 256, 512)]
# (K, H, M) menu for the interpolation pipeline (K anchors over M markers).
INTERP_SHAPES = [(12, 64, 120), (50, 256, 500)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(kind: str, name: str, spec: jax.ShapeDtypeStruct) -> str:
    dims = "x".join(str(d) for d in spec.shape)
    return f"{kind}:{name}:{spec.dtype}:{dims}"


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.rows: list[str] = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, args: dict[str, jax.ShapeDtypeStruct],
             outs: dict[str, jax.ShapeDtypeStruct]) -> None:
        lowered = jax.jit(fn).lower(*args.values())
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        cols = [name, fname]
        cols += [_sig("in", k, v) for k, v in args.items()]
        cols += [_sig("out", k, v) for k, v in outs.items()]
        self.rows.append("\t".join(cols))
        print(f"  {name}: {len(text)} chars")

    def finish(self) -> None:
        with open(os.path.join(self.out_dir, "manifest.tsv"), "w") as f:
            f.write("\n".join(self.rows) + "\n")
        print(f"wrote {len(self.rows)} artifacts + manifest.tsv to {self.out_dir}")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_all(out_dir: str) -> None:
    em = Emitter(out_dir)

    for h, m in RAW_SHAPES:
        em.emit(
            f"impute_raw_h{h}_m{m}",
            lambda tau, emis, alleles: (model.impute_raw(tau, emis, alleles),),
            {"tau": f32(m), "emis": f32(m, h), "alleles": f32(m, h)},
            {"dosage": f32(m)},
        )
        em.emit(
            f"fwd_h{h}_m{m}",
            lambda tau, emis: (model.forward(tau, emis),),
            {"tau": f32(m), "emis": f32(m, h)},
            {"alphas": f32(m, h)},
        )
        em.emit(
            f"bwd_h{h}_m{m}",
            lambda tau, emis: (model.backward(tau, emis),),
            {"tau": f32(m), "emis": f32(m, h)},
            {"betas": f32(m, h)},
        )

    for b, h, m in BATCH_SHAPES:
        em.emit(
            f"impute_batch_b{b}_h{h}_m{m}",
            lambda tau, obs, alleles: (model.impute_batch(tau, obs, alleles),),
            {"tau": f32(m), "obs": i32(b, m), "alleles": f32(m, h)},
            {"dosage": f32(b, m)},
        )

    for k, h, m in INTERP_SHAPES:
        em.emit(
            f"impute_interp_k{k}_h{h}_m{m}",
            lambda tau_k, emis_k, left, frac, alleles: (
                model.impute_interp(tau_k, emis_k, left, frac, alleles),
            ),
            {
                "tau_k": f32(k),
                "emis_k": f32(k, h),
                "left": i32(m),
                "frac": f32(m),
                "alleles": f32(m, h),
            },
            {"dosage": f32(m)},
        )

    em.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
