//! `cargo bench --bench fig13` — regenerates the paper's Fig 13 series
//! (linear-interpolation algorithm over expanding hardware) and prints the
//! E5 message-reduction accounting.
//!
//! For the full sweep use the CLI: `poets-impute bench fig13`.

use poets_impute::bench::{FigOpts, X86Cost, fig11, fig13};

fn main() {
    eprintln!("[fig13 bench] calibrating x86 throughput...");
    let x86 = X86Cost::measure_default();
    let opts = FigOpts {
        des_states_per_board: 24,
        des_targets: 8,
        full_targets: 10_000,
        skip_des: false,
        seed: 1303,
    };
    let report = fig13(&[1, 2, 4], &opts, &x86);
    println!("{}", report.render());

    // Shape assertions (E3): speedup grows with boards, and interpolation
    // beats the raw algorithm on matched hardware (message economics).
    let s: Vec<f64> = report.rows.iter().map(|r| r.full_speedup).collect();
    assert!(
        s.windows(2).all(|w| w[1] > w[0]),
        "Fig 13 shape violated: {s:?}"
    );

    let raw = fig11(&[2], &opts, &x86);
    let (raw_msgs, itp_msgs) = (
        raw.rows[0].messages.unwrap_or(0),
        report.rows[1].messages.unwrap_or(0),
    );
    println!(
        "fig13: E5 message accounting — raw {} sends vs interp {} sends \
         on comparable DES panels",
        raw_msgs, itp_msgs
    );
    println!("fig13: monotone speedup over boards OK {s:?}");
}
