//! `cargo bench --bench desim_hotpath` — micro-benchmark of the simulator's
//! host-side event throughput, the quantity that bounds how large a panel
//! the DES plane can sweep.  This is the L3 optimisation target of
//! EXPERIMENTS.md §Perf and the tracked gate of the wave-batching PR.
//!
//! Two sweeps, emitted into a machine-readable `BENCH_desim.json` so the
//! perf trajectory is tracked across PRs:
//!
//! * **host threads** (`SimConfig::threads`) per config — functional results
//!   are thread-count invariant (asserted here via `sim_cycles`), so this
//!   axis measures host parallel speedup only;
//! * **batch width** (the event plane's wave width) — width 1 is the
//!   per-target plane, width `LANES` packs a full SoA slab per event.
//!   Dosages are bit-identical across widths (asserted here), and the gate
//!   asserts that full-lane waves deliver **>= 2x fewer events per imputed
//!   target** than the per-target plane (they deliver ~LANES x fewer).
//!
//! A third tracked gate (the lane-group pipelining PR): at T=64 targets on
//! a 1000-marker panel, ONE 64-wide batch — eight lane groups pipelined one
//! superstep apart through the same graph — must finish in **<= 0.5x the
//! supersteps** of eight sequential `batch(LANES)` sweeps, with
//! bit-identical dosages.  Both supersteps/target and events/target are
//! recorded per row so the two cost axes (synchronisation and traffic) are
//! tracked independently.
//!
//! `--smoke` runs a reduced sweep for CI (the JSON is uploaded as a
//! workflow artifact per PR); the pipelining gate runs in both modes.
//! The document is stamped with schema / git commit / run-config
//! (`util::provenance`) so archived numbers stay attributable.
//!
//! `--trace` additionally runs ONE traced event-plane session after the
//! sweeps and writes `BENCH_desim_trace.jsonl` (`poets-impute/trace/v1`,
//! readable by `cli trace summarize|export`).  The benchmarked sweeps
//! themselves always run with tracing off — the observability plane is
//! opt-in per session, so the numbers above measure the untraced hot path.

use poets_impute::imputation::msg::LANES;
use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::util::json::Json;
use poets_impute::util::table::{Table, fmt_count, fmt_secs};
use poets_impute::util::timed;
use poets_impute::workload::panelgen::PanelConfig;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let trace = std::env::args().any(|a| a == "--trace");
    let thread_sweep: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let width_sweep: [usize; 2] = [1, LANES];
    let panels: &[(usize, usize, usize)] = if smoke {
        &[(16, 160, 8)]
    } else {
        &[(16, 160, 8), (32, 320, 8)]
    };

    let mut t = Table::new(&[
        "app",
        "panel",
        "targets",
        "width",
        "threads",
        "host time",
        "events",
        "events/target",
        "steps/target",
        "host events/s",
        "targets/s",
        "speedup",
        "sim time",
    ]);
    let mut json_rows = Json::Arr(Vec::new());

    for &(h, m, targets) in panels {
        let cfg = PanelConfig {
            n_hap: h,
            n_mark: m,
            annot_ratio: 0.1,
            seed: 7,
            ..PanelConfig::default()
        };
        let workload = Workload::synthetic(&cfg, targets);

        for (app_name, engine, spt) in [
            ("raw", EngineSpec::Event, 4usize),
            ("interp", EngineSpec::Interp, 1usize),
        ] {
            // Reference dosages + events/target of the per-target plane
            // (width 1, serial) — the batching gate compares against these.
            let mut reference: Option<(Vec<Vec<f32>>, f64)> = None;
            for &width in &width_sweep {
                let mut serial_time = 0.0f64;
                let mut serial_cycles = 0u64;
                for &threads in thread_sweep {
                    let session = ImputeSession::new(workload.clone())
                        .engine(engine)
                        .boards(4)
                        .states_per_thread(spt)
                        .batch(width)
                        .threads(threads);
                    let (out, host): (ImputeReport, f64) =
                        timed(|| session.run().expect("event planes are always available"));
                    let metrics = out.metrics.as_ref().expect("event planes report metrics");
                    if threads == thread_sweep[0] {
                        serial_time = host;
                        serial_cycles = metrics.sim_cycles;
                    } else {
                        assert_eq!(
                            metrics.sim_cycles, serial_cycles,
                            "thread count changed simulated timing"
                        );
                    }
                    let events = metrics.copies_delivered;
                    let events_per_target = events as f64 / targets as f64;
                    let steps_per_target = metrics.steps as f64 / targets as f64;
                    let eps = events as f64 / host;
                    match &reference {
                        None => reference = Some((out.dosages.clone(), events_per_target)),
                        Some((dosages, width1_ept)) => {
                            assert_eq!(
                                &out.dosages, dosages,
                                "{app_name}: width {width} / threads {threads} changed dosages"
                            );
                            // The tracked gate: full-lane waves must at least
                            // halve delivered events per imputed target.
                            if width >= LANES {
                                assert!(
                                    events_per_target * 2.0 <= *width1_ept,
                                    "{app_name}: width {width} events/target \
                                     {events_per_target:.1} vs per-target plane \
                                     {width1_ept:.1} — batching gate (>= 2x) FAILED"
                                );
                            }
                        }
                    }
                    t.row(vec![
                        app_name.into(),
                        format!("{h}x{m}"),
                        targets.to_string(),
                        width.to_string(),
                        threads.to_string(),
                        fmt_secs(host),
                        fmt_count(events),
                        format!("{events_per_target:.1}"),
                        format!("{steps_per_target:.1}"),
                        format!("{eps:.2e}"),
                        format!("{:.1}", targets as f64 / host),
                        format!("{:.2}x", serial_time / host),
                        fmt_secs(out.sim_seconds.expect("event planes report sim time")),
                    ]);
                    let mut row = Json::obj();
                    row.set("app", app_name)
                        .set("panel", format!("{h}x{m}"))
                        .set("n_hap", h)
                        .set("n_mark", m)
                        .set("targets", targets)
                        .set("batch_width", width)
                        .set("threads", threads)
                        .set("host_seconds", host)
                        .set("events", events)
                        .set("lanes", metrics.lanes_delivered)
                        .set("steps", metrics.steps)
                        .set("steps_per_target", steps_per_target)
                        .set("max_groups_in_flight", metrics.max_groups_in_flight)
                        .set("events_per_target", events_per_target)
                        .set("events_per_s", eps)
                        .set("targets_per_s", targets as f64 / host)
                        .set("speedup_vs_serial", serial_time / host)
                        .set("sim_seconds", out.sim_seconds.unwrap_or(0.0));
                    json_rows.push(row);
                }
            }
        }
    }

    println!("## DES hot path (host-side throughput, thread x wave-width sweep)\n{}", t.render());

    let gate = pipeline_gate();

    let mut run_config = Json::obj();
    run_config
        .set("smoke", smoke)
        .set("lanes", LANES)
        .set(
            "thread_sweep",
            Json::Arr(thread_sweep.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set(
            "width_sweep",
            Json::Arr(width_sweep.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set(
            "panels",
            Json::Arr(
                panels
                    .iter()
                    .map(|&(h, m, t)| {
                        let mut p = Json::obj();
                        p.set("n_hap", h).set("n_mark", m).set("targets", t);
                        p
                    })
                    .collect(),
            ),
        );

    let mut report = Json::obj();
    poets_impute::util::provenance::stamp(
        &mut report,
        "poets-impute/bench-desim/v1",
        run_config,
    );
    report
        .set("bench", "desim_hotpath")
        .set("smoke", smoke)
        .set("lanes", LANES)
        .set(
            "thread_sweep",
            Json::Arr(thread_sweep.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set(
            "width_sweep",
            Json::Arr(width_sweep.iter().map(|&n| Json::Int(n as i64)).collect()),
        )
        .set("pipeline_gate", gate)
        .set("rows", json_rows);
    let path = "BENCH_desim.json";
    match std::fs::write(path, report.pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if trace {
        write_trace_sample();
    }
}

/// `--trace`: one traced event-plane run written as `poets-impute/trace/v1`
/// JSONL.  Kept separate from the sweeps so the benchmark numbers always
/// measure the untraced hot path.
fn write_trace_sample() {
    use poets_impute::obs::TraceConfig;
    const H: usize = 16;
    const M: usize = 160;
    const T: usize = 8;
    let cfg = PanelConfig {
        n_hap: H,
        n_mark: M,
        annot_ratio: 0.1,
        seed: 7,
        ..PanelConfig::default()
    };
    let report = ImputeSession::new(Workload::synthetic(&cfg, T))
        .engine(EngineSpec::Event)
        .boards(4)
        .states_per_thread(4)
        .batch(LANES)
        .trace(TraceConfig::default())
        .run()
        .expect("event plane is always available");
    let t = report
        .trace
        .as_ref()
        .expect("a traced event-plane run records a trace");
    let mut rc = Json::obj();
    rc.set("bench", "desim_hotpath")
        .set("n_hap", H)
        .set("n_mark", M)
        .set("targets", T)
        .set("batch_width", LANES);
    let path = "BENCH_desim_trace.jsonl";
    match std::fs::write(path, t.to_jsonl(rc)) {
        Ok(()) => println!(
            "wrote {path} ({} superstep record(s), {} tiles)",
            t.steps.len(),
            t.n_tiles
        ),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// The lane-group pipelining gate: T=64 targets on a 1000-marker panel,
/// ONE 64-wide batch (eight lane groups staggered one superstep apart in
/// the same graph) vs eight sequential `batch(LANES)` sweeps.  Asserts
/// bit-identical dosages and a >= 2x superstep cut, and returns the JSON
/// block archived under `"pipeline_gate"`.
fn pipeline_gate() -> Json {
    const T: usize = 64;
    const M: usize = 1000;
    let cfg = PanelConfig {
        n_hap: 8,
        n_mark: M,
        annot_ratio: 0.1,
        seed: 7,
        ..PanelConfig::default()
    };
    let workload = Workload::synthetic(&cfg, T);
    let run = |width: usize| -> ImputeReport {
        ImputeSession::new(workload.clone())
            .engine(EngineSpec::Event)
            .boards(4)
            .states_per_thread(4)
            .batch(width)
            .run()
            .expect("event plane is always available")
    };
    let sequential = run(LANES); // 8 engine runs of one lane group each
    let pipelined = run(T); // 1 engine run, 8 groups in flight
    assert_eq!(
        pipelined.dosages, sequential.dosages,
        "pipelining changed dosages — determinism gate FAILED"
    );
    let (sm, pm) = (
        sequential.metrics.as_ref().expect("metrics"),
        pipelined.metrics.as_ref().expect("metrics"),
    );
    assert!(
        pm.steps * 2 <= sm.steps,
        "pipelined {} supersteps vs sequential {} — <= 0.5x gate FAILED",
        pm.steps,
        sm.steps
    );
    println!(
        "## lane-group pipelining gate (T={T}, M={M}): {} supersteps pipelined \
         ({} groups in flight) vs {} sequential — {:.2}x cut, dosages bit-identical",
        pm.steps,
        pm.max_groups_in_flight,
        sm.steps,
        sm.steps as f64 / pm.steps as f64
    );
    let mut gate = Json::obj();
    gate.set("targets", T)
        .set("n_mark", M)
        .set("sequential_steps", sm.steps)
        .set("pipelined_steps", pm.steps)
        .set("sequential_steps_per_target", sm.steps as f64 / T as f64)
        .set("pipelined_steps_per_target", pm.steps as f64 / T as f64)
        .set("max_groups_in_flight", pm.max_groups_in_flight)
        .set("max_busy_tiles", pm.max_busy_tiles)
        .set("dosages_bit_identical", true);
    gate
}
