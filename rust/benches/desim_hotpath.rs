//! `cargo bench --bench desim_hotpath` — micro-benchmark of the simulator's
//! host-side event throughput (events/second of *host* time), the quantity
//! that bounds how large a panel the DES plane can sweep.  This is the L3
//! optimisation target of EXPERIMENTS.md §Perf.
//!
//! Sweeps host worker threads (`SimConfig::threads`) per config and emits a
//! machine-readable `BENCH_desim.json` so the perf trajectory is tracked
//! across PRs.  Functional results are thread-count invariant (asserted
//! here via `sim_cycles`), so the sweep measures host throughput only.

use poets_impute::imputation::app::{EventRunResult, RawAppConfig, run_raw};
use poets_impute::imputation::interp_app::run_interp;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::json::Json;
use poets_impute::util::rng::Rng;
use poets_impute::util::table::{Table, fmt_count, fmt_secs};
use poets_impute::util::timed;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

const THREAD_SWEEP: &[usize] = &[1, 2, 4, 8];

fn main() {
    let mut t = Table::new(&[
        "app",
        "panel",
        "targets",
        "threads",
        "host time",
        "events",
        "host events/s",
        "speedup",
        "sim time",
    ]);
    let mut json_rows = Json::Arr(Vec::new());

    for &(h, m, targets) in &[(16usize, 160usize, 8usize), (32, 320, 8)] {
        let cfg = PanelConfig {
            n_hap: h,
            n_mark: m,
            annot_ratio: 0.1,
            seed: 7,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(8);
        let tgts: Vec<_> = generate_targets(&panel, &cfg, targets, &mut rng)
            .into_iter()
            .map(|c| c.masked)
            .collect();
        let base = RawAppConfig {
            cluster: ClusterConfig::with_boards(4),
            states_per_thread: 4,
            ..RawAppConfig::default()
        };

        for (app_name, spt) in [("raw", 4usize), ("interp", 1usize)] {
            let mut serial_time = 0.0f64;
            let mut serial_cycles = 0u64;
            for &threads in THREAD_SWEEP {
                let app = RawAppConfig {
                    states_per_thread: spt,
                    ..base.clone()
                }
                .with_threads(threads);
                let (out, host): (EventRunResult, f64) = if app_name == "raw" {
                    timed(|| run_raw(&panel, &tgts, &app))
                } else {
                    timed(|| run_interp(&panel, &tgts, &app))
                };
                if threads == 1 {
                    serial_time = host;
                    serial_cycles = out.metrics.sim_cycles;
                } else {
                    assert_eq!(
                        out.metrics.sim_cycles, serial_cycles,
                        "thread count changed simulated timing"
                    );
                }
                let events = out.metrics.copies_delivered;
                let eps = events as f64 / host;
                t.row(vec![
                    app_name.into(),
                    format!("{h}x{m}"),
                    targets.to_string(),
                    threads.to_string(),
                    fmt_secs(host),
                    fmt_count(events),
                    format!("{eps:.2e}"),
                    format!("{:.2}x", serial_time / host),
                    fmt_secs(out.sim_seconds),
                ]);
                let mut row = Json::obj();
                row.set("app", app_name)
                    .set("panel", format!("{h}x{m}"))
                    .set("n_hap", h)
                    .set("n_mark", m)
                    .set("targets", targets)
                    .set("threads", threads)
                    .set("host_seconds", host)
                    .set("events", events)
                    .set("events_per_s", eps)
                    .set("speedup_vs_serial", serial_time / host)
                    .set("sim_seconds", out.sim_seconds);
                json_rows.push(row);
            }
        }
    }

    println!("## DES hot path (host-side throughput)\n{}", t.render());

    let mut report = Json::obj();
    report
        .set("bench", "desim_hotpath")
        .set("thread_sweep", Json::Arr(THREAD_SWEEP.iter().map(|&n| Json::Int(n as i64)).collect()))
        .set("rows", json_rows);
    let path = "BENCH_desim.json";
    match std::fs::write(path, report.pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
