//! `cargo bench --bench desim_hotpath` — micro-benchmark of the simulator's
//! host-side event throughput (events/second of *host* time), the quantity
//! that bounds how large a panel the DES plane can sweep.  This is the L3
//! optimisation target of EXPERIMENTS.md §Perf.
//!
//! Sweeps host worker threads (`SimConfig::threads`) per config and emits a
//! machine-readable `BENCH_desim.json` so the perf trajectory is tracked
//! across PRs.  Functional results are thread-count invariant (asserted
//! here via `sim_cycles`), so the sweep measures host throughput only.

use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::util::json::Json;
use poets_impute::util::table::{Table, fmt_count, fmt_secs};
use poets_impute::util::timed;
use poets_impute::workload::panelgen::PanelConfig;

const THREAD_SWEEP: &[usize] = &[1, 2, 4, 8];

fn main() {
    let mut t = Table::new(&[
        "app",
        "panel",
        "targets",
        "threads",
        "host time",
        "events",
        "host events/s",
        "speedup",
        "sim time",
    ]);
    let mut json_rows = Json::Arr(Vec::new());

    for &(h, m, targets) in &[(16usize, 160usize, 8usize), (32, 320, 8)] {
        let cfg = PanelConfig {
            n_hap: h,
            n_mark: m,
            annot_ratio: 0.1,
            seed: 7,
            ..PanelConfig::default()
        };
        let workload = Workload::synthetic(&cfg, targets);

        for (app_name, engine, spt) in [
            ("raw", EngineSpec::Event, 4usize),
            ("interp", EngineSpec::Interp, 1usize),
        ] {
            let mut serial_time = 0.0f64;
            let mut serial_cycles = 0u64;
            for &threads in THREAD_SWEEP {
                let session = ImputeSession::new(workload.clone())
                    .engine(engine)
                    .boards(4)
                    .states_per_thread(spt)
                    .threads(threads);
                let (out, host): (ImputeReport, f64) =
                    timed(|| session.run().expect("event planes are always available"));
                let metrics = out.metrics.as_ref().expect("event planes report metrics");
                if threads == 1 {
                    serial_time = host;
                    serial_cycles = metrics.sim_cycles;
                } else {
                    assert_eq!(
                        metrics.sim_cycles, serial_cycles,
                        "thread count changed simulated timing"
                    );
                }
                let events = metrics.copies_delivered;
                let eps = events as f64 / host;
                t.row(vec![
                    app_name.into(),
                    format!("{h}x{m}"),
                    targets.to_string(),
                    threads.to_string(),
                    fmt_secs(host),
                    fmt_count(events),
                    format!("{eps:.2e}"),
                    format!("{:.2}x", serial_time / host),
                    fmt_secs(out.sim_seconds.expect("event planes report sim time")),
                ]);
                let mut row = Json::obj();
                row.set("app", app_name)
                    .set("panel", format!("{h}x{m}"))
                    .set("n_hap", h)
                    .set("n_mark", m)
                    .set("targets", targets)
                    .set("threads", threads)
                    .set("host_seconds", host)
                    .set("events", events)
                    .set("events_per_s", eps)
                    .set("speedup_vs_serial", serial_time / host)
                    .set("sim_seconds", out.sim_seconds.unwrap_or(0.0));
                json_rows.push(row);
            }
        }
    }

    println!("## DES hot path (host-side throughput)\n{}", t.render());

    let mut report = Json::obj();
    report
        .set("bench", "desim_hotpath")
        .set("thread_sweep", Json::Arr(THREAD_SWEEP.iter().map(|&n| Json::Int(n as i64)).collect()))
        .set("rows", json_rows);
    let path = "BENCH_desim.json";
    match std::fs::write(path, report.pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
