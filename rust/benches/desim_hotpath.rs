//! `cargo bench --bench desim_hotpath` — micro-benchmark of the simulator's
//! host-side event throughput (events/second of *host* time), the quantity
//! that bounds how large a panel the DES plane can sweep.  This is the L3
//! optimisation target of EXPERIMENTS.md §Perf.

use poets_impute::imputation::app::{RawAppConfig, run_raw};
use poets_impute::imputation::interp_app::run_interp;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::util::table::{Table, fmt_count, fmt_secs};
use poets_impute::util::timed;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn main() {
    let mut t = Table::new(&[
        "app",
        "panel",
        "targets",
        "host time",
        "events",
        "host events/s",
        "sim time",
    ]);
    for &(h, m, targets) in &[(16usize, 160usize, 8usize), (32, 320, 8)] {
        let cfg = PanelConfig {
            n_hap: h,
            n_mark: m,
            annot_ratio: 0.1,
            seed: 7,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(8);
        let tgts: Vec<_> = generate_targets(&panel, &cfg, targets, &mut rng)
            .into_iter()
            .map(|c| c.masked)
            .collect();
        let app = RawAppConfig {
            cluster: ClusterConfig::with_boards(4),
            states_per_thread: 4,
            ..RawAppConfig::default()
        };
        let (raw, host) = timed(|| run_raw(&panel, &tgts, &app));
        t.row(vec![
            "raw".into(),
            format!("{h}x{m}"),
            targets.to_string(),
            fmt_secs(host),
            fmt_count(raw.metrics.copies_delivered),
            format!("{:.2e}", raw.metrics.copies_delivered as f64 / host),
            fmt_secs(raw.sim_seconds),
        ]);
        let (itp, host) = timed(|| {
            run_interp(
                &panel,
                &tgts,
                &RawAppConfig {
                    states_per_thread: 1,
                    ..app
                },
            )
        });
        t.row(vec![
            "interp".into(),
            format!("{h}x{m}"),
            targets.to_string(),
            fmt_secs(host),
            fmt_count(itp.metrics.copies_delivered),
            format!("{:.2e}", itp.metrics.copies_delivered as f64 / host),
            fmt_secs(itp.sim_seconds),
        ]);
    }
    println!("## DES hot path (host-side throughput)\n{}", t.render());
}
