//! `cargo bench --bench fig12` — regenerates the paper's Fig 12 series
//! (soft-scheduling sweep on the full cluster; optimum ≈10 states/thread,
//! peak ≈270× at 10,000 targets vs a paper-era x86).
//!
//! For the full sweep use the CLI: `poets-impute bench fig12`.

use poets_impute::bench::calibrate::{PAPER_ERA_X86_MACS_PER_S, anchor_speedup};
use poets_impute::bench::{FigOpts, X86Cost, fig12};
use poets_impute::poets::costmodel::CostModel;

fn main() {
    eprintln!("[fig12 bench] calibrating x86 throughput...");
    let x86 = X86Cost::measure_default();
    let opts = FigOpts {
        des_states_per_board: 48,
        des_targets: 8,
        full_targets: 10_000,
        skip_des: false,
        seed: 1202,
    };
    let report = fig12(&[1, 2, 5, 10, 20, 40], &opts, &x86);
    println!("{}", report.render());

    // Shape assertions (E2): interior optimum near 10 states/thread.
    let s: Vec<f64> = report.rows.iter().map(|r| r.full_speedup).collect();
    let peak = s
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert!(
        (1..report.rows.len() - 1).contains(&peak),
        "Fig 12 shape violated: optimum at edge, speedups {s:?}"
    );
    println!(
        "fig12: interior optimum at {} states/thread OK",
        report.rows[peak].x
    );

    let anchor = anchor_speedup(&CostModel::default(), PAPER_ERA_X86_MACS_PER_S, 10_000);
    println!("fig12: 270x-anchor check (paper-era x86): {anchor:.0}x");
    assert!((90.0..900.0).contains(&anchor), "anchor {anchor} off-band");
}
