//! `cargo bench --bench baseline_hotpath` — micro-benchmark of the x86-style
//! baseline (the denominator of every figure; it must be honest).
//!
//! Reports per-MAC cost for the dense three-loop and rank-1 formulations,
//! plus the interpolated pipeline, across panel shapes.

use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::interpolation::impute_interp;
use poets_impute::util::rng::Rng;
use poets_impute::util::stats::Summary;
use poets_impute::util::table::{Table, fmt_secs};
use poets_impute::util::timed_reps;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn main() {
    let mut t = Table::new(&["panel", "method", "per-target", "MAC/s"]);
    for &(h, m) in &[(16usize, 128usize), (64, 512), (128, 1024)] {
        let cfg = PanelConfig {
            n_hap: h,
            n_mark: m,
            annot_ratio: 0.1,
            seed: 42,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(1);
        let target = generate_targets(&panel, &cfg, 1, &mut rng)
            .into_iter()
            .next()
            .unwrap()
            .masked;
        let b = Baseline::default();
        for (name, method) in [
            ("dense", Method::DenseThreeLoop),
            ("rank1", Method::Rank1),
        ] {
            let reps = if method == Method::DenseThreeLoop { 3 } else { 10 };
            let (_, times) = timed_reps(reps, || {
                let o: ImputeOut<f32> = b.impute(&panel, &target, method);
                std::hint::black_box(o)
            });
            let s = Summary::of(&times);
            let macs = b.flops_per_target(&panel, method) as f64;
            t.row(vec![
                format!("{h}x{m}"),
                name.into(),
                fmt_secs(s.p50),
                format!("{:.2e}", macs / s.p50),
            ]);
        }
        let (_, times) = timed_reps(5, || {
            let o: ImputeOut<f32> = impute_interp(&b, &panel, &target, Method::Rank1);
            std::hint::black_box(o)
        });
        let s = Summary::of(&times);
        t.row(vec![
            format!("{h}x{m}"),
            "interp(rank1)".into(),
            fmt_secs(s.p50),
            "-".into(),
        ]);
    }
    println!("## baseline hot path\n{}", t.render());
}
