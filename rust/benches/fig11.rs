//! `cargo bench --bench fig11` — regenerates the paper's Fig 11 series
//! (raw event-driven algorithm over expanding hardware) at bench-friendly
//! scale: DES plane at reduced panels + analytic plane at full paper scale.
//!
//! For the full sweep use the CLI: `poets-impute bench fig11`.

use poets_impute::bench::{FigOpts, X86Cost, fig11};

fn main() {
    eprintln!("[fig11 bench] calibrating x86 throughput...");
    let x86 = X86Cost::measure_default();
    let opts = FigOpts {
        des_states_per_board: 64,
        des_targets: 8,
        full_targets: 10_000,
        skip_des: false,
        seed: 1101,
    };
    let report = fig11(&[1, 2, 4, 8], &opts, &x86);
    println!("{}", report.render());

    // Shape assertions (the reproduction criterion for E1).
    let s: Vec<f64> = report.rows.iter().map(|r| r.full_speedup).collect();
    assert!(
        s.windows(2).all(|w| w[1] > w[0]),
        "Fig 11 shape violated: {s:?}"
    );
    println!("fig11: monotone speedup over boards OK {s:?}");
}
