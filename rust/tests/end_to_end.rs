//! End-to-end: the full GWAS-upscale workflow (workload generation →
//! event-driven imputation on the simulated cluster → accuracy scoring →
//! figure-harness sanity), mirroring examples/gwas_upscale.rs at test size.

use poets_impute::bench::{FigOpts, X86Cost, fig11, fig13};
use poets_impute::imputation::app::{RawAppConfig, run_raw};
use poets_impute::imputation::interp_app::run_interp;
use poets_impute::model::accuracy;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

#[test]
fn gwas_upscale_end_to_end() {
    let cfg = PanelConfig {
        n_hap: 24,
        n_mark: 201,
        maf: 0.05,
        annot_ratio: 0.1,
        seed: 77,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(78);
    let cases = generate_targets(&panel, &cfg, 8, &mut rng);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();

    let app = RawAppConfig {
        cluster: ClusterConfig::with_boards(4),
        states_per_thread: 4,
        ..RawAppConfig::default()
    };
    let raw = run_raw(&panel, &targets, &app);
    let itp = run_interp(
        &panel,
        &targets,
        &RawAppConfig {
            states_per_thread: 1,
            ..app
        },
    );

    // Both engines must genuinely impute (accuracy far above the 5% MAF
    // majority-vote floor would sit near 0.95 concordance; require learning
    // beyond "always major" by checking minor-allele concordance too).
    for (name, dosages) in [("raw", &raw.dosages), ("interp", &itp.dosages)] {
        let accs: Vec<_> = cases
            .iter()
            .zip(dosages)
            .map(|(c, d)| accuracy::score(d, &c.truth, &c.masked))
            .collect();
        let agg = accuracy::aggregate(&accs);
        assert!(
            agg.concordance > 0.9,
            "{name}: concordance {agg:?}"
        );
        assert!(
            agg.minor_concordance > 0.1,
            "{name}: no minor-allele signal {agg:?}"
        );
    }

    // The paper's economics, end to end.
    assert!(raw.metrics.sends > 5 * itp.metrics.sends);
    assert!(itp.sim_seconds < raw.sim_seconds);
    // Pipelined run completes in ~M + T + slack steps.
    assert!(raw.metrics.steps <= (201 + 8 + 8) as u64);
}

#[test]
fn figure_harnesses_end_to_end_tiny() {
    // The complete figure pipeline (workload gen → DES + analytic + x86
    // measurement → report) at minimum size.
    let opts = FigOpts {
        des_states_per_board: 32,
        des_targets: 4,
        full_targets: 10_000,
        skip_des: false,
        seed: 3,
    };
    let x86 = X86Cost::measure_default();
    let f11 = fig11(&[1, 2], &opts, &x86);
    assert_eq!(f11.rows.len(), 2);
    for row in &f11.rows {
        assert!(row.des_speedup.is_some());
        assert!(row.full_speedup > 0.0);
        assert!(row.full_poets_s > 0.0);
    }
    let f13 = fig13(&[1], &opts, &x86);
    assert!(f13.rows[0].des_speedup.is_some());
    // Rendering must produce the paper-style series.
    assert!(f11.render().contains("boards"));
    assert!(f13.to_json().render().contains("rows"));
}
