//! End-to-end: the full GWAS-upscale workflow (workload generation →
//! event-driven imputation on the simulated cluster → accuracy scoring →
//! figure-harness sanity), mirroring examples/gwas_upscale.rs at test size —
//! all through the session API.

use poets_impute::bench::{FigOpts, X86Cost, fig11, fig13};
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::workload::panelgen::PanelConfig;

#[test]
fn gwas_upscale_end_to_end() {
    let cfg = PanelConfig {
        n_hap: 24,
        n_mark: 201,
        maf: 0.05,
        annot_ratio: 0.1,
        seed: 77,
        ..PanelConfig::default()
    };
    let workload = Workload::synthetic(&cfg, 8);

    let raw = ImputeSession::new(workload.clone())
        .engine(EngineSpec::Event)
        .boards(4)
        .states_per_thread(4)
        .run()
        .unwrap();
    let itp = ImputeSession::new(workload)
        .engine(EngineSpec::Interp)
        .boards(4)
        .states_per_thread(1)
        .run()
        .unwrap();

    // Both engines must genuinely impute (accuracy far above the 5% MAF
    // majority-vote floor would sit near 0.95 concordance; require learning
    // beyond "always major" by checking minor-allele concordance too).
    for (name, report) in [("raw", &raw), ("interp", &itp)] {
        let agg = report.accuracy.expect("synthetic workload has truth");
        assert!(agg.concordance > 0.9, "{name}: concordance {agg:?}");
        assert!(
            agg.minor_concordance > 0.1,
            "{name}: no minor-allele signal {agg:?}"
        );
    }

    // The paper's economics, end to end.  Both planes wave-batch their
    // targets now (and interp's hit vectors cannot lane-batch), so the
    // per-event gap narrows vs the per-target design — but lane for lane
    // the anchor grid's ~10x reduction is intact.
    let raw_m = raw.metrics.as_ref().unwrap();
    let itp_m = itp.metrics.as_ref().unwrap();
    assert!(raw_m.sends > 2 * itp_m.sends);
    assert!(raw_m.lanes_delivered > 5 * itp_m.lanes_delivered);
    assert!(itp.sim_seconds.unwrap() < raw.sim_seconds.unwrap());
    // A single wave sweep completes in ~M + slack steps (the per-target
    // pipeline needed ~M + T).
    assert!(raw_m.steps <= (201 + 8) as u64);
}

#[test]
fn figure_harnesses_end_to_end_tiny() {
    // The complete figure pipeline (workload gen → DES + analytic + x86
    // measurement → report) at minimum size.
    let opts = FigOpts {
        des_states_per_board: 32,
        des_targets: 4,
        full_targets: 10_000,
        skip_des: false,
        seed: 3,
    };
    let x86 = X86Cost::measure_default();
    let f11 = fig11(&[1, 2], &opts, &x86);
    assert_eq!(f11.rows.len(), 2);
    for row in &f11.rows {
        assert!(row.des_speedup.is_some());
        assert!(row.full_speedup > 0.0);
        assert!(row.full_poets_s > 0.0);
    }
    let f13 = fig13(&[1], &opts, &x86);
    assert!(f13.rows[0].des_speedup.is_some());
    // Rendering must produce the paper-style series.
    assert!(f11.render().contains("boards"));
    assert!(f13.to_json().render().contains("rows"));
}
