//! Integration: event-driven cluster execution vs the x86-style baseline
//! across a grid of panel shapes, target counts, mappings and cluster sizes.
//!
//! This is the paper's central correctness property: Algorithm 1 running as
//! messages over the simulated POETS fabric computes exactly the Li &
//! Stephens forward/backward posteriors (§3.2 / §5.2).

use poets_impute::imputation::app::{RawAppConfig, run_raw};
use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn check(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize, boards: usize, spt: usize) {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.2,
        annot_ratio: 0.15,
        seed,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(seed ^ 0xE1E1);
    let targets: Vec<_> = generate_targets(&panel, &cfg, n_targets, &mut rng)
        .into_iter()
        .map(|c| c.masked)
        .collect();
    let app = RawAppConfig {
        cluster: ClusterConfig::with_boards(boards),
        states_per_thread: spt,
        ..RawAppConfig::default()
    };
    let out = run_raw(&panel, &targets, &app);
    let b = Baseline::default();
    for (t, target) in targets.iter().enumerate() {
        let want: ImputeOut<f32> = b.impute(&panel, target, Method::DenseThreeLoop);
        for m in 0..n_mark {
            let d = (out.dosages[t][m] - want.dosage[m]).abs();
            assert!(
                d < 1e-3,
                "seed={seed} H={n_hap} M={n_mark} boards={boards} spt={spt} \
                 target={t} marker={m}: event {} vs baseline {}",
                out.dosages[t][m],
                want.dosage[m]
            );
        }
    }
}

#[test]
fn tall_panel() {
    check(1, 32, 12, 2, 2, 8);
}

#[test]
fn wide_panel() {
    check(2, 4, 200, 2, 2, 4);
}

#[test]
fn many_targets_deep_pipeline() {
    // T > M: full steady-state pipelining with wave overlap.
    check(3, 6, 18, 30, 1, 4);
}

#[test]
fn single_column_pair() {
    // Minimal M=2: init columns are adjacent; posterior pairing is immediate.
    check(4, 8, 2, 3, 1, 2);
}

#[test]
fn two_haplotypes() {
    // Minimal H=2: every multicast has exactly one same + one diff receiver.
    check(5, 2, 40, 4, 1, 1);
}

#[test]
fn spread_across_all_48_boards() {
    check(6, 8, 80, 2, 48, 1);
}

#[test]
fn heavy_soft_scheduling() {
    check(7, 10, 50, 3, 1, 64);
}

#[test]
fn partitioned_mapping_matches_too() {
    // POLite-style auto-partitioned mapping must not change numerics.
    use poets_impute::graph::partition::partition_mapping;
    use poets_impute::imputation::app::{build_raw_graph, extract_results};
    use poets_impute::poets::desim::{SimConfig, Simulator};

    let cfg = PanelConfig {
        n_hap: 8,
        n_mark: 30,
        maf: 0.2,
        annot_ratio: 0.15,
        seed: 8,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(0x9A9A);
    let targets: Vec<_> = generate_targets(&panel, &cfg, 2, &mut rng)
        .into_iter()
        .map(|c| c.masked)
        .collect();
    let cluster = ClusterConfig::with_boards(2);
    let graph = build_raw_graph(&panel, &targets, &Default::default());
    let mapping = partition_mapping(&graph, 4, &cluster);
    let mut sim = Simulator::new(
        graph,
        mapping,
        cluster,
        Default::default(),
        SimConfig::default(),
    );
    sim.run();
    let out = extract_results(&sim, &panel, targets.len());

    let b = Baseline::default();
    for (t, target) in targets.iter().enumerate() {
        let want: ImputeOut<f32> = b.impute(&panel, target, Method::DenseThreeLoop);
        for m in 0..panel.n_mark() {
            assert!(
                (out.dosages[t][m] - want.dosage[m]).abs() < 1e-3,
                "partitioned mapping corrupted numerics at t={t} m={m}"
            );
        }
    }
}
