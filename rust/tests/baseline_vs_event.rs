//! Integration: event-driven cluster execution vs the x86-style baseline
//! across a grid of panel shapes, target counts, mappings and cluster sizes,
//! all driven through the session API.
//!
//! This is the paper's central correctness property: Algorithm 1 running as
//! messages over the simulated POETS fabric computes exactly the Li &
//! Stephens forward/backward posteriors (§3.2 / §5.2).

use poets_impute::graph::mapping::MappingStrategy;
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::workload::panelgen::PanelConfig;

fn workload(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize) -> Workload {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.2,
        annot_ratio: 0.15,
        seed,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, n_targets)
}

fn check(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize, boards: usize, spt: usize) {
    let wl = workload(seed, n_hap, n_mark, n_targets);
    let event = ImputeSession::new(wl.clone())
        .engine(EngineSpec::Event)
        .boards(boards)
        .states_per_thread(spt)
        .run()
        .unwrap();
    let dense = ImputeSession::new(wl)
        .engine(EngineSpec::Baseline)
        .run()
        .unwrap();
    for t in 0..n_targets {
        for m in 0..n_mark {
            let d = (event.dosages[t][m] - dense.dosages[t][m]).abs();
            assert!(
                d < 1e-3,
                "seed={seed} H={n_hap} M={n_mark} boards={boards} spt={spt} \
                 target={t} marker={m}: event {} vs baseline {}",
                event.dosages[t][m],
                dense.dosages[t][m]
            );
        }
    }
}

#[test]
fn tall_panel() {
    check(1, 32, 12, 2, 2, 8);
}

#[test]
fn wide_panel() {
    check(2, 4, 200, 2, 2, 4);
}

#[test]
fn many_targets_deep_pipeline() {
    // T > M: full steady-state pipelining with wave overlap.
    check(3, 6, 18, 30, 1, 4);
}

#[test]
fn single_column_pair() {
    // Minimal M=2: init columns are adjacent; posterior pairing is immediate.
    check(4, 8, 2, 3, 1, 2);
}

#[test]
fn two_haplotypes() {
    // Minimal H=2: every multicast has exactly one same + one diff receiver.
    check(5, 2, 40, 4, 1, 1);
}

#[test]
fn spread_across_all_48_boards() {
    check(6, 8, 80, 2, 48, 1);
}

#[test]
fn heavy_soft_scheduling() {
    check(7, 10, 50, 3, 1, 64);
}

#[test]
fn partitioned_mapping_matches_too() {
    // POLite-style auto-partitioned mapping must not change numerics.
    let wl = workload(8, 8, 30, 2);
    let event = ImputeSession::new(wl.clone())
        .engine(EngineSpec::Event)
        .boards(2)
        .states_per_thread(4)
        .mapping(MappingStrategy::Partitioned)
        .run()
        .unwrap();
    let dense = ImputeSession::new(wl)
        .engine(EngineSpec::Baseline)
        .run()
        .unwrap();
    for t in 0..2 {
        for m in 0..30 {
            assert!(
                (event.dosages[t][m] - dense.dosages[t][m]).abs() < 1e-3,
                "partitioned mapping corrupted numerics at t={t} m={m}"
            );
        }
    }
}
