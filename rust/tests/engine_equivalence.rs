//! Integration: every `EngineSpec` through `ImputeSession` on one small
//! workload, asserting dosage agreement within the repo's established
//! tolerances — the acceptance test of the unified session API.
//!
//! Oracles: the dense three-loop baseline for rank1/event/xla; the x86
//! interpolation pipeline for the interp plane (it approximates the HMM by
//! design, so comparing it to the dense baseline would conflate model error
//! with execution error).

use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::interpolation::impute_interp;
use poets_impute::session::{
    EngineSpec, ImputeSession, Workload, max_abs_dosage_diff,
};
use poets_impute::util::json::Json;
use poets_impute::workload::panelgen::PanelConfig;

fn workload() -> Workload {
    let cfg = PanelConfig {
        n_hap: 8,
        n_mark: 41,
        maf: 0.2,
        annot_ratio: 0.1,
        seed: 2024,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, 3)
}

fn session(spec: EngineSpec) -> ImputeSession {
    ImputeSession::new(workload())
        .engine(spec)
        .boards(2)
        .states_per_thread(4)
}

/// The interp plane's oracle: the x86 interpolation pipeline.
fn interp_oracle(wl: &Workload) -> Vec<Vec<f32>> {
    let b = Baseline::default();
    wl.targets()
        .iter()
        .map(|t| {
            let out: ImputeOut<f32> = impute_interp(&b, wl.panel(), t, Method::DenseThreeLoop);
            out.dosage
        })
        .collect()
}

#[test]
fn every_engine_agrees_with_its_oracle() {
    let wl = workload();
    let dense = session(EngineSpec::Baseline).run().unwrap();
    let interp_want = interp_oracle(&wl);

    for spec in EngineSpec::ALL {
        let report = match session(spec).run() {
            Ok(r) => r,
            Err(e) => {
                // The XLA plane needs the `pjrt` feature + built artifacts;
                // every other plane must always be available.
                assert_eq!(spec, EngineSpec::Xla, "{spec:?} unavailable: {e}");
                continue;
            }
        };
        assert_eq!(report.engine, spec);
        assert_eq!(report.dosages.len(), wl.n_targets());
        let oracle: &[Vec<f32>] = if spec == EngineSpec::Interp {
            &interp_want
        } else {
            &dense.dosages
        };
        let diff = max_abs_dosage_diff(&report.dosages, oracle);
        assert!(
            diff <= spec.tolerance(),
            "{spec:?} vs {}: max |Δdosage| {diff:.2e} > tolerance {:.0e}",
            spec.oracle_name(),
            spec.tolerance()
        );
    }
}

#[test]
fn event_plane_batching_preserves_results() {
    // TargetBatch is the event plane's lane group: the wave-batched plane
    // reduces its fan-in in canonical sender order, so a batched run is
    // BIT-IDENTICAL to the one-shot run — batch composition no longer
    // shifts the f32 sum order (tests/parallel_equivalence.rs sweeps the
    // full width × thread matrix).
    let full = session(EngineSpec::Event).run().unwrap();
    let batched = session(EngineSpec::Event).batch(1).run().unwrap();
    assert_eq!(batched.n_batches, 3);
    assert_eq!(batched.dosages.len(), full.dosages.len());
    assert_eq!(
        batched.dosages, full.dosages,
        "per-target batches must reproduce the one-shot wave bit for bit"
    );
    // Accounting accumulates across batches, and the one-shot wave needs
    // strictly fewer events for the same per-target work.
    let m = batched.metrics.as_ref().unwrap();
    assert_eq!(m.step_durations.len() as u64, m.steps);
    assert!(m.sends > 0);
    let fm = full.metrics.as_ref().unwrap();
    assert_eq!(fm.lanes_delivered, m.lanes_delivered);
    assert!(fm.copies_delivered < m.copies_delivered);
}

#[test]
fn report_manifest_matches_schema() {
    let report = session(EngineSpec::Event).batch(2).run().unwrap();
    let j = report.to_json();
    assert_eq!(
        j.get("schema"),
        Some(&Json::Str("poets-impute/impute-report/v1".into()))
    );
    assert_eq!(j.get("engine"), Some(&Json::Str("event".into())));
    for key in ["workload", "run", "timing", "accuracy", "sim_metrics"] {
        assert!(j.get(key).is_some(), "manifest missing {key:?}");
    }
    let wl = j.get("workload").unwrap();
    assert_eq!(wl.get("n_targets"), Some(&Json::Int(3)));
    assert_eq!(wl.get("seed"), Some(&Json::Int(2024)));
    let run = j.get("run").unwrap();
    assert_eq!(run.get("batch_size"), Some(&Json::Int(2)));
    assert_eq!(run.get("n_batches"), Some(&Json::Int(2)));
    let timing = j.get("timing").unwrap();
    assert!(timing.get("host_seconds").is_some());
    assert!(timing.get("poets_sim_seconds").is_some());
}

#[test]
fn spec_parsing_matches_cli_surface() {
    for spec in EngineSpec::ALL {
        assert_eq!(spec.name().parse::<EngineSpec>().unwrap(), spec);
    }
    // Legacy spelling from the pre-session CLI.
    assert_eq!(
        "event-interp".parse::<EngineSpec>().unwrap(),
        EngineSpec::Interp
    );
    assert!("".parse::<EngineSpec>().is_err());
}
