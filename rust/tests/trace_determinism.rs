//! Integration: the observability contract of the DES trace plane
//! (schema `poets-impute/trace/v1`, see `obs::trace`).
//!
//! The load-bearing invariant: trace capture rides the simulator's
//! deterministic serial shard reduce, so at a FIXED wave/batch width the
//! serialised JSONL is **byte-identical for any host thread count** — the
//! trace is an observation of the simulated schedule, not of host timing.
//! Different widths pipeline different lane groups through the graph and
//! legitimately record different schedules, so identity is asserted per
//! width, never across widths (each width stays deterministic run to run).
//!
//! Also covered here: the parse → render identity of trace files, the
//! line-numbered rejection of malformed input, and the structural validity
//! of the Chrome `trace_event` export.

use poets_impute::imputation::msg::LANES;
use poets_impute::obs::{self, TRACE_SCHEMA, TraceConfig, TraceFile};
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::util::json::Json;
use poets_impute::workload::panelgen::PanelConfig;

const THREADS: [usize; 3] = [1, 2, 4];

fn workload(seed: u64, n_targets: usize) -> Workload {
    let cfg = PanelConfig {
        n_hap: 8,
        n_mark: 24,
        maf: 0.2,
        annot_ratio: 0.2,
        seed,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, n_targets)
}

/// One traced event-plane run, serialised.  The run_config deliberately
/// excludes the thread count, so byte equality across threads is meaningful.
fn traced_jsonl(wl: &Workload, width: usize, threads: usize) -> String {
    let report = ImputeSession::new(wl.clone())
        .engine(EngineSpec::Event)
        .boards(2)
        .states_per_thread(4)
        .threads(threads)
        .batch(width)
        .trace(TraceConfig::default())
        .run()
        .expect("event plane is always available");
    let trace = report.trace.expect("a traced event run records a trace");
    let mut rc = Json::obj();
    rc.set("suite", "trace_determinism").set("batch_width", width);
    trace.to_jsonl(rc)
}

#[test]
fn trace_is_bit_identical_across_threads_at_every_width() {
    let wl = workload(11, LANES + 3);
    for &width in &[1usize, LANES - 1, LANES, LANES + 3] {
        let reference = traced_jsonl(&wl, width, THREADS[0]);
        assert!(
            reference.contains(TRACE_SCHEMA),
            "header names the schema: {}",
            reference.lines().next().unwrap_or("")
        );
        for &threads in &THREADS[1..] {
            let got = traced_jsonl(&wl, width, threads);
            assert_eq!(
                reference, got,
                "trace diverged at width={width} threads={threads}"
            );
        }
    }
}

/// The same contract holds for the per-link NoC samples, on a scenario
/// cluster whose boards are small enough that the panel spans both of them
/// (plain `.boards(2)` keeps this workload on board 0, recording no link
/// traffic).  Link samples are drained from the NoC in the simulator's
/// serial dispatch, so they ride the same deterministic reduce: byte
/// identity across host threads — and they must actually be present.
#[test]
fn link_samples_are_bit_identical_across_threads() {
    use poets_impute::poets::ScenarioSpec;
    let wl = workload(17, 3);
    let spec = ScenarioSpec::parse("name=lab,boards=2,tiles=4,cores=2,threads=4,bw=0.5")
        .expect("valid scenario spec");
    let run = |threads: usize| {
        let report = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Event)
            .scenario(spec.clone())
            .states_per_thread(4)
            .threads(threads)
            .trace(TraceConfig::default())
            .run()
            .expect("event plane is always available");
        let mut rc = Json::obj();
        rc.set("suite", "scenario_link_determinism");
        report.trace.expect("traced run records a trace").to_jsonl(rc)
    };
    let reference = run(THREADS[0]);
    assert!(
        reference.contains("\"links\":[["),
        "spanning two boards must record per-link samples"
    );
    for &threads in &THREADS[1..] {
        assert_eq!(
            reference,
            run(threads),
            "link samples diverged at threads={threads}"
        );
    }
}

#[test]
fn trace_round_trips_byte_identically() {
    let wl = workload(29, 3);
    let text = traced_jsonl(&wl, 1, 2);
    let file = TraceFile::parse(&text).expect("self-produced traces parse");
    assert_eq!(file.render(), text, "parse -> render must be the identity");
    assert!(file.trace.total_steps > 0, "the run recorded supersteps");
    // The analysis front end accepts any parsed trace.
    let summary = obs::trace::summarize(&file);
    assert!(summary.contains("tiles"), "{summary}");
}

#[test]
fn malformed_lines_are_rejected_with_their_line_number() {
    let wl = workload(31, 2);
    let text = traced_jsonl(&wl, 1, 1);
    let mut lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() > 1, "need at least one step record to corrupt");
    let n = lines.len();
    // An unknown record kind on the final line must name that line.
    *lines.last_mut().unwrap() = "{\"kind\":\"wibble\"}";
    let err = TraceFile::parse(&(lines.join("\n") + "\n")).unwrap_err();
    assert!(err.contains(&format!("line {n}")), "{err}");
}

#[test]
fn chrome_export_is_structurally_valid() {
    let wl = workload(43, LANES);
    let text = traced_jsonl(&wl, LANES, 2);
    let file = TraceFile::parse(&text).expect("parse");
    let doc = obs::chrome::to_chrome(&file);
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = match doc.get("traceEvents") {
        Some(Json::Arr(xs)) => xs,
        other => panic!("traceEvents missing or not an array: {other:?}"),
    };
    assert!(!events.is_empty());
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).expect("every event has ph");
        assert!(matches!(ph, "M" | "X" | "C"), "unexpected phase {ph:?}");
        assert!(e.get("pid").and_then(Json::as_i64).is_some());
        if ph == "X" {
            assert!(e.get("ts").and_then(Json::as_i64).unwrap() >= 0);
            assert!(e.get("dur").and_then(Json::as_i64).unwrap() >= 0);
        }
    }
    // The export itself must be valid JSON end to end.
    assert!(Json::parse(&doc.pretty()).is_ok());
}
