//! Integration: the AOT artifact plane (JAX/Pallas → HLO text → PJRT)
//! against the native Rust baseline — the cross-language correctness seam.
//!
//! Requires `make artifacts` (skipped gracefully otherwise, but `make test`
//! always builds artifacts first).

use std::path::Path;

use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::panel::TargetHaplotype;
use poets_impute::model::params::ModelParams;
use poets_impute::runtime::{Runtime, XlaImputer};
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn artifacts_dir() -> Option<&'static Path> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature (stub runtime)");
        return None;
    }
    let p = Path::new("artifacts");
    if p.join("manifest.tsv").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn problem(seed: u64, n_hap: usize, n_mark: usize, n: usize) -> (poets_impute::model::panel::ReferencePanel, Vec<TargetHaplotype>) {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.2,
        annot_ratio: 0.2,
        seed,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(seed ^ 0x5EED);
    let targets = generate_targets(&panel, &cfg, n, &mut rng)
        .into_iter()
        .map(|c| c.masked)
        .collect();
    (panel, targets)
}

#[test]
fn manifest_loads_and_lists_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    assert!(rt.manifest().artifacts.len() >= 10);
    assert!(rt.manifest().get("impute_raw_h16_m32").is_some());
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn xla_plane_matches_native_baseline_exact_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    let mut imputer = XlaImputer::new(rt, ModelParams::default());
    let (panel, targets) = problem(1, 16, 32, 3);
    let b = Baseline::default();
    for t in &targets {
        let got = imputer.impute_raw(&panel, t).expect("xla impute");
        let want: ImputeOut<f32> = b.impute(&panel, t, Method::Rank1);
        assert_eq!(got.len(), 32);
        for m in 0..32 {
            assert!(
                (got[m] - want.dosage[m]).abs() < 1e-4,
                "marker {m}: xla {} vs native {}",
                got[m],
                want.dosage[m]
            );
        }
    }
}

#[test]
fn marker_padding_is_inert() {
    // M=20 < canonical 32: the runtime pads with τ=0/emis=1/allele=0 columns;
    // dosages over the real markers must be unchanged.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    let mut imputer = XlaImputer::new(rt, ModelParams::default());
    let (panel, targets) = problem(2, 16, 20, 2);
    let b = Baseline::default();
    for t in &targets {
        let got = imputer.impute_raw(&panel, t).expect("xla impute (padded)");
        let want: ImputeOut<f32> = b.impute(&panel, t, Method::Rank1);
        assert_eq!(got.len(), 20);
        for m in 0..20 {
            assert!(
                (got[m] - want.dosage[m]).abs() < 1e-4,
                "marker {m}: padded xla {} vs native {}",
                got[m],
                want.dosage[m]
            );
        }
    }
}

#[test]
fn xla_plane_matches_event_driven() {
    // Full three-layer agreement: Pallas/XLA plane == event-driven cluster.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    let mut imputer = XlaImputer::new(rt, ModelParams::default());
    let (panel, targets) = problem(3, 16, 30, 2);
    let event = poets_impute::session::ImputeSession::new(
        poets_impute::session::Workload::from_parts(panel.clone(), targets.clone()),
    )
    .engine(poets_impute::session::EngineSpec::Event)
    .boards(2)
    .states_per_thread(8)
    .run()
    .expect("event plane");
    for (t, target) in targets.iter().enumerate() {
        let xla = imputer.impute_raw(&panel, target).expect("xla");
        for m in 0..panel.n_mark() {
            assert!(
                (xla[m] - event.dosages[t][m]).abs() < 1e-3,
                "target {t} marker {m}: xla {} vs event {}",
                xla[m],
                event.dosages[t][m]
            );
        }
    }
}

#[test]
fn unknown_h_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    let mut imputer = XlaImputer::new(rt, ModelParams::default());
    let (panel, targets) = problem(4, 12, 20, 1); // H=12 not canonical
    let err = imputer.impute_raw(&panel, &targets[0]).unwrap_err();
    assert!(err.to_string().contains("canonical H"), "{err}");
}

#[test]
fn executables_are_cached() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    let mut imputer = XlaImputer::new(rt, ModelParams::default());
    let (panel, targets) = problem(5, 16, 32, 4);
    assert_eq!(imputer.runtime.n_compiled(), 0);
    imputer.impute_batch(&panel, &targets).expect("batch");
    assert_eq!(imputer.runtime.n_compiled(), 1, "one artifact, one compile");
}
