//! Integration: the scenario lab's analytic-vs-DES contract.
//!
//! The analytic model ([`predict_scenario`]) and the DES must keep
//! modelling the same machine across the heterogeneous-cluster design
//! space: for randomized [`ScenarioSpec`]s — global and per-link bandwidth
//! degradation, latency inflation, failed links with BFS reroute — the
//! predicted/measured cycle ratio must stay inside the same band that
//! `bench topology` hard-gates on.  A spec that drifts outside the band
//! means one of the two planes stopped modelling the shared cost model.
//!
//! The random shapes keep every board small (4–16 threads) so the fixed
//! 48-thread workload always spans several boards and genuinely exercises
//! the link plane, while total_threads stays >= the mapper's needs.

use poets_impute::bench::topology::GATE_BAND;
use poets_impute::imputation::analytic::{AppKind, Workload as AWorkload, predict_scenario};
use poets_impute::poets::ScenarioSpec;
use poets_impute::poets::costmodel::CostModel;
use poets_impute::poets::noc::Dir;
use poets_impute::poets::scenario::LinkMod;
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::PanelConfig;

const N_HAP: usize = 8;
const N_MARK: usize = 24;
const N_TARGETS: usize = 4;
const SPT: usize = 4;

/// Run the DES and the analytic predictor on one scenario; return
/// (analytic cycles / DES cycles, inter-board copies observed).
fn ratio_for(spec: &ScenarioSpec) -> (f64, u64) {
    let cfg = PanelConfig {
        n_hap: N_HAP,
        n_mark: N_MARK,
        maf: 0.2,
        annot_ratio: 0.2,
        seed: 97,
        ..PanelConfig::default()
    };
    let wl = Workload::synthetic(&cfg, N_TARGETS);
    let report = ImputeSession::new(wl)
        .engine(EngineSpec::Event)
        .scenario(spec.clone())
        .states_per_thread(SPT)
        .run()
        .expect("the event plane runs every valid scenario");
    let m = report.metrics.expect("event plane reports DES metrics");
    assert!(m.sim_cycles > 0, "{}: empty run", spec.name);
    let pred = predict_scenario(
        &AWorkload {
            n_hap: N_HAP,
            n_mark: N_MARK,
            n_targets: N_TARGETS,
            states_per_thread: SPT,
            // The session runs all targets as one batch.
            lane_width: N_TARGETS,
            kind: AppKind::Raw,
        },
        spec,
        &CostModel::default(),
    );
    (
        pred.total_cycles as f64 / m.sim_cycles as f64,
        m.inter_board_copies,
    )
}

/// Draw one random heterogeneous spec on the 8-board 4x2 grid.
fn random_spec(rng: &mut Rng, i: usize) -> ScenarioSpec {
    let mut spec = ScenarioSpec::baseline(8);
    spec.name = format!("prop-{i}");
    // 8 or 16 threads per board: 64..128 total >= the 48 threads needed.
    spec.tiles_per_board = Some(if rng.chance(0.5) { 2 } else { 4 });
    spec.cores_per_tile = Some(1);
    spec.threads_per_core = Some(4);
    spec.bw_scale = rng.uniform(0.25, 1.0);
    spec.lat_mult = rng.uniform(1.0, 4.0);
    if rng.chance(0.6) {
        spec.links.push(LinkMod {
            board: rng.range(0, 8),
            dir: Dir::ALL[rng.range(0, 4)],
            bw_scale: rng.uniform(0.5, 1.0),
            lat_mult: rng.uniform(1.0, 2.0),
        });
    }
    if rng.chance(0.5) {
        spec.failed.push((rng.range(0, 8), Dir::ALL[rng.range(0, 4)]));
        if spec.validate().is_err() {
            // That draw disconnected the grid; keep the rest of the spec.
            spec.failed.clear();
        }
    }
    spec.validate().expect("generated spec must be valid");
    spec
}

fn assert_in_band(spec: &ScenarioSpec) {
    let (ratio, inter_board) = ratio_for(spec);
    assert!(
        inter_board > 0,
        "scenario {}: workload never left board 0 — the property is vacuous",
        spec.name
    );
    assert!(
        (GATE_BAND.0..=GATE_BAND.1).contains(&ratio),
        "scenario {} left the gate band {:?}: ratio {ratio:.3}\nspec: {spec:?}",
        spec.name,
        GATE_BAND
    );
}

#[test]
fn analytic_tracks_des_across_random_scenarios() {
    let mut rng = Rng::new(0x5eed_1ab);
    for i in 0..6 {
        assert_in_band(&random_spec(&mut rng, i));
    }
}

// ---------------------------------------------------------------------------
// Fault plane: recovery must be invisible in the numbers.
//
// For randomized fault schedules — tile kills (remap + replay from the last
// barrier checkpoint), lossy links (drop = NACK/retransmit), duplicating
// links (mailbox suppression) — the dosages must be BIT-identical to the
// fault-free run at every host thread count and wave width.  Recovery may
// only show up in simulated time and the recovery counters.
// ---------------------------------------------------------------------------

const FAULT_SHAPE: &str = "boards=8,tiles=2,cores=1,threads=4";
const N_FAULT_TARGETS: usize = 11;

/// Run the event plane under `schedule`; return the dosage bit patterns
/// plus (failed_tiles, recovery_cycles) summed over the run's batches.
fn fault_run(schedule: &str, threads: usize, width: usize) -> (Vec<Vec<u32>>, u64, u64) {
    let cfg = PanelConfig {
        n_hap: N_HAP,
        n_mark: N_MARK,
        maf: 0.2,
        annot_ratio: 0.2,
        seed: 97,
        ..PanelConfig::default()
    };
    let wl = Workload::synthetic(&cfg, N_FAULT_TARGETS);
    let spec = ScenarioSpec::parse(schedule).expect("fault schedule must parse");
    let report = ImputeSession::new(wl)
        .engine(EngineSpec::Event)
        .scenario(spec)
        .states_per_thread(SPT)
        .threads(threads)
        .batch(width)
        .run()
        .unwrap_or_else(|e| panic!("schedule {schedule:?} t={threads} w={width}: {e}"));
    let bits: Vec<Vec<u32>> = report
        .dosages
        .iter()
        .map(|row| row.iter().map(|d| d.to_bits()).collect())
        .collect();
    let m = report.metrics.expect("event plane reports DES metrics");
    (bits, m.failed_tiles, m.recovery_cycles)
}

/// Draw one random fault schedule on the 8-board grid: 1–2 tile kills on
/// distinct boards (a half-dead board stays powered), an optional lossy
/// link, an optional duplicating link, and sometimes a non-default
/// checkpoint cadence.
fn random_fault_schedule(rng: &mut Rng, i: usize) -> String {
    let mut parts = vec![format!("name=fault-{i},{FAULT_SHAPE}")];
    let b1 = rng.range(0, 8);
    parts.push(format!("failtile={b1}.{}@{}", rng.range(0, 2), 3 + rng.range(0, 10)));
    if rng.chance(0.5) {
        let b2 = (b1 + 1 + rng.range(0, 7)) % 8;
        parts.push(format!("failtile={b2}.{}@{}", rng.range(0, 2), 3 + rng.range(0, 10)));
    }
    if rng.chance(0.7) {
        parts.push(format!(
            "drop={}E:{:.2}@{}",
            rng.range(0, 3),
            0.1 + 0.3 * rng.uniform(0.0, 1.0),
            7 + i
        ));
    }
    if rng.chance(0.5) {
        parts.push(format!(
            "dup={}E:{:.2}@{}",
            rng.range(0, 3),
            0.1 + 0.3 * rng.uniform(0.0, 1.0),
            17 + i
        ));
    }
    if rng.chance(0.5) {
        parts.push(format!("ckpt={}", 2 + rng.range(0, 6)));
    }
    let schedule = parts.join(",");
    ScenarioSpec::parse(&schedule).expect("generated schedule must be valid");
    schedule
}

#[test]
fn fault_schedules_preserve_bit_identical_dosages() {
    let (oracle, clean_failed, _) = fault_run(&format!("name=clean,{FAULT_SHAPE}"), 2, 11);
    assert_eq!(clean_failed, 0, "the oracle run must be fault-free");

    let mut rng = Rng::new(0xfa_17ab);
    let mut schedules: Vec<String> = (0..2).map(|i| random_fault_schedule(&mut rng, i)).collect();
    // One deterministic compound corner: two kills + loss + duplication +
    // tight checkpoints, so the full recovery machinery composes in one run.
    schedules.push(format!(
        "name=compound,{FAULT_SHAPE},failtile=2.1@6,failtile=5.0@11,\
         drop=0E:0.3@7,dup=1E:0.25@9,ckpt=4"
    ));
    for schedule in &schedules {
        for threads in [1usize, 2, 4] {
            for width in [1usize, 8, 11] {
                let (bits, failed, recovery) = fault_run(schedule, threads, width);
                assert!(
                    failed > 0,
                    "{schedule}: scheduled tile kill never fired (t={threads} w={width})"
                );
                assert!(
                    recovery > 0,
                    "{schedule}: recovery was free (t={threads} w={width})"
                );
                assert_eq!(
                    bits, oracle,
                    "{schedule}: dosages diverged from the fault-free oracle \
                     (t={threads} w={width})"
                );
            }
        }
    }
}

#[test]
fn schedules_that_disconnect_surviving_boards_are_hard_errors() {
    // Killing every tile of the middle board on a 1x3 grid powers it off,
    // stranding board 2 from board 0 — a schedule the simulator could never
    // honour, so it must be rejected up front, not degraded into.
    let err = ScenarioSpec::parse(
        "name=stranded,boards=3,tiles=2,cores=1,threads=4,failtile=1.0@5,failtile=1.1@5",
    )
    .unwrap_err();
    assert!(err.contains("disconnect"), "{err}");
}

#[test]
fn analytic_tracks_des_at_the_design_space_corners() {
    // Deterministic edge cases the random draw may miss: a failed link
    // (reroute penalties on every diverted crossing) and a compound
    // worst-case (slow everywhere + one extra-slow hotspot + high latency).
    for spec in [
        ScenarioSpec::parse("name=failed,boards=8,tiles=2,cores=1,threads=4,fail=0E").unwrap(),
        ScenarioSpec::parse(
            "name=worst,boards=8,tiles=2,cores=1,threads=4,bw=0.25,lat=4,link=1E:bw=0.5,fail=2N",
        )
        .unwrap(),
    ] {
        assert_in_band(&spec);
    }
}
