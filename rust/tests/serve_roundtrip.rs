//! Integration: the serve acceptance test.
//!
//! N concurrent clients submit disjoint target sets through
//! `serve::Service`; every client's dosages must be **bit-identical** to a
//! direct single-request `ImputeSession` run with the same engine
//! configuration, for every `EngineSpec` (the XLA plane may be absent in
//! offline builds — then both paths must agree it is unavailable), with
//! coalescing both on and off.  The framed TCP transport must return the
//! same response bytes as the stdin JSONL frontend (volatile timing fields
//! scrubbed) for every engine.  Plus: the `bench-serve` CLI must emit a
//! `BENCH_serve.json` throughput baseline covering >= 2 worker-pool sizes.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use poets_impute::serve::{
    CoalescePolicy, ImputeRequest, PanelRegistry, RequestTargets, ServeConfig, Service,
};
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::util::json::Json;

const PANEL: &str = "synth:hap=8,mark=41,annot=0.1,seed=2024";
const N_CLIENTS: usize = 3;

fn serve_config(coalesce: bool) -> ServeConfig {
    let base = ServeConfig::default()
        .workers(3)
        .boards(2)
        .states_per_thread(8);
    if coalesce {
        base.coalesce(CoalescePolicy {
            max_batch_targets: 64,
            max_linger: Duration::from_millis(25),
        })
    } else {
        base.no_coalesce()
    }
}

#[test]
fn concurrent_clients_match_direct_sessions_bit_exactly() {
    for spec in EngineSpec::ALL {
        for coalesce in [false, true] {
            let registry = Arc::new(PanelRegistry::new());
            let panel = registry.resolve(PANEL).unwrap();
            // Disjoint per-client target sets (distinct seeds).
            let per_client: Vec<_> = (0..N_CLIENTS)
                .map(|c| panel.synthetic_targets(2, 100 + c as u64).unwrap())
                .collect();
            let cfg = serve_config(coalesce);
            let app = cfg.app.clone();
            let mapping = cfg.mapping;
            let service = Service::start(Arc::clone(&registry), cfg);

            let served: Vec<Result<_, String>> = thread::scope(|s| {
                let handles: Vec<_> = per_client
                    .iter()
                    .map(|targets| {
                        let service = &service;
                        let targets = targets.clone();
                        s.spawn(move || {
                            service.submit_wait(ImputeRequest::new(PANEL, spec, targets))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            for (c, result) in served.iter().enumerate() {
                let direct = ImputeSession::new(
                    Workload::from_shared(panel.panel_arc(), per_client[c].clone()).unwrap(),
                )
                .engine(spec)
                .app_config(app.clone())
                .mapping(mapping)
                .run();
                match (result, direct) {
                    (Ok(report), Ok(direct)) => {
                        assert_eq!(
                            report.dosages(),
                            &direct.dosages[..],
                            "{spec:?} coalesce={coalesce} client {c}: served dosages \
                             are not bit-identical to the direct session run"
                        );
                        assert_eq!(report.report.n_targets, 2);
                        assert!(report.coalesce_width >= 1);
                        if !coalesce {
                            assert_eq!(
                                report.coalesce_width, 1,
                                "coalescing off must never merge requests"
                            );
                        }
                    }
                    // Offline builds have no XLA runtime: both paths must
                    // agree the plane is unavailable.
                    (Err(se), Err(de)) if spec == EngineSpec::Xla => {
                        assert!(!se.is_empty() && !de.is_empty());
                    }
                    (r, d) => panic!(
                        "{spec:?} coalesce={coalesce} client {c}: serve and direct \
                         disagree on availability: served {r:?} vs direct {d:?}"
                    ),
                }
            }
            let stats = service.shutdown();
            assert_eq!(stats.accepted, N_CLIENTS as u64);
            assert_eq!(stats.completed + stats.failed, N_CLIENTS as u64);
        }
    }
}

#[test]
fn coalesced_burst_actually_merges_and_still_matches() {
    // Beyond bit-equality: under a single worker and a generous linger a
    // same-panel burst must actually share engine batches (width > 1), and
    // the answers must still be per-request exact.
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).unwrap();
    let cfg = ServeConfig::default()
        .workers(1)
        .boards(2)
        .states_per_thread(8)
        .coalesce(CoalescePolicy {
            max_batch_targets: 64,
            max_linger: Duration::from_millis(200),
        });
    let app = cfg.app.clone();
    let mapping = cfg.mapping;
    let service = Service::start(Arc::clone(&registry), cfg);

    let tickets: Vec<_> = (0..4)
        .map(|c| {
            service
                .submit(ImputeRequest::new(
                    PANEL,
                    EngineSpec::Rank1,
                    panel.synthetic_targets(1, 500 + c).unwrap(),
                ))
                .unwrap()
        })
        .collect();
    let reports: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let max_width = reports.iter().map(|r| r.coalesce_width).max().unwrap();
    assert!(max_width >= 2, "burst should coalesce (got width {max_width})");

    for (c, report) in reports.iter().enumerate() {
        let direct = ImputeSession::new(
            Workload::from_shared(
                panel.panel_arc(),
                panel.synthetic_targets(1, 500 + c as u64).unwrap(),
            )
            .unwrap(),
        )
        .engine(EngineSpec::Rank1)
        .app_config(app.clone())
        .mapping(mapping)
        .run()
        .unwrap();
        assert_eq!(report.dosages(), &direct.dosages[..], "client {c}");
    }
    service.shutdown();
}

#[test]
fn merged_event_waves_match_solo_sessions_bit_exactly() {
    // The wave-batching payoff in serve: a coalesced event-plane group
    // merges every member's targets into ONE lane-group sweep, and the
    // scattered-back responses must still be bit-identical to solo
    // ImputeSession runs (batch-width-invariant numerics).
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).unwrap();
    let cfg = ServeConfig::default()
        .workers(1)
        .boards(2)
        .states_per_thread(8)
        .coalesce(CoalescePolicy {
            max_batch_targets: 64,
            max_linger: Duration::from_millis(200),
        });
    let app = cfg.app.clone();
    let mapping = cfg.mapping;
    let service = Service::start(Arc::clone(&registry), cfg);

    let tickets: Vec<_> = (0..4)
        .map(|c| {
            service
                .submit(ImputeRequest::new(
                    PANEL,
                    EngineSpec::Event,
                    panel.synthetic_targets(2, 900 + c).unwrap(),
                ))
                .unwrap()
        })
        .collect();
    let reports: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let max_width = reports.iter().map(|r| r.coalesce_width).max().unwrap();
    assert!(max_width >= 2, "burst should coalesce (got width {max_width})");

    for (c, report) in reports.iter().enumerate() {
        let direct = ImputeSession::new(
            Workload::from_shared(
                panel.panel_arc(),
                panel.synthetic_targets(2, 900 + c as u64).unwrap(),
            )
            .unwrap(),
        )
        .engine(EngineSpec::Event)
        .app_config(app.clone())
        .mapping(mapping)
        .run()
        .unwrap();
        assert_eq!(
            report.dosages(),
            &direct.dosages[..],
            "merged wave changed client {c}'s dosages"
        );
        assert_eq!(report.report.n_targets, 2);
    }
    let stats = service.shutdown();
    assert!(
        stats.merged_waves >= 1,
        "no group actually merged targets into a wave: {stats:?}"
    );
}

#[test]
fn deferred_mint_requests_match_explicit_targets() {
    // synth_targets minting now runs in the worker pool; a deferred mint
    // must produce exactly what minting client-side and sending explicit
    // targets produces, and mint failures stay in-band per-request.
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).unwrap();
    let service = Service::start(
        Arc::clone(&registry),
        ServeConfig::default().workers(2).no_coalesce(),
    );
    let minted = service
        .submit_wait(ImputeRequest::new(
            PANEL,
            EngineSpec::Rank1,
            RequestTargets::Mint { count: 2, seed: 77 },
        ))
        .unwrap();
    let explicit = service
        .submit_wait(ImputeRequest::new(
            PANEL,
            EngineSpec::Rank1,
            panel.minted_targets(2, 77).unwrap(),
        ))
        .unwrap();
    assert_eq!(minted.dosages(), explicit.dosages());
    assert_eq!(minted.report.n_targets, 2);

    // An over-cap mint fails in the worker, in-band — not at admission,
    // and never by killing the worker.
    let err = service
        .submit_wait(ImputeRequest::new(
            PANEL,
            EngineSpec::Rank1,
            RequestTargets::Mint {
                count: usize::MAX / 2,
                seed: 0,
            },
        ))
        .unwrap_err();
    assert!(err.contains("exceeds"), "{err}");
    // A zero-wide mint is empty at admission time.
    let err = service
        .submit(ImputeRequest::new(
            PANEL,
            EngineSpec::Rank1,
            RequestTargets::Mint { count: 0, seed: 0 },
        ))
        .unwrap_err();
    assert!(err.starts_with("admission:"), "{err}");
    let stats = service.shutdown();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.rejected, 1);
}

#[test]
fn file_backed_panel_failures_are_in_band_serve_errors() {
    // A request naming a missing or corrupt vcf:/packed: path must come
    // back as a serve-error/v1 line — the worker survives and the stream
    // keeps serving (the same contract as admission: rejects).
    use poets_impute::serve::jsonl::serve_stream;

    let corrupt = std::env::temp_dir().join(format!(
        "poets-serve-corrupt-{}.ppnl",
        std::process::id()
    ));
    // A well-formed 32-byte header (magic, version 1, no flags, 4 x 11)
    // followed by garbage: passes the cheap pre-admission shape peek, then
    // fails the full read's integrity check.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"POETSPNL");
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&4u64.to_le_bytes());
    bytes.extend_from_slice(&11u64.to_le_bytes());
    bytes.extend_from_slice(&[0xAB; 120]);
    std::fs::write(&corrupt, &bytes).unwrap();
    let corrupt_spec = format!("packed:{}", corrupt.display());

    // Lines 1-3 fail in the worker (resolve — line 3's deferred mint also
    // resolves there now, never on the reader thread); line 4 must still
    // succeed.
    let l1 = r#"{"id":1,"panel":"packed:/nonexistent/cohort.ppnl","engine":"baseline","targets":[[0,1,-1]]}"#;
    let l2 = format!(
        r#"{{"id":2,"panel":"{corrupt_spec}","engine":"baseline","targets":[[0,1,-1]]}}"#
    );
    let l3 = r#"{"id":3,"panel":"vcf:/nonexistent/cohort.vcf","engine":"baseline","synth_targets":1}"#;
    let l4 = format!(r#"{{"id":4,"panel":"{PANEL}","engine":"rank1","synth_targets":1}}"#);
    let input = format!("{l1}\n{l2}\n{l3}\n{l4}\n");
    let service = poets_impute::serve::ShardedService::start(
        Arc::new(PanelRegistry::new()),
        ServeConfig::default().workers(2),
        1,
    );
    let mut out = Vec::new();
    let summary = serve_stream(&service, input.as_bytes(), &mut out).unwrap();
    let _ = std::fs::remove_file(&corrupt);
    service.shutdown();

    assert_eq!(summary.requests, 4);
    assert_eq!(summary.failed, 3);
    assert_eq!(summary.ok, 1);
    let lines: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).expect("every response line is valid JSON"))
        .collect();
    assert_eq!(lines.len(), 4);
    for (i, needle) in [
        (0, "cannot read"),
        (1, "checksum"), // corrupt .ppnl trips the integrity check
        (2, "cannot read"),
    ] {
        let j = &lines[i];
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "line {i}");
        assert_eq!(
            j.get("schema").unwrap().as_str(),
            Some("poets-impute/serve-error/v1"),
            "line {i}"
        );
        let err = j.get("error").unwrap().as_str().unwrap();
        assert!(err.contains(needle), "line {i}: {err}");
    }
    assert_eq!(lines[3].get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(lines[3].get("id").unwrap().as_i64(), Some(4));
}

/// Drop the fields that legitimately differ between two service runs
/// (wall-clock timings and worker/batch assignment); everything else must
/// be byte-identical across transports.
fn scrub_volatile(line: &str) -> String {
    let mut j = Json::parse(line).expect("response line parses");
    j.remove("timing");
    if let Some(serve) = j.get_mut("serve") {
        for key in ["request_id", "batch_id", "worker", "queue_wait_seconds"] {
            serve.remove(key);
        }
    }
    j.render()
}

#[test]
fn tcp_responses_match_stdin_jsonl_and_solo_sessions_for_every_engine() {
    // The wire contract: the framed TCP transport and the stdin JSONL
    // frontend are the same protocol.  For one request per EngineSpec the
    // response documents must be byte-identical after scrubbing volatile
    // timing/assignment fields, and the dosages must equal a solo
    // ImputeSession run exactly.
    use std::io::Write as _;
    use std::net::{Shutdown, TcpListener, TcpStream};

    use poets_impute::serve::ShardedService;
    use poets_impute::serve::net::{self, frame};

    let lines: Vec<String> = EngineSpec::ALL
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            format!(
                r#"{{"id":{},"panel":"{PANEL}","engine":"{}","synth_targets":2,"target_seed":{}}}"#,
                i + 1,
                spec.name(),
                40 + i
            )
        })
        .collect();

    // Leg 1: stdin JSONL through a 2-shard service.
    let stdin_svc = ShardedService::start(Arc::new(PanelRegistry::new()), serve_config(false), 2);
    let input = lines.join("\n") + "\n";
    let mut out = Vec::new();
    poets_impute::serve::jsonl::serve_stream(&stdin_svc, input.as_bytes(), &mut out).unwrap();
    stdin_svc.shutdown();
    let stdin_lines: Vec<String> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(stdin_lines.len(), lines.len());

    // Leg 2: the same bytes framed over TCP.
    let tcp_svc = Arc::new(ShardedService::start(
        Arc::new(PanelRegistry::new()),
        serve_config(false),
        2,
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = {
        let svc = Arc::clone(&tcp_svc);
        thread::spawn(move || net::serve_tcp(&svc, listener).unwrap())
    };
    let mut conn = TcpStream::connect(addr).unwrap();
    for line in &lines {
        frame::write_frame(&mut conn, line.as_bytes()).unwrap();
    }
    conn.flush().unwrap();
    conn.shutdown(Shutdown::Write).unwrap();
    let mut reader = std::io::BufReader::new(conn);
    let mut tcp_lines = Vec::new();
    loop {
        match frame::read_frame(&mut reader).unwrap() {
            frame::ReadFrame::Frame(payload) => {
                tcp_lines.push(String::from_utf8(payload).unwrap())
            }
            frame::ReadFrame::Eof => break,
        }
    }
    // Stop the accept loop so the server thread can be joined.
    let mut admin = TcpStream::connect(addr).unwrap();
    frame::write_frame(&mut admin, br#"{"shutdown":true}"#).unwrap();
    admin.flush().unwrap();
    admin.shutdown(Shutdown::Write).unwrap();
    let mut admin = std::io::BufReader::new(admin);
    while !matches!(frame::read_frame(&mut admin).unwrap(), frame::ReadFrame::Eof) {}
    server.join().unwrap();
    Arc::try_unwrap(tcp_svc).ok().unwrap().shutdown();

    assert_eq!(tcp_lines.len(), lines.len());
    for (i, (s, t)) in stdin_lines.iter().zip(&tcp_lines).enumerate() {
        assert_eq!(
            scrub_volatile(s),
            scrub_volatile(t),
            "request {i}: TCP response diverges from the stdin JSONL response"
        );
    }

    // Leg 3: solo ImputeSession runs with the same deferred-mint targets.
    let cfg = serve_config(false);
    let (app, mapping) = (cfg.app.clone(), cfg.mapping);
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).unwrap();
    for (i, spec) in EngineSpec::ALL.iter().enumerate() {
        let j = Json::parse(&stdin_lines[i]).unwrap();
        let direct = ImputeSession::new(
            Workload::from_shared(
                panel.panel_arc(),
                panel.minted_targets(2, 40 + i as u64).unwrap(),
            )
            .unwrap(),
        )
        .engine(*spec)
        .app_config(app.clone())
        .mapping(mapping)
        .run();
        match direct {
            Ok(direct) => {
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{spec:?}");
                let rows = j.get("dosages").unwrap().as_arr().unwrap();
                assert_eq!(rows.len(), direct.dosages.len(), "{spec:?}");
                for (t, row) in rows.iter().enumerate() {
                    let served: Vec<f64> = row
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|v| v.as_f64().unwrap())
                        .collect();
                    let want: Vec<f64> =
                        direct.dosages[t].iter().map(|&d| d as f64).collect();
                    assert_eq!(served, want, "{spec:?} target {t}");
                }
            }
            // Offline builds: the XLA plane errors identically everywhere.
            Err(_) => {
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(false), "{spec:?}");
            }
        }
    }
}

#[test]
fn request_spans_are_monotone_across_transports() {
    // Observability satellite: a request opting in with "spans":true gets a
    // monotone phase timeline on both the library and JSONL paths; requests
    // that do not opt in carry no spans (so the transport byte-equality
    // checks above are unaffected).
    use poets_impute::serve::ShardedService;

    // Library path: a coalesced event-plane burst with spans on.
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).unwrap();
    let service = Service::start(Arc::clone(&registry), serve_config(true));
    let tickets: Vec<_> = (0..3)
        .map(|c| {
            service
                .submit(
                    ImputeRequest::new(
                        PANEL,
                        EngineSpec::Event,
                        panel.synthetic_targets(1, 700 + c).unwrap(),
                    )
                    .with_spans(),
                )
                .unwrap()
        })
        .collect();
    for (c, t) in tickets.into_iter().enumerate() {
        let report = t.wait().unwrap();
        let span = report.span.expect("spans were requested");
        let stamps = [
            span.admitted_us,
            span.dequeued_us,
            span.minted_us,
            span.prepared_us,
            span.run_us,
            span.responded_us,
        ];
        assert!(
            stamps.windows(2).all(|w| w[0] <= w[1]),
            "client {c}: non-monotone span {stamps:?}"
        );
        assert_eq!(
            span.coalesced_with as usize, report.coalesce_width,
            "client {c}: span width disagrees with the report"
        );
    }
    service.shutdown();

    // JSONL path: spans surface as serve.spans only when requested.
    let svc = ShardedService::start(Arc::new(PanelRegistry::new()), serve_config(false), 1);
    let input = format!(
        "{{\"id\":1,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1,\"spans\":true}}\n\
         {{\"id\":2,\"panel\":\"{PANEL}\",\"engine\":\"rank1\",\"synth_targets\":1}}\n"
    );
    let mut out = Vec::new();
    poets_impute::serve::jsonl::serve_stream(&svc, input.as_bytes(), &mut out).unwrap();
    svc.shutdown();
    let lines: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 2);
    let sp = lines[0]
        .get("serve")
        .unwrap()
        .get("spans")
        .expect("id 1 opted in");
    let mut prev = 0i64;
    for key in [
        "admitted_us",
        "dequeued_us",
        "minted_us",
        "prepared_us",
        "run_us",
        "responded_us",
    ] {
        let v = sp.get(key).unwrap().as_i64().unwrap();
        assert!(v >= prev, "{key} regressed: {v} < {prev}");
        prev = v;
    }
    assert!(
        lines[1].get("serve").unwrap().get("spans").is_none(),
        "spans are strictly opt-in"
    );
}

#[test]
fn bench_serve_cli_emits_throughput_baseline() {
    let argv: Vec<String> = [
        "bench-serve",
        "--clients",
        "1,2",
        "--workers",
        "1,2",
        "--requests",
        "2",
        "--targets-per-request",
        "1",
        "--hap",
        "8",
        "--mark",
        "21",
        "--annot-ratio",
        "0.2",
        "--engine",
        "rank1",
        "--linger-ms",
        "1",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(poets_impute::cli::run(argv), 0);

    let text = std::fs::read_to_string("BENCH_serve.json").unwrap();
    let _ = std::fs::remove_file("BENCH_serve.json");
    let j = Json::parse(&text).unwrap();
    assert_eq!(
        j.get("schema").unwrap().as_str(),
        Some("poets-impute/bench-serve/v1")
    );
    let rows = j.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 8, "workers x clients x coalesce on/off");
    let workers: std::collections::BTreeSet<i64> = rows
        .iter()
        .map(|r| r.get("workers").unwrap().as_i64().unwrap())
        .collect();
    assert!(
        workers.len() >= 2,
        "baseline must cover >= 2 worker counts, got {workers:?}"
    );
    for r in rows {
        assert!(r.get("requests_per_s").unwrap().as_f64().unwrap() > 0.0);
        for key in ["p50_ms", "p99_ms", "mean_batch_width"] {
            assert!(r.get(key).unwrap().as_f64().is_some(), "row missing {key}");
        }
    }
}

#[test]
fn connect_bridge_reconnects_and_resubmits_only_unanswered_requests() {
    // Kill-and-reconnect for the `serve --connect` bridge: a scripted
    // server answers the first request, then drops the connection with the
    // second request still unanswered.  The bridge must reconnect (capped
    // backoff) and resubmit ONLY the unanswered request — the answered one
    // is never re-executed — then drain cleanly.
    use std::io::BufReader;
    use std::net::TcpListener;

    use poets_impute::serve::net::{self, frame};

    fn id_of(payload: &[u8]) -> i64 {
        Json::parse(std::str::from_utf8(payload).unwrap())
            .unwrap()
            .get("id")
            .unwrap()
            .as_i64()
            .unwrap()
    }

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let server = thread::spawn(move || -> Vec<Vec<i64>> {
        let mut seen = Vec::new();
        // Connection 1: read BOTH requests (so the close below is an
        // orderly FIN, not an RST that could destroy the buffered reply),
        // answer only the first, then drop the socket — a simulated crash
        // with request 2 in flight.
        {
            let (conn, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut w = conn;
            let mut ids = Vec::new();
            for _ in 0..2 {
                match frame::read_frame(&mut reader).unwrap() {
                    frame::ReadFrame::Frame(payload) => ids.push(id_of(&payload)),
                    frame::ReadFrame::Eof => panic!("bridge half-closed early"),
                }
            }
            let reply = format!("{{\"id\":{},\"ok\":true,\"leg\":1}}", ids[0]);
            frame::write_frame(&mut w, reply.as_bytes()).unwrap();
            seen.push(ids);
        }
        // Connection 2 (the reconnect): answer everything until EOF.
        {
            let (conn, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut w = conn;
            let mut ids = Vec::new();
            loop {
                match frame::read_frame(&mut reader).unwrap() {
                    frame::ReadFrame::Frame(payload) => {
                        let id = id_of(&payload);
                        ids.push(id);
                        let reply = format!("{{\"id\":{id},\"ok\":true,\"leg\":2}}");
                        frame::write_frame(&mut w, reply.as_bytes()).unwrap();
                    }
                    frame::ReadFrame::Eof => break,
                }
            }
            seen.push(ids);
        }
        seen
    });

    let input: &[u8] = b"{\"id\":1,\"probe\":true}\n{\"id\":2,\"probe\":true}\n";
    let mut out = Vec::new();
    let summary = net::bridge_jsonl(BufReader::new(input), &mut out, &addr.to_string()).unwrap();
    let seen = server.join().unwrap();

    assert_eq!(summary.reconnects, 1, "exactly one reconnect");
    assert_eq!(summary.responses, 2, "both requests answered");
    assert_eq!(seen[0], vec![1, 2], "first connection saw both requests");
    assert_eq!(
        seen[1],
        vec![2],
        "reconnect must resubmit only the unanswered request"
    );
    let lines: Vec<Json> = String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 2);
    assert_eq!(lines[0].get("id").unwrap().as_i64(), Some(1));
    assert_eq!(lines[0].get("leg").unwrap().as_i64(), Some(1));
    assert_eq!(lines[1].get("id").unwrap().as_i64(), Some(2));
    assert_eq!(lines[1].get("leg").unwrap().as_i64(), Some(2));
}
