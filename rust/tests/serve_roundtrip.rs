//! Integration: the serve acceptance test.
//!
//! N concurrent clients submit disjoint target sets through
//! `serve::Service`; every client's dosages must be **bit-identical** to a
//! direct single-request `ImputeSession` run with the same engine
//! configuration, for every `EngineSpec` (the XLA plane may be absent in
//! offline builds — then both paths must agree it is unavailable), with
//! coalescing both on and off.  Plus: the `bench-serve` CLI must emit a
//! `BENCH_serve.json` throughput baseline covering >= 2 worker-pool sizes.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use poets_impute::serve::{
    CoalescePolicy, ImputeRequest, PanelRegistry, ServeConfig, Service,
};
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::util::json::Json;

const PANEL: &str = "synth:hap=8,mark=41,annot=0.1,seed=2024";
const N_CLIENTS: usize = 3;

fn serve_config(coalesce: bool) -> ServeConfig {
    let base = ServeConfig::default()
        .workers(3)
        .boards(2)
        .states_per_thread(8);
    if coalesce {
        base.coalesce(CoalescePolicy {
            max_batch_targets: 64,
            max_linger: Duration::from_millis(25),
        })
    } else {
        base.no_coalesce()
    }
}

#[test]
fn concurrent_clients_match_direct_sessions_bit_exactly() {
    for spec in EngineSpec::ALL {
        for coalesce in [false, true] {
            let registry = Arc::new(PanelRegistry::new());
            let panel = registry.resolve(PANEL).unwrap();
            // Disjoint per-client target sets (distinct seeds).
            let per_client: Vec<_> = (0..N_CLIENTS)
                .map(|c| panel.synthetic_targets(2, 100 + c as u64).unwrap())
                .collect();
            let cfg = serve_config(coalesce);
            let app = cfg.app.clone();
            let mapping = cfg.mapping;
            let service = Service::start(Arc::clone(&registry), cfg);

            let served: Vec<Result<_, String>> = thread::scope(|s| {
                let handles: Vec<_> = per_client
                    .iter()
                    .map(|targets| {
                        let service = &service;
                        let targets = targets.clone();
                        s.spawn(move || {
                            service.submit_wait(ImputeRequest {
                                panel: PANEL.into(),
                                engine: spec,
                                targets,
                            })
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            for (c, result) in served.iter().enumerate() {
                let direct = ImputeSession::new(
                    Workload::from_shared(panel.panel_arc(), per_client[c].clone()).unwrap(),
                )
                .engine(spec)
                .app_config(app.clone())
                .mapping(mapping)
                .run();
                match (result, direct) {
                    (Ok(report), Ok(direct)) => {
                        assert_eq!(
                            report.dosages(),
                            &direct.dosages[..],
                            "{spec:?} coalesce={coalesce} client {c}: served dosages \
                             are not bit-identical to the direct session run"
                        );
                        assert_eq!(report.report.n_targets, 2);
                        assert!(report.coalesce_width >= 1);
                        if !coalesce {
                            assert_eq!(
                                report.coalesce_width, 1,
                                "coalescing off must never merge requests"
                            );
                        }
                    }
                    // Offline builds have no XLA runtime: both paths must
                    // agree the plane is unavailable.
                    (Err(se), Err(de)) if spec == EngineSpec::Xla => {
                        assert!(!se.is_empty() && !de.is_empty());
                    }
                    (r, d) => panic!(
                        "{spec:?} coalesce={coalesce} client {c}: serve and direct \
                         disagree on availability: served {r:?} vs direct {d:?}"
                    ),
                }
            }
            let stats = service.shutdown();
            assert_eq!(stats.accepted, N_CLIENTS as u64);
            assert_eq!(stats.completed + stats.failed, N_CLIENTS as u64);
        }
    }
}

#[test]
fn coalesced_burst_actually_merges_and_still_matches() {
    // Beyond bit-equality: under a single worker and a generous linger a
    // same-panel burst must actually share engine batches (width > 1), and
    // the answers must still be per-request exact.
    let registry = Arc::new(PanelRegistry::new());
    let panel = registry.resolve(PANEL).unwrap();
    let cfg = ServeConfig::default()
        .workers(1)
        .boards(2)
        .states_per_thread(8)
        .coalesce(CoalescePolicy {
            max_batch_targets: 64,
            max_linger: Duration::from_millis(200),
        });
    let app = cfg.app.clone();
    let mapping = cfg.mapping;
    let service = Service::start(Arc::clone(&registry), cfg);

    let tickets: Vec<_> = (0..4)
        .map(|c| {
            service
                .submit(ImputeRequest {
                    panel: PANEL.into(),
                    engine: EngineSpec::Rank1,
                    targets: panel.synthetic_targets(1, 500 + c).unwrap(),
                })
                .unwrap()
        })
        .collect();
    let reports: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    let max_width = reports.iter().map(|r| r.coalesce_width).max().unwrap();
    assert!(max_width >= 2, "burst should coalesce (got width {max_width})");

    for (c, report) in reports.iter().enumerate() {
        let direct = ImputeSession::new(
            Workload::from_shared(
                panel.panel_arc(),
                panel.synthetic_targets(1, 500 + c as u64).unwrap(),
            )
            .unwrap(),
        )
        .engine(EngineSpec::Rank1)
        .app_config(app.clone())
        .mapping(mapping)
        .run()
        .unwrap();
        assert_eq!(report.dosages(), &direct.dosages[..], "client {c}");
    }
    service.shutdown();
}

#[test]
fn bench_serve_cli_emits_throughput_baseline() {
    let argv: Vec<String> = [
        "bench-serve",
        "--clients",
        "1,2",
        "--workers",
        "1,2",
        "--requests",
        "2",
        "--targets-per-request",
        "1",
        "--hap",
        "8",
        "--mark",
        "21",
        "--annot-ratio",
        "0.2",
        "--engine",
        "rank1",
        "--linger-ms",
        "1",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(poets_impute::cli::run(argv), 0);

    let text = std::fs::read_to_string("BENCH_serve.json").unwrap();
    let _ = std::fs::remove_file("BENCH_serve.json");
    let j = Json::parse(&text).unwrap();
    assert_eq!(
        j.get("schema").unwrap().as_str(),
        Some("poets-impute/bench-serve/v1")
    );
    let rows = j.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 8, "workers x clients x coalesce on/off");
    let workers: std::collections::BTreeSet<i64> = rows
        .iter()
        .map(|r| r.get("workers").unwrap().as_i64().unwrap())
        .collect();
    assert!(
        workers.len() >= 2,
        "baseline must cover >= 2 worker counts, got {workers:?}"
    );
    for r in rows {
        assert!(r.get("requests_per_s").unwrap().as_f64().unwrap() > 0.0);
        for key in ["p50_ms", "p99_ms", "mean_batch_width"] {
            assert!(r.get(key).unwrap().as_f64().is_some(), "row missing {key}");
        }
    }
}
