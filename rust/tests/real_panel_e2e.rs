//! Integration: the real-data acceptance test.
//!
//! `examples/data/tiny.vcf` (40 phased bi-allelic sites, 8 haplotypes, one
//! chromosome) flows through the whole genomics front door: VCF parse →
//! bit-packed `.ppnl` → `packed:` registry resolution → mosaic targets →
//! windowed imputation stitched back to full width.  The fixture's blocks
//! of 10 sites are separated by 10 Mb gaps (τ = 1 recombination hotspots),
//! and the window geometry (length 30, overlap 20) puts window edges on
//! those gaps at unobserved markers — so the Li & Stephens chain carries no
//! information across a window boundary and the windowed run must match the
//! unwindowed run everywhere, not just deep in the cores.
//!
//! Bit-level guarantees asserted here: the packed round-trip is lossless
//! (alleles and f64 distances exact), a single-window plan reproduces the
//! unwindowed run bit-for-bit, and the windowed event plane is
//! bit-identical across host thread counts.  Cross-engine and
//! windowed-vs-full agreement hold at the planes' established tolerances.

use std::sync::Arc;

use poets_impute::genomics::packed::PackedPanel;
use poets_impute::genomics::stream::run_streamed;
use poets_impute::genomics::vcf;
use poets_impute::genomics::window::{WindowPlan, run_windowed};
use poets_impute::serve::{PanelRegistry, RegisteredPanel};
use poets_impute::session::{EngineSpec, ImputeSession, Workload, max_abs_dosage_diff};

const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/data/tiny.vcf");
const N_TARGETS: usize = 3;
const ANNOT: f64 = 0.25;

fn resolve_fixture() -> (PanelRegistry, Arc<RegisteredPanel>) {
    let registry = PanelRegistry::new();
    let panel = registry.resolve(&format!("vcf:{FIXTURE}")).unwrap();
    (registry, panel)
}

fn fixture_workload(panel: &RegisteredPanel) -> Workload {
    let cases = panel.mosaic_targets(N_TARGETS, ANNOT, 9).unwrap();
    Workload::from_shared_cases(panel.panel_arc(), cases).unwrap()
}

/// The per-window session knobs; the engine is passed to the runners
/// explicitly (they apply it after this closure, so the spec is
/// authoritative — see `genomics::window`).
fn configure(threads: usize) -> impl Fn(ImputeSession) -> ImputeSession {
    move |s: ImputeSession| s.boards(1).states_per_thread(8).threads(threads)
}

#[test]
fn vcf_ingest_pack_and_registry_roundtrip() {
    let parsed = vcf::load(FIXTURE).unwrap();
    assert_eq!(parsed.panel.n_hap(), 8);
    assert_eq!(parsed.panel.n_mark(), 40);
    assert_eq!(parsed.n_samples(), 4);
    assert_eq!(parsed.sites[0].chrom, "20");
    // Block structure: 10 Mb gaps every 10 markers (τ = 1 hotspots),
    // ~200 bp spacing inside blocks.
    for m in 1..40 {
        let d = parsed.panel.gen_dist(m);
        if m % 10 == 0 {
            assert!((d - 0.1).abs() < 1e-12, "gap distance at {m}: {d}");
        } else {
            assert!((d - 2e-6).abs() < 1e-12, "in-block distance at {m}: {d}");
        }
    }

    // Pack, write, resolve through the registry as `packed:`.
    let packed = PackedPanel::from_vcf(&parsed);
    assert_eq!(packed.packed_allele_bytes(), 8 * 5); // 40 bits -> 5 B/row
    let path = std::env::temp_dir().join(format!("poets-e2e-{}.ppnl", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    packed.write(&path).unwrap();

    let (_registry, from_packed) = {
        let registry = PanelRegistry::new();
        let p = registry.resolve(&format!("packed:{path}")).unwrap();
        (registry, p)
    };
    let _ = std::fs::remove_file(&path);
    // Lossless both ways: alleles and bit-exact distances survive the disk.
    for h in 0..8 {
        assert_eq!(
            from_packed.panel().haplotype(h),
            parsed.panel.haplotype(h),
            "haplotype {h}"
        );
    }
    for m in 0..40 {
        assert_eq!(
            from_packed.panel().gen_dist(m).to_bits(),
            parsed.panel.gen_dist(m).to_bits()
        );
    }
    // Site metadata survives the .ppnl round-trip.
    assert_eq!(from_packed.sites().unwrap(), &parsed.sites[..]);
}

#[test]
fn windowed_real_dosages_match_across_engines_and_the_full_run() {
    let (_registry, panel) = resolve_fixture();
    let wl = fixture_workload(&panel);
    // Window edges at markers 10 and 30 — hotspot boundaries where the
    // chain forgets its history, and *unobserved* markers on the 1-in-4
    // mosaic grid (a window applies no emission at its first marker, so an
    // exact match needs the full run to carry no evidence there either).
    let plan = WindowPlan::new(40, 30, 20).unwrap();
    assert_eq!(plan.len(), 2);
    assert_eq!(
        plan.windows().iter().map(|w| (w.start, w.end)).collect::<Vec<_>>(),
        vec![(0, 30), (10, 40)]
    );

    let full_base = configure(1)(ImputeSession::new(wl.clone()))
        .engine(EngineSpec::Baseline)
        .run()
        .unwrap();
    let full_event = configure(1)(ImputeSession::new(wl.clone()))
        .engine(EngineSpec::Event)
        .run()
        .unwrap();
    let win_base = run_windowed(&wl, &plan, EngineSpec::Baseline, configure(1)).unwrap();
    let win_event = run_windowed(&wl, &plan, EngineSpec::Event, configure(1)).unwrap();

    assert_eq!(win_base.dosages.len(), N_TARGETS);
    assert_eq!(win_base.dosages[0].len(), 40);
    assert_eq!(win_event.windows, Some(2));

    // The engines agree on the windowed pipeline exactly as tightly as the
    // repo's engine-equivalence tests demand unwindowed.
    let cross = max_abs_dosage_diff(&win_base.dosages, &win_event.dosages);
    assert!(cross <= 1e-3, "windowed baseline vs event: {cross:.2e}");

    // Hotspot-aligned windows: the stitched run tracks the full run within
    // f32 noise on every marker (the boundary condition is identical — in
    // exact arithmetic windowed == full, verified to 3e-16 in f64).
    let drift_base = max_abs_dosage_diff(&win_base.dosages, &full_base.dosages);
    assert!(drift_base <= 1e-4, "windowed baseline drifted {drift_base:.2e}");
    // Event bound by the triangle through the baseline runs: within the
    // 1e-3 engine tolerance of win_base, which equals full_base, which is
    // within 1e-3 of full_event.
    let drift_event = max_abs_dosage_diff(&win_event.dosages, &full_event.dosages);
    assert!(drift_event <= 2e-3, "windowed event drifted {drift_event:.2e}");

    // The windowed event plane keeps the execution-semantics contract:
    // bit-identical results for any host thread count.
    let win_event_mt = run_windowed(&wl, &plan, EngineSpec::Event, configure(4)).unwrap();
    assert_eq!(
        win_event.dosages, win_event_mt.dosages,
        "host thread count changed windowed numerics"
    );

    // Truth survived the pipeline: accuracy is re-scored on the stitch and
    // beats chance (mosaic targets are drawn from the panel itself).
    let acc = win_event.accuracy.expect("mosaic targets retain truth");
    assert!(acc.n_scored > 0);
    assert!(acc.concordance > 0.5, "concordance {}", acc.concordance);
}

#[test]
fn single_window_plan_reproduces_the_unwindowed_run_bit_for_bit() {
    let (_registry, panel) = resolve_fixture();
    let wl = fixture_workload(&panel);
    let plan = WindowPlan::new(40, 64, 0).unwrap();
    assert_eq!(plan.len(), 1);
    for spec in [EngineSpec::Baseline, EngineSpec::Event] {
        let windowed = run_windowed(&wl, &plan, spec, configure(1)).unwrap();
        let plain = configure(1)(ImputeSession::new(wl.clone()))
            .engine(spec)
            .run()
            .unwrap();
        assert_eq!(windowed.dosages, plain.dosages, "{spec:?}");
    }
}

#[test]
fn streamed_real_panel_matches_the_materialised_windowed_run() {
    // The chromosome-streaming path on the real fixture: same plan, same
    // engine, builder-thread slicing + rendezvous backpressure — and the
    // stitched report must still be bit-identical to the materialised
    // windowed runner (they share the stitch/merge code path).
    let (_registry, panel) = resolve_fixture();
    let wl = fixture_workload(&panel);
    let plan = WindowPlan::new(40, 30, 20).unwrap();
    let streamed = run_streamed(&wl, &plan, EngineSpec::Event, configure(1)).unwrap();
    let windowed = run_windowed(&wl, &plan, EngineSpec::Event, configure(1)).unwrap();
    assert_eq!(
        streamed.dosages, windowed.dosages,
        "streaming changed real-panel numerics"
    );
    let telemetry = streamed.stream.expect("streamed runs carry telemetry");
    assert_eq!(telemetry.windows_streamed, plan.len());
    assert!(
        telemetry.peak_resident_windows <= 2,
        "double-buffer bound violated: {}",
        telemetry.peak_resident_windows
    );
}
