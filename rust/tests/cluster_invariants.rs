//! Integration: simulator-level invariants of the POETS model — message
//! conservation, timing monotonicity, mapping-independence of results,
//! analytic-model agreement, and the E4 sync-overhead regime — driven
//! through the session API.

use poets_impute::imputation::analytic::{AppKind, Workload as AnalyticWorkload, predict};
use poets_impute::imputation::app::build_raw_graph;
use poets_impute::poets::costmodel::CostModel;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::workload::panelgen::PanelConfig;

fn workload(seed: u64, h: usize, m: usize, t: usize) -> Workload {
    let cfg = PanelConfig {
        n_hap: h,
        n_mark: m,
        maf: 0.2,
        annot_ratio: 0.2,
        seed,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, t)
}

fn run(wl: &Workload, boards: usize, spt: usize) -> ImputeReport {
    ImputeSession::new(wl.clone())
        .engine(EngineSpec::Event)
        .boards(boards)
        .states_per_thread(spt)
        .run()
        .unwrap()
}

#[test]
fn message_conservation_exact() {
    // Every multicast copy is delivered exactly once.  Wave batching: T ≤
    // LANES targets ride ONE chunk per (vertex, wave), so event copies
    // follow the per-WAVE closed form 2(M−1)H² + M(H−1), while delivered
    // lanes recover the per-target form T·(2(M−1)H² + M(H−1)) exactly.
    let (h, m, t) = (7usize, 13usize, 3usize);
    let out = run(&workload(1, h, m, t), 2, 4);
    let metrics = out.metrics.as_ref().unwrap();
    let per_wave = (2 * (m as u64 - 1) * (h as u64).pow(2)) + m as u64 * (h as u64 - 1);
    assert_eq!(metrics.copies_delivered, per_wave);
    assert_eq!(metrics.lanes_delivered, t as u64 * per_wave);
    assert_eq!(
        metrics.recv_handlers, per_wave,
        "every delivered copy runs exactly one handler"
    );
}

#[test]
fn results_independent_of_cluster_shape() {
    let wl = workload(2, 8, 40, 3);
    let a = run(&wl, 1, 16);
    let b = run(&wl, 48, 1);
    assert_eq!(a.dosages, b.dosages, "cluster shape changed numerics");
}

#[test]
fn more_boards_never_slower_at_fixed_softsched() {
    // Same panel, same states/thread, more boards → more cores/mailboxes →
    // simulated time must not increase (locality effects are second-order
    // next to serial-resource relief in this workload).
    let wl = workload(3, 16, 64, 6);
    let t1 = run(&wl, 1, 16).sim_seconds.unwrap();
    let t4 = run(&wl, 4, 4).sim_seconds.unwrap();
    assert!(
        t4 <= t1 * 1.05,
        "4 boards ({t4}s) slower than 1 board ({t1}s)"
    );
}

#[test]
fn sim_time_scales_with_targets() {
    let wl = workload(4, 8, 30, 24);
    let small = Workload::from_parts(wl.panel().clone(), wl.targets()[..6].to_vec());
    let few = run(&small, 1, 8).sim_seconds.unwrap();
    let many = run(&wl, 1, 8).sim_seconds.unwrap();
    // 24 vs 6 targets in one wave sweep: 3 chunk events per wave vs 1 and
    // 4x the lane arithmetic, but the same superstep count and the same
    // per-step barrier floor — strictly more time, far less than linear.
    assert!(many > few * 1.05, "few={few} many={many}");
    assert!(many < few * 4.0, "wave batching should amortise: few={few} many={many}");
}

#[test]
fn analytic_predictor_within_band_of_des() {
    // T ≳ M on one board; the session runs all 60 targets as one lane
    // group, so the predictor is evaluated in its wave regime.
    let des = run(&workload(5, 8, 24, 60), 1, 1);
    let pred = predict(
        &AnalyticWorkload {
            n_hap: 8,
            n_mark: 24,
            n_targets: 60,
            states_per_thread: 1,
            lane_width: 60,
            kind: AppKind::Raw,
        },
        &ClusterConfig::with_boards(1),
        &CostModel::default(),
    );
    let des_seconds = des.sim_seconds.unwrap();
    let ratio = pred.seconds / des_seconds;
    assert!(
        (0.3..3.0).contains(&ratio),
        "analytic {} vs DES {des_seconds} (x{ratio:.2})",
        pred.seconds,
    );
}

#[test]
fn barrier_fraction_reported() {
    let out = run(&workload(6, 8, 40, 10), 2, 8);
    let f = out.metrics.as_ref().unwrap().barrier_fraction();
    assert!(f > 0.0 && f < 0.9, "barrier fraction {f}");
}

#[test]
fn graph_memory_within_board_dram() {
    // The paper's capacity limit: panel + graph state must fit board DRAM.
    let wl = workload(7, 16, 100, 2);
    let graph = build_raw_graph(wl.panel(), wl.targets(), &Default::default());
    let cluster = ClusterConfig::with_boards(1);
    // Rough per-vertex footprint: device struct + shared dest lists.
    let bytes = graph.n_vertices() * 200 + graph.n_edges() as usize * 4;
    assert!(
        bytes < cluster.dram_per_board,
        "tiny panel must fit one board's DRAM"
    );
}

#[test]
fn deterministic_across_runs() {
    let wl = workload(8, 8, 30, 4);
    let a = run(&wl, 2, 8);
    let b = run(&wl, 2, 8);
    assert_eq!(a.dosages, b.dosages);
    let (am, bm) = (a.metrics.as_ref().unwrap(), b.metrics.as_ref().unwrap());
    assert_eq!(am.sim_cycles, bm.sim_cycles);
    assert_eq!(am.copies_delivered, bm.copies_delivered);
}
