//! Integration: simulator-level invariants of the POETS model — message
//! conservation, timing monotonicity, mapping-independence of results,
//! analytic-model agreement, and the E4 sync-overhead regime.

use poets_impute::imputation::analytic::{AppKind, Workload, predict};
use poets_impute::imputation::app::{RawAppConfig, build_raw_graph, run_raw};
use poets_impute::poets::costmodel::CostModel;
use poets_impute::poets::desim::SimConfig;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn workload(seed: u64, h: usize, m: usize, t: usize)
    -> (poets_impute::model::panel::ReferencePanel, Vec<poets_impute::model::panel::TargetHaplotype>) {
    let cfg = PanelConfig {
        n_hap: h,
        n_mark: m,
        maf: 0.2,
        annot_ratio: 0.2,
        seed,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(seed ^ 0xC1A0);
    let targets = generate_targets(&panel, &cfg, t, &mut rng)
        .into_iter()
        .map(|c| c.masked)
        .collect();
    (panel, targets)
}

fn app(boards: usize, spt: usize) -> RawAppConfig {
    RawAppConfig {
        cluster: ClusterConfig::with_boards(boards),
        states_per_thread: spt,
        sim: SimConfig::default(),
        ..RawAppConfig::default()
    }
}

#[test]
fn message_conservation_exact() {
    // Every multicast copy is delivered exactly once: counts follow the
    // closed form T·(2(M−1)H² + M(H−1)).
    let (h, m, t) = (7usize, 13usize, 3usize);
    let (panel, targets) = workload(1, h, m, t);
    let out = run_raw(&panel, &targets, &app(2, 4));
    let expected = t as u64
        * ((2 * (m as u64 - 1) * (h as u64).pow(2)) + m as u64 * (h as u64 - 1));
    assert_eq!(out.metrics.copies_delivered, expected);
    assert_eq!(
        out.metrics.recv_handlers, expected,
        "every delivered copy runs exactly one handler"
    );
}

#[test]
fn results_independent_of_cluster_shape() {
    let (panel, targets) = workload(2, 8, 40, 3);
    let a = run_raw(&panel, &targets, &app(1, 16));
    let b = run_raw(&panel, &targets, &app(48, 1));
    assert_eq!(a.dosages, b.dosages, "cluster shape changed numerics");
}

#[test]
fn more_boards_never_slower_at_fixed_softsched() {
    // Same panel, same states/thread, more boards → more cores/mailboxes →
    // simulated time must not increase (locality effects are second-order
    // next to serial-resource relief in this workload).
    let (panel, targets) = workload(3, 16, 64, 6);
    let t1 = run_raw(&panel, &targets, &app(1, 16)).sim_seconds;
    let t4 = run_raw(&panel, &targets, &app(4, 4)).sim_seconds;
    assert!(
        t4 <= t1 * 1.05,
        "4 boards ({t4}s) slower than 1 board ({t1}s)"
    );
}

#[test]
fn sim_time_scales_with_targets() {
    let (panel, targets) = workload(4, 8, 30, 24);
    let few = run_raw(&panel, &targets[..6].to_vec(), &app(1, 8)).sim_seconds;
    let many = run_raw(&panel, &targets, &app(1, 8)).sim_seconds;
    // 24 vs 6 targets in a pipeline of depth 30: sub-linear but strictly more.
    assert!(many > few * 1.2, "few={few} many={many}");
    assert!(many < few * 4.0, "pipelining should amortise: few={few} many={many}");
}

#[test]
fn analytic_predictor_within_band_of_des() {
    // Steady-state regime (T ≳ M) on one board.
    let (panel, targets) = workload(5, 8, 24, 60);
    let des = run_raw(&panel, &targets, &app(1, 1));
    let pred = predict(
        &Workload {
            n_hap: 8,
            n_mark: 24,
            n_targets: 60,
            states_per_thread: 1,
            kind: AppKind::Raw,
        },
        &ClusterConfig::with_boards(1),
        &CostModel::default(),
    );
    let ratio = pred.seconds / des.sim_seconds;
    assert!(
        (0.3..3.0).contains(&ratio),
        "analytic {} vs DES {} (x{ratio:.2})",
        pred.seconds,
        des.sim_seconds
    );
}

#[test]
fn barrier_fraction_reported() {
    let (panel, targets) = workload(6, 8, 40, 10);
    let out = run_raw(&panel, &targets, &app(2, 8));
    let f = out.metrics.barrier_fraction();
    assert!(f > 0.0 && f < 0.9, "barrier fraction {f}");
}

#[test]
fn graph_memory_within_board_dram() {
    // The paper's capacity limit: panel + graph state must fit board DRAM.
    let (panel, targets) = workload(7, 16, 100, 2);
    let graph = build_raw_graph(&panel, &targets, &Default::default());
    let cluster = ClusterConfig::with_boards(1);
    // Rough per-vertex footprint: device struct + shared dest lists.
    let bytes = graph.n_vertices() * 200 + graph.n_edges() as usize * 4;
    assert!(
        bytes < cluster.dram_per_board,
        "tiny panel must fit one board's DRAM"
    );
}

#[test]
fn deterministic_across_runs() {
    let (panel, targets) = workload(8, 8, 30, 4);
    let a = run_raw(&panel, &targets, &app(2, 8));
    let b = run_raw(&panel, &targets, &app(2, 8));
    assert_eq!(a.dosages, b.dosages);
    assert_eq!(a.metrics.sim_cycles, b.metrics.sim_cycles);
    assert_eq!(a.metrics.copies_delivered, b.metrics.copies_delivered);
}
