//! Property-based invariants (offline proptest substitute, util::prop):
//! randomised sweeps over panels, mappings and cluster shapes asserting the
//! model/simulator invariants that no example should ever violate.  Engine
//! runs go through the session API.

use poets_impute::genomics::packed::PackedPanel;
use poets_impute::genomics::window::{WindowPlan, stitch};
use poets_impute::graph::mapping::Mapping;
use poets_impute::graph::partition::{adjacency, bisect, edge_cut};
use poets_impute::imputation::app::build_raw_graph;
use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::interpolation::blends;
use poets_impute::model::panel::ReferencePanel;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::session::{EngineSpec, ImputeSession, Workload};
use poets_impute::util::prop::forall;
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn random_problem(
    rng: &mut Rng,
    max_h: usize,
    max_m: usize,
    n_targets: usize,
) -> (
    poets_impute::model::panel::ReferencePanel,
    Vec<poets_impute::workload::panelgen::TargetCase>,
) {
    let cfg = PanelConfig {
        n_hap: rng.range(2, max_h),
        n_mark: rng.range(2, max_m),
        maf: rng.uniform(0.05, 0.45),
        annot_ratio: rng.uniform(0.05, 0.5),
        seed: rng.next_u64(),
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut trng = Rng::new(rng.next_u64());
    let cases = generate_targets(&panel, &cfg, n_targets, &mut trng);
    (panel, cases)
}

fn random_workload(rng: &mut Rng, max_h: usize, max_m: usize, n_targets: usize) -> Workload {
    let (panel, cases) = random_problem(rng, max_h, max_m, n_targets);
    Workload::from_cases(panel, cases)
}

#[test]
fn prop_dosage_in_unit_interval_all_engines() {
    forall("dosage ∈ [0,1]", 25, |rng| {
        let (panel, cases) = random_problem(rng, 12, 40, 1);
        let target = &cases[0].masked;
        let b = Baseline::default();
        let dense: ImputeOut<f32> = b.impute(&panel, target, Method::DenseThreeLoop);
        let r1: ImputeOut<f32> = b.impute(&panel, target, Method::Rank1);
        for d in dense.dosage.iter().chain(&r1.dosage) {
            if !(-1e-5..=1.00001).contains(&(*d as f64)) {
                return Err(format!("dosage {d} out of range"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dense_equals_rank1() {
    forall("dense == rank1", 25, |rng| {
        let (panel, cases) = random_problem(rng, 12, 30, 1);
        let b = Baseline::default();
        let d: ImputeOut<f64> = b.impute(&panel, &cases[0].masked, Method::DenseThreeLoop);
        let r: ImputeOut<f64> = b.impute(&panel, &cases[0].masked, Method::Rank1);
        for (x, y) in d.dosage.iter().zip(&r.dosage) {
            if (x - y).abs() > 1e-9 {
                return Err(format!("{x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_event_driven_equals_baseline() {
    forall("event == baseline", 10, |rng| {
        let wl = random_workload(rng, 9, 24, 2);
        let boards = rng.range(1, 4);
        let spt = rng.range(1, 32);
        let out = ImputeSession::new(wl.clone())
            .engine(EngineSpec::Event)
            .boards(boards)
            .states_per_thread(spt)
            .run()
            .map_err(|e| format!("session: {e}"))?;
        let b = Baseline::default();
        for (t, target) in wl.targets().iter().enumerate() {
            let want: ImputeOut<f32> = b.impute(wl.panel(), target, Method::DenseThreeLoop);
            for m in 0..wl.panel().n_mark() {
                if (out.dosages[t][m] - want.dosage[m]).abs() >= 1e-3 {
                    return Err(format!(
                        "t={t} m={m}: {} vs {}",
                        out.dosages[t][m], want.dosage[m]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blends_are_valid_convex_weights() {
    forall("blend fracs ∈ [0,1], anchors exact", 40, |rng| {
        let (panel, cases) = random_problem(rng, 8, 60, 1);
        let anchors = cases[0].masked.annotated();
        if anchors.len() < 2 {
            return Ok(()); // degenerate: nothing to interpolate
        }
        let ws = blends(&panel, &anchors);
        if ws.len() != panel.n_mark() {
            return Err("blend length".into());
        }
        for (m, w) in ws.iter().enumerate() {
            if !(0.0..=1.0).contains(&w.frac) {
                return Err(format!("frac {} at {m}", w.frac));
            }
            if w.left + 1 >= anchors.len() {
                return Err(format!("left index {} out of range", w.left));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mapping_covers_all_vertices_within_cluster() {
    forall("mapping total and in-range", 40, |rng| {
        let n = rng.range(1, 2000);
        let spt = rng.range(1, 64);
        let cluster = ClusterConfig::with_boards(rng.range(1, 49));
        if n.div_ceil(spt) > cluster.total_threads() {
            return Ok(()); // would be rejected (tested elsewhere)
        }
        let m = Mapping::manual_2d(n, spt, &cluster);
        if m.n_vertices() != n {
            return Err("vertex count".into());
        }
        if m.max_load() > spt {
            return Err(format!("load {} > spt {spt}", m.max_load()));
        }
        Ok(())
    });
}

#[test]
fn prop_partitioner_balanced_and_no_worse_than_random() {
    forall("bisection balance", 15, |rng| {
        let (panel, cases) = random_problem(rng, 8, 30, 1);
        let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
        let g = build_raw_graph(&panel, &targets, &Default::default());
        let adj = adjacency(&g);
        let parts = rng.range(2, 9);
        let assign = bisect(&adj, parts);
        let mut counts = vec![0usize; parts];
        for &p in &assign {
            if p as usize >= parts {
                return Err(format!("part id {p} out of range"));
            }
            counts[p as usize] += 1;
        }
        let n = assign.len();
        let target = n / parts;
        for &c in &counts {
            if c > 2 * target + 2 {
                return Err(format!("imbalance {counts:?}"));
            }
        }
        // Sanity: cut no worse than round-robin's.
        let rr: Vec<u32> = (0..n).map(|v| (v % parts) as u32).collect();
        if edge_cut(&adj, &assign) > edge_cut(&adj, &rr) {
            return Err("worse than round-robin".into());
        }
        Ok(())
    });
}

#[test]
fn prop_route_lengths_symmetric_and_bounded() {
    use poets_impute::poets::noc::Noc;
    forall("route symmetry", 60, |rng| {
        let boards = rng.range(1, 49);
        let c = ClusterConfig::with_boards(boards);
        let a = rng.range(0, boards);
        let b = rng.range(0, boards);
        let ab = Noc::board_route(&c, a, b).len();
        let ba = Noc::board_route(&c, b, a).len();
        if ab != ba {
            return Err(format!("asymmetric {a}->{b}: {ab} vs {ba}"));
        }
        let (gx, gy) = c.board_grid;
        if ab > gx + gy {
            return Err(format!("route too long: {ab}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_metrics_consistent() {
    forall("metrics consistency", 8, |rng| {
        let wl = random_workload(rng, 8, 20, 2);
        let spt = rng.range(1, 16);
        let out = ImputeSession::new(wl)
            .engine(EngineSpec::Event)
            .boards(2)
            .states_per_thread(spt)
            .run()
            .map_err(|e| format!("session: {e}"))?;
        let m = out.metrics.as_ref().unwrap();
        if m.copies_delivered != m.recv_handlers {
            return Err("copies != handlers".into());
        }
        if m.sim_cycles == 0 || m.steps == 0 {
            return Err("empty run".into());
        }
        if m.max_core_busy > m.sim_cycles {
            return Err("core busier than time".into());
        }
        if m.step_durations.len() as u64 != m.steps {
            return Err("step records mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mapping_strategies_valid_and_shuffled_is_a_permutation() {
    use poets_impute::graph::mapping::MappingStrategy;
    forall("mapping strategies valid; shuffled permutes manual-2d", 15, |rng| {
        let (panel, cases) = random_problem(rng, 9, 24, 2);
        let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
        let g = build_raw_graph(&panel, &targets, &Default::default());
        let n = g.n_vertices();
        let cluster = ClusterConfig::with_boards(rng.range(1, 5));
        // Keep the graph mappable at this soft-scheduling factor.
        let spt = rng.range(1, 9).max(n.div_ceil(cluster.total_threads()));
        let seed = rng.next_u64();

        // Every strategy must yield a complete, in-range thread assignment.
        let strategies = [
            MappingStrategy::Manual2d,
            MappingStrategy::Partitioned,
            MappingStrategy::Shuffled { seed },
        ];
        for strategy in strategies {
            let m = strategy.build(&g, spt, &cluster);
            if m.n_vertices() != n {
                return Err(format!("{}: vertex count", strategy.name()));
            }
            if m.n_threads_used() == 0 || m.n_threads_used() > cluster.total_threads() {
                return Err(format!(
                    "{}: {} threads used",
                    strategy.name(),
                    m.n_threads_used()
                ));
            }
            for v in 0..n {
                let t = m.thread_of(v as u32).0 as usize;
                if t >= cluster.total_threads() {
                    return Err(format!(
                        "{}: vertex {v} on out-of-range thread {t}",
                        strategy.name()
                    ));
                }
            }
        }

        // Shuffled is the manual packing randomly permuted: same thread
        // multiset (so same device set and identical load shape), just
        // scattered — and deterministic under a fixed seed.
        let manual = MappingStrategy::Manual2d.build(&g, spt, &cluster);
        let shuffled = MappingStrategy::Shuffled { seed }.build(&g, spt, &cluster);
        let sorted_ids = |m: &Mapping| {
            let mut ids: Vec<u32> = (0..n).map(|v| m.thread_of(v as u32).0).collect();
            ids.sort_unstable();
            ids
        };
        if sorted_ids(&manual) != sorted_ids(&shuffled) {
            return Err("shuffled is not a permutation of manual-2d".into());
        }
        if manual.max_load() != shuffled.max_load()
            || manual.n_threads_used() != shuffled.n_threads_used()
        {
            return Err("permutation changed the load shape".into());
        }
        let again = MappingStrategy::Shuffled { seed }.build(&g, spt, &cluster);
        let assignment = |m: &Mapping| -> Vec<u32> {
            (0..n).map(|v| m.thread_of(v as u32).0).collect()
        };
        if assignment(&shuffled) != assignment(&again) {
            return Err("shuffled mapping is not deterministic under a fixed seed".into());
        }
        Ok(())
    });
}

#[test]
fn prop_packed_panel_roundtrip_lossless_at_ragged_widths() {
    forall("pack/encode/decode/unpack is lossless", 60, |rng| {
        // Widths deliberately hit n_mark % 8 != 0 most of the time, so row
        // padding is exercised alongside whole-byte rows.
        let n_hap = rng.range(2, 12);
        let n_mark = rng.range(2, 48);
        let mut alleles = vec![0u8; n_hap * n_mark];
        for a in alleles.iter_mut() {
            if rng.chance(0.35) {
                *a = 1;
            }
        }
        let mut gen_dist = vec![0.0];
        for _ in 1..n_mark {
            gen_dist.push(rng.uniform(1e-7, 1e-5));
        }
        let panel = ReferencePanel::new(n_hap, n_mark, alleles, gen_dist);
        let packed = PackedPanel::from_panel(&panel);
        if packed.packed_allele_bytes() != n_hap * n_mark.div_ceil(8) {
            return Err(format!(
                "{}x{n_mark}: packed to {} bytes",
                n_hap,
                packed.packed_allele_bytes()
            ));
        }
        let back = PackedPanel::decode(&packed.encode()).map_err(|e| format!("decode: {e}"))?;
        let unpacked = back.to_panel();
        for h in 0..n_hap {
            if unpacked.haplotype(h) != panel.haplotype(h) {
                return Err(format!("haplotype {h} changed"));
            }
        }
        for m in 0..n_mark {
            if unpacked.gen_dist(m).to_bits() != panel.gen_dist(m).to_bits() {
                return Err(format!("gen_dist[{m}] not bit-exact"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_window_plan_covers_all_markers_with_consistent_overlaps() {
    forall("windows cover; cores partition; stitch routes cores", 60, |rng| {
        let n_mark = rng.range(2, 300);
        let w = rng.range(2, 64);
        let eff = w.min(n_mark);
        let v = rng.range(0, eff);
        let plan = WindowPlan::new(n_mark, w, v)?;
        let ws = plan.windows();
        if ws[0].start != 0 || ws[ws.len() - 1].end != n_mark {
            return Err(format!("span {:?}..{:?}", ws[0], ws[ws.len() - 1]));
        }
        let mut prev_core_end = 0usize;
        for (i, win) in ws.iter().enumerate() {
            if win.len() != eff {
                return Err(format!("window {i} has length {}", win.len()));
            }
            if i > 0 {
                let prev = ws[i - 1];
                if prev.start >= win.start {
                    return Err(format!("starts not increasing at {i}"));
                }
                if prev.end < win.start {
                    return Err(format!("coverage gap before window {i}"));
                }
            }
            // Cores: nonempty, inside their window, and an exact partition.
            if win.core_start != prev_core_end
                || win.core_start >= win.core_end
                || win.core_start < win.start
                || win.core_end > win.end
            {
                return Err(format!("bad core in window {i}: {win:?}"));
            }
            prev_core_end = win.core_end;
        }
        if prev_core_end != n_mark {
            return Err(format!("cores end at {prev_core_end}, not {n_mark}"));
        }
        // Stitch must read every core from its own window: fill window i's
        // dosages with the constant i and check the stitched row.
        let per: Vec<Vec<Vec<f32>>> = (0..ws.len())
            .map(|i| vec![vec![i as f32; eff]])
            .collect();
        let full = stitch(&plan, &per).map_err(|e| format!("stitch: {e}"))?;
        for (i, win) in ws.iter().enumerate() {
            for m in win.core_start..win.core_end {
                if full[0][m] != i as f32 {
                    return Err(format!("marker {m} stitched from the wrong window"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_single_window_stitch_is_identity() {
    forall("stitch of a 1-window split is identity", 40, |rng| {
        let n_mark = rng.range(2, 100);
        let n_targets = rng.range(1, 4);
        let plan = WindowPlan::new(n_mark, n_mark + rng.range(0, 50), 0)?;
        if plan.len() != 1 {
            return Err(format!("{} windows for a full-width plan", plan.len()));
        }
        let dosages: Vec<Vec<f32>> = (0..n_targets)
            .map(|_| (0..n_mark).map(|_| rng.f64() as f32).collect())
            .collect();
        let full = stitch(&plan, std::slice::from_ref(&dosages))
            .map_err(|e| format!("stitch: {e}"))?;
        if full != dosages {
            return Err("identity stitch changed the dosages".into());
        }
        Ok(())
    });
}
