//! Integration: the execution-semantics contract of the parallel delivery
//! engine — host thread count must never change anything observable except
//! host wall-clock.  Runs both event planes through the session API at
//! threads = 1, 2, 8 over three seeds and asserts bit-identical dosages plus
//! identical event/step accounting (the superstep barrier makes the
//! equivalence exact, not approximate — see `poets::desim` module docs).
//!
//! Since PR 5 the contract has a second axis: the wave-batched event plane
//! must be bit-identical across **batch widths** too — width 1 is exactly
//! the per-target plane the paper describes, so batched runs at any width
//! and any host thread count must reproduce its dosages bit for bit (the
//! canonical sender-order reduce in `imputation::vertex` makes the f32 sum
//! order a property of the model, not of event timing).

use poets_impute::genomics::stream::run_streamed;
use poets_impute::genomics::window::{WindowPlan, run_windowed_threads};
use poets_impute::imputation::msg::LANES;
use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::workload::panelgen::PanelConfig;

const SEEDS: [u64; 3] = [11, 29, 4242];
const THREADS: [usize; 3] = [1, 2, 8];

fn workload(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize, annot_ratio: f64) -> Workload {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.2,
        annot_ratio,
        seed,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, n_targets)
}

fn run(engine: EngineSpec, workload: &Workload, threads: usize) -> ImputeReport {
    ImputeSession::new(workload.clone())
        .engine(engine)
        .boards(2)
        .states_per_thread(4)
        .threads(threads)
        .run()
        .expect("event planes are always available")
}

/// Everything observable about a run that must be thread-count invariant.
fn fingerprint(report: &ImputeReport) -> (Vec<Vec<u32>>, u64, u64, u64, u64, u64) {
    // Compare dosages bit-exactly via their raw representation so an assert
    // failure shows the differing bits rather than rounded decimals.
    let bits: Vec<Vec<u32>> = report
        .dosages
        .iter()
        .map(|row| row.iter().map(|d| d.to_bits()).collect())
        .collect();
    let m = report.metrics.as_ref().expect("event planes report metrics");
    (
        bits,
        m.sim_cycles,
        m.sends,
        m.copies_delivered,
        m.recv_handlers,
        m.steps,
    )
}

#[test]
fn raw_app_is_thread_count_invariant() {
    for &seed in &SEEDS {
        let wl = workload(seed, 8, 24, 3, 0.2);
        let reference = fingerprint(&run(EngineSpec::Event, &wl, 1));
        for &threads in &THREADS[1..] {
            let got = fingerprint(&run(EngineSpec::Event, &wl, threads));
            assert_eq!(
                reference, got,
                "raw app diverged at seed={seed} threads={threads}"
            );
        }
    }
}

#[test]
fn interp_app_is_thread_count_invariant() {
    for &seed in &SEEDS {
        let wl = workload(seed, 6, 41, 2, 0.1);
        let reference = fingerprint(&run(EngineSpec::Interp, &wl, 1));
        for &threads in &THREADS[1..] {
            let got = fingerprint(&run(EngineSpec::Interp, &wl, threads));
            assert_eq!(
                reference, got,
                "interp app diverged at seed={seed} threads={threads}"
            );
        }
    }
}

#[test]
fn step_timeline_is_fully_accounted() {
    // Satellite invariant: recorded step durations cover the whole simulated
    // timeline (superstep 0 and the final step-handler tail included).
    let wl = workload(7, 8, 20, 2, 0.2);
    for &threads in &THREADS {
        let report = run(EngineSpec::Event, &wl, threads);
        let m = report.metrics.as_ref().unwrap();
        assert_eq!(
            m.step_durations.iter().sum::<u64>(),
            m.sim_cycles,
            "timeline gap at threads={threads}"
        );
    }
}

/// Dosage bits only — event accounting legitimately differs across widths
/// (that's the point of batching), so cross-width comparisons use this.
fn dosage_bits(report: &ImputeReport) -> Vec<Vec<u32>> {
    report
        .dosages
        .iter()
        .map(|row| row.iter().map(|d| d.to_bits()).collect())
        .collect()
}

fn run_batched(
    engine: EngineSpec,
    workload: &Workload,
    width: usize,
    threads: usize,
) -> ImputeReport {
    ImputeSession::new(workload.clone())
        .engine(engine)
        .boards(2)
        .states_per_thread(4)
        .threads(threads)
        .batch(width)
        .run()
        .expect("event planes are always available")
}

#[test]
fn raw_wave_batching_is_width_and_thread_invariant() {
    // Widths straddle the SoA chunk boundary: 1 (the per-target plane),
    // LANES-1, LANES (one full chunk) and LANES+3 (two chunks per wave).
    let wl = workload(17, 8, 24, LANES + 3, 0.2);
    let per_target = run_batched(EngineSpec::Event, &wl, 1, 1);
    let reference = dosage_bits(&per_target);
    for &width in &[1usize, LANES - 1, LANES, LANES + 3] {
        let base = run_batched(EngineSpec::Event, &wl, width, 1);
        assert_eq!(
            dosage_bits(&base),
            reference,
            "raw plane diverged from per-target events at width={width}"
        );
        for &threads in &[2usize, 4] {
            let got = run_batched(EngineSpec::Event, &wl, width, threads);
            assert_eq!(
                fingerprint(&got),
                fingerprint(&base),
                "raw plane diverged at width={width} threads={threads}"
            );
        }
    }
}

#[test]
fn interp_wave_batching_is_width_and_thread_invariant() {
    let wl = workload(19, 6, 41, LANES + 3, 0.1);
    let per_target = run_batched(EngineSpec::Interp, &wl, 1, 1);
    let reference = dosage_bits(&per_target);
    for &width in &[1usize, LANES - 1, LANES, LANES + 3] {
        let base = run_batched(EngineSpec::Interp, &wl, width, 1);
        assert_eq!(
            dosage_bits(&base),
            reference,
            "interp plane diverged from per-target events at width={width}"
        );
        for &threads in &[2usize, 4] {
            let got = run_batched(EngineSpec::Interp, &wl, width, threads);
            assert_eq!(
                fingerprint(&got),
                fingerprint(&base),
                "interp plane diverged at width={width} threads={threads}"
            );
        }
    }
}

#[test]
fn batched_waves_deliver_fewer_events_per_target() {
    // The perf claim behind the wave: a full-lane batch services every
    // target of a chunk with ONE event, so delivered events per target
    // drop by ~LANES while delivered lanes stay exactly constant.
    let wl = workload(23, 8, 24, LANES, 0.2);
    let narrow = run_batched(EngineSpec::Event, &wl, 1, 1);
    let wide = run_batched(EngineSpec::Event, &wl, LANES, 1);
    let (nm, wm) = (
        narrow.metrics.as_ref().unwrap(),
        wide.metrics.as_ref().unwrap(),
    );
    assert_eq!(nm.lanes_delivered, wm.lanes_delivered, "same per-target work");
    assert!(
        wm.copies_delivered * 2 <= nm.copies_delivered,
        "width {LANES} must at least halve delivered events: {} vs {}",
        wm.copies_delivered,
        nm.copies_delivered
    );
    assert_eq!(
        nm.copies_delivered, nm.lanes_delivered,
        "width 1 is the per-target plane: one lane per event"
    );
}

#[test]
fn streamed_windows_are_width_and_thread_invariant() {
    // Satellite: chromosome streaming keeps BOTH axes of the contract.
    // For every host thread count and batch width the streamed run must be
    // bit-identical to the materialised windowed runner — same dosage bits
    // AND same event/step accounting per stitched report.
    let wl = workload(31, 8, 40, LANES + 9, 0.25);
    let plan = WindowPlan::new(40, 26, 19).unwrap();
    assert!(plan.len() > 1, "need a multi-window plan");
    for &threads in &[1usize, 2, 4] {
        for &width in &[1usize, LANES - 1, LANES, LANES + 9] {
            let cfg = move |s: ImputeSession| {
                s.boards(2).states_per_thread(4).threads(threads).batch(width)
            };
            let streamed = run_streamed(&wl, &plan, EngineSpec::Event, cfg).unwrap();
            let windowed =
                run_windowed_threads(&wl, &plan, EngineSpec::Event, threads, cfg).unwrap();
            assert_eq!(
                fingerprint(&streamed),
                fingerprint(&windowed),
                "stream diverged at threads={threads} width={width}"
            );
            let t = streamed.stream.expect("streamed runs carry telemetry");
            assert_eq!(t.windows_streamed, plan.len());
            assert!(t.peak_resident_windows <= 2, "peak {}", t.peak_resident_windows);
        }
    }
}

#[test]
fn single_window_stream_reproduces_the_unwindowed_session() {
    // One window covering the whole axis: streaming must collapse to the
    // plain session bit for bit (the stitch is the identity).
    let wl = workload(37, 8, 24, LANES + 3, 0.25);
    let plan = WindowPlan::new(24, 64, 0).unwrap();
    assert_eq!(plan.len(), 1);
    for &threads in &[1usize, 2, 4] {
        let cfg =
            move |s: ImputeSession| s.boards(2).states_per_thread(4).threads(threads);
        let streamed = run_streamed(&wl, &plan, EngineSpec::Event, cfg).unwrap();
        let plain = cfg(ImputeSession::new(wl.clone()))
            .engine(EngineSpec::Event)
            .run()
            .unwrap();
        assert_eq!(
            dosage_bits(&streamed),
            dosage_bits(&plain),
            "single-window stream diverged at threads={threads}"
        );
        assert_eq!(streamed.stream.unwrap().peak_resident_windows, 1);
    }
}

#[test]
fn oversubscribed_threads_are_safe() {
    // More workers than tiles with work: the engine clamps and stays exact.
    let wl = workload(13, 6, 16, 2, 0.2);
    let reference = fingerprint(&run(EngineSpec::Event, &wl, 1));
    let got = fingerprint(&run(EngineSpec::Event, &wl, 64));
    assert_eq!(reference, got);
}
