//! Integration: the execution-semantics contract of the parallel delivery
//! engine — host thread count must never change anything observable except
//! host wall-clock.  Runs both event planes through the session API at
//! threads = 1, 2, 8 over three seeds and asserts bit-identical dosages plus
//! identical event/step accounting (the superstep barrier makes the
//! equivalence exact, not approximate — see `poets::desim` module docs).

use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::workload::panelgen::PanelConfig;

const SEEDS: [u64; 3] = [11, 29, 4242];
const THREADS: [usize; 3] = [1, 2, 8];

fn workload(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize, annot_ratio: f64) -> Workload {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.2,
        annot_ratio,
        seed,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, n_targets)
}

fn run(engine: EngineSpec, workload: &Workload, threads: usize) -> ImputeReport {
    ImputeSession::new(workload.clone())
        .engine(engine)
        .boards(2)
        .states_per_thread(4)
        .threads(threads)
        .run()
        .expect("event planes are always available")
}

/// Everything observable about a run that must be thread-count invariant.
fn fingerprint(report: &ImputeReport) -> (Vec<Vec<u32>>, u64, u64, u64, u64, u64) {
    // Compare dosages bit-exactly via their raw representation so an assert
    // failure shows the differing bits rather than rounded decimals.
    let bits: Vec<Vec<u32>> = report
        .dosages
        .iter()
        .map(|row| row.iter().map(|d| d.to_bits()).collect())
        .collect();
    let m = report.metrics.as_ref().expect("event planes report metrics");
    (
        bits,
        m.sim_cycles,
        m.sends,
        m.copies_delivered,
        m.recv_handlers,
        m.steps,
    )
}

#[test]
fn raw_app_is_thread_count_invariant() {
    for &seed in &SEEDS {
        let wl = workload(seed, 8, 24, 3, 0.2);
        let reference = fingerprint(&run(EngineSpec::Event, &wl, 1));
        for &threads in &THREADS[1..] {
            let got = fingerprint(&run(EngineSpec::Event, &wl, threads));
            assert_eq!(
                reference, got,
                "raw app diverged at seed={seed} threads={threads}"
            );
        }
    }
}

#[test]
fn interp_app_is_thread_count_invariant() {
    for &seed in &SEEDS {
        let wl = workload(seed, 6, 41, 2, 0.1);
        let reference = fingerprint(&run(EngineSpec::Interp, &wl, 1));
        for &threads in &THREADS[1..] {
            let got = fingerprint(&run(EngineSpec::Interp, &wl, threads));
            assert_eq!(
                reference, got,
                "interp app diverged at seed={seed} threads={threads}"
            );
        }
    }
}

#[test]
fn step_timeline_is_fully_accounted() {
    // Satellite invariant: recorded step durations cover the whole simulated
    // timeline (superstep 0 and the final step-handler tail included).
    let wl = workload(7, 8, 20, 2, 0.2);
    for &threads in &THREADS {
        let report = run(EngineSpec::Event, &wl, threads);
        let m = report.metrics.as_ref().unwrap();
        assert_eq!(
            m.step_durations.iter().sum::<u64>(),
            m.sim_cycles,
            "timeline gap at threads={threads}"
        );
    }
}

#[test]
fn oversubscribed_threads_are_safe() {
    // More workers than tiles with work: the engine clamps and stays exact.
    let wl = workload(13, 6, 16, 2, 0.2);
    let reference = fingerprint(&run(EngineSpec::Event, &wl, 1));
    let got = fingerprint(&run(EngineSpec::Event, &wl, 64));
    assert_eq!(reference, got);
}
