//! Integration: the execution-semantics contract of the parallel delivery
//! engine — host thread count must never change anything observable except
//! host wall-clock.  Runs both applications at threads = 1, 2, 8 over three
//! seeds and asserts bit-identical dosages plus identical event/step
//! accounting (the superstep barrier makes the equivalence exact, not
//! approximate — see `poets::desim` module docs).

use poets_impute::imputation::app::{EventRunResult, RawAppConfig, run_raw};
use poets_impute::imputation::interp_app::run_interp;
use poets_impute::model::panel::{ReferencePanel, TargetHaplotype};
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

const SEEDS: [u64; 3] = [11, 29, 4242];
const THREADS: [usize; 3] = [1, 2, 8];

fn problem(
    seed: u64,
    n_hap: usize,
    n_mark: usize,
    n_targets: usize,
    annot_ratio: f64,
) -> (ReferencePanel, Vec<TargetHaplotype>) {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.2,
        annot_ratio,
        seed,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(seed ^ 0xE91A);
    let targets = generate_targets(&panel, &cfg, n_targets, &mut rng)
        .into_iter()
        .map(|c| c.masked)
        .collect();
    (panel, targets)
}

fn cfg(threads: usize) -> RawAppConfig {
    RawAppConfig {
        cluster: ClusterConfig::with_boards(2),
        states_per_thread: 4,
        ..RawAppConfig::default()
    }
    .with_threads(threads)
}

/// Everything observable about a run that must be thread-count invariant.
fn fingerprint(out: &EventRunResult) -> (Vec<Vec<u32>>, u64, u64, u64, u64, u64) {
    // Compare dosages bit-exactly via their raw representation so an assert
    // failure shows the differing bits rather than rounded decimals.
    let bits: Vec<Vec<u32>> = out
        .dosages
        .iter()
        .map(|row| row.iter().map(|d| d.to_bits()).collect())
        .collect();
    (
        bits,
        out.metrics.sim_cycles,
        out.metrics.sends,
        out.metrics.copies_delivered,
        out.metrics.recv_handlers,
        out.metrics.steps,
    )
}

#[test]
fn raw_app_is_thread_count_invariant() {
    for &seed in &SEEDS {
        let (panel, targets) = problem(seed, 8, 24, 3, 0.2);
        let reference = fingerprint(&run_raw(&panel, &targets, &cfg(1)));
        for &threads in &THREADS[1..] {
            let got = fingerprint(&run_raw(&panel, &targets, &cfg(threads)));
            assert_eq!(
                reference, got,
                "raw app diverged at seed={seed} threads={threads}"
            );
        }
    }
}

#[test]
fn interp_app_is_thread_count_invariant() {
    for &seed in &SEEDS {
        let (panel, targets) = problem(seed, 6, 41, 2, 0.1);
        let reference = fingerprint(&run_interp(&panel, &targets, &cfg(1)));
        for &threads in &THREADS[1..] {
            let got = fingerprint(&run_interp(&panel, &targets, &cfg(threads)));
            assert_eq!(
                reference, got,
                "interp app diverged at seed={seed} threads={threads}"
            );
        }
    }
}

#[test]
fn step_timeline_is_fully_accounted() {
    // Satellite invariant: recorded step durations cover the whole simulated
    // timeline (superstep 0 and the final step-handler tail included).
    let (panel, targets) = problem(7, 8, 20, 2, 0.2);
    for &threads in &THREADS {
        let out = run_raw(&panel, &targets, &cfg(threads));
        assert_eq!(
            out.metrics.step_durations.iter().sum::<u64>(),
            out.metrics.sim_cycles,
            "timeline gap at threads={threads}"
        );
    }
}

#[test]
fn oversubscribed_threads_are_safe() {
    // More workers than tiles with work: the engine clamps and stays exact.
    let (panel, targets) = problem(13, 6, 16, 2, 0.2);
    let reference = fingerprint(&run_raw(&panel, &targets, &cfg(1)));
    let got = fingerprint(&run_raw(&panel, &targets, &cfg(64)));
    assert_eq!(reference, got);
}
