//! Integration: the event-driven linear-interpolation plane vs the baseline
//! interpolation pipeline vs the raw plane (accuracy preservation), driven
//! through the session API.

use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::interpolation::impute_interp;
use poets_impute::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use poets_impute::workload::panelgen::PanelConfig;

fn workload(seed: u64, n_hap: usize, n_mark: usize, n: usize) -> Workload {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.15,
        annot_ratio: 0.1,
        seed,
        ..PanelConfig::default()
    };
    Workload::synthetic(&cfg, n)
}

fn run(engine: EngineSpec, wl: &Workload, spt: usize) -> ImputeReport {
    ImputeSession::new(wl.clone())
        .engine(engine)
        .boards(2)
        .states_per_thread(spt)
        .run()
        .expect("event planes are always available")
}

#[test]
fn event_interp_matches_baseline_interp_across_shapes() {
    for &(seed, h, m) in &[(1u64, 6usize, 41usize), (2, 12, 61), (3, 4, 101)] {
        let wl = workload(seed, h, m, 2);
        let out = run(EngineSpec::Interp, &wl, 1);
        let b = Baseline::default();
        for (t, target) in wl.targets().iter().enumerate() {
            let want: ImputeOut<f32> =
                impute_interp(&b, wl.panel(), target, Method::DenseThreeLoop);
            for mk in 0..m {
                assert!(
                    (out.dosages[t][mk] - want.dosage[mk]).abs() < 2e-3,
                    "seed={seed} H={h} M={m} t={t} mk={mk}: {} vs {}",
                    out.dosages[t][mk],
                    want.dosage[mk]
                );
            }
        }
    }
}

#[test]
fn interp_accuracy_within_tolerance_of_raw() {
    // Paper §5.3: "significant performance improvement in exchange for a
    // negligible impact on the accuracy of the results".
    let wl = workload(10, 16, 201, 6);
    let raw = run(EngineSpec::Event, &wl, 16);
    let itp = run(EngineSpec::Interp, &wl, 2);

    let raw_acc = raw.accuracy.expect("synthetic workload has truth");
    let itp_acc = itp.accuracy.expect("synthetic workload has truth");
    assert!(raw_acc.concordance > 0.85, "raw {raw_acc:?}");
    assert!(
        itp_acc.concordance > raw_acc.concordance - 0.03,
        "interp accuracy dropped: {} vs {}",
        itp_acc.concordance,
        raw_acc.concordance
    );
}

#[test]
fn interp_message_and_time_economics() {
    // §6.3: message count drops by ~the section size; simulated time follows.
    let wl = workload(11, 10, 201, 3);
    let raw = run(EngineSpec::Event, &wl, 8);
    let itp = run(EngineSpec::Interp, &wl, 1);
    let raw_m = raw.metrics.as_ref().unwrap();
    let itp_m = itp.metrics.as_ref().unwrap();
    let msg_ratio = raw_m.copies_delivered as f64 / itp_m.copies_delivered as f64;
    assert!(msg_ratio > 4.0, "copies ratio {msg_ratio}");
    assert!(
        itp.sim_seconds.unwrap() < raw.sim_seconds.unwrap() / 2.0,
        "interp {:?} vs raw {:?}",
        itp.sim_seconds,
        raw.sim_seconds
    );
}

#[test]
fn anchor_columns_match_raw_model_closely() {
    // At annotated columns the interpolated pipeline runs the HMM (with
    // accumulated distances); its dosages there track the full model.
    let wl = workload(12, 8, 101, 2);
    let raw = run(EngineSpec::Event, &wl, 8);
    let itp = run(EngineSpec::Interp, &wl, 1);
    for (t, target) in wl.targets().iter().enumerate() {
        for &a in &target.annotated() {
            assert!(
                (raw.dosages[t][a] - itp.dosages[t][a]).abs() < 5e-2,
                "anchor {a} target {t}: raw {} vs interp {}",
                raw.dosages[t][a],
                itp.dosages[t][a]
            );
        }
    }
}
