//! Integration: the event-driven linear-interpolation app vs the baseline
//! interpolation pipeline vs the raw model (accuracy preservation).

use poets_impute::imputation::app::{RawAppConfig, run_raw};
use poets_impute::imputation::interp_app::run_interp;
use poets_impute::model::accuracy;
use poets_impute::model::baseline::{Baseline, ImputeOut, Method};
use poets_impute::model::interpolation::impute_interp;
use poets_impute::poets::topology::ClusterConfig;
use poets_impute::util::rng::Rng;
use poets_impute::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

fn workload(
    seed: u64,
    n_hap: usize,
    n_mark: usize,
    n: usize,
) -> (
    poets_impute::model::panel::ReferencePanel,
    Vec<poets_impute::workload::panelgen::TargetCase>,
) {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.15,
        annot_ratio: 0.1,
        seed,
        ..PanelConfig::default()
    };
    let panel = generate_panel(&cfg);
    let mut rng = Rng::new(seed ^ 0x17E9);
    let cases = generate_targets(&panel, &cfg, n, &mut rng);
    (panel, cases)
}

fn app(spt: usize) -> RawAppConfig {
    RawAppConfig {
        cluster: ClusterConfig::with_boards(2),
        states_per_thread: spt,
        ..RawAppConfig::default()
    }
}

#[test]
fn event_interp_matches_baseline_interp_across_shapes() {
    for &(seed, h, m) in &[(1u64, 6usize, 41usize), (2, 12, 61), (3, 4, 101)] {
        let (panel, cases) = workload(seed, h, m, 2);
        let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
        let out = run_interp(&panel, &targets, &app(1));
        let b = Baseline::default();
        for (t, target) in targets.iter().enumerate() {
            let want: ImputeOut<f32> =
                impute_interp(&b, &panel, target, Method::DenseThreeLoop);
            for mk in 0..m {
                assert!(
                    (out.dosages[t][mk] - want.dosage[mk]).abs() < 2e-3,
                    "seed={seed} H={h} M={m} t={t} mk={mk}: {} vs {}",
                    out.dosages[t][mk],
                    want.dosage[mk]
                );
            }
        }
    }
}

#[test]
fn interp_accuracy_within_tolerance_of_raw() {
    // Paper §5.3: "significant performance improvement in exchange for a
    // negligible impact on the accuracy of the results".
    let (panel, cases) = workload(10, 16, 201, 6);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
    let raw = run_raw(&panel, &targets, &app(16));
    let itp = run_interp(&panel, &targets, &app(2));

    let agg = |dosages: &[Vec<f32>]| {
        let accs: Vec<_> = cases
            .iter()
            .zip(dosages)
            .map(|(c, d)| accuracy::score(d, &c.truth, &c.masked))
            .collect();
        accuracy::aggregate(&accs)
    };
    let raw_acc = agg(&raw.dosages);
    let itp_acc = agg(&itp.dosages);
    assert!(raw_acc.concordance > 0.85, "raw {raw_acc:?}");
    assert!(
        itp_acc.concordance > raw_acc.concordance - 0.03,
        "interp accuracy dropped: {} vs {}",
        itp_acc.concordance,
        raw_acc.concordance
    );
}

#[test]
fn interp_message_and_time_economics() {
    // §6.3: message count drops by ~the section size; simulated time follows.
    let (panel, cases) = workload(11, 10, 201, 3);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
    let raw = run_raw(&panel, &targets, &app(8));
    let itp = run_interp(&panel, &targets, &app(1));
    let msg_ratio = raw.metrics.copies_delivered as f64 / itp.metrics.copies_delivered as f64;
    assert!(msg_ratio > 4.0, "copies ratio {msg_ratio}");
    assert!(
        itp.sim_seconds < raw.sim_seconds / 2.0,
        "interp {} vs raw {}",
        itp.sim_seconds,
        raw.sim_seconds
    );
}

#[test]
fn anchor_columns_match_raw_model_closely() {
    // At annotated columns the interpolated pipeline runs the HMM (with
    // accumulated distances); its dosages there track the full model.
    let (panel, cases) = workload(12, 8, 101, 2);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
    let raw = run_raw(&panel, &targets, &app(8));
    let itp = run_interp(&panel, &targets, &app(1));
    for (t, target) in targets.iter().enumerate() {
        for &a in &target.annotated() {
            assert!(
                (raw.dosages[t][a] - itp.dosages[t][a]).abs() < 5e-2,
                "anchor {a} target {t}: raw {} vs interp {}",
                raw.dosages[t][a],
                itp.dosages[t][a]
            );
        }
    }
}
