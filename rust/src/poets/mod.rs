//! The POETS cluster substrate — paper §4.
//!
//! A cycle-approximate functional + timing simulator of the 48-FPGA RISC-V
//! NoC cluster: topology ([`topology`]), calibrated cost model
//! ([`costmodel`]), inter-board NoC ([`noc`]), tile mailboxes ([`mailbox`]),
//! hardware multicast ([`multicast`]), termination detection
//! ([`termination`]), the discrete-event core ([`desim`]), run metrics
//! ([`metrics`]), heterogeneous what-if cluster models ([`scenario`]) and
//! the fault-tolerance plane ([`fault`]: checkpoint/remap/replay plus
//! loss-tolerant delivery).
//!
//! DESIGN.md §1 records why simulation preserves the paper's relative claims:
//! every figure compares POETS wall-clock against x86 wall-clock, and the
//! mechanisms those shapes come from (mailbox fan-in serialisation, multicast
//! amortisation, link bandwidth, handler cost at 210 MHz, thread occupancy
//! under soft-scheduling) are each modelled explicitly.

pub mod capacity;
pub mod costmodel;
pub mod desim;
pub mod event;
pub mod fault;
pub mod mailbox;
pub mod metrics;
pub mod multicast;
pub mod noc;
pub mod scenario;
pub mod termination;
pub mod topology;

pub use costmodel::CostModel;
pub use desim::{SimConfig, Simulator};
pub use metrics::SimMetrics;
pub use scenario::ScenarioSpec;
pub use topology::{ClusterConfig, ThreadId};
