//! Termination detection — Tinsel's hardware idle-detection wave [22].
//!
//! The real cluster runs a distributed wave: every thread votes
//! "no more messages to send"; when the wave completes with no activity seen,
//! a global *step* signal fires (used here, as in the paper, to time-step the
//! globally-synchronous imputation pipeline).  The simulator reaches the same
//! decision point when its event heap drains; this module charges the wave's
//! time cost and aggregates the application's halt votes.
//!
//! The paper measures the synchronisation penalty at ~3 % of the average
//! timestep — `overhead_fraction` lets experiments verify our model lands in
//! that regime (see EXPERIMENTS.md E4).

use super::costmodel::CostModel;

/// Outcome of one termination-detection round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepDecision {
    /// Time at which the step signal reaches every thread.
    pub step_at: u64,
    /// Whether the application halted (all devices voted halt and no sends
    /// were buffered).
    pub halted: bool,
}

/// Run one detection round: the fabric quiesced at `quiesce_at`; the wave
/// then costs `cost.barrier(n_threads)` cycles.
pub fn detect(
    quiesce_at: u64,
    n_threads: usize,
    all_voted_halt: bool,
    sends_buffered: usize,
    cost: &CostModel,
) -> StepDecision {
    StepDecision {
        step_at: quiesce_at + cost.barrier(n_threads),
        halted: all_voted_halt && sends_buffered == 0,
    }
}

/// Fraction of a step spent in the detection wave.
pub fn overhead_fraction(step_duration: u64, n_threads: usize, cost: &CostModel) -> f64 {
    if step_duration == 0 {
        return 0.0;
    }
    cost.barrier(n_threads) as f64 / step_duration as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_adds_barrier_cost() {
        let cost = CostModel::default();
        let d = detect(1_000, 49_152, false, 5, &cost);
        assert_eq!(d.step_at, 1_000 + cost.barrier(49_152));
        assert!(!d.halted);
    }

    #[test]
    fn halt_requires_votes_and_empty_sends() {
        let cost = CostModel::default();
        assert!(!detect(0, 64, true, 1, &cost).halted);
        assert!(!detect(0, 64, false, 0, &cost).halted);
        assert!(detect(0, 64, true, 0, &cost).halted);
    }

    #[test]
    fn overhead_fraction_sane() {
        let cost = CostModel::default();
        // At the paper's Fig 12 operating point a step is ~800k cycles; the
        // wave must land in the paper's measured ~3% regime.
        let f = overhead_fraction(813_000, 49_152, &cost);
        assert!((0.005..0.10).contains(&f), "{f}");
        assert_eq!(overhead_fraction(0, 64, &cost), 0.0);
    }
}
