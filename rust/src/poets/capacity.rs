//! Board-memory capacity model — paper §6.3.
//!
//! "The limiting factor is the memory required to store the reference
//! panel": each board's 4 GB DRAM holds its shard of the panel, the vertex
//! state, edge (multicast) tables and the Tinsel runtime overhead.  This
//! module prices a panel against a cluster and reproduces the paper's
//! forward-looking claims:
//!
//! * genuine reference panels (HapMap3-scale chr-1: ~1,000 haplotypes ×
//!   ~112k markers ≈ 1.1e8 states) need a POETS cluster **~16× larger** than
//!   the current 48-board machine;
//! * the next-generation (Stratix-10) cluster — ~6.5× threads, 2× clock,
//!   8× DRAM/board, 2× memory bandwidth, 10× inter-board bandwidth — closes
//!   most of that gap.

use super::topology::ClusterConfig;

/// Per-entity byte costs on the real machine (derivations in comments).
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// Bytes of DRAM per panel state resident on a board: allele label,
    /// τ/transition constants, α/β accumulators, pending rings, POLite
    /// device descriptor. The paper's vertices are "loaded with" reference
    /// base, haplotype, marker number and genetic distance (§5.1).
    pub bytes_per_state: usize,
    /// Bytes per vertex for edge/multicast tables (shared per column but
    /// charged amortised per state, as Tinsel stores per-thread tables).
    pub bytes_per_state_edges: usize,
    /// Fixed Tinsel/POLite runtime reservation per board.
    pub runtime_reserve: usize,
    /// Fraction of DRAM usable for application data.
    pub usable_fraction: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel {
            // allele(1) + a_same/a_diff f32(8) + next pair (8) + acc α/β +
            // counters (16) + ring slots ≈ 2×8 avg (16) + descriptor (15).
            bytes_per_state: 64,
            // dest-list entry share + mailbox routing table share.
            bytes_per_state_edges: 16,
            runtime_reserve: 256 << 20, // code, stacks, host buffers
            usable_fraction: 0.9,
        }
    }
}

/// Capacity verdict for a panel on a cluster.
#[derive(Clone, Copy, Debug)]
pub struct CapacityReport {
    pub states: u64,
    pub bytes_needed: u64,
    pub bytes_available: u64,
    pub fits: bool,
    /// How many times larger (in boards) the cluster must be to fit.
    pub scale_factor_needed: f64,
}

/// Price `states` panel states against `cluster` under `mem`.
pub fn capacity(states: u64, cluster: &ClusterConfig, mem: &MemoryModel) -> CapacityReport {
    let per_state = (mem.bytes_per_state + mem.bytes_per_state_edges) as u64;
    let bytes_needed = states * per_state;
    let per_board =
        (cluster.dram_per_board as f64 * mem.usable_fraction) as u64 - mem.runtime_reserve as u64;
    let bytes_available = per_board * cluster.n_boards as u64;
    CapacityReport {
        states,
        bytes_needed,
        bytes_available,
        fits: bytes_needed <= bytes_available,
        scale_factor_needed: bytes_needed as f64 / bytes_available as f64,
    }
}

/// A genuine modern reference panel, chromosome-1 slice: 1000-Genomes scale
/// (~5,008 haplotypes × ~6.4M chr-1 variants ≈ 3.2e10 states).  At ~80 B of
/// board DRAM per state this is what makes the current 48-board cluster
/// ~16× too small — the paper's §6.3 claim.
pub const GENUINE_PANEL_STATES: u64 = 5_008 * 6_400_000;

/// The next-generation Stratix-10 cluster of §6.3.
pub fn stratix10_next_gen() -> ClusterConfig {
    let base = ClusterConfig::poets_48();
    ClusterConfig {
        // ~6.5x hardware threads via more tiles per board.
        tiles_per_board: base.tiles_per_board * 13 / 2, // 104 tiles ≈ 6.5x
        tile_mesh: (13, 8),
        clock_hz: base.clock_hz * 2.0,    // 2x core frequency
        dram_per_board: base.dram_per_board * 8, // 8x DRAM per board
        ..base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn current_cluster_needs_about_16x_for_genuine_panels() {
        // The paper's §6.3 claim, reproduced by the memory model.
        let r = capacity(
            GENUINE_PANEL_STATES,
            &ClusterConfig::poets_48(),
            &MemoryModel::default(),
        );
        assert!(!r.fits);
        assert!(
            (8.0..32.0).contains(&r.scale_factor_needed),
            "scale factor {} not ~16x",
            r.scale_factor_needed
        );
    }

    #[test]
    fn small_panels_fit() {
        let r = capacity(2_000_000, &ClusterConfig::poets_48(), &MemoryModel::default());
        assert!(r.fits, "{r:?}");
    }

    #[test]
    fn next_gen_closes_most_of_the_gap() {
        let mem = MemoryModel::default();
        let now = capacity(GENUINE_PANEL_STATES, &ClusterConfig::poets_48(), &mem);
        let next = capacity(GENUINE_PANEL_STATES, &stratix10_next_gen(), &mem);
        assert!(next.scale_factor_needed < now.scale_factor_needed / 7.0);
        assert!(
            next.scale_factor_needed < 3.0,
            "next-gen still {}x short",
            next.scale_factor_needed
        );
    }

    #[test]
    fn next_gen_spec_matches_paper_ratios() {
        let base = ClusterConfig::poets_48();
        let next = stratix10_next_gen();
        let thread_ratio = next.total_threads() as f64 / base.total_threads() as f64;
        assert!((6.0..7.0).contains(&thread_ratio), "{thread_ratio}");
        assert_eq!(next.clock_hz, base.clock_hz * 2.0);
        assert_eq!(next.dram_per_board, base.dram_per_board * 8);
    }

    #[test]
    fn capacity_scales_linearly_in_boards() {
        let mem = MemoryModel::default();
        let one = capacity(1_000_000, &ClusterConfig::with_boards(1), &mem);
        let four = capacity(1_000_000, &ClusterConfig::with_boards(4), &mem);
        assert!((four.bytes_available as f64 / one.bytes_available as f64 - 4.0).abs() < 1e-9);
    }
}
