//! Fault plane: deterministic tile-failure remap-and-replay and
//! loss-tolerant inter-board delivery.
//!
//! A [`ScenarioSpec`] fault schedule compiles into a [`FaultPlan`] the
//! simulator consults from its **serial** phases only, so every fault
//! decision — which superstep a tile dies at, which link crossing is
//! dropped or duplicated — is a function of the schedule and the event
//! stream alone, never of host thread count or wave width.
//!
//! ## Tile failure: checkpoint, remap, replay
//!
//! While un-fired tile failures remain, the simulator takes a
//! barrier-aligned checkpoint every `ckpt` supersteps (default
//! [`DEFAULT_CKPT_INTERVAL`]): the superstep number, the sends pending at
//! the barrier, outstanding retransmissions, and every device's serialised
//! state ([`crate::graph::device::Device::snapshot`]).  Checkpoint capture
//! itself is charged nothing — the model assumes the fabric DMAs tile SRAM
//! to board DRAM behind the barrier — but **recovery** is charged in full:
//! when a tile dies, its resident vertices are remapped round-robin onto
//! the surviving tiles, device state is reloaded from the last checkpoint
//! ([`RESTORE_BASE_CYCLES`] plus [`RESTORE_CYCLES_PER_BYTE`] per snapshot
//! byte), and every superstep between the checkpoint and the failure is
//! re-executed on the remapped cluster.  Simulated time never rolls back;
//! replayed supersteps and the restore penalty accumulate into
//! `SimMetrics::{replayed_supersteps, recovery_cycles}`.
//!
//! Remap preserves results bit-exactly because the imputation planes
//! reduce wave arrivals in canonical sender order (`imputation::wave`):
//! dosages are a function of the graph, not of vertex placement.
//!
//! Tile death kills compute, not routing — a board with dead tiles still
//! forwards NoC traffic through its switch.  The exception is a board whose
//! tiles *all* die: it is assumed powered off for replacement, switch
//! included, so schedules that would strand surviving boards behind it
//! (possibly together with failed links) are rejected at validation time
//! (`ScenarioSpec::validate_for`, error contains "disconnect").
//!
//! ## Loss-tolerant delivery: NACK/retransmit and duplicate suppression
//!
//! `drop=LINK:p@seed` / `dup=LINK:p@seed` attach an independent seeded
//! Bernoulli stream to an inter-board link.  Every group crossing consults
//! the streams of the links on its route (drop wins over duplicate):
//!
//! * **Dropped** crossings still occupy the links (the bits were sent) but
//!   never reach the destination mailbox.  The barrier's sequence-number
//!   audit detects the gap — every arrival carries a per-(sender,
//!   superstep) sequence number — and NACKs the sender, which retransmits
//!   at the next superstep's dispatch.  Retransmissions are **unicast**:
//!   the NACK names the missing destinations, so the re-send goes
//!   point-to-point and loses the multicast amortisation (and is charged
//!   [`NACK_PENALTY_CYCLES`] of round-trip latency per copy).  Keying
//!   retransmissions by destination *vertex* rather than multicast-group
//!   index also keeps them valid across a tile-failure remap, which
//!   rebuilds the group table.  A retransmission may itself be dropped;
//!   it is retried until delivered (`p < 1` is enforced at validation).
//! * **Duplicated** crossings deliver normally plus a spurious second copy
//!   flagged [`crate::poets::event::FLAG_DUP`]; the destination mailbox
//!   recognises the repeated sequence number and discards it after one
//!   ingress slot of detection work ([`Mailbox::suppress_dup`]) — no
//!   handler runs, so duplicates are timing-only noise.
//!
//! Because waves wait for *all* expected arrivals before reducing, a
//! retransmission landing a superstep late is functionally invisible:
//! dosages under any drop/dup schedule are bit-identical to the
//! fault-free run (`tests/scenario_lab.rs` asserts this across thread
//! counts and wave widths).
//!
//! [`Mailbox::suppress_dup`]: crate::poets::mailbox::Mailbox::suppress_dup

use std::collections::HashSet;

use crate::graph::device::{PortId, VertexId};
use crate::util::rng::Rng;

use super::noc::LinkId;
use super::scenario::ScenarioSpec;
use super::topology::ClusterConfig;

/// Checkpoint cadence (supersteps) when the scenario does not set `ckpt=K`.
pub const DEFAULT_CKPT_INTERVAL: u64 = 16;

/// Fixed cycles to fault in a checkpoint and re-seat remapped threads
/// (barrier extension while survivors re-synchronise).
pub const RESTORE_BASE_CYCLES: u64 = 2_000;

/// Cycles per snapshot byte reloaded from board DRAM at 210 MHz.
pub const RESTORE_CYCLES_PER_BYTE: u64 = 1;

/// Round-trip latency charged to each retransmitted copy: the barrier-time
/// NACK travelling back to the sender plus protocol handling at both ends.
pub const NACK_PENALTY_CYCLES: u64 = 360;

/// Outcome of one inter-board group crossing under the loss models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrossingFate {
    /// Delivered intact.
    Deliver,
    /// Lost in flight: the destination never sees it this superstep.
    Drop,
    /// Delivered, plus a spurious second copy the mailbox must suppress.
    Dup,
}

/// One outstanding retransmission: `msg` still owed to `dests`, re-sent
/// unicast by `src` at the next superstep's dispatch.  `port` records the
/// original send's port for provenance — routing is per destination vertex.
#[derive(Clone, Debug)]
pub struct Retransmit<M> {
    pub src: VertexId,
    pub port: PortId,
    pub msg: M,
    pub dests: Vec<VertexId>,
}

/// A barrier-aligned recovery point: everything `Simulator::run` needs to
/// re-enter the superstep loop at `step` — the sends pending at that
/// barrier, retransmissions still owed, and each device's serialised state
/// (`bytes[offsets[v]..offsets[v + 1]]` is vertex `v`'s snapshot).
pub struct Checkpoint<M> {
    pub step: u64,
    pub pending: Vec<(VertexId, PortId, M)>,
    pub retrans: Vec<Retransmit<M>>,
    pub bytes: Vec<u8>,
    pub offsets: Vec<u32>,
}

impl<M> Checkpoint<M> {
    /// Device-state bytes captured (the `checkpoint_bytes` gauge).
    pub fn state_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }
}

/// Per-link Bernoulli loss streams (either side may be absent).
#[derive(Clone, Debug)]
struct LinkLoss {
    drop: Option<(f64, Rng)>,
    dup: Option<(f64, Rng)>,
}

/// The compiled fault schedule the simulator consults from serial code.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Checkpoint cadence in supersteps (≥ 1).
    pub ckpt_interval: u64,
    /// Tile failures as `(superstep, global tile index)`, ascending by
    /// superstep; `next_failure` marks how many have already fired.
    failures: Vec<(u64, usize)>,
    next_failure: usize,
    /// Per-link loss streams, indexed by `LinkId.0`; `None` ⇒ lossless.
    loss: Vec<Option<LinkLoss>>,
    any_loss: bool,
    /// Tiles killed so far (global index) — excluded from remap targets.
    dead: HashSet<usize>,
}

impl FaultPlan {
    /// Compile `spec`'s fault schedule; `None` when it has no faults.
    /// `spec` must already be validated for `cluster`.
    pub fn build(spec: &ScenarioSpec, cluster: &ClusterConfig) -> Option<FaultPlan> {
        if !spec.has_faults() {
            return None;
        }
        let mut failures: Vec<(u64, usize)> = spec
            .fail_tiles
            .iter()
            .map(|f| (f.step, f.board * cluster.tiles_per_board + f.tile))
            .collect();
        failures.sort_unstable();
        let mut loss: Vec<Option<LinkLoss>> = vec![None; cluster.n_boards * 4];
        let mut arm = |link: LinkId, p: f64, seed: u64, is_drop: bool| {
            let slot = loss[link.0 as usize].get_or_insert(LinkLoss {
                drop: None,
                dup: None,
            });
            // Salt the stream with the link id so `drop=0E:p@7,drop=1E:p@7`
            // draw independently even at equal seeds.
            let rng = Rng::new(seed ^ (u64::from(link.0) << 32));
            if is_drop {
                slot.drop = Some((p, rng));
            } else {
                slot.dup = Some((p, rng));
            }
        };
        for m in &spec.drop_links {
            arm(LinkId::of(m.board, m.dir), m.p, m.seed, true);
        }
        for m in &spec.dup_links {
            arm(LinkId::of(m.board, m.dir), m.p, m.seed, false);
        }
        let any_loss = loss.iter().any(|l| l.is_some());
        Some(FaultPlan {
            ckpt_interval: spec.ckpt_interval.unwrap_or(DEFAULT_CKPT_INTERVAL),
            failures,
            next_failure: 0,
            loss,
            any_loss,
            dead: HashSet::new(),
        })
    }

    /// Any drop/dup stream armed?  (Gates the per-crossing route lookup.)
    pub fn has_loss(&self) -> bool {
        self.any_loss
    }

    /// Un-fired tile failures remain ⇒ checkpoints are still worth taking.
    pub fn failures_pending(&self) -> bool {
        self.next_failure < self.failures.len()
    }

    /// Take a checkpoint at the top of `step`?  Barrier-aligned every
    /// `ckpt_interval` supersteps while failures are still pending —
    /// including the step a failure fires at, so replay distance is always
    /// `fail_step % ckpt_interval` at most.
    pub fn checkpoint_due(&self, step: u64) -> bool {
        self.failures_pending() && step % self.ckpt_interval == 0
    }

    /// Global tile indices failing at `step` (marked fired).  Call after
    /// [`FaultPlan::checkpoint_due`] is handled.
    pub fn fire_failures(&mut self, step: u64) -> Vec<usize> {
        let mut out = Vec::new();
        while self.next_failure < self.failures.len() && self.failures[self.next_failure].0 == step
        {
            let tile = self.failures[self.next_failure].1;
            self.next_failure += 1;
            if self.dead.insert(tile) {
                out.push(tile);
            }
        }
        out
    }

    /// Tiles killed so far.
    pub fn dead_tiles(&self) -> &HashSet<usize> {
        &self.dead
    }

    /// Decide the fate of one crossing over `route`.  Consumes one draw
    /// per armed stream per traversed link; a drop on any link loses the
    /// whole crossing, otherwise a duplicate on any link forwards a
    /// spurious copy the rest of the way.
    pub fn crossing_fate(&mut self, route: &[LinkId]) -> CrossingFate {
        let mut fate = CrossingFate::Deliver;
        for l in route {
            let Some(loss) = self.loss[l.0 as usize].as_mut() else {
                continue;
            };
            if let Some((p, rng)) = loss.drop.as_mut() {
                if rng.chance(*p) {
                    return CrossingFate::Drop;
                }
            }
            if fate == CrossingFate::Deliver {
                if let Some((p, rng)) = loss.dup.as_mut() {
                    if rng.chance(*p) {
                        fate = CrossingFate::Dup;
                    }
                }
            }
        }
        fate
    }

    /// Cycles charged to reload `bytes` of device state and re-seat the
    /// remapped threads.
    pub fn restore_cycles(bytes: u64) -> u64 {
        RESTORE_BASE_CYCLES + bytes * RESTORE_CYCLES_PER_BYTE
    }
}

/// Byte-oriented writer for [`crate::graph::device::Device::snapshot`]
/// implementations: little-endian scalars, length-prefixed slices.
pub struct SnapWriter<'a> {
    out: &'a mut Vec<u8>,
}

impl<'a> SnapWriter<'a> {
    pub fn new(out: &'a mut Vec<u8>) -> SnapWriter<'a> {
        SnapWriter { out }
    }

    pub fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bool(&mut self, v: bool) {
        self.out.push(v as u8);
    }

    /// Length-prefixed f32 slice.
    pub fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }

    /// Length-prefixed bool slice (one byte per flag).
    pub fn bools(&mut self, vs: &[bool]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.bool(v);
        }
    }
}

/// Reader matching [`SnapWriter`]; panics on malformed input (checkpoint
/// bytes are produced and consumed by the same device type in-process).
pub struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    pub fn new(buf: &'a [u8]) -> SnapReader<'a> {
        SnapReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> &'a [u8] {
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    pub fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    pub fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    pub fn f32(&mut self) -> f32 {
        f32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    pub fn bool(&mut self) -> bool {
        self.take(1)[0] != 0
    }

    pub fn f32s(&mut self) -> Vec<f32> {
        let n = self.u32() as usize;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn bools(&mut self) -> Vec<bool> {
        let n = self.u32() as usize;
        (0..n).map(|_| self.bool()).collect()
    }

    /// Snapshot fully consumed?  Restore implementations assert this to
    /// catch encode/decode drift.
    pub fn exhausted(&self) -> bool {
        self.pos == self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poets::noc::Dir;

    fn spec(s: &str) -> ScenarioSpec {
        ScenarioSpec::parse(s).unwrap()
    }

    #[test]
    fn faultless_spec_compiles_to_none() {
        let s = spec("boards=2,tiles=4");
        assert!(FaultPlan::build(&s, &s.cluster()).is_none());
    }

    #[test]
    fn failures_fire_once_in_step_order() {
        let s = spec("boards=2,tiles=4,failtile=1.2@40,failtile=0.1@8");
        let c = s.cluster();
        let mut fp = FaultPlan::build(&s, &c).unwrap();
        assert!(fp.failures_pending());
        assert!(fp.fire_failures(7).is_empty());
        // Board 0 tile 1 = global tile 1.
        assert_eq!(fp.fire_failures(8), vec![1]);
        assert!(fp.fire_failures(8).is_empty(), "failures fire once");
        assert!(fp.failures_pending());
        // Board 1 tile 2 = global tile 4 + 2.
        assert_eq!(fp.fire_failures(40), vec![c.tiles_per_board + 2]);
        assert!(!fp.failures_pending());
        assert_eq!(fp.dead_tiles().len(), 2);
    }

    #[test]
    fn checkpoint_cadence_follows_pending_failures() {
        let s = spec("boards=2,tiles=4,failtile=0.0@10,ckpt=4");
        let mut fp = FaultPlan::build(&s, &s.cluster()).unwrap();
        assert_eq!(fp.ckpt_interval, 4);
        assert!(fp.checkpoint_due(0));
        assert!(!fp.checkpoint_due(3));
        assert!(fp.checkpoint_due(8));
        fp.fire_failures(10);
        assert!(!fp.checkpoint_due(12), "no checkpoints after the last failure fires");
    }

    #[test]
    fn default_interval_applies_without_ckpt_key() {
        let s = spec("boards=2,tiles=4,failtile=0.0@10");
        let fp = FaultPlan::build(&s, &s.cluster()).unwrap();
        assert_eq!(fp.ckpt_interval, DEFAULT_CKPT_INTERVAL);
    }

    #[test]
    fn crossing_fates_are_deterministic_and_drop_wins() {
        let s = spec("boards=2,tiles=4,drop=0E:0.5@7,dup=0E:0.5@7");
        let c = s.cluster();
        let route = [LinkId::of(0, Dir::East)];
        let mut a = FaultPlan::build(&s, &c).unwrap();
        let mut b = FaultPlan::build(&s, &c).unwrap();
        assert!(a.has_loss());
        let fates: Vec<CrossingFate> = (0..64).map(|_| a.crossing_fate(&route)).collect();
        let again: Vec<CrossingFate> = (0..64).map(|_| b.crossing_fate(&route)).collect();
        assert_eq!(fates, again, "fates are a pure function of the schedule");
        assert!(fates.contains(&CrossingFate::Drop));
        assert!(fates.contains(&CrossingFate::Deliver));
        // A lossless route never consumes the streams.
        let other = [LinkId::of(1, Dir::West)];
        assert_eq!(a.crossing_fate(&other), CrossingFate::Deliver);
    }

    #[test]
    fn equal_seeds_on_different_links_draw_independently() {
        let s = spec("boards=4,tiles=4,drop=0E:0.5@7,drop=1E:0.5@7");
        let mut fp = FaultPlan::build(&s, &s.cluster()).unwrap();
        let a: Vec<CrossingFate> = (0..64)
            .map(|_| fp.crossing_fate(&[LinkId::of(0, Dir::East)]))
            .collect();
        let b: Vec<CrossingFate> = (0..64)
            .map(|_| fp.crossing_fate(&[LinkId::of(1, Dir::East)]))
            .collect();
        assert_ne!(a, b, "per-link streams must not be lockstep");
    }

    #[test]
    fn snap_roundtrip() {
        let mut bytes = Vec::new();
        let mut w = SnapWriter::new(&mut bytes);
        w.u32(7);
        w.u64(1 << 40);
        w.f32(2.5);
        w.bool(true);
        w.f32s(&[1.0, -2.0, 3.5]);
        w.bools(&[true, false, true]);
        let mut r = SnapReader::new(&bytes);
        assert_eq!(r.u32(), 7);
        assert_eq!(r.u64(), 1 << 40);
        assert_eq!(r.f32(), 2.5);
        assert!(r.bool());
        assert_eq!(r.f32s(), vec![1.0, -2.0, 3.5]);
        assert_eq!(r.bools(), vec![true, false, true]);
        assert!(r.exhausted());
    }

    #[test]
    fn restore_cost_scales_with_state() {
        assert_eq!(FaultPlan::restore_cycles(0), RESTORE_BASE_CYCLES);
        assert_eq!(
            FaultPlan::restore_cycles(1024),
            RESTORE_BASE_CYCLES + 1024 * RESTORE_CYCLES_PER_BYTE
        );
    }
}
