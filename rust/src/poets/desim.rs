//! The discrete-event simulator: functional + timing execution of an
//! application graph on the POETS cluster model.
//!
//! # Execution semantics (paper §4.2/§5.2)
//!
//! Execution is a sequence of globally-synchronous *supersteps*, separated by
//! the termination-detection wave (the paper explicitly time-steps the
//! imputation pipeline this way, at a measured ~3 % step cost):
//!
//! 1. **Dispatch** — send requests buffered during the previous superstep are
//!    serviced: the sending core pays the send-request cost, the event
//!    traverses the NoC (inter-board links serialise per event), and one
//!    *group arrival* per destination tile is pushed onto the time-ordered
//!    heap.
//! 2. **Deliver** — group arrivals pop in time order; the tile mailbox
//!    ingests one copy per destination vertex (serialised — the fan-in
//!    bottleneck), and each copy's `recv` handler executes on its vertex's
//!    core (cores are serial servers shared by their resident threads, which
//!    is how soft-scheduling costs emerge).  Handlers buffer new sends for
//!    the *next* superstep.
//! 3. **Step** — when the heap drains, the termination wave runs; if every
//!    device voted halt and nothing is buffered, the run ends, otherwise all
//!    `step` handlers execute and the next superstep begins.
//!
//! Because messages sent in superstep *k* are delivered only in *k+1*, the
//! functional results are independent of the timing model — timing
//! approximations can never corrupt numerics (asserted by the
//! baseline-vs-event integration tests).

use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::graph::builder::Graph;
use crate::graph::device::{Ctx, Device, PortId, VertexId};
use crate::graph::mapping::Mapping;

use super::costmodel::CostModel;
use super::event::{GroupArrival, assert_event_fits};
use super::mailbox::MailboxBank;
use super::metrics::SimMetrics;
use super::multicast::McastPlan;
use super::noc::Noc;
use super::termination;
use super::topology::ClusterConfig;

/// Simulation limits / switches.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Hard cap on supersteps (guards runaway applications).
    pub max_steps: u64,
    /// Record per-step durations (small overhead, used by figure harnesses).
    pub record_steps: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            max_steps: 1_000_000,
            record_steps: true,
        }
    }
}

/// A buffered send request: (sender, port, message).
type Send<M> = (VertexId, PortId, M);

/// The simulator. Owns the application graph and all cluster state.
pub struct Simulator<D: Device> {
    pub graph: Graph<D>,
    mapping: Mapping,
    cluster: ClusterConfig,
    cost: CostModel,
    cfg: SimConfig,
    /// Immutable after build; Arc so the delivery hot path can hold a view
    /// while mutating simulator state (no per-event clone of dest lists).
    plan: Arc<McastPlan>,
    noc: Noc,
    mailboxes: MailboxBank,
    core_free: Vec<u64>,
    core_busy: Vec<u64>,
    /// Cached core index per vertex (hot path).
    core_of: Vec<u32>,
    /// Vertices per core (bulk step-handler charging).
    core_vertex_count: Vec<u32>,
    /// Cached (board, tile) per vertex's thread.
    board_of: Vec<u32>,
    tile_of: Vec<u32>,
    pending: Vec<Send<D::Msg>>,
    heap: BinaryHeap<GroupArrival<D::Msg>>,
    seq: u64,
    pub metrics: SimMetrics,
}

impl<D: Device> Simulator<D> {
    pub fn new(
        graph: Graph<D>,
        mapping: Mapping,
        cluster: ClusterConfig,
        cost: CostModel,
        cfg: SimConfig,
    ) -> Self {
        assert_event_fits::<D::Msg>(cost.event_bytes);
        assert_eq!(
            mapping.n_vertices(),
            graph.n_vertices(),
            "mapping covers a different vertex count"
        );
        let plan = Arc::new(McastPlan::build(&graph, &mapping, &cluster));
        let n_cores = cluster.total_cores();
        let n_tiles = cluster.total_tiles();
        let core_of: Vec<u32> = (0..graph.n_vertices())
            .map(|v| cluster.core_of(mapping.thread_of(v as VertexId)) as u32)
            .collect();
        let mut core_vertex_count = vec![0u32; n_cores];
        for &c in &core_of {
            core_vertex_count[c as usize] += 1;
        }
        let board_of: Vec<u32> = (0..graph.n_vertices())
            .map(|v| cluster.board_of(mapping.thread_of(v as VertexId)) as u32)
            .collect();
        let tile_of: Vec<u32> = (0..graph.n_vertices())
            .map(|v| cluster.tile_of(mapping.thread_of(v as VertexId)) as u32)
            .collect();
        Simulator {
            graph,
            mapping,
            cluster,
            cost,
            cfg,
            plan,
            noc: Noc::new(&cluster),
            mailboxes: MailboxBank::new(n_tiles),
            core_free: vec![0; n_cores],
            core_busy: vec![0; n_cores],
            core_of,
            core_vertex_count,
            board_of,
            tile_of,
            pending: Vec::new(),
            heap: BinaryHeap::new(),
            seq: 0,
            metrics: SimMetrics::default(),
        }
    }

    pub fn cluster(&self) -> &ClusterConfig {
        &self.cluster
    }

    pub fn mapping(&self) -> &Mapping {
        &self.mapping
    }

    pub fn plan(&self) -> &McastPlan {
        &self.plan
    }

    /// Run to halt (or `max_steps`). Returns the final metrics.
    pub fn run(&mut self) -> &SimMetrics {
        let mut now = 0u64;
        // Superstep 0: init handlers on every device.
        let mut ctx = Ctx::new(0, 0);
        for v in 0..self.graph.n_vertices() as u32 {
            ctx.reset(v, 0);
            self.graph.devices[v as usize].init(&mut ctx);
            now = now.max(self.charge_handler(v, ctx.flops(), 0));
            self.buffer_sends(v, &mut ctx);
        }

        let mut step = 0u64;
        loop {
            // Phase 1: dispatch buffered sends.
            let step_start = now;
            let sends = std::mem::take(&mut self.pending);
            for (src, port, msg) in sends {
                self.dispatch(src, port, msg, step_start);
            }
            // Phase 2: deliver group arrivals in time order.
            let mut quiesce = step_start;
            while let Some(ev) = self.heap.pop() {
                quiesce = quiesce.max(self.deliver(ev, step));
            }
            quiesce = quiesce.max(self.core_free.iter().copied().max().unwrap_or(0));
            quiesce = quiesce.max(self.mailboxes.max_free());

            // Phase 3: termination detection + step handlers.
            let mut all_halt = true;
            let mut ctx = Ctx::new(0, step);
            // Step handlers run after the barrier; their sends go into the
            // next superstep.
            let decision = termination::detect(
                quiesce,
                self.mapping.n_threads_used(),
                true, // vote collected below; recomputed before halt
                self.pending.len(),
                &self.cost,
            );
            self.metrics.barrier_cycles += decision.step_at - quiesce;
            now = decision.step_at;
            self.sync_clocks(now);

            // Bulk-charge the uniform part of every step handler: at the
            // barrier all cores are synced to `now`, so per-vertex serial
            // charging telescopes to count·handler(0) per core.  Only the
            // rare handlers that do extra FP work pay the delta individually.
            for (c, &n) in self.core_vertex_count.iter().enumerate() {
                let cycles = n as u64 * self.cost.handler(0);
                self.core_free[c] += cycles;
                self.core_busy[c] += cycles;
            }
            self.metrics.step_handlers += self.graph.n_vertices() as u64;
            for v in 0..self.graph.n_vertices() as u32 {
                ctx.reset(v, step);
                let vote_continue = self.graph.devices[v as usize].step(&mut ctx);
                all_halt &= !vote_continue;
                if ctx.flops() > 0 {
                    let core = self.core_of[v as usize] as usize;
                    let cycles = ctx.flops() * self.cost.flop;
                    self.core_free[core] += cycles;
                    self.core_busy[core] += cycles;
                }
                self.buffer_sends(v, &mut ctx);
            }
            if self.cfg.record_steps {
                self.metrics.step_durations.push(now - step_start);
            }
            step += 1;
            self.metrics.steps = step;

            if all_halt && self.pending.is_empty() {
                break;
            }
            assert!(
                step < self.cfg.max_steps,
                "simulation exceeded max_steps={} — runaway application?",
                self.cfg.max_steps
            );
        }

        // Account for the final quiesce point.
        let end = now.max(self.core_free.iter().copied().max().unwrap_or(0));
        self.metrics.sim_cycles = end;
        self.metrics.max_core_busy = self.core_busy.iter().copied().max().unwrap_or(0);
        self.metrics.max_mailbox_busy = self.mailboxes.max_busy();
        &self.metrics
    }

    /// Simulated wall-clock seconds of the finished run.
    pub fn sim_seconds(&self) -> f64 {
        self.metrics.sim_seconds(self.cluster.clock_hz)
    }

    // ----- internals -------------------------------------------------------

    fn buffer_sends(&mut self, v: VertexId, ctx: &mut Ctx<D::Msg>) {
        for (port, msg) in ctx.take_sends() {
            self.pending.push((v, port, msg));
        }
    }

    /// Charge a handler invocation to the vertex's core; returns finish time.
    fn charge_handler(&mut self, v: VertexId, flops: u64, ready: u64) -> u64 {
        let core = self.core_of[v as usize] as usize;
        let start = ready.max(self.core_free[core]);
        let cycles = self.cost.handler(flops);
        self.core_free[core] = start + cycles;
        self.core_busy[core] += cycles;
        start + cycles
    }

    /// Service one send request: NoC transit + one group arrival per tile.
    fn dispatch(&mut self, src: VertexId, port: PortId, msg: D::Msg, step_start: u64) {
        let core = self.core_of[src as usize] as usize;
        let t_send = step_start.max(self.core_free[core]) + self.cost.send_request;
        self.core_free[core] = t_send;
        self.core_busy[core] += self.cost.send_request;
        self.metrics.sends += 1;

        let list = self.graph.dest_list(src, port);
        let src_board = self.board_of[src as usize];
        let src_tile = self.tile_of[src as usize] as usize;
        let plan = Arc::clone(&self.plan);
        let groups = plan.tile_groups(list);
        let mut crossed_board = false;
        for (gi, group) in groups.iter().enumerate() {
            let t_arr = if group.board == src_board {
                // Intra-board mesh: per-hop latency.
                let hops =
                    self.cluster.intra_board_hops(
                        src_tile % self.cluster.tiles_per_board,
                        group.tile as usize % self.cluster.tiles_per_board,
                    ) as u64;
                t_send + hops * self.cost.hop
            } else {
                crossed_board = true;
                // Inter-board: dimension-ordered over board links (serialised
                // per event per link), then worst-case half-mesh to the tile.
                let route = Noc::board_route(&self.cluster, src_board as usize, group.board as usize);
                let t_board = self.noc.traverse(&route, t_send, &self.cost);
                let ingress_hops = (self.cluster.tile_mesh.0 + self.cluster.tile_mesh.1) as u64 / 2;
                t_board + ingress_hops * self.cost.hop
            };
            self.seq += 1;
            self.heap.push(GroupArrival {
                t: t_arr,
                seq: self.seq,
                src,
                list,
                group: gi as u32,
                msg: msg.clone(),
            });
        }
        if crossed_board {
            self.metrics.inter_board_sends += 1;
        }
    }

    /// Deliver one group arrival: mailbox ingest + per-copy recv handlers.
    /// Returns the latest completion time it produced.
    fn deliver(&mut self, ev: GroupArrival<D::Msg>, step: u64) -> u64 {
        let plan = Arc::clone(&self.plan);
        let group = &plan.tile_groups(ev.list)[ev.group as usize];
        let tile = group.tile as usize;
        let n = group.dests.len();
        let first_ready = self.mailboxes.ingest(tile, ev.t, n, &self.cost);
        self.metrics.copies_delivered += n as u64;

        let mut ctx = Ctx::new(0, step);
        let mut latest = ev.t;
        for (i, &d) in group.dests.iter().enumerate() {
            let ready = first_ready + i as u64 * self.cost.mailbox_ingress;
            ctx.reset(d, step);
            self.graph.devices[d as usize].recv(&ev.msg, ev.src, &mut ctx);
            let done = self.charge_handler(d, ctx.flops(), ready);
            latest = latest.max(done);
            self.buffer_sends(d, &mut ctx);
        }
        self.metrics.recv_handlers += n as u64;
        latest
    }

    /// Floor every resource clock to `t` at a superstep boundary.
    fn sync_clocks(&mut self, t: u64) {
        for f in &mut self.core_free {
            *f = (*f).max(t);
        }
        self.mailboxes.advance_to(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    /// Ring of N devices passing a token `rounds` times.
    struct Ring {
        hops_seen: u32,
        rounds: u32,
        is_seed: bool,
        pending_send: Option<u32>,
    }

    impl Device for Ring {
        type Msg = u32;
        fn init(&mut self, ctx: &mut Ctx<u32>) {
            if self.is_seed {
                ctx.send(0, 0);
            }
        }
        fn recv(&mut self, msg: &u32, _src: VertexId, ctx: &mut Ctx<u32>) {
            self.hops_seen += 1;
            ctx.flop(1);
            if *msg < self.rounds {
                // Forward at the *next* step (buffered via pending_send so the
                // test also exercises step-handler sends).
                self.pending_send = Some(*msg + 1);
            }
        }
        fn step(&mut self, ctx: &mut Ctx<u32>) -> bool {
            if let Some(m) = self.pending_send.take() {
                ctx.send(0, m);
                true
            } else {
                false
            }
        }
    }

    fn ring_sim(n: usize, rounds: u32) -> Simulator<Ring> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(Ring {
                hops_seen: 0,
                rounds,
                is_seed: i == 0,
                pending_send: None,
            });
        }
        for v in 0..n as u32 {
            b.add_port_to(v, vec![(v + 1) % n as u32]);
        }
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let mapping = Mapping::round_robin(n, &cluster);
        Simulator::new(g, mapping, cluster, CostModel::default(), SimConfig::default())
    }

    #[test]
    fn token_ring_delivers_every_hop() {
        let mut sim = ring_sim(8, 23);
        sim.run();
        let total: u32 = sim.graph.devices.iter().map(|d| d.hops_seen).sum();
        assert_eq!(total, 24); // msgs 0..=23 delivered once each
        assert_eq!(sim.metrics.sends, 24);
        assert_eq!(sim.metrics.copies_delivered, 24);
        assert!(sim.metrics.sim_cycles > 0);
    }

    #[test]
    fn time_advances_monotonically_with_work() {
        let short = {
            let mut s = ring_sim(4, 3);
            s.run();
            s.metrics.sim_cycles
        };
        let long = {
            let mut s = ring_sim(4, 30);
            s.run();
            s.metrics.sim_cycles
        };
        assert!(long > short, "{long} vs {short}");
    }

    /// A broadcaster fanning out to N listeners through one multicast send.
    struct Fan {
        n_recv: u32,
        is_root: bool,
    }
    impl Device for Fan {
        type Msg = f32;
        fn init(&mut self, ctx: &mut Ctx<f32>) {
            if self.is_root {
                ctx.send(0, 1.5);
            }
        }
        fn recv(&mut self, msg: &f32, _src: VertexId, ctx: &mut Ctx<f32>) {
            assert_eq!(*msg, 1.5);
            self.n_recv += 1;
            ctx.flop(2);
        }
        fn step(&mut self, _ctx: &mut Ctx<f32>) -> bool {
            false
        }
    }

    #[test]
    fn multicast_delivers_one_copy_each() {
        let mut b = GraphBuilder::new();
        let root = b.add_vertex(Fan {
            n_recv: 0,
            is_root: true,
        });
        let listeners: Vec<VertexId> = (0..50)
            .map(|_| {
                b.add_vertex(Fan {
                    n_recv: 0,
                    is_root: false,
                })
            })
            .collect();
        b.add_port_to(root, listeners.clone());
        // Listeners need a port too? No — only senders need ports.
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let mapping = Mapping::round_robin(51, &cluster);
        let mut sim = Simulator::new(g, mapping, cluster, CostModel::default(), SimConfig::default());
        sim.run();
        assert_eq!(sim.metrics.sends, 1, "multicast is ONE send request");
        assert_eq!(sim.metrics.copies_delivered, 50);
        for &l in &listeners {
            assert_eq!(sim.graph.devices[l as usize].n_recv, 1);
        }
        // Mailbox fan-in must have serialised copies: busiest mailbox saw
        // multiple ingress slots.
        assert!(sim.metrics.max_mailbox_busy >= 2 * CostModel::default().mailbox_ingress);
    }

    #[test]
    fn inter_board_traffic_counted() {
        // Map sender to board 0, receiver to board 1 via explicit assignment.
        let mut b = GraphBuilder::new();
        let a = b.add_vertex(Fan {
            n_recv: 0,
            is_root: true,
        });
        let z = b.add_vertex(Fan {
            n_recv: 0,
            is_root: false,
        });
        b.add_port_to(a, vec![z]);
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let tpb = cluster.threads_per_board() as u32;
        let mapping = Mapping::from_assignment(
            vec![
                crate::poets::topology::ThreadId(0),
                crate::poets::topology::ThreadId(tpb), // first thread of board 1
            ],
            &cluster,
        );
        let mut sim = Simulator::new(g, mapping, cluster, CostModel::default(), SimConfig::default());
        sim.run();
        assert_eq!(sim.metrics.inter_board_sends, 1);
        assert_eq!(sim.graph.devices[1].n_recv, 1);
    }

    #[test]
    fn steps_counted_and_barrier_charged() {
        let mut sim = ring_sim(6, 11);
        sim.run();
        assert!(sim.metrics.steps >= 11);
        assert!(sim.metrics.barrier_cycles > 0);
        assert_eq!(
            sim.metrics.step_durations.len() as u64,
            sim.metrics.steps
        );
    }

    #[test]
    #[should_panic(expected = "max_steps")]
    fn runaway_detected() {
        // A device that always keeps sending.
        struct Loop;
        impl Device for Loop {
            type Msg = u8;
            fn init(&mut self, ctx: &mut Ctx<u8>) {
                ctx.send(0, 0);
            }
            fn recv(&mut self, _m: &u8, _s: VertexId, ctx: &mut Ctx<u8>) {
                ctx.send(0, 0);
            }
            fn step(&mut self, _ctx: &mut Ctx<u8>) -> bool {
                true
            }
        }
        let mut b = GraphBuilder::new();
        let v = b.add_vertex(Loop);
        b.add_port_to(v, vec![v]);
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let mapping = Mapping::round_robin(1, &cluster);
        let mut sim = Simulator::new(
            g,
            mapping,
            cluster,
            CostModel::default(),
            SimConfig {
                max_steps: 50,
                record_steps: false,
            },
        );
        sim.run();
    }
}
