//! The discrete-event simulator: functional + timing execution of an
//! application graph on the POETS cluster model.
//!
//! # Execution semantics (paper §4.2/§5.2)
//!
//! Execution is a sequence of globally-synchronous *supersteps*, separated by
//! the termination-detection wave (the paper explicitly time-steps the
//! imputation pipeline this way, at a measured ~3 % step cost):
//!
//! 1. **Dispatch** — send requests buffered during the previous superstep are
//!    serviced: the sending core pays the send-request cost, the event
//!    traverses the NoC (inter-board links serialise per event), and one
//!    *group arrival* per destination tile is appended to that tile's queue.
//! 2. **Deliver** — each tile processes its own queue in time order; the tile
//!    mailbox ingests one copy per destination vertex (serialised — the
//!    fan-in bottleneck), and each copy's `recv` handler executes on its
//!    vertex's core (cores are serial servers shared by their resident
//!    threads, which is how soft-scheduling costs emerge).  Handlers buffer
//!    new sends for the *next* superstep.
//! 3. **Step** — when every queue drains, the termination wave runs; if every
//!    device voted halt and nothing is buffered, the run ends, otherwise all
//!    `step` handlers execute and the next superstep begins.
//!
//! # The execution-semantics contract (host-side parallelism)
//!
//! Because messages sent in superstep *k* are delivered only in *k+1*, the
//! functional results are independent of the timing model — timing
//! approximations can never corrupt numerics (asserted by the
//! baseline-vs-event integration tests).  The same barrier makes the
//! *deliver* and *step* phases embarrassingly parallel on the host — the
//! property POETS itself exploits in hardware:
//!
//! * every resource a delivery touches (mailbox, cores, resident devices) is
//!   owned by exactly one tile, so the simulator partitions all mutable
//!   per-tile state into [`TileShard`]s and hands disjoint shard slices to
//!   worker threads (type-level disjointness — no locks, no aliasing);
//! * message payloads are written once per superstep into a shared
//!   read-only *arena*; queue entries are 32-byte POD records
//!   ([`GroupArrival`]) carrying an arena index, so multicast never clones
//!   a payload per destination group;
//! * **lane groups**: a payload may be a multi-lane SoA slab servicing many
//!   in-flight targets at once (the wave-batched imputation planes — see
//!   `imputation::msg`).  The arena recognises this only through
//!   [`Device::lanes`]: the per-tile queues still carry exactly one
//!   `GroupArrival` per wave chunk per multicast group, one mailbox ingest
//!   and one `recv` handler per destination copy, however many lanes the
//!   payload carries — that amortisation is the point of batching, and the
//!   simulator reports it as `SimMetrics::lanes_delivered` next to
//!   `copies_delivered`;
//! * **pipelined lane groups**: an application may keep several lane groups
//!   in flight at once by injecting group *g* at superstep `g·stagger`
//!   instead of waiting for group *g−1* to finish (the wave-batched
//!   imputation planes do exactly this for batches wider than one group).
//!   The simulator needs no new mechanism for it — each group's chunks are
//!   ordinary arena payloads, and the per-group canonical reductions live in
//!   the vertices — but it *observes* the resulting occupancy:
//!   `SimMetrics::busy_tile_steps` integrates, per superstep, how many tiles
//!   delivered at least one event, and `SimMetrics::max_busy_tiles` records
//!   the peak, both counted in the deterministic serial shard reduce so they
//!   are thread-count invariant like every other counter;
//! * **opt-in tracing** ([`SimConfig::trace`]): when enabled, each shard
//!   additionally snapshots per-superstep delivery scratch — queue-depth
//!   high-water, copies/lanes delivered, wavefront column span — with no
//!   locks and no atomics; the scratch is folded into one
//!   [`crate::obs::StepRecord`] per superstep inside the same deterministic
//!   serial shard reduce, so the emitted trace — like every other counter —
//!   is **bit-identical for every thread count and every wave/batch width**
//!   (asserted by `tests/trace_determinism.rs`).  Tracing also samples the
//!   inter-board link plane per superstep (events crossed, busy cycles,
//!   queue high-water per link) — the NoC is mutated only by the *serial*
//!   dispatch phase, so those samples are drained before the tile-parallel
//!   phases and inherit determinism for free.  Disabled (the default),
//!   the whole feature costs one branch on an `Option` per delivered event
//!   batch: no allocation, no atomics on the hot path;
//! * the only cross-tile values are the quiesce time (a `max`-reduce,
//!   exact over `u64`) and the halt vote (an `and`-reduce), so a run is
//!   **bit-identical for every thread count** — `SimConfig::threads`
//!   changes host wall-clock only, never dosages, `sim_cycles`, or event
//!   counts (asserted by `tests/parallel_equivalence.rs`).  The contract
//!   extends to lane groups lane-by-lane: deliveries stay deterministically
//!   ordered by `(t, seq)`, and the wave-batched vertices additionally
//!   reduce their fan-in in canonical sender order, so their numerics are
//!   invariant to batch width as well as to host thread count.
//!
//! Set [`SimConfig::threads`] to `Some(n)` to fan each superstep's
//! deliver+step phases out over `n` OS threads (`None`/`Some(1)` = serial;
//! the same shard code runs either way).  Dispatch stays serial: it mutates
//! the global NoC link clocks and assigns the deterministic arrival
//! sequence numbers.
//!
//! # The contract under faults (checkpoint, remap, replay)
//!
//! A [`ScenarioSpec`] fault schedule (`failtile=`, `drop=`, `dup=` — see
//! [`super::fault`]) extends the determinism contract rather than weakening
//! it.  Every fault decision is made in the **serial** dispatch phase from
//! seeded per-link streams, so which crossings drop or duplicate — and
//! therefore the whole recovery timeline — is a pure function of the
//! schedule, invariant to host thread count.  When a tile dies, its
//! vertices are remapped onto the surviving tiles and execution rewinds to
//! the last barrier-aligned checkpoint: the replayed supersteps run the
//! same canonical reductions under the new placement, and because the
//! functional results are placement-independent (waves reduce in sender
//! order), dosages after remap-and-replay are **bit-identical to the
//! fault-free run** at every thread count and wave width
//! (`tests/scenario_lab.rs`).  Replay re-records per-step durations — the
//! step timeline still sums to `sim_cycles` exactly, but
//! `step_durations.len()` exceeds the logical `steps` count by the number
//! of replayed supersteps, and each recovery opens a new trace segment.

use std::sync::Barrier;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::graph::builder::Graph;
use crate::graph::device::{Ctx, Device, PortId, VertexId};
use crate::graph::mapping::Mapping;
use crate::obs::trace::{LinkSample, RunTrace, StepRecord, TileSample, TraceConfig, NO_COL};

use super::costmodel::CostModel;
use super::event::{FLAG_DUP, FLAG_RETRANS, GroupArrival, assert_event_fits};
use super::fault::{Checkpoint, CrossingFate, FaultPlan, NACK_PENALTY_CYCLES, Retransmit};
use super::mailbox::Mailbox;
use super::metrics::SimMetrics;
use super::multicast::McastPlan;
use super::noc::Noc;
use super::scenario::ScenarioSpec;
use super::termination;
use super::topology::{ClusterConfig, ThreadId};

/// Simulation limits / switches.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Hard cap on supersteps (guards runaway applications).
    pub max_steps: u64,
    /// Record per-step durations (small overhead, used by figure harnesses).
    pub record_steps: bool,
    /// Host worker threads for the deliver/step phases.  `None` or `Some(1)`
    /// runs serially; `Some(n)` fans the per-tile shards out over `n` OS
    /// threads.  Results are bit-identical for every value (see module docs).
    pub threads: Option<usize>,
    /// Opt-in per-superstep, per-tile trace capture (see [`crate::obs`]).
    /// `None` (the default) records nothing and costs one branch per event
    /// batch; the captured trace is bit-identical for every `threads` value.
    pub trace: Option<TraceConfig>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            max_steps: 1_000_000,
            record_steps: true,
            threads: None,
            trace: None,
        }
    }
}

/// A buffered send request: (sender, port, message).
type SendReq<M> = (VertexId, PortId, M);

/// All mutable state owned by one tile: its mailbox, its cores' clocks, the
/// devices resident on it, its superstep delivery queue and its outbound
/// send buffer.  Shards are disjoint by construction, so the deliver/step
/// phases may run one shard per worker with no synchronisation.
struct TileShard<D: Device> {
    /// Resident vertices, ascending vertex id (slot order).
    vertices: Vec<VertexId>,
    /// Devices for `vertices` (same order), moved out of the graph per run.
    devices: Vec<D>,
    /// Busy-until / cumulative-busy clocks of this tile's cores.
    core_free: Vec<u64>,
    core_busy: Vec<u64>,
    /// Resident vertices per local core (bulk step-handler charging).
    core_vertex_count: Vec<u32>,
    mailbox: Mailbox,
    /// Group arrivals for the current superstep (bucketed by dispatch).
    queue: Vec<GroupArrival>,
    /// Sends buffered by this shard's handlers during the current superstep.
    out: Vec<SendReq<D::Msg>>,
    /// Reusable handler context.
    ctx: Ctx<D::Msg>,
    /// Latest completion time produced by the current phase.
    latest: u64,
    /// Whether any resident device voted to continue this superstep.
    voted_continue: bool,
    /// Whether this tile delivered at least one event this superstep
    /// (occupancy probe; read + reset in the serial shard reduce).
    delivered: bool,
    // Per-shard event counters, folded into `SimMetrics` at run end.
    copies_delivered: u64,
    lanes_delivered: u64,
    recv_handlers: u64,
    /// Spurious duplicates the mailbox suppressed (fault schedules only).
    dup_events: u64,
    // Per-superstep trace scratch, written only when tracing is enabled
    // (`Env::trace`) and read in the serial shard reduce.  `t_copies` /
    // `t_lanes` snapshot the cumulative counters at deliver start, so the
    // superstep delta needs no extra adds in the delivery loop.
    t_queue_hw: u32,
    t_copies: u64,
    t_lanes: u64,
    t_col_min: u32,
    t_col_max: u32,
}

/// Immutable per-superstep environment shared by every shard worker.
struct Env<'a, M> {
    plan: &'a McastPlan,
    cost: &'a CostModel,
    /// This superstep's message payloads (one slot per send request).
    arena: &'a [M],
    /// Vertex → slot within its tile shard.
    slot_of: &'a [u32],
    /// Vertex → core index within its tile.
    local_core_of: &'a [u32],
    /// Simulated hardware threads (termination-wave cost input).
    n_sim_threads: usize,
    /// `Some(col_stride)` when trace capture is on (`col_stride == 0`
    /// disables wavefront column attribution); `None` = tracing off.
    trace: Option<u32>,
}

impl<D: Device> TileShard<D> {
    /// Charge one handler invocation on `v`'s core; returns its finish time.
    fn charge_handler(&mut self, v: VertexId, ready: u64, env: &Env<'_, D::Msg>) -> u64 {
        let lc = env.local_core_of[v as usize] as usize;
        let start = ready.max(self.core_free[lc]);
        let cycles = env.cost.handler(self.ctx.flops());
        self.core_free[lc] = start + cycles;
        self.core_busy[lc] += cycles;
        start + cycles
    }

    /// Move the context's buffered sends into this shard's outbox.
    fn flush_sends(&mut self, v: VertexId) {
        for (port, msg) in self.ctx.drain_sends() {
            self.out.push((v, port, msg));
        }
    }

    /// Superstep 0: run every resident device's init handler.
    fn init_phase(&mut self, env: &Env<'_, D::Msg>) {
        let mut latest = 0u64;
        for slot in 0..self.vertices.len() {
            let v = self.vertices[slot];
            self.ctx.reset(v, 0);
            self.devices[slot].init(&mut self.ctx);
            latest = latest.max(self.charge_handler(v, 0, env));
            self.flush_sends(v);
        }
        self.latest = latest;
    }

    /// Deliver this tile's group arrivals in time order: mailbox ingest +
    /// per-copy recv handlers, all against tile-local state.
    #[allow(clippy::needless_range_loop)] // index loop: `self` split-borrows
    fn deliver_phase(&mut self, step: u64, env: &Env<'_, D::Msg>) {
        self.queue.sort_unstable(); // ascending (t, seq)
        self.delivered = !self.queue.is_empty();
        if env.trace.is_some() {
            self.t_queue_hw = self.queue.len() as u32;
            self.t_copies = self.copies_delivered;
            self.t_lanes = self.lanes_delivered;
            self.t_col_min = NO_COL;
            self.t_col_max = 0;
        }
        let mut latest = 0u64;
        for qi in 0..self.queue.len() {
            let ev = self.queue[qi];
            if ev.flags & FLAG_DUP != 0 {
                // Spurious duplicate: the mailbox recognises the repeated
                // (sender, superstep) sequence number and discards it after
                // one ingress slot of detection work — no handler runs, so
                // duplicates never touch the functional state.
                self.mailbox.suppress_dup(ev.t, env.cost);
                self.dup_events += 1;
                latest = latest.max(ev.t);
                continue;
            }
            // Retransmissions are unicast: `group` carries the destination
            // vertex id, not a multicast-group index (see `poets::fault`).
            let one: [VertexId; 1] = [ev.group];
            let dests: &[VertexId] = if ev.flags & FLAG_RETRANS != 0 {
                &one
            } else {
                env.plan.group_dests(ev.group as usize)
            };
            let n = dests.len();
            let first_ready = self.mailbox.ingest(ev.t, n, env.cost);
            self.copies_delivered += n as u64;
            self.recv_handlers += n as u64;
            latest = latest.max(ev.t);
            let msg = &env.arena[ev.msg_idx as usize];
            self.lanes_delivered += n as u64 * D::lanes(msg) as u64;
            // One branch per event batch when tracing is off; the column
            // scan only runs when a stride was configured.
            if let Some(stride) = env.trace {
                if stride > 0 {
                    for &d in dests {
                        let c = d / stride;
                        self.t_col_min = self.t_col_min.min(c);
                        self.t_col_max = self.t_col_max.max(c);
                    }
                }
            }
            for (i, &d) in dests.iter().enumerate() {
                let ready = first_ready + i as u64 * env.cost.mailbox_ingress;
                let slot = env.slot_of[d as usize] as usize;
                self.ctx.reset(d, step);
                self.devices[slot].recv(msg, ev.src, &mut self.ctx);
                latest = latest.max(self.charge_handler(d, ready, env));
                self.flush_sends(d);
            }
        }
        self.queue.clear();
        self.latest = latest;
    }

    /// Latest busy-until point this shard contributes to the quiesce time.
    fn quiesce_point(&self) -> u64 {
        let core_max = self.core_free.iter().copied().max().unwrap_or(0);
        self.latest.max(core_max).max(self.mailbox.free_at())
    }

    /// Post-barrier phase: floor clocks to the step signal, bulk-charge the
    /// uniform handler cost, run every resident device's step handler.
    #[allow(clippy::needless_range_loop)] // index loop: `self` split-borrows
    fn step_phase(&mut self, now: u64, step: u64, env: &Env<'_, D::Msg>) {
        for f in &mut self.core_free {
            *f = (*f).max(now);
        }
        self.mailbox.advance_to(now);
        // At the barrier all cores are synced to `now`, so per-vertex serial
        // charging telescopes to count·handler(0) per core.  Only the rare
        // handlers that do extra FP work pay the delta individually.
        for lc in 0..self.core_vertex_count.len() {
            let cycles = self.core_vertex_count[lc] as u64 * env.cost.handler(0);
            self.core_free[lc] += cycles;
            self.core_busy[lc] += cycles;
        }
        let mut any_continue = false;
        for slot in 0..self.vertices.len() {
            let v = self.vertices[slot];
            self.ctx.reset(v, step);
            any_continue |= self.devices[slot].step(&mut self.ctx);
            if self.ctx.flops() > 0 {
                let lc = env.local_core_of[v as usize] as usize;
                let cycles = self.ctx.flops() * env.cost.flop;
                self.core_free[lc] += cycles;
                self.core_busy[lc] += cycles;
            }
            self.flush_sends(v);
        }
        self.voted_continue = any_continue;
    }
}

/// One worker's share of a superstep: deliver its shards, contribute to the
/// global quiesce max, wait at the barrier, then run its shards' step
/// handlers against the (identically recomputed) step-signal time.
fn superstep_worker<D: Device>(
    shards: &mut [TileShard<D>],
    env: &Env<'_, D::Msg>,
    step: u64,
    step_start: u64,
    quiesce: &AtomicU64,
    barrier: &Barrier,
) {
    let mut local_q = step_start;
    for s in shards.iter_mut() {
        s.deliver_phase(step, env);
        local_q = local_q.max(s.quiesce_point());
    }
    quiesce.fetch_max(local_q, Ordering::SeqCst);
    barrier.wait();
    // Every worker derives the same step-signal time from the shared quiesce
    // point — exact u64 arithmetic, so bit-identical across thread counts.
    let q = quiesce.load(Ordering::SeqCst);
    let now = termination::detect(q, env.n_sim_threads, true, 0, env.cost).step_at;
    for s in shards.iter_mut() {
        s.step_phase(now, step, env);
    }
}

/// Run one full superstep (deliver + step phases) over all shards, fanning
/// out over at most `host_threads` workers.  Returns the quiesce time.
fn run_superstep<D: Device>(
    shards: &mut [TileShard<D>],
    host_threads: usize,
    env: &Env<'_, D::Msg>,
    step: u64,
    step_start: u64,
) -> u64 {
    let n = shards.len();
    let quiesce = AtomicU64::new(step_start);
    if host_threads <= 1 || n <= 1 {
        let barrier = Barrier::new(1);
        superstep_worker(shards, env, step, step_start, &quiesce, &barrier);
    } else {
        let workers = host_threads.min(n);
        let chunk = n.div_ceil(workers);
        let n_chunks = n.div_ceil(chunk);
        let barrier = Barrier::new(n_chunks);
        std::thread::scope(|sc| {
            let (envr, qr, br) = (env, &quiesce, &barrier);
            for ch in shards.chunks_mut(chunk) {
                sc.spawn(move || superstep_worker(ch, envr, step, step_start, qr, br));
            }
        });
    }
    quiesce.load(Ordering::SeqCst)
}

/// Run the init phase over all shards (tile-parallel, no barrier needed).
fn run_init<D: Device>(shards: &mut [TileShard<D>], host_threads: usize, env: &Env<'_, D::Msg>) {
    let n = shards.len();
    if host_threads <= 1 || n <= 1 {
        for s in shards.iter_mut() {
            s.init_phase(env);
        }
    } else {
        let workers = host_threads.min(n);
        let chunk = n.div_ceil(workers);
        std::thread::scope(|sc| {
            let envr = env;
            for ch in shards.chunks_mut(chunk) {
                sc.spawn(move || {
                    for s in ch.iter_mut() {
                        s.init_phase(envr);
                    }
                });
            }
        });
    }
}

/// The simulator. Owns the application graph and all cluster state.
pub struct Simulator<D: Device> {
    pub graph: Graph<D>,
    mapping: Mapping,
    cluster: ClusterConfig,
    cost: CostModel,
    cfg: SimConfig,
    /// Immutable after build; flat offsets pre-resolved so the dispatch and
    /// deliver hot paths do no per-event `Arc` or nested-`Vec` traffic.
    plan: McastPlan,
    noc: Noc,
    /// Per-tile mutable state (see [`TileShard`]).
    shards: Vec<TileShard<D>>,
    /// Cached (board, tile, core-in-tile, slot-in-shard) per vertex.
    board_of: Vec<u32>,
    tile_of: Vec<u32>,
    local_core_of: Vec<u32>,
    slot_of: Vec<u32>,
    pending: Vec<SendReq<D::Msg>>,
    seq: u64,
    pub metrics: SimMetrics,
    /// Bounded trace ring, present iff `cfg.trace` is set.  Filled in the
    /// serial shard reduce; handed out via [`Simulator::take_trace`].
    trace: Option<RunTrace>,
    /// Compiled fault schedule (`None` for fault-free runs: the hot paths
    /// pay one `Option` branch).
    fault: Option<FaultPlan>,
    /// Messages owed after dropped crossings, re-sent unicast at the next
    /// superstep's dispatch.
    retrans: Vec<Retransmit<D::Msg>>,
    /// Shard counters folded out of the pre-remap shard set at each
    /// recovery — the shards are rebuilt, but work already executed (and
    /// re-executed during replay) stays accounted.
    carry: Carry,
}

/// Counter carry-over across tile-failure remaps (see `Simulator::carry`).
#[derive(Default)]
struct Carry {
    copies: u64,
    lanes: u64,
    recvs: u64,
    dups: u64,
    core_busy: u64,
    mailbox_busy: u64,
}

impl<D: Device> Simulator<D> {
    pub fn new(
        graph: Graph<D>,
        mapping: Mapping,
        cluster: ClusterConfig,
        cost: CostModel,
        cfg: SimConfig,
    ) -> Self {
        Self::with_scenario(graph, mapping, cluster, cost, cfg, None)
    }

    /// Build a simulator whose NoC follows a heterogeneous [`ScenarioSpec`]
    /// (per-link costs, failed-link reroutes).  `cluster` should be the
    /// scenario's own cluster (`spec.cluster()`); panics on a spec that is
    /// invalid for it — callers parse and validate specs up front.
    pub fn with_scenario(
        graph: Graph<D>,
        mapping: Mapping,
        cluster: ClusterConfig,
        cost: CostModel,
        cfg: SimConfig,
        scenario: Option<&ScenarioSpec>,
    ) -> Self {
        assert_event_fits::<D::Msg>(cost.event_bytes);
        assert_eq!(
            mapping.n_vertices(),
            graph.n_vertices(),
            "mapping covers a different vertex count"
        );
        let (plan, board_of, tile_of, local_core_of, slot_of, shards) =
            Self::layout(&graph, &mapping, &cluster);
        let n_tiles = cluster.total_tiles();
        let fault = scenario.and_then(|s| FaultPlan::build(s, &cluster));

        let mut noc = match scenario {
            Some(spec) => Noc::with_scenario(&cluster, &cost, spec)
                .unwrap_or_else(|e| panic!("invalid scenario: {e}")),
            None => Noc::new(&cluster),
        };
        if cfg.trace.is_some() {
            noc.enable_step_tracking();
        }
        let metrics = SimMetrics {
            board_traffic: vec![[0; 3]; cluster.n_boards],
            ..SimMetrics::default()
        };

        Simulator {
            graph,
            mapping,
            cluster,
            cost,
            cfg,
            plan,
            noc,
            shards,
            board_of,
            tile_of,
            local_core_of,
            slot_of,
            pending: Vec::new(),
            seq: 0,
            metrics,
            trace: cfg.trace.map(|tc| RunTrace::new(tc, n_tiles as u32)),
            fault,
            retrans: Vec::new(),
            carry: Carry::default(),
        }
    }

    /// Build the placement-derived state — multicast plan, per-vertex
    /// location caches, empty tile shards — from a mapping.  Shared by
    /// construction and by the fault plane's remap, which rebuilds all of
    /// it under the post-failure mapping.
    #[allow(clippy::type_complexity)]
    fn layout(
        graph: &Graph<D>,
        mapping: &Mapping,
        cluster: &ClusterConfig,
    ) -> (
        McastPlan,
        Vec<u32>,
        Vec<u32>,
        Vec<u32>,
        Vec<u32>,
        Vec<TileShard<D>>,
    ) {
        let plan = McastPlan::build(graph, mapping, cluster);
        let n_tiles = cluster.total_tiles();
        let cpt = cluster.cores_per_tile;
        let n_v = graph.n_vertices();

        let mut board_of = Vec::with_capacity(n_v);
        let mut tile_of = Vec::with_capacity(n_v);
        let mut local_core_of = Vec::with_capacity(n_v);
        for v in 0..n_v {
            let t = mapping.thread_of(v as VertexId);
            board_of.push(cluster.board_of(t) as u32);
            tile_of.push(cluster.tile_of(t) as u32);
            local_core_of.push((cluster.core_of(t) % cpt) as u32);
        }

        let mut shards: Vec<TileShard<D>> = (0..n_tiles)
            .map(|_| TileShard {
                vertices: Vec::new(),
                devices: Vec::new(),
                core_free: vec![0; cpt],
                core_busy: vec![0; cpt],
                core_vertex_count: vec![0; cpt],
                mailbox: Mailbox::new(),
                queue: Vec::new(),
                out: Vec::new(),
                ctx: Ctx::new(0, 0),
                latest: 0,
                voted_continue: false,
                delivered: false,
                copies_delivered: 0,
                lanes_delivered: 0,
                recv_handlers: 0,
                dup_events: 0,
                t_queue_hw: 0,
                t_copies: 0,
                t_lanes: 0,
                t_col_min: NO_COL,
                t_col_max: 0,
            })
            .collect();
        let mut slot_of = vec![0u32; n_v];
        for v in 0..n_v {
            let shard = &mut shards[tile_of[v] as usize];
            slot_of[v] = shard.vertices.len() as u32;
            shard.vertices.push(v as VertexId);
            shard.core_vertex_count[local_core_of[v] as usize] += 1;
        }
        (plan, board_of, tile_of, local_core_of, slot_of, shards)
    }

    /// Take the captured trace (if tracing was enabled), leaving `None`.
    pub fn take_trace(&mut self) -> Option<RunTrace> {
        self.trace.take()
    }

    pub fn cluster(&self) -> &ClusterConfig {
        &self.cluster
    }

    pub fn mapping(&self) -> &Mapping {
        &self.mapping
    }

    pub fn plan(&self) -> &McastPlan {
        &self.plan
    }

    /// Run to halt (or `max_steps`). Returns the final metrics.
    pub fn run(&mut self) -> &SimMetrics {
        let host_threads = self.cfg.threads.unwrap_or(1).max(1);
        let mut n_sim_threads = self.mapping.n_threads_used();
        let n_vertices = self.graph.n_vertices() as u64;
        let max_steps = self.cfg.max_steps;
        let record_steps = self.cfg.record_steps;
        let trace_env = self.trace.as_ref().map(|t| t.col_stride.unwrap_or(0));

        // Partition the devices into their tile shards (vertex-id order is
        // slot order); restored to the graph before returning.
        let devices = self.graph.take_devices();
        for (v, dev) in devices.into_iter().enumerate() {
            self.shards[self.tile_of[v] as usize].devices.push(dev);
        }

        // Superstep message arena + dispatch metadata, reused across steps.
        let mut arena: Vec<D::Msg> = Vec::new();
        let mut meta: Vec<(VertexId, PortId)> = Vec::new();

        // Superstep 0: init handlers on every device.
        {
            let env = Env {
                plan: &self.plan,
                cost: &self.cost,
                arena: &arena,
                slot_of: &self.slot_of,
                local_core_of: &self.local_core_of,
                n_sim_threads,
                trace: trace_env,
            };
            run_init(&mut self.shards, host_threads, &env);
        }
        let mut now = 0u64;
        for s in &mut self.shards {
            now = now.max(s.latest);
            self.pending.extend(s.out.drain(..));
        }

        let mut step = 0u64;
        // Superstep 0's handler time is folded into the first recorded step
        // so `step_durations.iter().sum() == sim_cycles` (see metrics).
        let mut record_from = 0u64;
        // Fault-plane state: the last barrier-aligned checkpoint, the step
        // horizon below which the loop is replaying destroyed work, and the
        // recovery epoch (trace segment id).
        let mut ckpt: Option<Checkpoint<D::Msg>> = None;
        let mut replay_until = 0u64;
        let mut epoch = 0u32;
        loop {
            // Phase 0 (fault plane, serial): take a due barrier-aligned
            // checkpoint, then fire any tile failures scheduled for this
            // step — remap the dead tiles' vertices onto survivors, rewind
            // to the checkpoint, replay.  Checkpoint-before-fail bounds
            // replay at `fail_step % ckpt_interval` supersteps.
            if self.fault.as_ref().is_some_and(|fp| fp.checkpoint_due(step)) {
                let c = self.capture_checkpoint(step);
                self.metrics.checkpoint_bytes =
                    self.metrics.checkpoint_bytes.max(c.state_bytes());
                ckpt = Some(c);
            }
            let dead = match self.fault.as_mut() {
                Some(fp) => fp.fire_failures(step),
                None => Vec::new(),
            };
            if !dead.is_empty() {
                let c = ckpt.take().expect("a checkpoint precedes every tile failure");
                let penalty = self.recover_from_failure(&dead, &c, step);
                // Time never rolls back: survivors stall for the restore,
                // then replay.  The stall is folded into the last recorded
                // duration so the step timeline still sums to `sim_cycles`.
                now += penalty;
                if record_steps {
                    if let Some(last) = self.metrics.step_durations.last_mut() {
                        *last += penalty;
                        record_from = now;
                    }
                    // else: failure at step 0 — nothing recorded yet (and
                    // nothing to replay); the first step absorbs the stall.
                } else {
                    record_from = now;
                }
                replay_until = replay_until.max(step);
                n_sim_threads = self.mapping.n_threads_used();
                step = c.step;
                epoch += 1;
                if let Some(t) = self.trace.as_mut() {
                    t.segments += 1;
                }
                ckpt = Some(c);
            }

            // Phase 1: fill the arena from the buffered sends, dispatch
            // serially (NoC link clocks + arrival sequencing are global).
            let step_start = now;
            arena.clear();
            meta.clear();
            for (src, port, msg) in self.pending.drain(..) {
                meta.push((src, port));
                arena.push(msg);
            }
            // Outstanding retransmissions ride the same arena after the
            // ordinary sends; crossings dropped during this dispatch (of
            // either kind) re-arm `self.retrans` for the next superstep.
            let n_ordinary = meta.len();
            let resend: Vec<(VertexId, Vec<VertexId>)> = self
                .retrans
                .drain(..)
                .map(|r| {
                    arena.push(r.msg);
                    (r.src, r.dests)
                })
                .collect();
            for (i, &(src, port)) in meta.iter().enumerate() {
                self.dispatch(src, port, i as u32, step_start, &arena[i]);
            }
            for (j, (src, dests)) in resend.iter().enumerate() {
                let idx = n_ordinary + j;
                self.dispatch_retrans(*src, dests, idx as u32, step_start, &arena[idx]);
            }
            // The NoC is mutated only by the serial dispatch above, so the
            // per-superstep link samples are drained here — before the
            // tile-parallel phases — and are thread-count invariant by
            // construction.  Empty when tracing is off.
            let link_samples = if self.trace.is_some() {
                self.noc.take_step_samples()
            } else {
                Vec::new()
            };

            // Phases 2+3: tile-parallel deliver, barrier, step handlers.
            let quiesce = {
                let env = Env {
                    plan: &self.plan,
                    cost: &self.cost,
                    arena: &arena,
                    slot_of: &self.slot_of,
                    local_core_of: &self.local_core_of,
                    n_sim_threads,
                    trace: trace_env,
                };
                run_superstep(&mut self.shards, host_threads, &env, step, step_start)
            };
            let decision = termination::detect(quiesce, n_sim_threads, true, 0, &self.cost);
            self.metrics.barrier_cycles += decision.step_at - quiesce;
            now = decision.step_at;

            // Trace merge happens here, in the serial shard reduce, in tile
            // order — the one place per-shard scratch is read — so the
            // record is bit-identical for every `threads` value.
            if let Some(trace) = self.trace.as_mut() {
                let mut tiles: Vec<TileSample> = Vec::new();
                let mut copies = 0u64;
                let mut lanes = 0u64;
                let mut queue_hw = 0u32;
                let mut col_min = NO_COL;
                let mut col_max = 0u32;
                let mut busy = 0u32;
                for (ti, s) in self.shards.iter().enumerate() {
                    if !s.delivered {
                        continue;
                    }
                    busy += 1;
                    let t_copies = s.copies_delivered - s.t_copies;
                    let t_lanes = s.lanes_delivered - s.t_lanes;
                    copies += t_copies;
                    lanes += t_lanes;
                    queue_hw = queue_hw.max(s.t_queue_hw);
                    let (cmin, cmax) = if s.t_col_min == NO_COL {
                        (NO_COL, NO_COL)
                    } else {
                        col_min = col_min.min(s.t_col_min);
                        col_max = col_max.max(s.t_col_max);
                        (s.t_col_min, s.t_col_max)
                    };
                    tiles.push(TileSample {
                        tile: ti as u32,
                        queue_hw: s.t_queue_hw,
                        copies: t_copies,
                        lanes: t_lanes,
                        col_min: cmin,
                        col_max: cmax,
                    });
                }
                if col_min == NO_COL {
                    col_max = NO_COL;
                }
                let mut link_events = 0u64;
                let mut link_busy = 0u64;
                let links: Vec<LinkSample> = link_samples
                    .iter()
                    .map(|s| {
                        link_events += s.events as u64;
                        link_busy += s.busy;
                        LinkSample {
                            link: s.link,
                            events: s.events,
                            busy: s.busy,
                            queue_hw: s.queue_hw,
                        }
                    })
                    .collect();
                trace.push(StepRecord {
                    segment: epoch,
                    step,
                    t_start: record_from,
                    t_end: now,
                    busy_tiles: busy,
                    copies,
                    lanes,
                    queue_hw,
                    col_min,
                    col_max,
                    link_events,
                    link_busy,
                    tiles,
                    links,
                });
            }

            // Reduce shard outputs: halt votes and next superstep's sends
            // (deterministic tile order).
            let mut all_halt = true;
            let mut busy_tiles = 0u64;
            for s in &mut self.shards {
                all_halt &= !s.voted_continue;
                busy_tiles += s.delivered as u64;
                s.delivered = false;
                self.pending.extend(s.out.drain(..));
            }
            self.metrics.busy_tile_steps += busy_tiles;
            self.metrics.max_busy_tiles = self.metrics.max_busy_tiles.max(busy_tiles);
            self.metrics.step_handlers += n_vertices;
            if record_steps {
                self.metrics.step_durations.push(now - record_from);
            }
            if step < replay_until {
                // This superstep re-executed work a tile failure destroyed.
                self.metrics.recovery_cycles += now - record_from;
            }
            record_from = now;
            step += 1;
            self.metrics.steps = step;

            if all_halt && self.pending.is_empty() && self.retrans.is_empty() {
                break;
            }
            assert!(
                step < max_steps,
                "simulation exceeded max_steps={max_steps} — runaway application?"
            );
        }

        // Account for the final quiesce point (the step handlers that ran
        // after the last barrier); fold the tail into the last recorded step
        // so recorded durations cover the whole timeline exactly.
        let mut end = now;
        for s in &self.shards {
            end = end.max(s.core_free.iter().copied().max().unwrap_or(0));
        }
        if record_steps {
            if let Some(last) = self.metrics.step_durations.last_mut() {
                *last += end - now;
            }
        }
        self.metrics.sim_cycles = end;
        // Carried counters cover shard sets torn down by tile-failure
        // remaps; zero on fault-free runs.
        let mut max_core_busy = self.carry.core_busy;
        let mut max_mailbox_busy = self.carry.mailbox_busy;
        let mut copies = self.carry.copies;
        let mut lanes = self.carry.lanes;
        let mut recvs = self.carry.recvs;
        let mut dups = self.carry.dups;
        for s in &self.shards {
            max_core_busy = max_core_busy.max(s.core_busy.iter().copied().max().unwrap_or(0));
            max_mailbox_busy = max_mailbox_busy.max(s.mailbox.busy_cycles());
            copies += s.copies_delivered;
            lanes += s.lanes_delivered;
            recvs += s.recv_handlers;
            dups += s.dup_events;
        }
        self.metrics.max_core_busy = max_core_busy;
        self.metrics.max_mailbox_busy = max_mailbox_busy;
        self.metrics.copies_delivered = copies;
        self.metrics.lanes_delivered = lanes;
        self.metrics.recv_handlers = recvs;
        self.metrics.dup_events = dups;
        // Link-plane totals: surfaced in every manifest, tracing or not
        // (these are cumulative NoC counters, free to read once per run).
        self.metrics.n_links = self.noc.n_links() as u64;
        self.metrics.link_events_total = self.noc.total_link_events();
        self.metrics.link_busy_total = self.noc.total_link_busy();
        self.metrics.max_link_busy = self.noc.max_link_busy();
        self.metrics.rerouted_sends = self.noc.reroutes();

        self.restore_devices();
        &self.metrics
    }

    /// Simulated wall-clock seconds of the finished run.
    pub fn sim_seconds(&self) -> f64 {
        self.metrics.sim_seconds(self.cluster.clock_hz)
    }

    // ----- internals -------------------------------------------------------

    /// Service one send request: charge the sending core, route over the
    /// NoC, and append one POD group arrival per destination tile queue.
    /// `msg` is the arena slot for `msg_idx` — cloned only if a crossing
    /// drops and the payload must be owed to a retransmission.
    fn dispatch(
        &mut self,
        src: VertexId,
        port: PortId,
        msg_idx: u32,
        step_start: u64,
        msg: &D::Msg,
    ) {
        let src_tile = self.tile_of[src as usize] as usize;
        let lc = self.local_core_of[src as usize] as usize;
        let shard = &mut self.shards[src_tile];
        let t_send = step_start.max(shard.core_free[lc]) + self.cost.send_request;
        shard.core_free[lc] = t_send;
        shard.core_busy[lc] += self.cost.send_request;
        self.metrics.sends += 1;

        let list = self.graph.dest_list(src, port);
        let src_board = self.board_of[src as usize];
        let src_tile_in_board = src_tile % self.cluster.tiles_per_board;
        let mut crossed_board = false;
        for g in self.plan.group_range(list) {
            let (board, tile) = self.plan.group_loc(g);
            let n_copies = self.plan.group_dests(g).len() as u64;
            let mut dup = false;
            let t_arr = if board == src_board {
                if tile as usize == src_tile {
                    self.metrics.intra_tile_copies += n_copies;
                    self.metrics.board_traffic[src_board as usize][0] += n_copies;
                } else {
                    self.metrics.inter_tile_copies += n_copies;
                    self.metrics.board_traffic[src_board as usize][1] += n_copies;
                }
                // Intra-board mesh: per-hop latency.
                let hops = self.cluster.intra_board_hops(
                    src_tile_in_board,
                    tile as usize % self.cluster.tiles_per_board,
                ) as u64;
                t_send + hops * self.cost.hop
            } else {
                // Loss models live on the inter-board links: decide this
                // crossing's fate before any copy accounting so dropped
                // copies never enter the delivered-copy conservation.
                match self.crossing_fate_for(src_board as usize, board as usize) {
                    Some(CrossingFate::Drop) => {
                        // The bits were sent — the links serialise them —
                        // but the crossing is lost; the barrier audit NACKs
                        // it and the sender retransmits next superstep.
                        self.noc.traverse_between(
                            &self.cluster,
                            src_board as usize,
                            board as usize,
                            t_send,
                            &self.cost,
                        );
                        self.metrics.dropped_events += 1;
                        let dests = self.plan.group_dests(g).to_vec();
                        self.retrans.push(Retransmit {
                            src,
                            port,
                            msg: msg.clone(),
                            dests,
                        });
                        continue;
                    }
                    Some(CrossingFate::Dup) => dup = true,
                    _ => {}
                }
                crossed_board = true;
                self.metrics.inter_board_copies += n_copies;
                self.metrics.board_traffic[src_board as usize][2] += n_copies;
                // Inter-board: failure-aware over board links (serialised per
                // event per link; dimension-ordered unless a scenario failed
                // links), then worst-case half-mesh to the tile.
                let t_board = self.noc.traverse_between(
                    &self.cluster,
                    src_board as usize,
                    board as usize,
                    t_send,
                    &self.cost,
                );
                let ingress_hops = (self.cluster.tile_mesh.0 + self.cluster.tile_mesh.1) as u64 / 2;
                t_board + ingress_hops * self.cost.hop
            };
            self.seq += 1;
            self.shards[tile as usize].queue.push(GroupArrival {
                t: t_arr,
                seq: self.seq,
                src,
                group: g as u32,
                msg_idx,
                flags: 0,
            });
            if dup {
                // The spurious copy crossed the links too: charge a second
                // traversal, flag the arrival for mailbox suppression.
                let t_board = self.noc.traverse_between(
                    &self.cluster,
                    src_board as usize,
                    board as usize,
                    t_send,
                    &self.cost,
                );
                let ingress_hops = (self.cluster.tile_mesh.0 + self.cluster.tile_mesh.1) as u64 / 2;
                self.seq += 1;
                self.shards[tile as usize].queue.push(GroupArrival {
                    t: t_board + ingress_hops * self.cost.hop,
                    seq: self.seq,
                    src,
                    group: g as u32,
                    msg_idx,
                    flags: FLAG_DUP,
                });
            }
        }
        if crossed_board {
            self.metrics.inter_board_sends += 1;
        }
    }

    /// Loss-model fate of one `from → to` board crossing; `None` on
    /// lossless runs (one `Option` branch, no route materialised).
    fn crossing_fate_for(&mut self, from: usize, to: usize) -> Option<CrossingFate> {
        let fp = self.fault.as_mut()?;
        if !fp.has_loss() {
            return None;
        }
        let route = self.noc.route_between(&self.cluster, from, to);
        Some(fp.crossing_fate(&route))
    }

    /// Re-send messages owed after dropped crossings: unicast, one
    /// send-request charge and one crossing per missing destination (the
    /// multicast amortisation is lost), plus the NACK round-trip latency.
    /// A retransmission may itself be dropped and is then owed again.
    fn dispatch_retrans(
        &mut self,
        src: VertexId,
        dests: &[VertexId],
        msg_idx: u32,
        step_start: u64,
        msg: &D::Msg,
    ) {
        let src_tile = self.tile_of[src as usize] as usize;
        let lc = self.local_core_of[src as usize] as usize;
        let src_board = self.board_of[src as usize];
        let src_tile_in_board = src_tile % self.cluster.tiles_per_board;
        let ingress_hops = (self.cluster.tile_mesh.0 + self.cluster.tile_mesh.1) as u64 / 2;
        let mut crossed_board = false;
        for &d in dests {
            let shard = &mut self.shards[src_tile];
            let t_send = step_start.max(shard.core_free[lc]) + self.cost.send_request;
            shard.core_free[lc] = t_send;
            shard.core_busy[lc] += self.cost.send_request;
            self.metrics.sends += 1;

            let board = self.board_of[d as usize];
            let tile = self.tile_of[d as usize] as usize;
            let t_arr = if board == src_board {
                // A remap may have moved the destination next to the
                // sender; the re-send then stays on the board mesh.
                if tile == src_tile {
                    self.metrics.intra_tile_copies += 1;
                    self.metrics.board_traffic[src_board as usize][0] += 1;
                } else {
                    self.metrics.inter_tile_copies += 1;
                    self.metrics.board_traffic[src_board as usize][1] += 1;
                }
                let hops = self
                    .cluster
                    .intra_board_hops(src_tile_in_board, tile % self.cluster.tiles_per_board)
                    as u64;
                t_send + hops * self.cost.hop
            } else {
                if let Some(CrossingFate::Drop) =
                    self.crossing_fate_for(src_board as usize, board as usize)
                {
                    // Dropped again: still owed.  (A duplicated
                    // retransmission is suppressed like any duplicate;
                    // nothing observable beyond timing noise the first
                    // transmission already models, so it is not re-rolled.)
                    self.noc.traverse_between(
                        &self.cluster,
                        src_board as usize,
                        board as usize,
                        t_send,
                        &self.cost,
                    );
                    self.metrics.dropped_events += 1;
                    self.retrans.push(Retransmit {
                        src,
                        port: 0,
                        msg: msg.clone(),
                        dests: vec![d],
                    });
                    continue;
                }
                crossed_board = true;
                self.metrics.inter_board_copies += 1;
                self.metrics.board_traffic[src_board as usize][2] += 1;
                let t_board = self.noc.traverse_between(
                    &self.cluster,
                    src_board as usize,
                    board as usize,
                    t_send,
                    &self.cost,
                );
                t_board + ingress_hops * self.cost.hop
            };
            self.metrics.retransmits += 1;
            self.seq += 1;
            self.shards[tile].queue.push(GroupArrival {
                t: t_arr + NACK_PENALTY_CYCLES,
                seq: self.seq,
                src,
                group: d,
                msg_idx,
                flags: FLAG_RETRANS,
            });
        }
        if crossed_board {
            self.metrics.inter_board_sends += 1;
        }
    }

    /// Serialise a barrier-aligned recovery point: the superstep number,
    /// the sends pending at this barrier, retransmissions still owed and
    /// every device's snapshot (vertex order).  Hard error if any device
    /// opted out of checkpointing — a scheduled tile failure cannot be
    /// honoured without it.
    fn capture_checkpoint(&self, step: u64) -> Checkpoint<D::Msg> {
        let n = self.graph.n_vertices();
        let mut bytes = Vec::new();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        for v in 0..n {
            let shard = &self.shards[self.tile_of[v] as usize];
            let dev = &shard.devices[self.slot_of[v] as usize];
            assert!(
                dev.snapshot(&mut bytes),
                "scenario schedules a tile failure but device type {} does not \
                 implement Device::snapshot — checkpointing is impossible",
                std::any::type_name::<D>()
            );
            offsets.push(bytes.len() as u32);
        }
        Checkpoint {
            step,
            pending: self.pending.clone(),
            retrans: self.retrans.clone(),
            bytes,
            offsets,
        }
    }

    /// Tile failure at the top of superstep `at_step`: fold the doomed
    /// shard set's counters into the carries, rewind every device to the
    /// checkpoint, remap the dead tiles' vertices round-robin onto the
    /// surviving tiles, rebuild the placement-derived state and restore
    /// the event plane.  Returns the restore stall in cycles.
    fn recover_from_failure(
        &mut self,
        dead: &[usize],
        ckpt: &Checkpoint<D::Msg>,
        at_step: u64,
    ) -> u64 {
        // Work executed before the failure stays executed (and paid for):
        // the shards are about to be rebuilt, so bank their counters.
        for s in &self.shards {
            self.carry.copies += s.copies_delivered;
            self.carry.lanes += s.lanes_delivered;
            self.carry.recvs += s.recv_handlers;
            self.carry.dups += s.dup_events;
            self.carry.core_busy = self
                .carry
                .core_busy
                .max(s.core_busy.iter().copied().max().unwrap_or(0));
            self.carry.mailbox_busy = self.carry.mailbox_busy.max(s.mailbox.busy_cycles());
        }

        // Pull every device out of its shard (vertex order) and rewind it.
        let n = self.graph.n_vertices();
        let mut slots: Vec<Option<D>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for s in &mut self.shards {
            for (slot, dev) in s.devices.drain(..).enumerate() {
                slots[s.vertices[slot] as usize] = Some(dev);
            }
        }
        let mut devices: Vec<D> = slots
            .into_iter()
            .map(|d| d.expect("every device accounted for"))
            .collect();
        for (v, dev) in devices.iter_mut().enumerate() {
            let (a, b) = (ckpt.offsets[v] as usize, ckpt.offsets[v + 1] as usize);
            dev.restore(&ckpt.bytes[a..b]);
        }

        // Remap: every vertex on a dead tile moves to a surviving tile,
        // round-robin over tiles then over threads within each tile —
        // deterministic, placement changes dosages by nothing (canonical
        // reductions) and timing only through the new contention pattern.
        let all_dead = self
            .fault
            .as_ref()
            .expect("recovery implies a fault plan")
            .dead_tiles();
        let survivors: Vec<usize> = (0..self.cluster.total_tiles())
            .filter(|t| !all_dead.contains(t))
            .collect();
        assert!(!survivors.is_empty(), "tile failures killed every tile");
        let tpt = self.cluster.threads_per_tile();
        let mut cursor = 0usize;
        let assignment: Vec<ThreadId> = (0..n)
            .map(|v| {
                let t = self.mapping.thread_of(v as VertexId);
                if all_dead.contains(&self.cluster.tile_of(t)) {
                    let target = survivors[cursor % survivors.len()];
                    let lane = (cursor / survivors.len()) % tpt;
                    cursor += 1;
                    ThreadId((target * tpt + lane) as u32)
                } else {
                    t
                }
            })
            .collect();
        self.mapping = Mapping::from_assignment(assignment, &self.cluster);
        let (plan, board_of, tile_of, local_core_of, slot_of, shards) =
            Self::layout(&self.graph, &self.mapping, &self.cluster);
        self.plan = plan;
        self.board_of = board_of;
        self.tile_of = tile_of;
        self.local_core_of = local_core_of;
        self.slot_of = slot_of;
        self.shards = shards;
        for (v, dev) in devices.into_iter().enumerate() {
            self.shards[self.tile_of[v] as usize].devices.push(dev);
        }

        // Rewind the event plane to the checkpoint barrier.
        self.pending = ckpt.pending.clone();
        self.retrans = ckpt.retrans.clone();

        self.metrics.failed_tiles += dead.len() as u64;
        self.metrics.replayed_supersteps += at_step - ckpt.step;
        let penalty = FaultPlan::restore_cycles(ckpt.state_bytes());
        self.metrics.recovery_cycles += penalty;
        penalty
    }

    /// Hand the devices back to the graph in vertex-id order.
    fn restore_devices(&mut self) {
        let n = self.graph.n_vertices();
        let mut slots: Vec<Option<D>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for s in &mut self.shards {
            for (slot, dev) in s.devices.drain(..).enumerate() {
                slots[s.vertices[slot] as usize] = Some(dev);
            }
        }
        self.graph.restore_devices(
            slots
                .into_iter()
                .map(|d| d.expect("every device accounted for"))
                .collect(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::fault::{SnapReader, SnapWriter};
    use crate::graph::builder::GraphBuilder;
    use crate::graph::mapping::Mapping;

    /// Ring of N devices passing a token `rounds` times.
    struct Ring {
        hops_seen: u32,
        rounds: u32,
        is_seed: bool,
        pending_send: Option<u32>,
    }

    impl Device for Ring {
        type Msg = u32;
        fn init(&mut self, ctx: &mut Ctx<u32>) {
            if self.is_seed {
                ctx.send(0, 0);
            }
        }
        fn recv(&mut self, msg: &u32, _src: VertexId, ctx: &mut Ctx<u32>) {
            self.hops_seen += 1;
            ctx.flop(1);
            if *msg < self.rounds {
                // Forward at the *next* step (buffered via pending_send so the
                // test also exercises step-handler sends).
                self.pending_send = Some(*msg + 1);
            }
        }
        fn step(&mut self, ctx: &mut Ctx<u32>) -> bool {
            if let Some(m) = self.pending_send.take() {
                ctx.send(0, m);
                true
            } else {
                false
            }
        }
        fn snapshot(&self, out: &mut Vec<u8>) -> bool {
            let mut w = SnapWriter::new(out);
            w.u32(self.hops_seen);
            w.u32(self.pending_send.map_or(u32::MAX, |v| v));
            true
        }
        fn restore(&mut self, bytes: &[u8]) {
            let mut r = SnapReader::new(bytes);
            self.hops_seen = r.u32();
            self.pending_send = match r.u32() {
                u32::MAX => None,
                v => Some(v),
            };
            assert!(r.exhausted());
        }
    }

    fn ring_sim_threads(n: usize, rounds: u32, threads: Option<usize>) -> Simulator<Ring> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(Ring {
                hops_seen: 0,
                rounds,
                is_seed: i == 0,
                pending_send: None,
            });
        }
        for v in 0..n as u32 {
            b.add_port_to(v, vec![(v + 1) % n as u32]);
        }
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let mapping = Mapping::round_robin(n, &cluster);
        Simulator::new(
            g,
            mapping,
            cluster,
            CostModel::default(),
            SimConfig {
                threads,
                ..SimConfig::default()
            },
        )
    }

    fn ring_sim(n: usize, rounds: u32) -> Simulator<Ring> {
        ring_sim_threads(n, rounds, None)
    }

    #[test]
    fn token_ring_delivers_every_hop() {
        let mut sim = ring_sim(8, 23);
        sim.run();
        let total: u32 = sim.graph.devices.iter().map(|d| d.hops_seen).sum();
        assert_eq!(total, 24); // msgs 0..=23 delivered once each
        assert_eq!(sim.metrics.sends, 24);
        assert_eq!(sim.metrics.copies_delivered, 24);
        // Scalar messages: one lane per copy (the Device::lanes default).
        assert_eq!(sim.metrics.lanes_delivered, 24);
        assert!(sim.metrics.sim_cycles > 0);
        // Occupancy probe: the token visits one tile per superstep.
        assert!(sim.metrics.busy_tile_steps >= 24);
        assert!(sim.metrics.max_busy_tiles >= 1);
    }

    #[test]
    fn time_advances_monotonically_with_work() {
        let short = {
            let mut s = ring_sim(4, 3);
            s.run();
            s.metrics.sim_cycles
        };
        let long = {
            let mut s = ring_sim(4, 30);
            s.run();
            s.metrics.sim_cycles
        };
        assert!(long > short, "{long} vs {short}");
    }

    #[test]
    fn parallel_run_is_bit_identical_to_serial() {
        // The execution-semantics contract: thread count changes host
        // wall-clock only.  Same graph, same mapping, 1 vs 4 workers.
        let mut serial = ring_sim_threads(12, 17, None);
        serial.run();
        let mut parallel = ring_sim_threads(12, 17, Some(4));
        parallel.run();
        let hops = |s: &Simulator<Ring>| -> Vec<u32> {
            s.graph.devices.iter().map(|d| d.hops_seen).collect()
        };
        assert_eq!(hops(&serial), hops(&parallel));
        assert_eq!(serial.metrics.sim_cycles, parallel.metrics.sim_cycles);
        assert_eq!(serial.metrics.sends, parallel.metrics.sends);
        assert_eq!(
            serial.metrics.copies_delivered,
            parallel.metrics.copies_delivered
        );
        assert_eq!(serial.metrics.steps, parallel.metrics.steps);
        assert_eq!(
            serial.metrics.step_durations,
            parallel.metrics.step_durations
        );
        assert_eq!(
            serial.metrics.busy_tile_steps,
            parallel.metrics.busy_tile_steps
        );
        assert_eq!(serial.metrics.max_busy_tiles, parallel.metrics.max_busy_tiles);
    }

    #[test]
    fn trace_capture_is_bit_identical_across_threads() {
        let run = |threads: Option<usize>| {
            let mut b = GraphBuilder::new();
            for i in 0..12 {
                b.add_vertex(Ring {
                    hops_seen: 0,
                    rounds: 17,
                    is_seed: i == 0,
                    pending_send: None,
                });
            }
            for v in 0..12u32 {
                b.add_port_to(v, vec![(v + 1) % 12]);
            }
            let cluster = ClusterConfig::tiny();
            let mapping = Mapping::round_robin(12, &cluster);
            let mut sim = Simulator::new(
                b.build(),
                mapping,
                cluster,
                CostModel::default(),
                SimConfig {
                    threads,
                    trace: Some(TraceConfig { max_steps: 0, col_stride: Some(3) }),
                    ..SimConfig::default()
                },
            );
            sim.run();
            (sim.take_trace().expect("tracing was enabled"), sim.metrics.steps)
        };
        let (serial, serial_steps) = run(None);
        let (parallel, _) = run(Some(4));
        assert_eq!(serial, parallel, "trace must be thread-count invariant");
        assert_eq!(serial.total_steps, serial_steps);
        assert_eq!(serial.total_steps, serial.steps.len() as u64, "unbounded ring drops nothing");
        assert!(serial.steps.iter().any(|r| !r.tiles.is_empty()));
        // Column attribution: vertex v maps to column v / 3.
        let max_col = serial
            .steps
            .iter()
            .filter(|r| r.col_max != NO_COL)
            .map(|r| r.col_max)
            .max()
            .expect("some step has column attribution");
        assert!(max_col <= 11 / 3);
        // Tracing off: no trace is allocated at all.
        let mut off = ring_sim_threads(4, 3, None);
        off.run();
        assert!(off.take_trace().is_none());
    }

    #[test]
    fn step_durations_sum_to_sim_cycles() {
        // Superstep 0 (init) and the trailing step-handler work are folded
        // into the recorded timeline.
        let mut sim = ring_sim(6, 9);
        sim.run();
        assert_eq!(
            sim.metrics.step_durations.iter().sum::<u64>(),
            sim.metrics.sim_cycles
        );
    }

    #[test]
    fn devices_restored_after_run() {
        let mut sim = ring_sim(5, 2);
        sim.run();
        assert_eq!(sim.graph.devices.len(), 5);
        // Slot order round-trips to vertex-id order: the seed is vertex 0.
        assert!(sim.graph.devices[0].is_seed);
        assert!(!sim.graph.devices[1].is_seed);
    }

    /// A broadcaster fanning out to N listeners through one multicast send.
    struct Fan {
        n_recv: u32,
        is_root: bool,
    }
    impl Device for Fan {
        type Msg = f32;
        fn init(&mut self, ctx: &mut Ctx<f32>) {
            if self.is_root {
                ctx.send(0, 1.5);
            }
        }
        fn recv(&mut self, msg: &f32, _src: VertexId, ctx: &mut Ctx<f32>) {
            assert_eq!(*msg, 1.5);
            self.n_recv += 1;
            ctx.flop(2);
        }
        fn step(&mut self, _ctx: &mut Ctx<f32>) -> bool {
            false
        }
    }

    #[test]
    fn multicast_delivers_one_copy_each() {
        let mut b = GraphBuilder::new();
        let root = b.add_vertex(Fan {
            n_recv: 0,
            is_root: true,
        });
        let listeners: Vec<VertexId> = (0..50)
            .map(|_| {
                b.add_vertex(Fan {
                    n_recv: 0,
                    is_root: false,
                })
            })
            .collect();
        b.add_port_to(root, listeners.clone());
        // Listeners need a port too? No — only senders need ports.
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let mapping = Mapping::round_robin(51, &cluster);
        let mut sim = Simulator::new(g, mapping, cluster, CostModel::default(), SimConfig::default());
        sim.run();
        assert_eq!(sim.metrics.sends, 1, "multicast is ONE send request");
        assert_eq!(sim.metrics.copies_delivered, 50);
        for &l in &listeners {
            assert_eq!(sim.graph.devices[l as usize].n_recv, 1);
        }
        // Mailbox fan-in must have serialised copies: busiest mailbox saw
        // multiple ingress slots.
        assert!(sim.metrics.max_mailbox_busy >= 2 * CostModel::default().mailbox_ingress);
    }

    #[test]
    fn inter_board_traffic_counted() {
        // Map sender to board 0, receiver to board 1 via explicit assignment.
        let mut b = GraphBuilder::new();
        let a = b.add_vertex(Fan {
            n_recv: 0,
            is_root: true,
        });
        let z = b.add_vertex(Fan {
            n_recv: 0,
            is_root: false,
        });
        b.add_port_to(a, vec![z]);
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let tpb = cluster.threads_per_board() as u32;
        let mapping = Mapping::from_assignment(
            vec![
                crate::poets::topology::ThreadId(0),
                crate::poets::topology::ThreadId(tpb), // first thread of board 1
            ],
            &cluster,
        );
        let mut sim = Simulator::new(g, mapping, cluster, CostModel::default(), SimConfig::default());
        sim.run();
        assert_eq!(sim.metrics.inter_board_sends, 1);
        assert_eq!(sim.graph.devices[1].n_recv, 1);
    }

    #[test]
    fn traffic_split_conserves_copies() {
        // Every delivered copy is classified exactly once, and the per-board
        // split sums to the same totals — tracing off, so this also covers
        // the "link totals surface without tracing" satellite.
        let mut sim = ring_sim(12, 17);
        sim.run();
        let m = &sim.metrics;
        assert_eq!(
            m.intra_tile_copies + m.inter_tile_copies + m.inter_board_copies,
            m.copies_delivered
        );
        let board_sum: u64 = m.board_traffic.iter().map(|t| t[0] + t[1] + t[2]).sum();
        assert_eq!(board_sum, m.copies_delivered);
        assert_eq!(m.board_traffic.len(), ClusterConfig::tiny().n_boards);
        // Round-robin over a 2-board tiny cluster crosses the board link.
        assert!(m.inter_board_copies > 0);
        assert_eq!(m.n_links, (ClusterConfig::tiny().n_boards * 4) as u64);
        assert!(m.link_events_total > 0);
        assert!(m.max_link_busy > 0);
        assert!(m.link_busy_total >= m.max_link_busy);
        assert_eq!(m.rerouted_sends, 0);
    }

    #[test]
    fn degraded_scenario_slows_the_run() {
        let run = |scenario: Option<&ScenarioSpec>| {
            let mut b = GraphBuilder::new();
            for i in 0..12 {
                b.add_vertex(Ring {
                    hops_seen: 0,
                    rounds: 17,
                    is_seed: i == 0,
                    pending_send: None,
                });
            }
            for v in 0..12u32 {
                b.add_port_to(v, vec![(v + 1) % 12]);
            }
            let cluster = scenario.map(|s| s.cluster()).unwrap_or_else(ClusterConfig::tiny);
            let mapping = Mapping::round_robin(12, &cluster);
            let mut sim = Simulator::with_scenario(
                b.build(),
                mapping,
                cluster,
                CostModel::default(),
                SimConfig::default(),
                scenario,
            );
            sim.run();
            sim.metrics.clone()
        };
        // Same shape as tiny(): 2 boards, 4 tiles, 2 cores, 4 threads.
        let spec = ScenarioSpec::parse("boards=2,tiles=4,cores=2,threads=4,bw=0.125,lat=4")
            .expect("valid scenario");
        let nominal = run(None);
        let degraded = run(Some(&spec));
        assert!(
            degraded.sim_cycles > nominal.sim_cycles,
            "eighth-bandwidth links must cost cycles: {} vs {}",
            degraded.sim_cycles,
            nominal.sim_cycles
        );
        assert_eq!(degraded.copies_delivered, nominal.copies_delivered);
        assert!(degraded.max_link_busy > nominal.max_link_busy);
    }

    #[test]
    fn failed_link_reroutes_traffic() {
        // 8 small boards on a 4x2 grid; fail 0->1 East so that pair detours.
        let spec = ScenarioSpec::parse("boards=8,tiles=2,cores=1,threads=2,fail=0E")
            .expect("valid scenario");
        let cluster = spec.cluster();
        let tpb = cluster.threads_per_board() as u32;
        let mut b = GraphBuilder::new();
        let a = b.add_vertex(Fan {
            n_recv: 0,
            is_root: true,
        });
        let z = b.add_vertex(Fan {
            n_recv: 0,
            is_root: false,
        });
        b.add_port_to(a, vec![z]);
        let mapping = Mapping::from_assignment(
            vec![
                crate::poets::topology::ThreadId(0),
                crate::poets::topology::ThreadId(tpb), // first thread of board 1
            ],
            &cluster,
        );
        let mut sim = Simulator::with_scenario(
            b.build(),
            mapping,
            cluster,
            CostModel::default(),
            SimConfig::default(),
            Some(&spec),
        );
        sim.run();
        assert_eq!(sim.graph.devices[1].n_recv, 1, "delivery survives the failure");
        assert_eq!(sim.metrics.rerouted_sends, 1);
        // The detour is 3 links instead of 1.
        assert_eq!(sim.metrics.link_events_total, 3);
    }

    #[test]
    fn steps_counted_and_barrier_charged() {
        let mut sim = ring_sim(6, 11);
        sim.run();
        assert!(sim.metrics.steps >= 11);
        assert!(sim.metrics.barrier_cycles > 0);
        assert_eq!(
            sim.metrics.step_durations.len() as u64,
            sim.metrics.steps
        );
    }

    #[test]
    #[should_panic(expected = "max_steps")]
    fn runaway_detected() {
        // A device that always keeps sending.
        struct Loop;
        impl Device for Loop {
            type Msg = u8;
            fn init(&mut self, ctx: &mut Ctx<u8>) {
                ctx.send(0, 0);
            }
            fn recv(&mut self, _m: &u8, _s: VertexId, ctx: &mut Ctx<u8>) {
                ctx.send(0, 0);
            }
            fn step(&mut self, _ctx: &mut Ctx<u8>) -> bool {
                true
            }
        }
        let mut b = GraphBuilder::new();
        let v = b.add_vertex(Loop);
        b.add_port_to(v, vec![v]);
        let g = b.build();
        let cluster = ClusterConfig::tiny();
        let mapping = Mapping::round_robin(1, &cluster);
        let mut sim = Simulator::new(
            g,
            mapping,
            cluster,
            CostModel::default(),
            SimConfig {
                max_steps: 50,
                record_steps: false,
                threads: None,
                trace: None,
            },
        );
        sim.run();
    }

    /// Small shape where a 12-vertex round-robin ring definitely crosses
    /// boards (4 threads per board): edge 3→4 rides link 0E, 7→8 rides 1W.
    const FAULT_SHAPE: &str = "boards=2,tiles=2,cores=1,threads=2";

    /// Run a ring under an optional scenario spec; returns per-device hop
    /// counts (the functional result) and the metrics.
    fn ring_run(
        n: usize,
        rounds: u32,
        threads: Option<usize>,
        spec: Option<&str>,
    ) -> (Vec<u32>, SimMetrics) {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(Ring {
                hops_seen: 0,
                rounds,
                is_seed: i == 0,
                pending_send: None,
            });
        }
        for v in 0..n as u32 {
            b.add_port_to(v, vec![(v + 1) % n as u32]);
        }
        let parsed = spec.map(|s| ScenarioSpec::parse(s).expect("valid scenario"));
        let cluster = parsed
            .as_ref()
            .map(|s| s.cluster())
            .unwrap_or_else(ClusterConfig::tiny);
        let mapping = Mapping::round_robin(n, &cluster);
        let mut sim = Simulator::with_scenario(
            b.build(),
            mapping,
            cluster,
            CostModel::default(),
            SimConfig {
                threads,
                ..SimConfig::default()
            },
            parsed.as_ref(),
        );
        sim.run();
        let hops = sim.graph.devices.iter().map(|d| d.hops_seen).collect();
        (hops, sim.metrics.clone())
    }

    #[test]
    fn tile_failure_replays_to_identical_results() {
        let (clean_hops, clean) = ring_run(12, 17, None, Some(FAULT_SHAPE));
        // Board 1 tile 0 (vertices 4 and 5) dies at step 6; checkpoint
        // cadence 4 bounds replay to supersteps 4 and 5.
        let spec = format!("{FAULT_SHAPE},failtile=1.0@6,ckpt=4");
        let (hops, m) = ring_run(12, 17, None, Some(&spec));
        assert_eq!(hops, clean_hops, "remap-and-replay must not change results");
        assert_eq!(m.failed_tiles, 1);
        assert_eq!(m.replayed_supersteps, 2);
        assert!(m.recovery_cycles > 0);
        assert!(m.checkpoint_bytes > 0);
        assert!(m.sim_cycles > clean.sim_cycles, "recovery must cost cycles");
        // The step timeline stays exact: one recorded duration per executed
        // superstep (logical + replayed), summing to sim_cycles.
        assert_eq!(m.step_durations.len() as u64, m.steps + m.replayed_supersteps);
        assert_eq!(m.step_durations.iter().sum::<u64>(), m.sim_cycles);
        // The whole recovery timeline is thread-count invariant.
        let (hops4, m4) = ring_run(12, 17, Some(4), Some(&spec));
        assert_eq!(hops, hops4);
        assert_eq!(m.sim_cycles, m4.sim_cycles);
        assert_eq!(m.sends, m4.sends);
        assert_eq!(m.recovery_cycles, m4.recovery_cycles);
        assert_eq!(m.step_durations, m4.step_durations);
    }

    #[test]
    fn dropped_crossings_are_retransmitted_exactly_once_each() {
        let (clean_hops, clean) = ring_run(12, 59, None, Some(FAULT_SHAPE));
        let spec = format!("{FAULT_SHAPE},drop=0E:0.7@5,drop=1W:0.7@11");
        let (hops, m) = ring_run(12, 59, None, Some(&spec));
        assert_eq!(hops, clean_hops, "drops must be invisible after retransmit");
        assert!(m.dropped_events > 0, "schedule must actually drop");
        assert!(m.retransmits > 0);
        assert_eq!(m.dup_events, 0);
        assert_eq!(
            m.copies_delivered, clean.copies_delivered,
            "every copy delivered exactly once"
        );
        assert_eq!(m.recv_handlers, clean.recv_handlers);
        assert!(m.sim_cycles > clean.sim_cycles, "NACKs must cost cycles");
        let (hops2, m2) = ring_run(12, 59, Some(2), Some(&spec));
        assert_eq!(hops, hops2);
        assert_eq!(m.sim_cycles, m2.sim_cycles);
        assert_eq!(m.dropped_events, m2.dropped_events);
        assert_eq!(m.retransmits, m2.retransmits);
    }

    #[test]
    fn duplicated_crossings_are_suppressed() {
        let (clean_hops, clean) = ring_run(12, 59, None, Some(FAULT_SHAPE));
        let spec = format!("{FAULT_SHAPE},dup=0E:0.7@3,dup=1W:0.7@9");
        let (hops, m) = ring_run(12, 59, None, Some(&spec));
        assert_eq!(hops, clean_hops, "duplicates must never reach handlers");
        assert!(m.dup_events > 0, "schedule must actually duplicate");
        assert_eq!(m.dropped_events, 0);
        assert_eq!(m.copies_delivered, clean.copies_delivered);
        assert_eq!(m.recv_handlers, clean.recv_handlers);
        assert_eq!(m.steps, clean.steps, "suppression is timing-only noise");
        let (hops2, m2) = ring_run(12, 59, Some(4), Some(&spec));
        assert_eq!(hops, hops2);
        assert_eq!(m.dup_events, m2.dup_events);
        assert_eq!(m.sim_cycles, m2.sim_cycles);
    }

    #[test]
    #[should_panic(expected = "snapshot")]
    fn tile_failure_requires_snapshot_support() {
        // Fan keeps the Device::snapshot default (opted out), so a schedule
        // with a tile failure must fail fast at the first checkpoint.
        let spec = ScenarioSpec::parse("boards=2,tiles=2,cores=1,threads=2,failtile=0.0@2")
            .expect("valid scenario");
        let cluster = spec.cluster();
        let mut b = GraphBuilder::new();
        let a = b.add_vertex(Fan {
            n_recv: 0,
            is_root: true,
        });
        let z = b.add_vertex(Fan {
            n_recv: 0,
            is_root: false,
        });
        b.add_port_to(a, vec![z]);
        let mapping = Mapping::round_robin(2, &cluster);
        let mut sim = Simulator::with_scenario(
            b.build(),
            mapping,
            cluster,
            CostModel::default(),
            SimConfig::default(),
            Some(&spec),
        );
        sim.run();
    }
}
