//! Tile mailbox model.
//!
//! Each tile's four cores share one mailbox (Fig 2).  Incoming event copies
//! are ingested serially — one copy per destination software thread — which
//! makes the mailbox the fan-in bottleneck the paper identifies: a vertex
//! with |H| predecessors causes |H| serialised ingest operations per wave at
//! its tile.  Ingest is FIFO in arrival order (the simulator delivers each
//! tile's group arrivals in time order from its per-tile queue).
//!
//! [`Mailbox`] is the single-tile state; the delivery engine embeds one per
//! tile shard so that the deliver phase mutates strictly tile-local state.
//! [`MailboxBank`] is a convenience wrapper (indexed collection) kept for
//! standalone mailbox modelling and its own invariant tests; the simulator
//! itself no longer uses it.

use super::costmodel::CostModel;

/// Busy-until state of one tile's mailbox.
#[derive(Clone, Copy, Debug, Default)]
pub struct Mailbox {
    free: u64,
    busy: u64,
    copies: u64,
}

impl Mailbox {
    pub fn new() -> Mailbox {
        Mailbox::default()
    }

    /// Ingest `n_copies` event copies arriving at `t`; returns the time the
    /// first copy is ready for its handler.  Copy `i`'s ready time is
    /// `ret + i * ingress`.
    pub fn ingest(&mut self, t: u64, n_copies: usize, cost: &CostModel) -> u64 {
        let start = t.max(self.free);
        let work = n_copies as u64 * cost.mailbox_ingress;
        self.free = start + work;
        self.busy += work;
        self.copies += n_copies as u64;
        start + cost.mailbox_ingress
    }

    /// Suppress a duplicate group arrival: the mailbox recognises the
    /// repeated (sender, superstep) sequence number, serialises one
    /// ingress-slot's worth of detection work for the whole group, and
    /// discards it — no handler ready time, no copies accounted.
    pub fn suppress_dup(&mut self, t: u64, cost: &CostModel) {
        let start = t.max(self.free);
        self.free = start + cost.mailbox_ingress;
        self.busy += cost.mailbox_ingress;
    }

    /// Queueing delay visible to an arrival at time `t`.
    pub fn backlog(&self, t: u64) -> u64 {
        self.free.saturating_sub(t)
    }

    /// Busy-until clock (the time this mailbox next idles).
    pub fn free_at(&self) -> u64 {
        self.free
    }

    /// Cumulative busy cycles.
    pub fn busy_cycles(&self) -> u64 {
        self.busy
    }

    /// Total copies ingested.
    pub fn copies(&self) -> u64 {
        self.copies
    }

    /// Floor the busy-until clock to `t` (superstep boundary).
    pub fn advance_to(&mut self, t: u64) {
        self.free = self.free.max(t);
    }
}

/// Indexed mailbox collection (one per tile).
#[derive(Clone, Debug)]
pub struct MailboxBank {
    boxes: Vec<Mailbox>,
}

impl MailboxBank {
    pub fn new(n_tiles: usize) -> MailboxBank {
        MailboxBank {
            boxes: vec![Mailbox::new(); n_tiles],
        }
    }

    /// Ingest at one tile; see [`Mailbox::ingest`].
    pub fn ingest(&mut self, tile: usize, t: u64, n_copies: usize, cost: &CostModel) -> u64 {
        self.boxes[tile].ingest(t, n_copies, cost)
    }

    /// Queueing delay currently visible at a tile arriving at time `t`.
    pub fn backlog(&self, tile: usize, t: u64) -> u64 {
        self.boxes[tile].backlog(t)
    }

    pub fn max_free(&self) -> u64 {
        self.boxes.iter().map(|b| b.free_at()).max().unwrap_or(0)
    }

    /// Cumulative busy cycles of the most-loaded mailbox.
    pub fn max_busy(&self) -> u64 {
        self.boxes.iter().map(|b| b.busy_cycles()).max().unwrap_or(0)
    }

    pub fn total_copies(&self) -> u64 {
        self.boxes.iter().map(|b| b.copies()).sum()
    }

    /// Reset busy-until clocks to `t` (superstep boundary) keeping counters.
    pub fn advance_to(&mut self, t: u64) {
        for b in &mut self.boxes {
            b.advance_to(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingest_serialises_fifo() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(2);
        let r1 = mb.ingest(0, 100, 4, &cost);
        assert_eq!(r1, 100 + cost.mailbox_ingress);
        // Next group at the same tile queues behind all 4 copies.
        let r2 = mb.ingest(0, 100, 1, &cost);
        assert_eq!(r2, 100 + 5 * cost.mailbox_ingress);
        // Different tile is independent.
        let r3 = mb.ingest(1, 100, 1, &cost);
        assert_eq!(r3, 100 + cost.mailbox_ingress);
    }

    #[test]
    fn backlog_visible() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(1);
        mb.ingest(0, 0, 10, &cost);
        assert_eq!(mb.backlog(0, 0), 10 * cost.mailbox_ingress);
        assert_eq!(mb.backlog(0, 10 * cost.mailbox_ingress), 0);
    }

    #[test]
    fn counters_accumulate() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(2);
        mb.ingest(0, 0, 3, &cost);
        mb.ingest(1, 0, 2, &cost);
        assert_eq!(mb.total_copies(), 5);
        assert_eq!(mb.max_busy(), 3 * cost.mailbox_ingress);
    }

    #[test]
    fn advance_to_floors_clocks() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(1);
        mb.ingest(0, 0, 1, &cost);
        mb.advance_to(1000);
        let r = mb.ingest(0, 500, 1, &cost);
        assert_eq!(r, 1000 + cost.mailbox_ingress);
    }

    #[test]
    fn dup_suppression_charges_detection_but_not_copies() {
        let cost = CostModel::default();
        let mut m = Mailbox::new();
        m.suppress_dup(10, &cost);
        assert_eq!(m.free_at(), 10 + cost.mailbox_ingress);
        assert_eq!(m.busy_cycles(), cost.mailbox_ingress);
        assert_eq!(m.copies(), 0, "suppressed duplicates must not count as ingested");
        // A later real ingest queues behind the detection work.
        let r = m.ingest(10, 1, &cost);
        assert_eq!(r, 10 + 2 * cost.mailbox_ingress);
    }

    #[test]
    fn single_mailbox_tracks_its_own_state() {
        let cost = CostModel::default();
        let mut m = Mailbox::new();
        let r = m.ingest(10, 2, &cost);
        assert_eq!(r, 10 + cost.mailbox_ingress);
        assert_eq!(m.free_at(), 10 + 2 * cost.mailbox_ingress);
        assert_eq!(m.busy_cycles(), 2 * cost.mailbox_ingress);
        assert_eq!(m.copies(), 2);
    }
}
