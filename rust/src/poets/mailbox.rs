//! Tile mailbox model.
//!
//! Each tile's four cores share one mailbox (Fig 2).  Incoming event copies
//! are ingested serially — one copy per destination software thread — which
//! makes the mailbox the fan-in bottleneck the paper identifies: a vertex
//! with |H| predecessors causes |H| serialised ingest operations per wave at
//! its tile.  Ingest is FIFO in arrival order (the simulator pops group
//! arrivals from a time-ordered heap).

use super::costmodel::CostModel;

/// Busy-until state for every mailbox (one per tile).
#[derive(Clone, Debug)]
pub struct MailboxBank {
    free: Vec<u64>,
    busy: Vec<u64>,
    copies: Vec<u64>,
}

impl MailboxBank {
    pub fn new(n_tiles: usize) -> MailboxBank {
        MailboxBank {
            free: vec![0; n_tiles],
            busy: vec![0; n_tiles],
            copies: vec![0; n_tiles],
        }
    }

    /// Ingest `n_copies` event copies arriving at `t`; returns the time the
    /// first copy is ready for its handler.  Copy `i`'s ready time is
    /// `ret + i * ingress`.
    pub fn ingest(&mut self, tile: usize, t: u64, n_copies: usize, cost: &CostModel) -> u64 {
        let start = t.max(self.free[tile]);
        let work = n_copies as u64 * cost.mailbox_ingress;
        self.free[tile] = start + work;
        self.busy[tile] += work;
        self.copies[tile] += n_copies as u64;
        start + cost.mailbox_ingress
    }

    /// Queueing delay currently visible at a tile arriving at time `t`.
    pub fn backlog(&self, tile: usize, t: u64) -> u64 {
        self.free[tile].saturating_sub(t)
    }

    pub fn max_free(&self) -> u64 {
        self.free.iter().copied().max().unwrap_or(0)
    }

    /// Cumulative busy cycles of the most-loaded mailbox.
    pub fn max_busy(&self) -> u64 {
        self.busy.iter().copied().max().unwrap_or(0)
    }

    pub fn total_copies(&self) -> u64 {
        self.copies.iter().sum()
    }

    /// Reset busy-until clocks to `t` (superstep boundary) keeping counters.
    pub fn advance_to(&mut self, t: u64) {
        for f in &mut self.free {
            *f = (*f).max(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingest_serialises_fifo() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(2);
        let r1 = mb.ingest(0, 100, 4, &cost);
        assert_eq!(r1, 100 + cost.mailbox_ingress);
        // Next group at the same tile queues behind all 4 copies.
        let r2 = mb.ingest(0, 100, 1, &cost);
        assert_eq!(r2, 100 + 5 * cost.mailbox_ingress);
        // Different tile is independent.
        let r3 = mb.ingest(1, 100, 1, &cost);
        assert_eq!(r3, 100 + cost.mailbox_ingress);
    }

    #[test]
    fn backlog_visible() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(1);
        mb.ingest(0, 0, 10, &cost);
        assert_eq!(mb.backlog(0, 0), 10 * cost.mailbox_ingress);
        assert_eq!(mb.backlog(0, 10 * cost.mailbox_ingress), 0);
    }

    #[test]
    fn counters_accumulate() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(2);
        mb.ingest(0, 0, 3, &cost);
        mb.ingest(1, 0, 2, &cost);
        assert_eq!(mb.total_copies(), 5);
        assert_eq!(mb.max_busy(), 3 * cost.mailbox_ingress);
    }

    #[test]
    fn advance_to_floors_clocks() {
        let cost = CostModel::default();
        let mut mb = MailboxBank::new(1);
        mb.ingest(0, 0, 1, &cost);
        mb.advance_to(1000);
        let r = mb.ingest(0, 500, 1, &cost);
        assert_eq!(r, 1000 + cost.mailbox_ingress);
    }
}
