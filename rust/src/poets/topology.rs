//! POETS cluster topology — paper §4.2, Figures 2–5.
//!
//! Hierarchy (current cluster):
//!
//! * **tile**: 4 custom RV32IMF cores sharing a mailbox, cache and FPU;
//!   16 hardware threads per core (Fig 2).
//! * **board**: Stratix-V DE5-net with 16 tiles in a 4×4 mesh sharing 4 GB
//!   DRAM; four 10 Gbps links for inter-board routing (Fig 3).
//! * **box**: 6 boards in a 3×2 grid plus an x86 host (Fig 4).
//! * **cluster**: 8 boxes in a 2×4 arrangement → 48 FPGAs, 49,152 truly
//!   parallel hardware threads (Fig 5).
//!
//! Threads are numbered densely: thread-in-core, core-in-tile, tile-in-board,
//! board-in-cluster.  Boards are laid out on a global 2-D grid (box grid ×
//! board-in-box grid) for inter-board mesh routing.

/// Global hardware-thread id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ThreadId(pub u32);

/// Static description of a POETS cluster.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub n_boards: usize,
    /// Tiles per board, arranged `tile_mesh.0 × tile_mesh.1`.
    pub tiles_per_board: usize,
    pub tile_mesh: (usize, usize),
    pub cores_per_tile: usize,
    pub threads_per_core: usize,
    /// Global board grid (columns, rows): the 48-board cluster is 6×8
    /// (boxes 2×4, each box 3×2 boards).
    pub board_grid: (usize, usize),
    /// Core clock in Hz (210 MHz on the Stratix-V cluster).
    pub clock_hz: f64,
    /// DRAM per board in bytes (4 GB).
    pub dram_per_board: usize,
}

impl ClusterConfig {
    /// The full 48-FPGA cluster of the paper.
    pub fn poets_48() -> ClusterConfig {
        ClusterConfig {
            n_boards: 48,
            tiles_per_board: 16,
            tile_mesh: (4, 4),
            cores_per_tile: 4,
            threads_per_core: 16,
            board_grid: (6, 8),
            clock_hz: 210e6,
            dram_per_board: 4 << 30,
        }
    }

    /// A cluster with `n` boards (1 ≤ n ≤ 48), board grid shrunk to fit —
    /// the Fig 11 "expanding hardware" axis.
    ///
    /// The grid is always an exact rectangle (largest divisor of `n` that is
    /// ≤ 6 columns, as boxes stack) so dimension-ordered routing never
    /// crosses an empty grid position.
    pub fn with_boards(n: usize) -> ClusterConfig {
        assert!((1..=48).contains(&n), "boards must be in 1..=48");
        let cols = (1..=n.min(6)).rev().find(|c| n % c == 0).unwrap_or(1);
        ClusterConfig {
            n_boards: n,
            board_grid: (cols, n / cols),
            ..ClusterConfig::poets_48()
        }
    }

    /// Near-square tile mesh (cols, rows) for `tiles` tiles, cols ≤ rows —
    /// how the scenario lab derives a mesh for overridden tile counts.
    pub fn mesh_for(tiles: usize) -> (usize, usize) {
        assert!(tiles >= 1, "a board needs at least one tile");
        let cols = (1..=tiles)
            .take_while(|c| c * c <= tiles)
            .filter(|c| tiles % c == 0)
            .last()
            .unwrap_or(1);
        (cols, tiles / cols)
    }

    /// A deliberately tiny cluster for unit tests.
    pub fn tiny() -> ClusterConfig {
        ClusterConfig {
            n_boards: 2,
            tiles_per_board: 4,
            tile_mesh: (2, 2),
            cores_per_tile: 2,
            threads_per_core: 4,
            board_grid: (2, 1),
            clock_hz: 210e6,
            dram_per_board: 1 << 20,
        }
    }

    #[inline]
    pub fn threads_per_tile(&self) -> usize {
        self.cores_per_tile * self.threads_per_core
    }

    #[inline]
    pub fn threads_per_board(&self) -> usize {
        self.tiles_per_board * self.threads_per_tile()
    }

    #[inline]
    pub fn total_threads(&self) -> usize {
        self.n_boards * self.threads_per_board()
    }

    #[inline]
    pub fn cores_per_board(&self) -> usize {
        self.tiles_per_board * self.cores_per_tile
    }

    #[inline]
    pub fn total_cores(&self) -> usize {
        self.n_boards * self.cores_per_board()
    }

    #[inline]
    pub fn total_tiles(&self) -> usize {
        self.n_boards * self.tiles_per_board
    }

    /// Seconds per core cycle.
    #[inline]
    pub fn secs_per_cycle(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// Decompose a thread id.
    #[inline]
    pub fn locate(&self, t: ThreadId) -> Location {
        let t = t.0 as usize;
        assert!(t < self.total_threads(), "thread {t} out of range");
        let board = t / self.threads_per_board();
        let in_board = t % self.threads_per_board();
        let tile = in_board / self.threads_per_tile();
        let in_tile = in_board % self.threads_per_tile();
        let core = in_tile / self.threads_per_core;
        let thread = in_tile % self.threads_per_core;
        Location {
            board,
            tile,
            core,
            thread,
        }
    }

    /// Global core index of a thread (cores are the serial compute servers).
    #[inline]
    pub fn core_of(&self, t: ThreadId) -> usize {
        let l = self.locate(t);
        (l.board * self.tiles_per_board + l.tile) * self.cores_per_tile + l.core
    }

    /// Global tile (= mailbox) index of a thread.
    #[inline]
    pub fn tile_of(&self, t: ThreadId) -> usize {
        let l = self.locate(t);
        l.board * self.tiles_per_board + l.tile
    }

    /// Board index of a thread.
    #[inline]
    pub fn board_of(&self, t: ThreadId) -> usize {
        self.locate(t).board
    }

    /// (x, y) of a tile within its board mesh.
    #[inline]
    pub fn tile_xy(&self, tile_in_board: usize) -> (usize, usize) {
        (
            tile_in_board % self.tile_mesh.0,
            tile_in_board / self.tile_mesh.0,
        )
    }

    /// (x, y) of a board on the global board grid.
    #[inline]
    pub fn board_xy(&self, board: usize) -> (usize, usize) {
        assert!(board < self.n_boards);
        (board % self.board_grid.0, board / self.board_grid.0)
    }

    /// Manhattan hop count between two tiles on the same board.
    #[inline]
    pub fn intra_board_hops(&self, tile_a: usize, tile_b: usize) -> usize {
        let (ax, ay) = self.tile_xy(tile_a);
        let (bx, by) = self.tile_xy(tile_b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }
}

/// Decomposed thread position.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Location {
    pub board: usize,
    pub tile: usize,
    pub core: usize,
    pub thread: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_counts() {
        let c = ClusterConfig::poets_48();
        assert_eq!(c.threads_per_tile(), 64);
        assert_eq!(c.threads_per_board(), 1024);
        assert_eq!(c.total_threads(), 49_152); // the paper's headline number
        assert_eq!(c.total_cores(), 3072);
        assert_eq!(c.total_tiles(), 768);
    }

    #[test]
    fn locate_roundtrip() {
        let c = ClusterConfig::poets_48();
        let l = c.locate(ThreadId(0));
        assert_eq!((l.board, l.tile, l.core, l.thread), (0, 0, 0, 0));
        let last = ThreadId(c.total_threads() as u32 - 1);
        let l = c.locate(last);
        assert_eq!(l.board, 47);
        assert_eq!(l.tile, 15);
        assert_eq!(l.core, 3);
        assert_eq!(l.thread, 15);
    }

    #[test]
    fn locate_is_dense() {
        let c = ClusterConfig::tiny();
        let mut seen = std::collections::HashSet::new();
        for t in 0..c.total_threads() {
            let l = c.locate(ThreadId(t as u32));
            assert!(seen.insert((l.board, l.tile, l.core, l.thread)));
        }
        assert_eq!(seen.len(), c.total_threads());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn locate_rejects_out_of_range() {
        let c = ClusterConfig::tiny();
        c.locate(ThreadId(c.total_threads() as u32));
    }

    #[test]
    fn with_boards_shapes() {
        for n in [1, 2, 6, 7, 12, 48] {
            let c = ClusterConfig::with_boards(n);
            assert_eq!(c.n_boards, n);
            let (gx, gy) = c.board_grid;
            assert!(gx * gy >= n, "grid {gx}x{gy} too small for {n}");
            // Every board must have valid grid coordinates.
            for b in 0..n {
                let (x, y) = c.board_xy(b);
                assert!(x < gx && y < gy);
            }
        }
    }

    #[test]
    fn mesh_for_is_near_square() {
        assert_eq!(ClusterConfig::mesh_for(16), (4, 4));
        assert_eq!(ClusterConfig::mesh_for(8), (2, 4));
        assert_eq!(ClusterConfig::mesh_for(4), (2, 2));
        assert_eq!(ClusterConfig::mesh_for(2), (1, 2));
        assert_eq!(ClusterConfig::mesh_for(1), (1, 1));
        assert_eq!(ClusterConfig::mesh_for(7), (1, 7));
    }

    #[test]
    fn intra_board_hops_manhattan() {
        let c = ClusterConfig::poets_48();
        assert_eq!(c.intra_board_hops(0, 0), 0);
        assert_eq!(c.intra_board_hops(0, 3), 3); // (0,0) -> (3,0)
        assert_eq!(c.intra_board_hops(0, 15), 6); // (0,0) -> (3,3)
        assert_eq!(c.intra_board_hops(5, 10), 2); // (1,1) -> (2,2)
    }

    #[test]
    fn core_and_tile_indices_consistent() {
        let c = ClusterConfig::tiny();
        for t in 0..c.total_threads() {
            let tid = ThreadId(t as u32);
            let l = c.locate(tid);
            assert_eq!(c.tile_of(tid), l.board * c.tiles_per_board + l.tile);
            assert_eq!(
                c.core_of(tid),
                c.tile_of(tid) * c.cores_per_tile + l.core
            );
        }
    }
}
