//! Simulation metrics: event counts, occupancy, per-step timings.

use crate::util::json::Json;

/// Aggregate counters from one simulation run.
#[derive(Clone, Debug, Default)]
pub struct SimMetrics {
    /// Send requests issued (one per port-send, multicast counts once).
    pub sends: u64,
    /// Event copies delivered to destination vertices.
    pub copies_delivered: u64,
    /// Per-target payload lanes delivered (`Σ copies × lanes-per-event`).
    /// Equals `copies_delivered` for scalar messages; for SoA wave-batched
    /// payloads the ratio `lanes_delivered / copies_delivered` is the mean
    /// lane width — the per-message amortisation the batching buys.
    pub lanes_delivered: u64,
    /// Handler invocations (recv only; init/step counted separately).
    pub recv_handlers: u64,
    pub step_handlers: u64,
    /// Events that crossed at least one inter-board link.
    pub inter_board_sends: u64,
    /// Global steps executed (target-haplotype pipeline waves).
    pub steps: u64,
    /// Final simulated time in cycles.
    pub sim_cycles: u64,
    /// Cycles spent inside termination-detection waves.
    pub barrier_cycles: u64,
    /// Busy cycles of the most-loaded core.
    pub max_core_busy: u64,
    /// Busy cycles of the most-loaded mailbox.
    pub max_mailbox_busy: u64,
    /// Σ over supersteps of tiles that delivered at least one event that
    /// superstep — the graph-occupancy integral (`busy_tile_steps / steps`
    /// is the mean number of busy tiles).
    pub busy_tile_steps: u64,
    /// Peak number of tiles delivering events in any single superstep.
    pub max_busy_tiles: u64,
    /// Peak number of pipelined lane groups in flight through one engine
    /// run (1 when the batch fits a single group).
    pub max_groups_in_flight: u64,
    /// Copies delivered within the sender's own tile (mailbox-local).
    pub intra_tile_copies: u64,
    /// Copies delivered to another tile on the sender's board.
    pub inter_tile_copies: u64,
    /// Copies delivered across at least one inter-board link.
    pub inter_board_copies: u64,
    /// Directional inter-board links modelled (4 per board).
    pub n_links: u64,
    /// Total events that crossed any inter-board link (one per link hop).
    pub link_events_total: u64,
    /// Serialisation cycles summed over all links.
    pub link_busy_total: u64,
    /// Busy cycles of the most-loaded inter-board link.
    pub max_link_busy: u64,
    /// Crossings that diverted around a failed link (scenario runs).
    pub rerouted_sends: u64,
    /// Tiles killed by a fault schedule and remapped onto survivors.
    pub failed_tiles: u64,
    /// Supersteps re-executed from the last checkpoint after tile deaths.
    pub replayed_supersteps: u64,
    /// Cycles charged to recovery: state restore plus the replayed steps.
    pub recovery_cycles: u64,
    /// Peak size of one barrier-aligned device-state checkpoint.
    pub checkpoint_bytes: u64,
    /// Event copies lost on lossy links (each is NACKed and retransmitted).
    pub dropped_events: u64,
    /// Barrier-time retransmissions of dropped events.
    pub retransmits: u64,
    /// Duplicate event copies suppressed at the destination mailbox.
    pub dup_events: u64,
    /// Per-board copy-traffic split, indexed by *source* board:
    /// `[intra_tile, inter_tile, inter_board]`.
    pub board_traffic: Vec<[u64; 3]>,
    /// Per-step durations in cycles (recorded when enabled).
    pub step_durations: Vec<u64>,
}

impl SimMetrics {
    /// Simulated wall-clock seconds at the given core clock.
    pub fn sim_seconds(&self, clock_hz: f64) -> f64 {
        self.sim_cycles as f64 / clock_hz
    }

    /// Total recorded step time in cycles.  The simulator folds superstep 0
    /// (init handlers) into the first recorded step and the post-final-barrier
    /// step-handler tail into the last, so with `record_steps` enabled this
    /// equals `sim_cycles` exactly.
    pub fn total_step_cycles(&self) -> u64 {
        self.step_durations.iter().sum()
    }

    /// Mean step duration in cycles.
    pub fn mean_step_cycles(&self) -> f64 {
        if self.step_durations.is_empty() {
            return 0.0;
        }
        self.step_durations.iter().sum::<u64>() as f64 / self.step_durations.len() as f64
    }

    /// Fraction of simulated time the busiest core was busy.
    pub fn core_occupancy(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.max_core_busy as f64 / self.sim_cycles as f64
    }

    /// Barrier overhead as a fraction of total simulated time (the paper's
    /// ~3 % claim is per-step; this is the run-level equivalent).
    pub fn barrier_fraction(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.barrier_cycles as f64 / self.sim_cycles as f64
    }

    /// Accumulate another run's counters — used when a session executes a
    /// workload as several sequential target batches (counts and cycles add;
    /// peak-occupancy gauges take the max).
    pub fn absorb(&mut self, other: &SimMetrics) {
        self.sends += other.sends;
        self.copies_delivered += other.copies_delivered;
        self.lanes_delivered += other.lanes_delivered;
        self.recv_handlers += other.recv_handlers;
        self.step_handlers += other.step_handlers;
        self.inter_board_sends += other.inter_board_sends;
        self.steps += other.steps;
        self.sim_cycles += other.sim_cycles;
        self.barrier_cycles += other.barrier_cycles;
        self.max_core_busy = self.max_core_busy.max(other.max_core_busy);
        self.max_mailbox_busy = self.max_mailbox_busy.max(other.max_mailbox_busy);
        self.busy_tile_steps += other.busy_tile_steps;
        self.max_busy_tiles = self.max_busy_tiles.max(other.max_busy_tiles);
        self.max_groups_in_flight = self.max_groups_in_flight.max(other.max_groups_in_flight);
        self.intra_tile_copies += other.intra_tile_copies;
        self.inter_tile_copies += other.inter_tile_copies;
        self.inter_board_copies += other.inter_board_copies;
        self.n_links = self.n_links.max(other.n_links);
        self.link_events_total += other.link_events_total;
        self.link_busy_total += other.link_busy_total;
        self.max_link_busy = self.max_link_busy.max(other.max_link_busy);
        self.rerouted_sends += other.rerouted_sends;
        self.failed_tiles += other.failed_tiles;
        self.replayed_supersteps += other.replayed_supersteps;
        self.recovery_cycles += other.recovery_cycles;
        self.checkpoint_bytes = self.checkpoint_bytes.max(other.checkpoint_bytes);
        self.dropped_events += other.dropped_events;
        self.retransmits += other.retransmits;
        self.dup_events += other.dup_events;
        if self.board_traffic.len() < other.board_traffic.len() {
            self.board_traffic.resize(other.board_traffic.len(), [0; 3]);
        }
        for (mine, theirs) in self.board_traffic.iter_mut().zip(&other.board_traffic) {
            for k in 0..3 {
                mine[k] += theirs[k];
            }
        }
        self.step_durations.extend_from_slice(&other.step_durations);
    }

    /// Peak link utilisation: busiest link's busy cycles over the run length.
    pub fn max_link_utilisation(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.max_link_busy as f64 / self.sim_cycles as f64
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("sends", self.sends)
            .set("copies_delivered", self.copies_delivered)
            .set("lanes_delivered", self.lanes_delivered)
            .set("recv_handlers", self.recv_handlers)
            .set("step_handlers", self.step_handlers)
            .set("inter_board_sends", self.inter_board_sends)
            .set("steps", self.steps)
            .set("sim_cycles", self.sim_cycles)
            .set("barrier_cycles", self.barrier_cycles)
            .set("max_core_busy", self.max_core_busy)
            .set("max_mailbox_busy", self.max_mailbox_busy)
            .set("busy_tile_steps", self.busy_tile_steps)
            .set("max_busy_tiles", self.max_busy_tiles)
            .set("max_groups_in_flight", self.max_groups_in_flight)
            .set("intra_tile_copies", self.intra_tile_copies)
            .set("inter_tile_copies", self.inter_tile_copies)
            .set("inter_board_copies", self.inter_board_copies)
            .set("n_links", self.n_links)
            .set("link_events_total", self.link_events_total)
            .set("link_busy_total", self.link_busy_total)
            .set("max_link_busy", self.max_link_busy)
            .set("max_link_utilisation", self.max_link_utilisation())
            .set("rerouted_sends", self.rerouted_sends)
            .set("failed_tiles", self.failed_tiles)
            .set("replayed_supersteps", self.replayed_supersteps)
            .set("recovery_cycles", self.recovery_cycles)
            .set("checkpoint_bytes", self.checkpoint_bytes)
            .set("dropped_events", self.dropped_events)
            .set("retransmits", self.retransmits)
            .set("dup_events", self.dup_events)
            .set(
                "board_traffic",
                Json::Arr(
                    self.board_traffic
                        .iter()
                        .map(|t| Json::from(t.to_vec()))
                        .collect(),
                ),
            );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_at_clock() {
        let m = SimMetrics {
            sim_cycles: 210_000_000,
            ..Default::default()
        };
        assert!((m.sim_seconds(210e6) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn occupancy_and_fractions() {
        let m = SimMetrics {
            sim_cycles: 1000,
            max_core_busy: 250,
            barrier_cycles: 30,
            step_durations: vec![400, 600],
            ..Default::default()
        };
        assert!((m.core_occupancy() - 0.25).abs() < 1e-12);
        assert!((m.barrier_fraction() - 0.03).abs() < 1e-12);
        assert!((m.mean_step_cycles() - 500.0).abs() < 1e-12);
        assert_eq!(m.total_step_cycles(), 1000);
    }

    #[test]
    fn zero_cycles_no_nan() {
        let m = SimMetrics::default();
        assert_eq!(m.core_occupancy(), 0.0);
        assert_eq!(m.barrier_fraction(), 0.0);
        assert_eq!(m.mean_step_cycles(), 0.0);
    }

    #[test]
    fn absorb_adds_counts_and_maxes_gauges() {
        let mut a = SimMetrics {
            sends: 10,
            sim_cycles: 100,
            steps: 2,
            max_core_busy: 40,
            busy_tile_steps: 6,
            max_busy_tiles: 4,
            max_groups_in_flight: 1,
            step_durations: vec![60, 40],
            ..Default::default()
        };
        let b = SimMetrics {
            sends: 5,
            sim_cycles: 50,
            steps: 1,
            max_core_busy: 45,
            busy_tile_steps: 3,
            max_busy_tiles: 3,
            max_groups_in_flight: 2,
            step_durations: vec![50],
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.sends, 15);
        assert_eq!(a.sim_cycles, 150);
        assert_eq!(a.steps, 3);
        assert_eq!(a.max_core_busy, 45);
        assert_eq!(a.busy_tile_steps, 9);
        assert_eq!(a.max_busy_tiles, 4);
        assert_eq!(a.max_groups_in_flight, 2);
        assert_eq!(a.step_durations, vec![60, 40, 50]);
        assert_eq!(a.total_step_cycles(), 150);
    }

    #[test]
    fn absorb_link_and_traffic_fields() {
        let mut a = SimMetrics {
            intra_tile_copies: 10,
            inter_tile_copies: 4,
            inter_board_copies: 2,
            n_links: 8,
            link_events_total: 6,
            link_busy_total: 66,
            max_link_busy: 44,
            rerouted_sends: 1,
            board_traffic: vec![[10, 4, 2]],
            ..Default::default()
        };
        let b = SimMetrics {
            intra_tile_copies: 1,
            inter_board_copies: 3,
            n_links: 16,
            link_events_total: 9,
            link_busy_total: 99,
            max_link_busy: 33,
            rerouted_sends: 2,
            board_traffic: vec![[1, 0, 3], [5, 5, 5]],
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.intra_tile_copies, 11);
        assert_eq!(a.inter_tile_copies, 4);
        assert_eq!(a.inter_board_copies, 5);
        assert_eq!(a.n_links, 16, "link count is a gauge, not a counter");
        assert_eq!(a.link_events_total, 15);
        assert_eq!(a.link_busy_total, 165);
        assert_eq!(a.max_link_busy, 44);
        assert_eq!(a.rerouted_sends, 3);
        assert_eq!(a.board_traffic, vec![[11, 4, 5], [5, 5, 5]]);
    }

    #[test]
    fn absorb_recovery_fields() {
        let mut a = SimMetrics {
            failed_tiles: 1,
            replayed_supersteps: 4,
            recovery_cycles: 900,
            checkpoint_bytes: 2048,
            dropped_events: 3,
            retransmits: 3,
            dup_events: 2,
            ..Default::default()
        };
        let b = SimMetrics {
            failed_tiles: 2,
            replayed_supersteps: 6,
            recovery_cycles: 100,
            checkpoint_bytes: 1024,
            dropped_events: 1,
            retransmits: 1,
            dup_events: 5,
            ..Default::default()
        };
        a.absorb(&b);
        assert_eq!(a.failed_tiles, 3);
        assert_eq!(a.replayed_supersteps, 10);
        assert_eq!(a.recovery_cycles, 1000);
        assert_eq!(a.checkpoint_bytes, 2048, "checkpoint size is a gauge");
        assert_eq!(a.dropped_events, 4);
        assert_eq!(a.retransmits, 4);
        assert_eq!(a.dup_events, 7);
    }

    #[test]
    fn json_has_recovery_telemetry() {
        let m = SimMetrics {
            failed_tiles: 1,
            replayed_supersteps: 7,
            recovery_cycles: 123,
            checkpoint_bytes: 456,
            dropped_events: 2,
            retransmits: 2,
            dup_events: 3,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("failed_tiles"), Some(&Json::Int(1)));
        assert_eq!(j.get("replayed_supersteps"), Some(&Json::Int(7)));
        assert_eq!(j.get("recovery_cycles"), Some(&Json::Int(123)));
        assert_eq!(j.get("checkpoint_bytes"), Some(&Json::Int(456)));
        assert_eq!(j.get("dropped_events"), Some(&Json::Int(2)));
        assert_eq!(j.get("retransmits"), Some(&Json::Int(2)));
        assert_eq!(j.get("dup_events"), Some(&Json::Int(3)));
    }

    #[test]
    fn link_utilisation_bounded() {
        let m = SimMetrics {
            sim_cycles: 1000,
            max_link_busy: 250,
            ..Default::default()
        };
        assert!((m.max_link_utilisation() - 0.25).abs() < 1e-12);
        assert_eq!(SimMetrics::default().max_link_utilisation(), 0.0);
    }

    #[test]
    fn json_has_link_telemetry() {
        let m = SimMetrics {
            n_links: 8,
            link_events_total: 12,
            max_link_busy: 99,
            board_traffic: vec![[7, 2, 3]],
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("n_links"), Some(&Json::Int(8)));
        assert_eq!(j.get("link_events_total"), Some(&Json::Int(12)));
        assert_eq!(j.get("max_link_busy"), Some(&Json::Int(99)));
        assert!(j.get("board_traffic").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn json_has_counters() {
        let m = SimMetrics {
            sends: 7,
            busy_tile_steps: 11,
            max_busy_tiles: 3,
            max_groups_in_flight: 2,
            ..Default::default()
        };
        let j = m.to_json();
        assert_eq!(j.get("sends"), Some(&crate::util::json::Json::Int(7)));
        assert_eq!(j.get("busy_tile_steps"), Some(&crate::util::json::Json::Int(11)));
        assert_eq!(j.get("max_busy_tiles"), Some(&crate::util::json::Json::Int(3)));
        assert_eq!(
            j.get("max_groups_in_flight"),
            Some(&crate::util::json::Json::Int(2))
        );
    }
}
