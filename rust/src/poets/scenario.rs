//! Heterogeneous-cluster scenario models — the "what-if" layer over
//! [`topology`](super::topology) and [`costmodel`](super::costmodel).
//!
//! A [`ScenarioSpec`] describes a cluster shape (board count, per-board
//! tile/core/thread counts) plus a *link plane overlay*: global and per-link
//! bandwidth/latency scaling, and failed links with dimension-ordered
//! reroute penalties.  The DES consumes it via [`Noc::with_scenario`]
//! (per-link effective cost tables + BFS reroutes), the analytic model via
//! [`worst_link_cost`](ScenarioSpec::worst_link_cost), and `bench topology`
//! sweeps a list of them.
//!
//! Board shape knobs are uniform across boards — the dense thread-numbering
//! contract of [`ClusterConfig`] is load-bearing for the whole mapper and
//! simulator — so *within* one cluster, heterogeneity is expressed on the
//! link plane (where the paper's scaling story lives); *across* sweep
//! points, every shape knob varies.
//!
//! Two input forms, one grammar per line of `bench topology --scenario`:
//!
//! * compact: `name=slow,boards=8,bw=0.25,lat=2,link=3E:bw=0.5,fail=0E`
//! * JSON (detected by a leading `{`):
//!   `{"name":"slow","boards":8,"bw_scale":0.25,"failed":["0E"]}`
//!
//! `bw` is a bandwidth *scale* (0.25 ⇒ quarter bandwidth ⇒ 4× the
//! serialisation cycles); `lat` is a latency multiplier.  Links are named
//! `<board><dir>` with dir ∈ E/W/N/S, e.g. `3E` = board 3's eastbound link.
//!
//! On top of the link plane, a spec can carry a *deterministic fault
//! schedule* consumed by the recovery plane ([`super::fault`]):
//!
//! * `failtile=B.T@STEP` — tile T of board B dies at the start of superstep
//!   STEP; its vertices are remapped onto surviving tiles and the run
//!   replays from the last barrier-aligned checkpoint.
//! * `drop=LINK:p@seed` / `dup=LINK:p@seed` — every crossing of the named
//!   inter-board link is dropped (resp. duplicated) with probability `p`,
//!   drawn from a deterministic per-link RNG stream seeded by `seed`.
//! * `ckpt=K` — checkpoint device state every K supersteps (default
//!   [`super::fault::DEFAULT_CKPT_INTERVAL`]).

use crate::util::json::Json;

use super::costmodel::CostModel;
use super::noc::{routes_avoiding, Dir, LinkId};
use super::topology::ClusterConfig;

/// Cycles charged on top of per-link costs for every crossing that had to
/// divert around a failed link (≈ two default link latencies: misroute
/// detection plus the extra turn).
pub const DEFAULT_REROUTE_PENALTY: u64 = 180;

/// Per-link override, multiplied on top of the scenario's global scaling.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkMod {
    pub board: usize,
    pub dir: Dir,
    /// Bandwidth scale (1.0 = nominal, 0.25 = quarter bandwidth).
    pub bw_scale: f64,
    /// Latency multiplier (1.0 = nominal).
    pub lat_mult: f64,
}

/// One scheduled tile death: `failtile=B.T@STEP`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileFailure {
    pub board: usize,
    /// Tile index within the board.
    pub tile: usize,
    /// Superstep at whose start the tile dies.
    pub step: u64,
}

impl TileFailure {
    /// The grammar spelling, `B.T@STEP`.
    pub fn name(&self) -> String {
        format!("{}.{}@{}", self.board, self.tile, self.step)
    }
}

/// A lossy-link model: each crossing of the link is dropped or duplicated
/// with probability `p`, decided by a deterministic RNG stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LossMod {
    pub board: usize,
    pub dir: Dir,
    /// Per-crossing loss/duplication probability, in `[0, 1)`.
    pub p: f64,
    /// Seed of the per-link decision stream.
    pub seed: u64,
}

impl LossMod {
    /// The grammar spelling, `<link>:p@seed`.
    pub fn name(&self) -> String {
        format!("{}:{}@{}", LinkId::of(self.board, self.dir).name(), self.p, self.seed)
    }
}

/// A heterogeneous-cluster scenario: shape + link plane overlay.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub boards: usize,
    /// Override `ClusterConfig::tiles_per_board` (mesh derived near-square).
    pub tiles_per_board: Option<usize>,
    pub cores_per_tile: Option<usize>,
    pub threads_per_core: Option<usize>,
    /// Global inter-board bandwidth scale (applies to every link).
    pub bw_scale: f64,
    /// Global inter-board latency multiplier.
    pub lat_mult: f64,
    /// Per-link overrides, composed onto the global scaling.
    pub links: Vec<LinkMod>,
    /// Failed links: traffic reroutes around them (BFS, deterministic).
    pub failed: Vec<(usize, Dir)>,
    /// Extra cycles per rerouted crossing.
    pub reroute_penalty: u64,
    /// Scheduled tile deaths (remap-and-replay; see [`super::fault`]).
    pub fail_tiles: Vec<TileFailure>,
    /// Links that drop crossings with probability p.
    pub drop_links: Vec<LossMod>,
    /// Links that duplicate crossings with probability p.
    pub dup_links: Vec<LossMod>,
    /// Checkpoint interval in supersteps (`None` = the fault plane default).
    pub ckpt_interval: Option<u64>,
}

impl ScenarioSpec {
    /// Nominal homogeneous cluster of `boards` boards.
    pub fn baseline(boards: usize) -> ScenarioSpec {
        ScenarioSpec {
            name: "baseline".into(),
            boards,
            tiles_per_board: None,
            cores_per_tile: None,
            threads_per_core: None,
            bw_scale: 1.0,
            lat_mult: 1.0,
            links: Vec::new(),
            failed: Vec::new(),
            reroute_penalty: DEFAULT_REROUTE_PENALTY,
            fail_tiles: Vec::new(),
            drop_links: Vec::new(),
            dup_links: Vec::new(),
            ckpt_interval: None,
        }
    }

    /// Whether this spec schedules any faults (tile deaths or lossy links)
    /// that the recovery plane must handle.
    pub fn has_faults(&self) -> bool {
        !self.fail_tiles.is_empty() || !self.drop_links.is_empty() || !self.dup_links.is_empty()
    }

    /// The `ClusterConfig` this scenario describes.
    pub fn cluster(&self) -> ClusterConfig {
        let mut c = ClusterConfig::with_boards(self.boards);
        if let Some(t) = self.tiles_per_board {
            c.tiles_per_board = t;
            c.tile_mesh = ClusterConfig::mesh_for(t);
        }
        if let Some(n) = self.cores_per_tile {
            c.cores_per_tile = n;
        }
        if let Some(n) = self.threads_per_core {
            c.threads_per_core = n;
        }
        c
    }

    /// Validate against the cluster this spec itself describes.
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=48).contains(&self.boards) {
            return Err(format!(
                "scenario {}: boards={} out of range 1..=48",
                self.name, self.boards
            ));
        }
        for (what, v) in [
            ("tiles", self.tiles_per_board),
            ("cores", self.cores_per_tile),
            ("threads", self.threads_per_core),
        ] {
            if v == Some(0) {
                return Err(format!("scenario {}: {what} must be >= 1", self.name));
            }
        }
        self.validate_for(&self.cluster())
    }

    /// Validate link indices, multipliers and (with failures) connectivity.
    pub fn validate_for(&self, cluster: &ClusterConfig) -> Result<(), String> {
        for (what, v) in [("bw", self.bw_scale), ("lat", self.lat_mult)] {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("scenario {}: {what} scale must be finite and > 0", self.name));
            }
        }
        for l in &self.links {
            if l.board >= cluster.n_boards {
                return Err(format!(
                    "scenario {}: link board {} out of range (boards={})",
                    self.name, l.board, cluster.n_boards
                ));
            }
            for (what, v) in [("bw", l.bw_scale), ("lat", l.lat_mult)] {
                if !(v.is_finite() && v > 0.0) {
                    return Err(format!(
                        "scenario {}: link {} {what} scale must be finite and > 0",
                        self.name,
                        LinkId::of(l.board, l.dir).name()
                    ));
                }
            }
        }
        for &(b, _) in &self.failed {
            if b >= cluster.n_boards {
                return Err(format!(
                    "scenario {}: failed-link board {b} out of range (boards={})",
                    self.name, cluster.n_boards
                ));
            }
        }
        if !self.failed.is_empty() {
            // Connectivity: every board pair must keep a surviving route.
            routes_avoiding(cluster, &self.failed_flags(cluster))?;
        }
        let mut killed = std::collections::HashSet::new();
        for f in &self.fail_tiles {
            if f.board >= cluster.n_boards || f.tile >= cluster.tiles_per_board {
                return Err(format!(
                    "scenario {}: failtile {} out of range ({} boards x {} tiles)",
                    self.name,
                    f.name(),
                    cluster.n_boards,
                    cluster.tiles_per_board
                ));
            }
            if !killed.insert((f.board, f.tile)) {
                return Err(format!(
                    "scenario {}: tile {}.{} scheduled to fail twice",
                    self.name, f.board, f.tile
                ));
            }
        }
        if !self.fail_tiles.is_empty() && self.fail_tiles.len() >= cluster.total_tiles() {
            return Err(format!(
                "scenario {}: fault schedule kills every tile — nothing left to remap onto",
                self.name
            ));
        }
        // A board whose tiles are ALL scheduled to die is assumed powered
        // off for replacement — its NoC switch goes with it.  Together with
        // failed links that can strand surviving boards; reject such
        // schedules up front (the simulator could never route the remapped
        // vertices' traffic).
        let mut killed_per_board = vec![0usize; cluster.n_boards];
        for &(b, _) in killed.iter() {
            killed_per_board[b] += 1;
        }
        let dead_board: Vec<bool> = killed_per_board
            .iter()
            .map(|&k| k >= cluster.tiles_per_board)
            .collect();
        if dead_board.iter().any(|&d| d) {
            let failed = self.failed_flags(cluster);
            let (cols, rows) = cluster.board_grid;
            let n = cluster.n_boards;
            let mut seen = vec![false; n];
            if let Some(start) = (0..n).find(|&b| !dead_board[b]) {
                let mut queue = std::collections::VecDeque::new();
                seen[start] = true;
                queue.push_back(start);
                while let Some(b) = queue.pop_front() {
                    let (x, y) = cluster.board_xy(b);
                    for dir in Dir::ALL {
                        let next = match dir {
                            Dir::East if x + 1 < cols => b + 1,
                            Dir::West if x > 0 => b - 1,
                            Dir::North if y > 0 => b - cols,
                            Dir::South if y + 1 < rows => b + cols,
                            _ => continue,
                        };
                        if next >= n || seen[next] || dead_board[next] {
                            continue;
                        }
                        if failed
                            .get(LinkId::of(b, dir).0 as usize)
                            .copied()
                            .unwrap_or(false)
                        {
                            continue;
                        }
                        seen[next] = true;
                        queue.push_back(next);
                    }
                }
            }
            for b in 0..n {
                if !dead_board[b] && !seen[b] {
                    return Err(format!(
                        "scenario {}: tile failures power off boards that disconnect \
                         surviving board {b} from the rest of the cluster",
                        self.name
                    ));
                }
            }
        }
        for (what, ls) in [("drop", &self.drop_links), ("dup", &self.dup_links)] {
            for l in ls {
                if l.board >= cluster.n_boards {
                    return Err(format!(
                        "scenario {}: {what} link board {} out of range (boards={})",
                        self.name, l.board, cluster.n_boards
                    ));
                }
                if !(l.p.is_finite() && (0.0..1.0).contains(&l.p)) {
                    return Err(format!(
                        "scenario {}: {what} probability {} must be in [0, 1)",
                        self.name, l.p
                    ));
                }
            }
        }
        if self.ckpt_interval == Some(0) {
            return Err(format!(
                "scenario {}: ckpt interval must be >= 1 superstep",
                self.name
            ));
        }
        Ok(())
    }

    /// Per-link effective (serialize, latency) cycles for the DES.
    pub fn link_costs(&self, cluster: &ClusterConfig, cost: &CostModel) -> Vec<(u64, u64)> {
        let n = cluster.n_boards * 4;
        let eff = |bw: f64, lat: f64| {
            let ser = (cost.board_link_serialize as f64 / bw).round().max(1.0) as u64;
            let lat = (cost.board_link_latency as f64 * lat).round().max(0.0) as u64;
            (ser, lat)
        };
        let mut table = vec![eff(self.bw_scale, self.lat_mult); n];
        for l in &self.links {
            let idx = LinkId::of(l.board, l.dir).0 as usize;
            if idx < n {
                table[idx] = eff(self.bw_scale * l.bw_scale, self.lat_mult * l.lat_mult);
            }
        }
        table
    }

    /// Failure flags indexed by link id.
    pub fn failed_flags(&self, cluster: &ClusterConfig) -> Vec<bool> {
        let mut flags = vec![false; cluster.n_boards * 4];
        for &(b, d) in &self.failed {
            let idx = LinkId::of(b, d).0 as usize;
            if idx < flags.len() {
                flags[idx] = true;
            }
        }
        flags
    }

    /// Worst-case effective (serialize, latency) cycles over surviving links
    /// — the analytic model's link-bound regime uses the slowest link.
    pub fn worst_link_cost(&self, cluster: &ClusterConfig, cost: &CostModel) -> (u64, u64) {
        let table = self.link_costs(cluster, cost);
        let flags = self.failed_flags(cluster);
        let mut worst = (0u64, 0u64);
        for (idx, &(ser, lat)) in table.iter().enumerate() {
            if flags[idx] {
                continue;
            }
            worst.0 = worst.0.max(ser);
            worst.1 = worst.1.max(lat);
        }
        worst
    }

    /// True when any link deviates from nominal (the analytic model and the
    /// manifests only mention scenarios that actually change something).
    pub fn is_degraded(&self) -> bool {
        self.bw_scale != 1.0
            || self.lat_mult != 1.0
            || !self.links.is_empty()
            || !self.failed.is_empty()
            || self.has_faults()
    }

    /// Parse either the compact grammar or (leading `{`) the JSON form.
    pub fn parse(text: &str) -> Result<ScenarioSpec, String> {
        let text = text.trim();
        if text.is_empty() {
            return Err("empty scenario spec".into());
        }
        if text.starts_with('{') {
            return Self::from_json(&Json::parse(text).map_err(|e| format!("scenario JSON: {e}"))?);
        }
        let mut spec = ScenarioSpec::baseline(2);
        spec.name = "custom".into();
        for pair in text.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let (key, val) = pair
                .split_once('=')
                .ok_or_else(|| format!("scenario field {pair:?} is not key=value"))?;
            match key.trim() {
                "name" => spec.name = val.trim().to_string(),
                "boards" => spec.boards = parse_num(val, "boards")?,
                "tiles" => spec.tiles_per_board = Some(parse_num(val, "tiles")?),
                "cores" => spec.cores_per_tile = Some(parse_num(val, "cores")?),
                "threads" => spec.threads_per_core = Some(parse_num(val, "threads")?),
                "bw" => spec.bw_scale = parse_f64(val, "bw")?,
                "lat" => spec.lat_mult = parse_f64(val, "lat")?,
                "reroute" => spec.reroute_penalty = parse_num(val, "reroute")?,
                "fail" => spec.failed.push(parse_link_name(val)?),
                "link" => spec.links.push(parse_link_mod(val)?),
                "failtile" => spec.fail_tiles.push(parse_tile_failure(val)?),
                "drop" => spec.drop_links.push(parse_loss_mod(val, "drop")?),
                "dup" => spec.dup_links.push(parse_loss_mod(val, "dup")?),
                "ckpt" => spec.ckpt_interval = Some(parse_num(val, "ckpt")? as u64),
                other => return Err(format!("unknown scenario field {other:?}")),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the JSON form (the grammar's keys, spelled out).
    pub fn from_json(j: &Json) -> Result<ScenarioSpec, String> {
        let mut spec = ScenarioSpec::baseline(2);
        spec.name = "custom".into();
        if let Some(s) = j.get("name").and_then(Json::as_str) {
            spec.name = s.to_string();
        }
        if let Some(n) = j.get("boards").and_then(Json::as_usize) {
            spec.boards = n;
        }
        spec.tiles_per_board = j.get("tiles_per_board").and_then(Json::as_usize);
        spec.cores_per_tile = j.get("cores_per_tile").and_then(Json::as_usize);
        spec.threads_per_core = j.get("threads_per_core").and_then(Json::as_usize);
        if let Some(x) = j.get("bw_scale").and_then(Json::as_f64) {
            spec.bw_scale = x;
        }
        if let Some(x) = j.get("lat_mult").and_then(Json::as_f64) {
            spec.lat_mult = x;
        }
        if let Some(n) = j.get("reroute_penalty").and_then(Json::as_i64) {
            spec.reroute_penalty = n.max(0) as u64;
        }
        if let Some(xs) = j.get("failed").and_then(Json::as_arr) {
            for x in xs {
                let s = x
                    .as_str()
                    .ok_or_else(|| "scenario JSON: failed[] entries are link names".to_string())?;
                spec.failed.push(parse_link_name(s)?);
            }
        }
        if let Some(xs) = j.get("links").and_then(Json::as_arr) {
            for x in xs {
                let name = x
                    .get("link")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "scenario JSON: links[] entries need a \"link\" name".to_string())?;
                let (board, dir) = parse_link_name(name)?;
                spec.links.push(LinkMod {
                    board,
                    dir,
                    bw_scale: x.get("bw_scale").and_then(Json::as_f64).unwrap_or(1.0),
                    lat_mult: x.get("lat_mult").and_then(Json::as_f64).unwrap_or(1.0),
                });
            }
        }
        // Fault-schedule arrays carry compact-grammar strings, so the JSON
        // echo round-trips through the same parsers.
        if let Some(xs) = j.get("fail_tiles").and_then(Json::as_arr) {
            for x in xs {
                let s = x.as_str().ok_or_else(|| {
                    "scenario JSON: fail_tiles[] entries are B.T@STEP strings".to_string()
                })?;
                spec.fail_tiles.push(parse_tile_failure(s)?);
            }
        }
        for (key, what) in [("drop", "drop"), ("dup", "dup")] {
            if let Some(xs) = j.get(key).and_then(Json::as_arr) {
                for x in xs {
                    let s = x.as_str().ok_or_else(|| {
                        format!("scenario JSON: {key}[] entries are LINK:p@seed strings")
                    })?;
                    let m = parse_loss_mod(s, what)?;
                    if key == "drop" {
                        spec.drop_links.push(m);
                    } else {
                        spec.dup_links.push(m);
                    }
                }
            }
        }
        if let Some(n) = j.get("ckpt_interval").and_then(Json::as_i64) {
            spec.ckpt_interval = Some(n.max(0) as u64);
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Echo into bench artifacts / manifests.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str()).set("boards", self.boards);
        if let Some(t) = self.tiles_per_board {
            j.set("tiles_per_board", t);
        }
        if let Some(c) = self.cores_per_tile {
            j.set("cores_per_tile", c);
        }
        if let Some(t) = self.threads_per_core {
            j.set("threads_per_core", t);
        }
        j.set("bw_scale", self.bw_scale).set("lat_mult", self.lat_mult);
        let mut links = Json::Arr(vec![]);
        for l in &self.links {
            let mut lj = Json::obj();
            lj.set("link", LinkId::of(l.board, l.dir).name())
                .set("bw_scale", l.bw_scale)
                .set("lat_mult", l.lat_mult);
            links.push(lj);
        }
        j.set("links", links);
        j.set(
            "failed",
            Json::Arr(
                self.failed
                    .iter()
                    .map(|&(b, d)| Json::from(LinkId::of(b, d).name()))
                    .collect(),
            ),
        );
        j.set("reroute_penalty", self.reroute_penalty);
        if !self.fail_tiles.is_empty() {
            j.set(
                "fail_tiles",
                Json::Arr(self.fail_tiles.iter().map(|f| Json::from(f.name())).collect()),
            );
        }
        if !self.drop_links.is_empty() {
            j.set(
                "drop",
                Json::Arr(self.drop_links.iter().map(|l| Json::from(l.name())).collect()),
            );
        }
        if !self.dup_links.is_empty() {
            j.set(
                "dup",
                Json::Arr(self.dup_links.iter().map(|l| Json::from(l.name())).collect()),
            );
        }
        if let Some(k) = self.ckpt_interval {
            j.set("ckpt_interval", k);
        }
        j
    }
}

fn parse_num(val: &str, what: &str) -> Result<usize, String> {
    val.trim()
        .parse::<usize>()
        .map_err(|_| format!("scenario {what}={val:?} is not a non-negative integer"))
}

fn parse_f64(val: &str, what: &str) -> Result<f64, String> {
    val.trim()
        .parse::<f64>()
        .map_err(|_| format!("scenario {what}={val:?} is not a number"))
}

/// `"3E"` → (board 3, East).
fn parse_link_name(s: &str) -> Result<(usize, Dir), String> {
    let s = s.trim();
    let (num, letter) = s.split_at(s.len().saturating_sub(1));
    let dir = letter
        .chars()
        .next()
        .and_then(Dir::from_letter)
        .ok_or_else(|| format!("link {s:?}: direction must be one of E/W/N/S"))?;
    let board = num
        .parse::<usize>()
        .map_err(|_| format!("link {s:?}: expected <board><dir>, e.g. 3E"))?;
    Ok((board, dir))
}

/// `0.1@40` → tile 1 of board 0 dies at superstep 40.
fn parse_tile_failure(s: &str) -> Result<TileFailure, String> {
    let s = s.trim();
    let (tile_part, step_part) = s
        .split_once('@')
        .ok_or_else(|| format!("failtile {s:?}: expected B.T@STEP, e.g. 0.1@40"))?;
    let (board, tile) = tile_part
        .split_once('.')
        .ok_or_else(|| format!("failtile {s:?}: tile must be B.T, e.g. 0.1"))?;
    Ok(TileFailure {
        board: parse_num(board, "failtile board")?,
        tile: parse_num(tile, "failtile tile")?,
        step: parse_num(step_part, "failtile step")? as u64,
    })
}

/// `0E:0.01@7` → drop/dup 1 % of board 0's eastbound crossings, seed 7.
fn parse_loss_mod(s: &str, what: &str) -> Result<LossMod, String> {
    let s = s.trim();
    let (link_part, rest) = s
        .split_once(':')
        .ok_or_else(|| format!("{what} {s:?}: expected LINK:p@seed, e.g. 0E:0.01@7"))?;
    let (board, dir) = parse_link_name(link_part)?;
    let (p_part, seed_part) = rest
        .split_once('@')
        .ok_or_else(|| format!("{what} {s:?}: expected p@seed after the link name"))?;
    Ok(LossMod {
        board,
        dir,
        p: parse_f64(p_part, &format!("{what} probability"))?,
        seed: parse_num(seed_part, &format!("{what} seed"))? as u64,
    })
}

/// `3E:bw=0.5:lat=2` → per-link override.
fn parse_link_mod(s: &str) -> Result<LinkMod, String> {
    let mut parts = s.split(':');
    let (board, dir) = parse_link_name(parts.next().unwrap_or(""))?;
    let mut m = LinkMod {
        board,
        dir,
        bw_scale: 1.0,
        lat_mult: 1.0,
    };
    for p in parts {
        let (key, val) = p
            .split_once('=')
            .ok_or_else(|| format!("link field {p:?} is not key=value"))?;
        match key.trim() {
            "bw" => m.bw_scale = parse_f64(val, "link bw")?,
            "lat" => m.lat_mult = parse_f64(val, "link lat")?,
            other => return Err(format!("unknown link field {other:?}")),
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_roundtrip() {
        let s = ScenarioSpec::parse(
            "name=degraded,boards=8,tiles=8,bw=0.5,lat=2,link=3E:bw=0.5:lat=1.5,fail=0E,reroute=90",
        )
        .unwrap();
        assert_eq!(s.name, "degraded");
        assert_eq!(s.boards, 8);
        assert_eq!(s.tiles_per_board, Some(8));
        assert_eq!(s.bw_scale, 0.5);
        assert_eq!(s.lat_mult, 2.0);
        assert_eq!(s.links.len(), 1);
        assert_eq!(s.links[0].board, 3);
        assert_eq!(s.failed, vec![(0, Dir::East)]);
        assert_eq!(s.reroute_penalty, 90);
        // JSON echo parses back to the same spec.
        let back = ScenarioSpec::parse(&s.to_json().render()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn json_form_parses() {
        let s = ScenarioSpec::parse(
            r#"{"name":"slow","boards":8,"bw_scale":0.25,"links":[{"link":"1W","lat_mult":3}],"failed":["2E"]}"#,
        )
        .unwrap();
        assert_eq!(s.name, "slow");
        assert_eq!(s.bw_scale, 0.25);
        assert_eq!(s.links[0].dir, Dir::West);
        assert_eq!(s.failed, vec![(2, Dir::East)]);
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "boards=8,bw=0",                // zero bandwidth
            "boards=8,frobnicate=1",        // unknown key
            "boards=8,fail=9E",             // board out of range
            "boards=2,fail=0E",             // disconnects the 2x1 grid
            "boards=8,link=0X:bw=2",        // bad direction
            "boards",                       // not key=value
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn cluster_shape_overrides_apply() {
        let s = ScenarioSpec::parse("boards=4,tiles=8,cores=2,threads=4").unwrap();
        let c = s.cluster();
        assert_eq!(c.n_boards, 4);
        assert_eq!(c.tiles_per_board, 8);
        assert_eq!(c.tile_mesh, (2, 4));
        assert_eq!(c.threads_per_board(), 8 * 2 * 4);
    }

    #[test]
    fn link_costs_scale_and_compose() {
        let cost = CostModel::default();
        let s = ScenarioSpec::parse("boards=2,bw=0.5,lat=2,link=0E:bw=0.5:lat=2").unwrap();
        let c = s.cluster();
        let table = s.link_costs(&c, &cost);
        let nominal = (cost.board_link_serialize, cost.board_link_latency);
        // Global scaling: half bandwidth = double serialize; double latency.
        let east1 = table[LinkId::of(1, Dir::East).0 as usize];
        assert_eq!(east1.0, nominal.0 * 2);
        assert_eq!(east1.1, nominal.1 * 2);
        // Per-link override composes on top of the global scaling.
        let east0 = table[LinkId::of(0, Dir::East).0 as usize];
        assert_eq!(east0.0, nominal.0 * 4);
        assert_eq!(east0.1, nominal.1 * 4);
        assert_eq!(s.worst_link_cost(&c, &cost), east0);
    }

    #[test]
    fn baseline_is_not_degraded() {
        assert!(!ScenarioSpec::baseline(8).is_degraded());
        assert!(ScenarioSpec::parse("boards=8,bw=0.5").unwrap().is_degraded());
        assert!(ScenarioSpec::parse("boards=8,fail=0E").unwrap().is_degraded());
        assert!(ScenarioSpec::parse("boards=8,failtile=0.1@40").unwrap().is_degraded());
    }

    #[test]
    fn fault_grammar_roundtrip() {
        let s = ScenarioSpec::parse(
            "name=faulty,boards=8,tiles=4,failtile=0.1@40,failtile=3.0@12,drop=0E:0.01@7,dup=1W:0.05@9,ckpt=8",
        )
        .unwrap();
        assert_eq!(
            s.fail_tiles,
            vec![
                TileFailure { board: 0, tile: 1, step: 40 },
                TileFailure { board: 3, tile: 0, step: 12 },
            ]
        );
        assert_eq!(s.drop_links.len(), 1);
        assert_eq!((s.drop_links[0].board, s.drop_links[0].dir), (0, Dir::East));
        assert_eq!(s.drop_links[0].p, 0.01);
        assert_eq!(s.drop_links[0].seed, 7);
        assert_eq!(s.dup_links.len(), 1);
        assert_eq!(s.dup_links[0].seed, 9);
        assert_eq!(s.ckpt_interval, Some(8));
        assert!(s.has_faults());
        // JSON echo parses back to the same spec.
        let back = ScenarioSpec::parse(&s.to_json().render()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn rejects_bad_fault_schedules() {
        for bad in [
            "boards=8,failtile=9.0@5",        // board out of range
            "boards=8,tiles=2,failtile=0.2@5", // tile out of range
            "boards=8,failtile=0.1@5,failtile=0.1@9", // same tile twice
            "boards=8,failtile=40",            // missing B.T
            "boards=8,drop=0E:1.5@7",          // p >= 1
            "boards=8,drop=0E:0.5",            // missing seed
            "boards=8,dup=0X:0.5@7",           // bad direction
            "boards=8,ckpt=0",                 // zero interval
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_schedules_that_strand_survivors() {
        // 3 boards on a (3, 1) grid: powering off the middle board (both of
        // its tiles die) disconnects board 0 from board 2.
        let err = ScenarioSpec::parse("boards=3,tiles=2,failtile=1.0@5,failtile=1.1@5")
            .expect_err("stranding schedule must be rejected");
        assert!(err.contains("disconnect"), "unexpected error: {err}");
        // Powering off an END board keeps the survivors connected.
        assert!(ScenarioSpec::parse("boards=3,tiles=2,failtile=2.0@5,failtile=2.1@5").is_ok());
        // A partially-dead middle board still routes.
        assert!(ScenarioSpec::parse("boards=3,tiles=2,failtile=1.0@5").is_ok());
    }
}
