//! Calibrated cost model for the POETS timing simulation.
//!
//! All costs are in core cycles at the cluster clock (210 MHz).  The
//! constants below are derived from the published descriptions of Tinsel
//! [20]–[22] and the paper's own measurements, and are **frozen across every
//! experiment** — figure shapes emerge from the model, they are not fitted
//! per figure.  `poets-impute bench calibrate` prints the model's prediction
//! against the paper's one anchor point (≈270× at the Fig 12 optimum) and the
//! per-constant sensitivity.
//!
//! Derivations (per 64-byte event):
//!
//! * `handler_dispatch` — Tinsel receive path: WFI wake-up, mailbox slot
//!   claim, POLite dispatch through the device table, state pointer chase to
//!   DRAM-backed vertex state.  Dozens of RV32 instructions on a 16-way
//!   barrel-scheduled core → ~200 issue slots of the *core*.
//! * `flop` — the shared tile FPU serves 4 cores; a dependent FP op averages
//!   ~2 cycles plus arbitration ~2 → 4, times contention headroom → 8.
//! * `mailbox_ingress` — 64 B over a 32-bit mailbox port ≈ 16 cycles, plus
//!   slot bookkeeping → 24.  This serialises *per destination thread copy*,
//!   which is exactly the fan-in bottleneck the paper identifies (§6.3).
//! * `send_request` — send-slot claim + header build + arbitration check.
//! * `hop` — one mesh router stage, wormhole, 64 B payload.
//! * inter-board: 10 Gbps per link → 64 B ≈ 51.2 ns ≈ 11 cycles serialisation;
//!   SERDES + board-crossing latency ≈ 90 cycles.
//! * `step_barrier_base`/`per_level` — Tinsel termination detection [22] is a
//!   hardware wave; the paper measures it at ~3 % of a step.  A tree wave
//!   over `log2(threads)` levels with per-level propagation matches that
//!   order.

/// Cycle costs of primitive operations (see module docs for derivations).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Core cycles to dispatch one received event into its handler.
    pub handler_dispatch: u64,
    /// Core cycles per floating-point operation (incl. shared-FPU contention).
    pub flop: u64,
    /// Mailbox cycles to ingest one event copy for one destination thread.
    pub mailbox_ingress: u64,
    /// Core cycles to issue one send request (multicast counts once).
    pub send_request: u64,
    /// Router cycles per intra-board mesh hop.
    pub hop: u64,
    /// Link-occupancy cycles per 64-byte event on a 10 Gbps board link.
    pub board_link_serialize: u64,
    /// Latency cycles added per board crossing (SERDES + ingress).
    pub board_link_latency: u64,
    /// Fixed cycles per global step for the termination-detection wave.
    pub step_barrier_base: u64,
    /// Additional cycles per tree level (log2 of thread count).
    pub step_barrier_per_level: u64,
    /// Event payload size in bytes (Tinsel events are small and atomic).
    pub event_bytes: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        // Calibration (frozen; see bench/calibrate.rs and EXPERIMENTS.md):
        // these constants reproduce the paper's three quantitative anchors
        // simultaneously —
        //   (1) ≈270× at the Fig 12 peak against a paper-era x86
        //       (~6e7 MAC/s, consistent with the paper's "days" runtimes),
        //   (2) termination-detection ≈3% of an average step (§5.2),
        //   (3) the soft-scheduling optimum at ≈10 states/thread (Fig 12:
        //       the barrier/latency floor penalises low spt, pipeline-fill
        //       and fan-in queueing penalise high spt).
        // Tinsel's receive path is hardware-assisted and the 16-thread
        // barrel core retires ~1 instruction/cycle, so a POLite handler of
        // a few dozen RV32 instructions costs ~30 issue slots.
        CostModel {
            handler_dispatch: 30,
            flop: 2,
            mailbox_ingress: 8,
            send_request: 15,
            hop: 3,
            board_link_serialize: 11,
            board_link_latency: 90,
            step_barrier_base: 10_000,
            step_barrier_per_level: 1_500,
            event_bytes: 64,
        }
    }
}

impl CostModel {
    /// Core cycles for a handler invocation doing `flops` FP ops.
    #[inline]
    pub fn handler(&self, flops: u64) -> u64 {
        self.handler_dispatch + flops * self.flop
    }

    /// Termination-detection wave cost for a cluster of `n_threads`.
    #[inline]
    pub fn barrier(&self, n_threads: usize) -> u64 {
        let levels = usize::BITS - n_threads.next_power_of_two().leading_zeros();
        self.step_barrier_base + self.step_barrier_per_level * levels as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handler_cost_scales_with_flops() {
        let c = CostModel::default();
        assert_eq!(c.handler(0), c.handler_dispatch);
        assert_eq!(c.handler(10), c.handler_dispatch + 10 * c.flop);
    }

    #[test]
    fn barrier_grows_logarithmically() {
        let c = CostModel::default();
        let small = c.barrier(64);
        let big = c.barrier(49_152);
        assert!(big > small);
        assert!(big < small + 20 * c.step_barrier_per_level);
    }

    #[test]
    fn barrier_is_small_fraction_of_busy_step() {
        // Paper §5.2: termination-detected stepping costs ~3% of a step at
        // the Fig 12 operating point: 10 states/thread, H≈70 → a core hosts
        // 160 states each receiving 2H+1 events per step.
        let c = CostModel::default();
        let step_work = 160u64 * 141 * c.handler(2);
        let overhead = c.barrier(49_152) as f64 / step_work as f64;
        assert!(
            (0.005..0.10).contains(&overhead),
            "barrier fraction {overhead} out of the paper's ~3% regime"
        );
    }

    #[test]
    fn event_fits_in_64_bytes() {
        assert_eq!(CostModel::default().event_bytes, 64);
    }
}
