//! Event (message) plumbing for the discrete-event simulation.
//!
//! POETS events are small atomic packets (≤ 64 bytes) carrying both control
//! and data.  The simulator is generic over the application's message type;
//! [`assert_event_fits`] enforces the size budget at graph-load time, exactly
//! where the real cluster would reject an oversized event.

use std::cmp::Ordering;

use crate::graph::builder::DestListId;
use crate::graph::device::VertexId;

/// Compile-time-ish check that a message type fits the Tinsel event budget
/// (64 bytes minus an 8-byte header worth of routing metadata).
pub fn assert_event_fits<M>(event_bytes: usize) {
    let payload_budget = event_bytes - 8;
    let size = std::mem::size_of::<M>();
    assert!(
        size <= payload_budget,
        "message type {} is {size} bytes; events carry at most {payload_budget}",
        std::any::type_name::<M>()
    );
}

/// A multicast group arrival at one destination tile's mailbox.
#[derive(Clone, Debug)]
pub struct GroupArrival<M> {
    /// Arrival time at the tile ingress (cycles).
    pub t: u64,
    /// Tie-break sequence for deterministic ordering.
    pub seq: u64,
    /// Sending vertex (receivers derive `a_ij` same/diff from it).
    pub src: VertexId,
    /// Which pooled destination list this send used.
    pub list: DestListId,
    /// Index of the tile group within the list's multicast plan.
    pub group: u32,
    pub msg: M,
}

impl<M> PartialEq for GroupArrival<M> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<M> Eq for GroupArrival<M> {}

impl<M> PartialOrd for GroupArrival<M> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap ordering: earliest time first, then sequence.
impl<M> Ord for GroupArrival<M> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.t, other.seq).cmp(&(self.t, self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;

    #[test]
    fn heap_pops_in_time_order() {
        let mut h: BinaryHeap<GroupArrival<u8>> = BinaryHeap::new();
        for (t, seq) in [(5u64, 0u64), (1, 1), (5, 2), (3, 3)] {
            h.push(GroupArrival {
                t,
                seq,
                src: 0,
                list: DestListId(0),
                group: 0,
                msg: 0,
            });
        }
        let order: Vec<(u64, u64)> = std::iter::from_fn(|| h.pop().map(|e| (e.t, e.seq))).collect();
        assert_eq!(order, vec![(1, 1), (3, 3), (5, 0), (5, 2)]);
    }

    #[test]
    fn small_messages_fit() {
        assert_event_fits::<[f32; 4]>(64);
    }

    #[test]
    #[should_panic(expected = "events carry at most")]
    fn oversized_messages_rejected() {
        assert_event_fits::<[u8; 100]>(64);
    }
}
