//! Event (message) plumbing for the discrete-event simulation.
//!
//! POETS events are small atomic packets (≤ 64 bytes) carrying both control
//! and data.  The simulator is generic over the application's message type;
//! [`assert_event_fits`] enforces the size budget at graph-load time, exactly
//! where the real cluster would reject an oversized event.
//!
//! Host-side representation: the simulator stores each superstep's message
//! payloads once in a *message arena* (`Vec<Msg>`, one slot per send request,
//! shared by every destination tile of the multicast).  A [`GroupArrival`]
//! is therefore a fixed-size POD record — an arena index plus routing
//! metadata — so per-tile delivery queues sort and copy 32-byte values
//! instead of cloning message payloads per destination group.

use std::cmp::Ordering;

use crate::graph::device::VertexId;

/// Compile-time-ish check that a message type fits the Tinsel event budget
/// (64 bytes minus an 8-byte header worth of routing metadata).
pub fn assert_event_fits<M>(event_bytes: usize) {
    let payload_budget = event_bytes - 8;
    let size = std::mem::size_of::<M>();
    assert!(
        size <= payload_budget,
        "message type {} is {size} bytes; events carry at most {payload_budget}",
        std::any::type_name::<M>()
    );
}

/// A multicast group arrival at one destination tile's mailbox.
///
/// Plain-old-data: the payload lives in the superstep message arena and is
/// referenced by `msg_idx`; `group` indexes the flattened multicast plan
/// ([`super::multicast::McastPlan`]), which resolves to the destination tile
/// and its resident destination vertices.
#[derive(Clone, Copy, Debug)]
pub struct GroupArrival {
    /// Arrival time at the tile ingress (cycles).
    pub t: u64,
    /// Tie-break sequence for deterministic ordering.
    pub seq: u64,
    /// Sending vertex (receivers derive `a_ij` same/diff from it).
    pub src: VertexId,
    /// Global tile-group index within the multicast plan.
    pub group: u32,
    /// Index of the payload in the superstep message arena.
    pub msg_idx: u32,
    /// Delivery-plane flags (see [`FLAG_DUP`]); 0 for ordinary arrivals.
    pub flags: u32,
}

/// Marks an arrival injected by a fault schedule's `dup=` link model: a
/// spurious second copy of an event the link already delivered.  The
/// destination mailbox recognises the repeated (sender, superstep) sequence
/// number and suppresses it — the copy is charged detection cost but no
/// recv handler runs (see `poets::fault`).
pub const FLAG_DUP: u32 = 1;

/// Marks a unicast retransmission: a copy re-sent point-to-point after the
/// barrier-time sequence-number audit NACKed a dropped crossing.  `group`
/// holds the destination *vertex* id instead of a multicast-group index —
/// vertex ids survive a tile-failure remap, group indices do not (see
/// `poets::fault`).
pub const FLAG_RETRANS: u32 = 2;

impl PartialEq for GroupArrival {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for GroupArrival {}

impl PartialOrd for GroupArrival {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Natural (ascending) delivery order: earliest time first, then sequence.
/// Per-tile queues sort ascending and deliver front-to-back.
impl Ord for GroupArrival {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.t, self.seq).cmp(&(other.t, other.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, seq: u64) -> GroupArrival {
        GroupArrival {
            t,
            seq,
            src: 0,
            group: 0,
            msg_idx: 0,
            flags: 0,
        }
    }

    #[test]
    fn sorts_in_time_order() {
        let mut q = vec![ev(5, 0), ev(1, 1), ev(5, 2), ev(3, 3)];
        q.sort_unstable();
        let order: Vec<(u64, u64)> = q.iter().map(|e| (e.t, e.seq)).collect();
        assert_eq!(order, vec![(1, 1), (3, 3), (5, 0), (5, 2)]);
    }

    #[test]
    fn arrival_is_fixed_size_pod() {
        // The whole point of the arena: queue entries are small and Copy.
        assert!(std::mem::size_of::<GroupArrival>() <= 32);
    }

    #[test]
    fn small_messages_fit() {
        assert_event_fits::<[f32; 4]>(64);
    }

    #[test]
    #[should_panic(expected = "events carry at most")]
    fn oversized_messages_rejected() {
        assert_event_fits::<[u8; 100]>(64);
    }
}
