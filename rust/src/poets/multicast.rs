//! Hardware-multicast planning — Tinsel's distributed multicast [21].
//!
//! A single send request covers an entire destination list; routers replicate
//! the event so each inter-board link and each destination tile sees exactly
//! one copy stream.  Since destination lists are pooled and static, the
//! expansion (group destinations by tile, order groups by board) is
//! precomputed once per (graph, mapping) pair.

use crate::graph::builder::{DestListId, Graph};
use crate::graph::device::{Device, VertexId};
use crate::graph::mapping::Mapping;

use super::topology::ClusterConfig;

/// One tile's share of a multicast: the destination vertices resident there.
#[derive(Clone, Debug)]
pub struct TileGroup {
    pub tile: u32,
    pub board: u32,
    pub dests: Vec<VertexId>,
}

/// The precomputed expansion of every pooled destination list.
#[derive(Clone, Debug, Default)]
pub struct McastPlan {
    /// `groups[list.0]` → tile groups, sorted by (board, tile).
    groups: Vec<Vec<TileGroup>>,
}

impl McastPlan {
    pub fn build<D: Device>(
        graph: &Graph<D>,
        mapping: &Mapping,
        cluster: &ClusterConfig,
    ) -> McastPlan {
        let mut groups = Vec::with_capacity(graph.n_dest_lists());
        for list in 0..graph.n_dest_lists() {
            let dests = graph.dests(DestListId(list as u32));
            let mut by_tile: std::collections::BTreeMap<(u32, u32), Vec<VertexId>> =
                Default::default();
            for &d in dests {
                let t = mapping.thread_of(d);
                let tile = cluster.tile_of(t) as u32;
                let board = cluster.board_of(t) as u32;
                by_tile.entry((board, tile)).or_default().push(d);
            }
            groups.push(
                by_tile
                    .into_iter()
                    .map(|((board, tile), dests)| TileGroup { tile, board, dests })
                    .collect(),
            );
        }
        McastPlan { groups }
    }

    #[inline]
    pub fn tile_groups(&self, list: DestListId) -> &[TileGroup] {
        &self.groups[list.0 as usize]
    }

    /// Total copies delivered by one send on this list.
    pub fn fan_out(&self, list: DestListId) -> usize {
        self.tile_groups(list).iter().map(|g| g.dests.len()).sum()
    }

    /// Distinct boards touched by one send on this list.
    pub fn boards_spanned(&self, list: DestListId) -> usize {
        let mut boards: Vec<u32> = self.tile_groups(list).iter().map(|g| g.board).collect();
        boards.dedup();
        boards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::device::Ctx;

    struct Null;
    impl Device for Null {
        type Msg = u8;
        fn init(&mut self, _c: &mut Ctx<u8>) {}
        fn recv(&mut self, _m: &u8, _s: VertexId, _c: &mut Ctx<u8>) {}
        fn step(&mut self, _c: &mut Ctx<u8>) -> bool {
            false
        }
    }

    #[test]
    fn groups_by_tile_and_board() {
        let cluster = ClusterConfig::tiny(); // 2 boards, 4 tiles, 8 thr/tile
        let mut b = GraphBuilder::new();
        // 40 vertices: round-robin over 64 threads puts consecutive vertices
        // on consecutive threads.
        for _ in 0..40 {
            b.add_vertex(Null);
        }
        let all: Vec<VertexId> = (0..40).collect();
        let list = b.intern_dests(all);
        b.add_port(0, list);
        let g = b.build();
        let mapping = Mapping::round_robin(40, &cluster);
        let plan = McastPlan::build(&g, &mapping, &cluster);

        assert_eq!(plan.fan_out(DestListId(0)), 40);
        let groups = plan.tile_groups(DestListId(0));
        // 40 threads cover 5 tiles (8 threads/tile).
        assert_eq!(groups.len(), 5);
        // Sorted by (board, tile); all destinations preserved exactly once.
        let mut seen: Vec<VertexId> = groups.iter().flat_map(|g| g.dests.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<_>>());
        assert!(groups.windows(2).all(|w| (w[0].board, w[0].tile) < (w[1].board, w[1].tile)));
        // Threads 0..31 are board 0 (4 tiles x 8), 32..39 board 1.
        assert_eq!(plan.boards_spanned(DestListId(0)), 2);
    }

    #[test]
    fn empty_list_empty_plan() {
        let cluster = ClusterConfig::tiny();
        let mut b = GraphBuilder::new();
        b.add_vertex(Null);
        let list = b.intern_dests(vec![]);
        b.add_port(0, list);
        let g = b.build();
        let mapping = Mapping::round_robin(1, &cluster);
        let plan = McastPlan::build(&g, &mapping, &cluster);
        assert_eq!(plan.fan_out(DestListId(0)), 0);
        assert!(plan.tile_groups(DestListId(0)).is_empty());
    }
}
