//! Hardware-multicast planning — Tinsel's distributed multicast [21].
//!
//! A single send request covers an entire destination list; routers replicate
//! the event so each inter-board link and each destination tile sees exactly
//! one copy stream.  Since destination lists are pooled and static, the
//! expansion (group destinations by tile, order groups by board) is
//! precomputed once per (graph, mapping) pair.
//!
//! The plan is stored *flat*: all tile groups of all lists live in one arena
//! with `(offset, len)` spans per list, and all group destinations live in a
//! single pooled `Vec<VertexId>`.  The dispatch hot path reads a group's
//! `(board, tile)` by value and the deliver hot path borrows its destination
//! slice — no per-event `Arc` traffic, no nested `Vec` pointer chasing.

use std::ops::Range;

use crate::graph::builder::{DestListId, Graph};
use crate::graph::device::{Device, VertexId};
use crate::graph::mapping::Mapping;

use super::topology::ClusterConfig;

/// The precomputed expansion of every pooled destination list.
///
/// Group ids are *global* (they index the flat arena); a list resolves to a
/// contiguous range of group ids via [`McastPlan::group_range`], sorted by
/// `(board, tile)`.
#[derive(Clone, Debug, Default)]
pub struct McastPlan {
    /// Per list: `(first_group, n_groups)` into the group arena.
    list_spans: Vec<(u32, u32)>,
    /// Per group: destination `(board, tile)`.
    group_loc: Vec<(u32, u32)>,
    /// Per group: `(first_dest, n_dests)` into `dest_pool`.
    dest_spans: Vec<(u32, u32)>,
    /// Pooled destination vertices of every group, concatenated.
    dest_pool: Vec<VertexId>,
}

impl McastPlan {
    pub fn build<D: Device>(
        graph: &Graph<D>,
        mapping: &Mapping,
        cluster: &ClusterConfig,
    ) -> McastPlan {
        let mut plan = McastPlan::default();
        for list in 0..graph.n_dest_lists() {
            let dests = graph.dests(DestListId(list as u32));
            let mut by_tile: std::collections::BTreeMap<(u32, u32), Vec<VertexId>> =
                Default::default();
            for &d in dests {
                let t = mapping.thread_of(d);
                let tile = cluster.tile_of(t) as u32;
                let board = cluster.board_of(t) as u32;
                by_tile.entry((board, tile)).or_default().push(d);
            }
            let first = plan.group_loc.len() as u32;
            for ((board, tile), ds) in by_tile {
                plan.group_loc.push((board, tile));
                let fd = plan.dest_pool.len() as u32;
                plan.dest_spans.push((fd, ds.len() as u32));
                plan.dest_pool.extend_from_slice(&ds);
            }
            let n = plan.group_loc.len() as u32 - first;
            plan.list_spans.push((first, n));
        }
        plan
    }

    /// Global group-id range of one destination list (sorted by board, tile).
    #[inline]
    pub fn group_range(&self, list: DestListId) -> Range<usize> {
        let (first, n) = self.list_spans[list.0 as usize];
        first as usize..(first + n) as usize
    }

    /// `(board, tile)` of a global group id — returned by value so the
    /// dispatch hot path holds no borrow while mutating simulator state.
    #[inline]
    pub fn group_loc(&self, group: usize) -> (u32, u32) {
        self.group_loc[group]
    }

    /// Destination vertices of a global group id (all resident on its tile).
    #[inline]
    pub fn group_dests(&self, group: usize) -> &[VertexId] {
        let (first, n) = self.dest_spans[group];
        &self.dest_pool[first as usize..(first + n) as usize]
    }

    /// Number of tile groups one send on this list fans out to.
    pub fn n_groups(&self, list: DestListId) -> usize {
        self.list_spans[list.0 as usize].1 as usize
    }

    /// Total copies delivered by one send on this list.
    pub fn fan_out(&self, list: DestListId) -> usize {
        self.group_range(list)
            .map(|g| self.group_dests(g).len())
            .sum()
    }

    /// Distinct boards touched by one send on this list.
    pub fn boards_spanned(&self, list: DestListId) -> usize {
        let mut boards: Vec<u32> = self
            .group_range(list)
            .map(|g| self.group_loc(g).0)
            .collect();
        boards.dedup(); // groups are sorted by (board, tile)
        boards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::device::Ctx;

    struct Null;
    impl Device for Null {
        type Msg = u8;
        fn init(&mut self, _c: &mut Ctx<u8>) {}
        fn recv(&mut self, _m: &u8, _s: VertexId, _c: &mut Ctx<u8>) {}
        fn step(&mut self, _c: &mut Ctx<u8>) -> bool {
            false
        }
    }

    #[test]
    fn groups_by_tile_and_board() {
        let cluster = ClusterConfig::tiny(); // 2 boards, 4 tiles, 8 thr/tile
        let mut b = GraphBuilder::new();
        // 40 vertices: round-robin over 64 threads puts consecutive vertices
        // on consecutive threads.
        for _ in 0..40 {
            b.add_vertex(Null);
        }
        let all: Vec<VertexId> = (0..40).collect();
        let list = b.intern_dests(all);
        b.add_port(0, list);
        let g = b.build();
        let mapping = Mapping::round_robin(40, &cluster);
        let plan = McastPlan::build(&g, &mapping, &cluster);

        assert_eq!(plan.fan_out(DestListId(0)), 40);
        // 40 threads cover 5 tiles (8 threads/tile).
        let range = plan.group_range(DestListId(0));
        assert_eq!(range.len(), 5);
        assert_eq!(plan.n_groups(DestListId(0)), 5);
        // Sorted by (board, tile); all destinations preserved exactly once.
        let mut seen: Vec<VertexId> = range
            .clone()
            .flat_map(|g| plan.group_dests(g).to_vec())
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<_>>());
        let locs: Vec<(u32, u32)> = range.clone().map(|g| plan.group_loc(g)).collect();
        assert!(locs.windows(2).all(|w| w[0] < w[1]));
        // Threads 0..31 are board 0 (4 tiles x 8), 32..39 board 1.
        assert_eq!(plan.boards_spanned(DestListId(0)), 2);
    }

    #[test]
    fn empty_list_empty_plan() {
        let cluster = ClusterConfig::tiny();
        let mut b = GraphBuilder::new();
        b.add_vertex(Null);
        let list = b.intern_dests(vec![]);
        b.add_port(0, list);
        let g = b.build();
        let mapping = Mapping::round_robin(1, &cluster);
        let plan = McastPlan::build(&g, &mapping, &cluster);
        assert_eq!(plan.fan_out(DestListId(0)), 0);
        assert!(plan.group_range(DestListId(0)).is_empty());
    }

    #[test]
    fn global_group_ids_are_contiguous_per_list() {
        let cluster = ClusterConfig::tiny();
        let mut b = GraphBuilder::new();
        for _ in 0..16 {
            b.add_vertex(Null);
        }
        let l0 = b.intern_dests((0..16).collect());
        let l1 = b.intern_dests(vec![0, 1]);
        b.add_port(0, l0);
        b.add_port(1, l1);
        let g = b.build();
        let mapping = Mapping::round_robin(16, &cluster);
        let plan = McastPlan::build(&g, &mapping, &cluster);
        let r0 = plan.group_range(DestListId(0));
        let r1 = plan.group_range(DestListId(1));
        assert_eq!(r0.end, r1.start, "lists pack the group arena densely");
        assert_eq!(plan.fan_out(DestListId(1)), 2);
    }
}
