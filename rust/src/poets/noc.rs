//! Network-on-chip model: intra-board mesh + inter-board 10 Gbps links.
//!
//! Intra-board: a 4×4 wormhole mesh between tiles; we charge per-hop router
//! latency (contention between tiles on the same board is dominated by the
//! mailbox ingress serialisation, which the simulator models separately).
//!
//! Inter-board: each board has four directional links (N/E/S/W, Fig 3).
//! Routing is dimension-ordered (X then Y) over the global board grid.  Each
//! link is a serial resource: events crossing it queue behind one another at
//! 64 B / 10 Gbps — this is where large fan-outs that span boards back up.
//!
//! Heterogeneous clusters (the scenario lab, `poets::scenario`) overlay this
//! model with per-link effective costs: a [`ScenarioSpec`] can slow or speed
//! individual links (bandwidth/latency multipliers) and fail links entirely.
//! With failed links, routes come from a precomputed deterministic BFS table
//! (shortest surviving path, fixed E/W/N/S neighbour order); any pair whose
//! shortest path is longer than its Manhattan distance is *rerouted* and
//! pays the scenario's dimension-ordered reroute penalty on top of the
//! per-link costs.
//!
//! The NoC is mutated only inside the simulator's **serial** dispatch phase,
//! so the opt-in per-superstep link telemetry (events crossed, busy cycles,
//! queue high-water) is deterministic for any host thread count by
//! construction.

use super::costmodel::CostModel;
use super::scenario::ScenarioSpec;
use super::topology::ClusterConfig;

/// Link direction out of a board.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    East = 0,
    West = 1,
    North = 2,
    South = 3,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::East, Dir::West, Dir::North, Dir::South];

    /// One-letter name used by the scenario grammar (`3E` = board 3, East).
    pub fn letter(self) -> char {
        match self {
            Dir::East => 'E',
            Dir::West => 'W',
            Dir::North => 'N',
            Dir::South => 'S',
        }
    }

    pub fn from_letter(c: char) -> Option<Dir> {
        match c.to_ascii_uppercase() {
            'E' => Some(Dir::East),
            'W' => Some(Dir::West),
            'N' => Some(Dir::North),
            'S' => Some(Dir::South),
            _ => None,
        }
    }
}

/// One directional inter-board link, identified by (board, direction).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkId(pub u32);

impl LinkId {
    #[inline]
    pub fn of(board: usize, dir: Dir) -> LinkId {
        LinkId((board * 4 + dir as usize) as u32)
    }

    #[inline]
    pub fn board(self) -> usize {
        self.0 as usize / 4
    }

    #[inline]
    pub fn dir(self) -> Dir {
        Dir::ALL[self.0 as usize % 4]
    }

    /// `"3E"`-style name (board, direction letter).
    pub fn name(self) -> String {
        format!("{}{}", self.board(), self.dir().letter())
    }
}

/// Per-superstep sample for one link, drained by the simulator's serial
/// trace merge (`Noc::take_step_samples`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkStepSample {
    pub link: u32,
    /// Events that crossed the link this superstep.
    pub events: u32,
    /// Serialisation cycles the link spent busy this superstep.
    pub busy: u64,
    /// Deepest backlog seen this superstep: events already queued on the
    /// link at the moment a new event arrived.
    pub queue_hw: u32,
}

/// The NoC state: busy-until time per inter-board link, plus (for
/// heterogeneous scenarios) per-link effective costs and failure-aware
/// routes.
#[derive(Clone, Debug)]
pub struct Noc {
    link_free: Vec<u64>,
    /// Cumulative busy cycles per link (utilisation metric).
    link_busy: Vec<u64>,
    link_events: Vec<u64>,
    /// Per-link effective (serialize, latency) cycles from a scenario;
    /// empty ⇒ homogeneous (the `CostModel` constants apply everywhere).
    link_cost: Vec<(u64, u64)>,
    /// BFS route table (`from * n_boards + to`), present only when the
    /// scenario failed at least one link; empty ⇒ dimension-ordered X-then-Y.
    routes: Vec<Vec<LinkId>>,
    /// Per board pair: does the surviving route exceed Manhattan distance?
    rerouted: Vec<bool>,
    /// Extra cycles charged to every rerouted crossing (misroute detection
    /// plus the turn the dimension-ordered router has to un-take).
    reroute_penalty: u64,
    /// Crossings that took a longer-than-Manhattan path.
    reroutes: u64,
    /// Opt-in per-superstep telemetry (tracing only: one branch when off).
    track: bool,
    step_events: Vec<u32>,
    step_busy: Vec<u64>,
    step_queue_hw: Vec<u32>,
}

impl Noc {
    /// Homogeneous NoC: every link gets the `CostModel` constants.
    pub fn new(cluster: &ClusterConfig) -> Noc {
        let n = cluster.n_boards * 4;
        Noc {
            link_free: vec![0; n],
            link_busy: vec![0; n],
            link_events: vec![0; n],
            link_cost: Vec::new(),
            routes: Vec::new(),
            rerouted: Vec::new(),
            reroute_penalty: 0,
            reroutes: 0,
            track: false,
            step_events: Vec::new(),
            step_busy: Vec::new(),
            step_queue_hw: Vec::new(),
        }
    }

    /// NoC with a scenario overlay: per-link effective costs and, when links
    /// are failed, a BFS route table.  Errors if the scenario is invalid for
    /// this cluster (bad indices, or failures that disconnect the grid).
    pub fn with_scenario(
        cluster: &ClusterConfig,
        cost: &CostModel,
        scenario: &ScenarioSpec,
    ) -> Result<Noc, String> {
        scenario.validate_for(cluster)?;
        let mut noc = Noc::new(cluster);
        noc.link_cost = scenario.link_costs(cluster, cost);
        noc.reroute_penalty = scenario.reroute_penalty;
        if !scenario.failed.is_empty() {
            let failed = scenario.failed_flags(cluster);
            let (routes, rerouted) = routes_avoiding(cluster, &failed)?;
            noc.routes = routes;
            noc.rerouted = rerouted;
        }
        Ok(noc)
    }

    /// Dimension-ordered route between two boards: the sequence of outbound
    /// links taken (empty if same board).
    pub fn board_route(cluster: &ClusterConfig, from: usize, to: usize) -> Vec<LinkId> {
        let mut path = Vec::new();
        let (mut x, mut y) = cluster.board_xy(from);
        let (tx, ty) = cluster.board_xy(to);
        let board_at = |x: usize, y: usize| y * cluster.board_grid.0 + x;
        while x != tx {
            let dir = if tx > x { Dir::East } else { Dir::West };
            path.push(LinkId((board_at(x, y) * 4 + dir as usize) as u32));
            x = if tx > x { x + 1 } else { x - 1 };
        }
        while y != ty {
            let dir = if ty > y { Dir::South } else { Dir::North };
            path.push(LinkId((board_at(x, y) * 4 + dir as usize) as u32));
            y = if ty > y { y + 1 } else { y - 1 };
        }
        path
    }

    /// Send one event along `route`, departing at `t`.  Each link serialises
    /// (busy-until) and adds crossing latency.  Returns arrival time at the
    /// destination board's ingress.
    pub fn traverse(&mut self, route: &[LinkId], t: u64, cost: &CostModel) -> u64 {
        let mut now = t;
        for l in route {
            let idx = l.0 as usize;
            let (ser, lat) = if self.link_cost.is_empty() {
                (cost.board_link_serialize, cost.board_link_latency)
            } else {
                self.link_cost[idx]
            };
            let start = now.max(self.link_free[idx]);
            if self.track {
                let backlog = self.link_free[idx].saturating_sub(now) / ser.max(1);
                self.step_queue_hw[idx] = self.step_queue_hw[idx].max(backlog as u32);
                self.step_events[idx] += 1;
                self.step_busy[idx] += ser;
            }
            self.link_free[idx] = start + ser;
            self.link_busy[idx] += ser;
            self.link_events[idx] += 1;
            now = start + ser + lat;
        }
        now
    }

    /// Route and traverse in one step: uses the failure-aware route table
    /// when present (charging the reroute penalty on diverted paths), the
    /// dimension-ordered route otherwise.
    pub fn traverse_between(
        &mut self,
        cluster: &ClusterConfig,
        from: usize,
        to: usize,
        t: u64,
        cost: &CostModel,
    ) -> u64 {
        if self.routes.is_empty() {
            let route = Self::board_route(cluster, from, to);
            return self.traverse(&route, t, cost);
        }
        let i = from * cluster.n_boards + to;
        let route = self.routes[i].clone();
        let mut now = self.traverse(&route, t, cost);
        if self.rerouted[i] {
            self.reroutes += 1;
            now += self.reroute_penalty;
        }
        now
    }

    /// The route [`Noc::traverse_between`] would take (failure-aware when a
    /// route table is present, dimension-ordered otherwise), without
    /// charging anything.  The fault plane consults this to evaluate
    /// per-link loss models before a crossing is committed.
    pub fn route_between(&self, cluster: &ClusterConfig, from: usize, to: usize) -> Vec<LinkId> {
        if self.routes.is_empty() {
            Self::board_route(cluster, from, to)
        } else {
            self.routes[from * cluster.n_boards + to].clone()
        }
    }

    /// Number of directional inter-board links modelled.
    pub fn n_links(&self) -> usize {
        self.link_free.len()
    }

    /// Peak cumulative busy cycles over all links.
    pub fn max_link_busy(&self) -> u64 {
        self.link_busy.iter().copied().max().unwrap_or(0)
    }

    /// Total busy cycles summed over all links.
    pub fn total_link_busy(&self) -> u64 {
        self.link_busy.iter().sum()
    }

    /// Total events that crossed any board link.
    pub fn total_link_events(&self) -> u64 {
        self.link_events.iter().sum()
    }

    /// Crossings that had to divert around a failed link.
    pub fn reroutes(&self) -> u64 {
        self.reroutes
    }

    /// Turn on per-superstep telemetry (the simulator calls this once when
    /// tracing is enabled; off by default so the hot path keeps one branch).
    pub fn enable_step_tracking(&mut self) {
        self.track = true;
        let n = self.link_free.len();
        self.step_events = vec![0; n];
        self.step_busy = vec![0; n];
        self.step_queue_hw = vec![0; n];
    }

    /// Drain this superstep's per-link samples (links with traffic only,
    /// ascending link id) and reset the scratch for the next superstep.
    pub fn take_step_samples(&mut self) -> Vec<LinkStepSample> {
        if !self.track {
            return Vec::new();
        }
        let mut out = Vec::new();
        for idx in 0..self.step_events.len() {
            if self.step_events[idx] > 0 {
                out.push(LinkStepSample {
                    link: idx as u32,
                    events: self.step_events[idx],
                    busy: self.step_busy[idx],
                    queue_hw: self.step_queue_hw[idx],
                });
                self.step_events[idx] = 0;
                self.step_busy[idx] = 0;
                self.step_queue_hw[idx] = 0;
            }
        }
        out
    }
}

/// Shortest routes over the board grid avoiding `failed` links, for every
/// ordered board pair: deterministic BFS with fixed E/W/N/S neighbour order.
/// Returns the route table plus a per-pair "longer than Manhattan" flag,
/// or an error naming the first disconnected pair.
pub fn routes_avoiding(
    cluster: &ClusterConfig,
    failed: &[bool],
) -> Result<(Vec<Vec<LinkId>>, Vec<bool>), String> {
    let n = cluster.n_boards;
    let (cols, rows) = cluster.board_grid;
    let mut routes = vec![Vec::new(); n * n];
    let mut rerouted = vec![false; n * n];
    for from in 0..n {
        // BFS with parent links.
        let mut prev: Vec<Option<(usize, LinkId)>> = vec![None; n];
        let mut seen = vec![false; n];
        seen[from] = true;
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(from);
        while let Some(b) = queue.pop_front() {
            let (x, y) = cluster.board_xy(b);
            for dir in Dir::ALL {
                let next = match dir {
                    Dir::East if x + 1 < cols => b + 1,
                    Dir::West if x > 0 => b - 1,
                    Dir::North if y > 0 => b - cols,
                    Dir::South if y + 1 < rows => b + cols,
                    _ => continue,
                };
                if next >= n || seen[next] {
                    continue;
                }
                let link = LinkId::of(b, dir);
                if failed.get(link.0 as usize).copied().unwrap_or(false) {
                    continue;
                }
                seen[next] = true;
                prev[next] = Some((b, link));
                queue.push_back(next);
            }
        }
        for to in 0..n {
            if to == from {
                continue;
            }
            if !seen[to] {
                return Err(format!(
                    "failed links disconnect board {from} from board {to}"
                ));
            }
            let mut path = Vec::new();
            let mut at = to;
            while at != from {
                let (p, link) = prev[at].expect("BFS parent chain reaches the source");
                path.push(link);
                at = p;
            }
            path.reverse();
            let (fx, fy) = cluster.board_xy(from);
            let (tx, ty) = cluster.board_xy(to);
            let manhattan = fx.abs_diff(tx) + fy.abs_diff(ty);
            rerouted[from * n + to] = path.len() > manhattan;
            routes[from * n + to] = path;
        }
    }
    Ok((routes, rerouted))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_board_route_empty() {
        let c = ClusterConfig::poets_48();
        assert!(Noc::board_route(&c, 7, 7).is_empty());
    }

    #[test]
    fn route_length_is_manhattan() {
        let c = ClusterConfig::poets_48(); // grid 6x8
        // board 0 at (0,0); board 47 at (5,7) -> 5 + 7 hops.
        assert_eq!(Noc::board_route(&c, 0, 47).len(), 12);
        assert_eq!(Noc::board_route(&c, 47, 0).len(), 12);
        assert_eq!(Noc::board_route(&c, 0, 5).len(), 5);
        assert_eq!(Noc::board_route(&c, 0, 6).len(), 1);
    }

    #[test]
    fn route_x_then_y() {
        let c = ClusterConfig::poets_48();
        let route = Noc::board_route(&c, 0, 8); // (0,0) -> (2,1)
        assert_eq!(route.len(), 3);
        // First two links eastbound from boards (0,0) and (1,0).
        assert_eq!(route[0].0, (0 * 4 + Dir::East as usize) as u32);
        assert_eq!(route[1].0, (1 * 4 + Dir::East as usize) as u32);
        // Then south from (2,0) = board 2.
        assert_eq!(route[2].0, (2 * 4 + Dir::South as usize) as u32);
    }

    #[test]
    fn traverse_serialises_on_shared_link() {
        let c = ClusterConfig::with_boards(2);
        let cost = CostModel::default();
        let mut noc = Noc::new(&c);
        let route = Noc::board_route(&c, 0, 1);
        assert_eq!(route.len(), 1);
        let a1 = noc.traverse(&route, 0, &cost);
        let a2 = noc.traverse(&route, 0, &cost);
        assert_eq!(a1, cost.board_link_serialize + cost.board_link_latency);
        assert_eq!(
            a2,
            2 * cost.board_link_serialize + cost.board_link_latency,
            "second event must queue behind the first"
        );
        assert_eq!(noc.total_link_events(), 2);
    }

    #[test]
    fn traverse_empty_route_is_free() {
        let c = ClusterConfig::with_boards(2);
        let mut noc = Noc::new(&c);
        assert_eq!(noc.traverse(&[], 123, &CostModel::default()), 123);
    }

    #[test]
    fn link_id_name_roundtrip() {
        let l = LinkId::of(3, Dir::East);
        assert_eq!(l.board(), 3);
        assert_eq!(l.dir(), Dir::East);
        assert_eq!(l.name(), "3E");
        assert_eq!(Dir::from_letter('s'), Some(Dir::South));
        assert_eq!(Dir::from_letter('x'), None);
    }

    #[test]
    fn step_tracking_drains_and_resets() {
        let c = ClusterConfig::with_boards(2);
        let cost = CostModel::default();
        let mut noc = Noc::new(&c);
        noc.enable_step_tracking();
        let route = Noc::board_route(&c, 0, 1);
        noc.traverse(&route, 0, &cost);
        noc.traverse(&route, 0, &cost);
        let samples = noc.take_step_samples();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].link, LinkId::of(0, Dir::East).0);
        assert_eq!(samples[0].events, 2);
        assert_eq!(samples[0].busy, 2 * cost.board_link_serialize);
        assert_eq!(samples[0].queue_hw, 1, "second event saw one queued ahead");
        // Drained: the next superstep starts clean.
        assert!(noc.take_step_samples().is_empty());
        // Cumulative totals keep accumulating regardless.
        assert_eq!(noc.total_link_events(), 2);
    }

    #[test]
    fn untracked_noc_returns_no_samples() {
        let c = ClusterConfig::with_boards(2);
        let mut noc = Noc::new(&c);
        let route = Noc::board_route(&c, 0, 1);
        noc.traverse(&route, 0, &CostModel::default());
        assert!(noc.take_step_samples().is_empty());
    }

    #[test]
    fn bfs_routes_match_manhattan_without_failures() {
        let c = ClusterConfig::with_boards(8); // grid 4x2
        let failed = vec![false; c.n_boards * 4];
        let (routes, rerouted) = routes_avoiding(&c, &failed).unwrap();
        for from in 0..c.n_boards {
            for to in 0..c.n_boards {
                let (fx, fy) = c.board_xy(from);
                let (tx, ty) = c.board_xy(to);
                assert_eq!(
                    routes[from * c.n_boards + to].len(),
                    fx.abs_diff(tx) + fy.abs_diff(ty)
                );
                assert!(!rerouted[from * c.n_boards + to]);
            }
        }
    }

    #[test]
    fn bfs_detours_around_failed_link() {
        let c = ClusterConfig::with_boards(8); // grid 4x2: 0..3 top, 4..7 bottom
        let mut failed = vec![false; c.n_boards * 4];
        failed[LinkId::of(0, Dir::East).0 as usize] = true;
        let (routes, rerouted) = routes_avoiding(&c, &failed).unwrap();
        let r = &routes[1]; // 0 -> 1
        assert_eq!(r.len(), 3, "detour via the second row: S, E, N");
        assert!(rerouted[1]);
        assert!(r.iter().all(|l| !failed[l.0 as usize]));
        // Unaffected pairs keep Manhattan-length paths.
        assert_eq!(routes[2 * c.n_boards + 3].len(), 1);
        assert!(!rerouted[2 * c.n_boards + 3]);
    }

    #[test]
    fn bfs_reports_disconnection() {
        let c = ClusterConfig::with_boards(2); // grid 2x1: one row
        let mut failed = vec![false; c.n_boards * 4];
        failed[LinkId::of(0, Dir::East).0 as usize] = true;
        let err = routes_avoiding(&c, &failed).unwrap_err();
        assert!(err.contains("disconnect"), "{err}");
    }
}
