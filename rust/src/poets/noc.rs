//! Network-on-chip model: intra-board mesh + inter-board 10 Gbps links.
//!
//! Intra-board: a 4×4 wormhole mesh between tiles; we charge per-hop router
//! latency (contention between tiles on the same board is dominated by the
//! mailbox ingress serialisation, which the simulator models separately).
//!
//! Inter-board: each board has four directional links (N/E/S/W, Fig 3).
//! Routing is dimension-ordered (X then Y) over the global board grid.  Each
//! link is a serial resource: events crossing it queue behind one another at
//! 64 B / 10 Gbps — this is where large fan-outs that span boards back up.

use super::costmodel::CostModel;
use super::topology::ClusterConfig;

/// Link direction out of a board.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    East = 0,
    West = 1,
    North = 2,
    South = 3,
}

/// One directional inter-board link, identified by (board, direction).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkId(pub u32);

/// The NoC state: busy-until time per inter-board link.
#[derive(Clone, Debug)]
pub struct Noc {
    link_free: Vec<u64>,
    /// Cumulative busy cycles per link (utilisation metric).
    link_busy: Vec<u64>,
    link_events: Vec<u64>,
}

impl Noc {
    pub fn new(cluster: &ClusterConfig) -> Noc {
        let n = cluster.n_boards * 4;
        Noc {
            link_free: vec![0; n],
            link_busy: vec![0; n],
            link_events: vec![0; n],
        }
    }

    /// Dimension-ordered route between two boards: the sequence of outbound
    /// links taken (empty if same board).
    pub fn board_route(cluster: &ClusterConfig, from: usize, to: usize) -> Vec<LinkId> {
        let mut path = Vec::new();
        let (mut x, mut y) = cluster.board_xy(from);
        let (tx, ty) = cluster.board_xy(to);
        let board_at = |x: usize, y: usize| y * cluster.board_grid.0 + x;
        while x != tx {
            let dir = if tx > x { Dir::East } else { Dir::West };
            path.push(LinkId((board_at(x, y) * 4 + dir as usize) as u32));
            x = if tx > x { x + 1 } else { x - 1 };
        }
        while y != ty {
            let dir = if ty > y { Dir::South } else { Dir::North };
            path.push(LinkId((board_at(x, y) * 4 + dir as usize) as u32));
            y = if ty > y { y + 1 } else { y - 1 };
        }
        path
    }

    /// Send one event along `route`, departing at `t`.  Each link serialises
    /// (busy-until) and adds crossing latency.  Returns arrival time at the
    /// destination board's ingress.
    pub fn traverse(&mut self, route: &[LinkId], t: u64, cost: &CostModel) -> u64 {
        let mut now = t;
        for l in route {
            let idx = l.0 as usize;
            let start = now.max(self.link_free[idx]);
            self.link_free[idx] = start + cost.board_link_serialize;
            self.link_busy[idx] += cost.board_link_serialize;
            self.link_events[idx] += 1;
            now = start + cost.board_link_serialize + cost.board_link_latency;
        }
        now
    }

    /// Peak cumulative busy cycles over all links.
    pub fn max_link_busy(&self) -> u64 {
        self.link_busy.iter().copied().max().unwrap_or(0)
    }

    /// Total events that crossed any board link.
    pub fn total_link_events(&self) -> u64 {
        self.link_events.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_board_route_empty() {
        let c = ClusterConfig::poets_48();
        assert!(Noc::board_route(&c, 7, 7).is_empty());
    }

    #[test]
    fn route_length_is_manhattan() {
        let c = ClusterConfig::poets_48(); // grid 6x8
        // board 0 at (0,0); board 47 at (5,7) -> 5 + 7 hops.
        assert_eq!(Noc::board_route(&c, 0, 47).len(), 12);
        assert_eq!(Noc::board_route(&c, 47, 0).len(), 12);
        assert_eq!(Noc::board_route(&c, 0, 5).len(), 5);
        assert_eq!(Noc::board_route(&c, 0, 6).len(), 1);
    }

    #[test]
    fn route_x_then_y() {
        let c = ClusterConfig::poets_48();
        let route = Noc::board_route(&c, 0, 8); // (0,0) -> (2,1)
        assert_eq!(route.len(), 3);
        // First two links eastbound from boards (0,0) and (1,0).
        assert_eq!(route[0].0, (0 * 4 + Dir::East as usize) as u32);
        assert_eq!(route[1].0, (1 * 4 + Dir::East as usize) as u32);
        // Then south from (2,0) = board 2.
        assert_eq!(route[2].0, (2 * 4 + Dir::South as usize) as u32);
    }

    #[test]
    fn traverse_serialises_on_shared_link() {
        let c = ClusterConfig::with_boards(2);
        let cost = CostModel::default();
        let mut noc = Noc::new(&c);
        let route = Noc::board_route(&c, 0, 1);
        assert_eq!(route.len(), 1);
        let a1 = noc.traverse(&route, 0, &cost);
        let a2 = noc.traverse(&route, 0, &cost);
        assert_eq!(a1, cost.board_link_serialize + cost.board_link_latency);
        assert_eq!(
            a2,
            2 * cost.board_link_serialize + cost.board_link_latency,
            "second event must queue behind the first"
        );
        assert_eq!(noc.total_link_events(), 2);
    }

    #[test]
    fn traverse_empty_route_is_free() {
        let c = ClusterConfig::with_boards(2);
        let mut noc = Noc::new(&c);
        assert_eq!(noc.traverse(&[], 123, &CostModel::default()), 123);
    }
}
