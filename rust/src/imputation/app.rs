//! Raw-model application assembly — paper §5.1/§5.2.
//!
//! The reference panel becomes a 2-D application graph, one vertex per HMM
//! state, column-major vertex ids (`v = m·H + h`) so the manual 2-D mapping
//! packs columns contiguously.  Each column's forward/backward multicast
//! destination lists are interned once and shared by the whole column.

use std::sync::Arc;

use crate::graph::builder::{Graph, GraphBuilder};
use crate::graph::device::VertexId;
use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::model::params::ModelParams;
use crate::obs::trace::RunTrace;
use crate::poets::costmodel::CostModel;
use crate::poets::desim::{SimConfig, Simulator};
use crate::poets::metrics::SimMetrics;
use crate::poets::scenario::ScenarioSpec;
use crate::poets::topology::ClusterConfig;

use super::obs::ObsMatrix;
use super::vertex::RawVertex;

/// Everything needed to run the raw event-driven imputation.
#[derive(Clone)]
pub struct RawAppConfig {
    pub params: ModelParams,
    /// Soft-scheduling factor: panel states per hardware thread (Fig 12).
    pub states_per_thread: usize,
    /// Supersteps between successive lane-group injections when a batch is
    /// wider than [`LANES`](crate::imputation::msg::LANES): group *g*
    /// enters the edge columns at superstep `g·stagger`.  The wavefront
    /// advances one column per superstep, so the default of 1 packs groups
    /// back to back without ever colliding; larger values spread them out
    /// (0 degenerates to PR 5's single-superstep injection).  Numerics are
    /// stagger-invariant — only superstep counts and simulated time change.
    pub stagger: u64,
    pub cluster: ClusterConfig,
    pub cost: CostModel,
    pub sim: SimConfig,
    /// Heterogeneous what-if cluster model (degraded/failed links, shape
    /// overrides).  `None` = the homogeneous cluster in `cluster`.  Setters
    /// that take a scenario keep `cluster` consistent with it; the engines
    /// pass the spec through to `Simulator::with_scenario`.
    pub scenario: Option<ScenarioSpec>,
}

impl Default for RawAppConfig {
    fn default() -> Self {
        RawAppConfig {
            params: ModelParams::default(),
            states_per_thread: 1,
            stagger: 1,
            cluster: ClusterConfig::poets_48(),
            cost: CostModel::default(),
            sim: SimConfig::default(),
            scenario: None,
        }
    }
}

impl RawAppConfig {
    /// Fan the simulator's deliver/step phases out over `threads` host
    /// workers.  Functional results and simulated timings are thread-count
    /// invariant (the superstep barrier makes parallel delivery exact — see
    /// `poets::desim` module docs); only host wall-clock changes.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.sim.threads = Some(threads.max(1));
        self
    }
}

/// Result of an event-driven run.
pub struct EventRunResult {
    /// `dosages[target][marker]`.
    pub dosages: Vec<Vec<f32>>,
    pub metrics: SimMetrics,
    /// Simulated POETS wall-clock seconds.
    pub sim_seconds: f64,
    /// Per-superstep trace, present iff `SimConfig::trace` was set (the
    /// engine pulls it off the simulator after the run — the extract
    /// helpers themselves leave it `None`).
    pub trace: Option<RunTrace>,
}

/// Build the raw application graph (one vertex per panel state).  `cfg`
/// supplies the model parameters and the lane-group injection stagger.
pub fn build_raw_graph(
    panel: &ReferencePanel,
    targets: &[TargetHaplotype],
    cfg: &RawAppConfig,
) -> Graph<RawVertex> {
    let params = &cfg.params;
    let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
    let obs = ObsMatrix::from_targets(targets);
    assert_eq!(obs.n_mark(), m_n, "targets/panel marker mismatch");
    let n_targets = targets.len() as u32;
    let taus: Vec<f64> = (0..m_n)
        .map(|m| {
            if m == 0 {
                0.0
            } else {
                params.tau(panel.gen_dist(m), h_n)
            }
        })
        .collect();

    let mut b = GraphBuilder::new();
    for m in 0..m_n {
        let tau_m = taus[m];
        let tau_next = if m + 1 < m_n { taus[m + 1] } else { 0.0 };
        for h in 0..h_n {
            b.add_vertex(RawVertex::new(
                h as u32,
                m as u32,
                h_n as u32,
                m_n as u32,
                panel.allele(h, m),
                tau_m,
                tau_next,
                params.err,
                n_targets,
                cfg.stagger,
                Arc::clone(&obs),
            ));
        }
    }

    // Shared destination lists: one per column (its full vertex set), plus
    // one per column for the accumulator unicast, plus one shared empty list.
    let col_ids: Vec<Vec<VertexId>> = (0..m_n)
        .map(|m| (0..h_n).map(|h| (m * h_n + h) as VertexId).collect())
        .collect();
    let col_lists: Vec<_> = col_ids.iter().map(|c| b.intern_dests(c.clone())).collect();
    let down_lists: Vec<_> = (0..m_n)
        .map(|m| b.intern_dests(vec![(m * h_n + h_n - 1) as VertexId]))
        .collect();
    let empty = b.intern_dests(vec![]);

    for m in 0..m_n {
        for h in 0..h_n {
            let v = (m * h_n + h) as VertexId;
            // PORT_FWD
            b.add_port(v, if m + 1 < m_n { col_lists[m + 1] } else { empty });
            // PORT_BWD
            b.add_port(v, if m > 0 { col_lists[m - 1] } else { empty });
            // PORT_DOWN (the accumulator itself tallies locally).
            b.add_port(v, if h == h_n - 1 { empty } else { down_lists[m] });
        }
    }
    b.build()
}

/// Pull per-target dosage vectors out of the accumulator vertices.
pub fn extract_results(
    sim: &Simulator<RawVertex>,
    panel: &ReferencePanel,
    n_targets: usize,
) -> EventRunResult {
    let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
    let mut dosages = vec![vec![f32::NAN; m_n]; n_targets];
    for m in 0..m_n {
        let acc = &sim.graph.devices[m * h_n + (h_n - 1)];
        assert_eq!(acc.dosage.len(), n_targets);
        for (t, row) in dosages.iter_mut().enumerate() {
            let d = acc.dosage[t];
            assert!(
                d.is_finite(),
                "dosage for target {t} marker {m} never completed"
            );
            row[m] = d;
        }
    }
    let mut metrics = sim.metrics.clone();
    metrics.max_groups_in_flight = super::wave::n_groups(n_targets) as u64;
    EventRunResult {
        dosages,
        metrics,
        sim_seconds: sim.sim_seconds(),
        trace: None,
    }
}

// The raw plane's canonical numerics/metrics checks, driven through the
// session pipeline (the only entry point since the deprecated `run_raw`
// shim was removed).
#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::baseline::{Baseline, ImputeOut, Method};
    use crate::session::{EngineSpec, ImputeSession, Workload};
    use crate::util::rng::Rng;
    use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

    /// Run the raw event plane on one workload (what `run_raw` used to do).
    fn run_event(
        panel: &ReferencePanel,
        targets: &[TargetHaplotype],
        cfg: &RawAppConfig,
    ) -> EventRunResult {
        let report = ImputeSession::new(Workload::from_parts(panel.clone(), targets.to_vec()))
            .engine(EngineSpec::Event)
            .app_config(cfg.clone())
            .run()
            .expect("event plane is always available");
        EventRunResult {
            dosages: report.dosages,
            metrics: report.metrics.expect("event plane reports metrics"),
            sim_seconds: report.sim_seconds.expect("event plane reports simulated time"),
            trace: None,
        }
    }

    fn small_cfg() -> RawAppConfig {
        RawAppConfig {
            cluster: ClusterConfig::with_boards(2),
            states_per_thread: 4,
            ..RawAppConfig::default()
        }
    }

    fn problem(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize)
        -> (ReferencePanel, Vec<TargetHaplotype>) {
        let pcfg = PanelConfig {
            n_hap,
            n_mark,
            maf: 0.25,
            annot_ratio: 0.2,
            seed,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&pcfg);
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let targets = generate_targets(&panel, &pcfg, n_targets, &mut rng)
            .into_iter()
            .map(|c| c.masked)
            .collect();
        (panel, targets)
    }

    #[test]
    fn graph_shape() {
        let (panel, targets) = problem(1, 6, 10, 1);
        let g = build_raw_graph(&panel, &targets, &RawAppConfig::default());
        assert_eq!(g.n_vertices(), 60);
        // fwd H per vertex except last column; bwd except first; down except
        // accumulator row.
        let expected_edges = (6 * 9 * 6) + (6 * 9 * 6) + (5 * 10);
        assert_eq!(g.n_edges(), expected_edges as u64);
    }

    #[test]
    fn event_driven_matches_baseline_single_target() {
        let (panel, targets) = problem(2, 8, 12, 1);
        let out = run_event(&panel, &targets, &small_cfg());
        let b = Baseline::default();
        let want: ImputeOut<f32> = b.impute(&panel, &targets[0], Method::DenseThreeLoop);
        for m in 0..panel.n_mark() {
            assert!(
                (out.dosages[0][m] - want.dosage[m]).abs() < 1e-4,
                "marker {m}: event {} vs baseline {}",
                out.dosages[0][m],
                want.dosage[m]
            );
        }
    }

    #[test]
    fn event_driven_matches_baseline_pipelined_targets() {
        let (panel, targets) = problem(3, 6, 15, 4);
        let out = run_event(&panel, &targets, &small_cfg());
        let b = Baseline::default();
        for (t, target) in targets.iter().enumerate() {
            let want: ImputeOut<f32> = b.impute(&panel, target, Method::DenseThreeLoop);
            for m in 0..panel.n_mark() {
                assert!(
                    (out.dosages[t][m] - want.dosage[m]).abs() < 1e-4,
                    "target {t} marker {m}: {} vs {}",
                    out.dosages[t][m],
                    want.dosage[m]
                );
            }
        }
    }

    #[test]
    fn wave_sweep_completes_in_m_plus_slack_steps() {
        // Wave batching: the whole lane group sweeps the panel together, so
        // the superstep count is ~M + constant — independent of the target
        // count (the per-target plane needed ~M + T).
        let (panel, targets) = problem(4, 6, 12, 5);
        let out = run_event(&panel, &targets, &small_cfg());
        let steps = out.metrics.steps;
        assert!(steps <= (12 + 6) as u64, "steps {steps} > bound");
        assert!(steps >= (12 - 1) as u64, "steps {steps} implausibly low");
    }

    #[test]
    fn message_counts_match_theory() {
        let (panel, targets) = problem(5, 6, 10, 2);
        let out = run_event(&panel, &targets, &small_cfg());
        let (h, m, t) = (6u64, 10u64, 2u64);
        // One wave, one chunk (T=2 ≤ LANES): each vertex sends one α chunk
        // (except last col), one β chunk (except col 0) and non-accumulators
        // one posterior chunk — per WAVE, not per target.
        let expected_sends = (m - 1) * h + (m - 1) * h + m * (h - 1);
        assert_eq!(out.metrics.sends, expected_sends);
        // Copies: each α/β multicast chunk delivers H copies; posteriors 1.
        let expected_copies = (m - 1) * h * h * 2 + m * (h - 1);
        assert_eq!(out.metrics.copies_delivered, expected_copies);
        // Every event carries all T lanes, so the delivered lane count is
        // the per-target plane's copy count exactly.
        assert_eq!(out.metrics.lanes_delivered, t * expected_copies);
    }

    #[test]
    fn pipelined_groups_match_sequential_groups_and_cut_supersteps() {
        // A batch of 2·LANES+1 targets pipelines as three staggered lane
        // groups inside ONE engine run.  Dosages must be bit-identical to
        // running the groups as sequential LANES-wide batches, in at most
        // half the total supersteps (the groups overlap instead of queueing).
        use crate::imputation::msg::LANES;
        let t = 2 * LANES + 1;
        let (panel, targets) = problem(8, 6, 30, t);
        let wl = Workload::from_parts(panel, targets);
        let run = |batch: usize| {
            ImputeSession::new(wl.clone())
                .engine(EngineSpec::Event)
                .app_config(small_cfg())
                .batch(batch)
                .run()
                .expect("event plane is always available")
        };
        let pipelined = run(t);
        let sequential = run(LANES);
        assert_eq!(
            pipelined.dosages, sequential.dosages,
            "pipelined groups must reproduce sequential-group dosages bit for bit"
        );
        let (pm, sm) = (
            pipelined.metrics.as_ref().unwrap(),
            sequential.metrics.as_ref().unwrap(),
        );
        assert_eq!(pm.max_groups_in_flight, 3);
        assert_eq!(sm.max_groups_in_flight, 1);
        // Same traffic, fewer barriers.
        assert_eq!(pm.sends, sm.sends, "pipelining must not change event counts");
        assert_eq!(pm.lanes_delivered, sm.lanes_delivered);
        assert!(
            2 * pm.steps <= sm.steps,
            "pipelined {} supersteps vs sequential {}",
            pm.steps,
            sm.steps
        );
    }

    #[test]
    fn host_threads_do_not_change_results_or_timing() {
        let (panel, targets) = problem(7, 8, 14, 3);
        let serial = run_event(&panel, &targets, &small_cfg());
        let parallel = run_event(&panel, &targets, &small_cfg().with_threads(4));
        assert_eq!(serial.dosages, parallel.dosages, "thread count changed numerics");
        assert_eq!(serial.metrics.sim_cycles, parallel.metrics.sim_cycles);
        assert_eq!(serial.metrics.sends, parallel.metrics.sends);
        assert_eq!(
            serial.metrics.copies_delivered,
            parallel.metrics.copies_delivered
        );
    }

    #[test]
    fn soft_scheduling_changes_time_not_results() {
        let (panel, targets) = problem(6, 8, 10, 2);
        let mut cfg1 = small_cfg();
        cfg1.states_per_thread = 1;
        let mut cfg8 = small_cfg();
        cfg8.states_per_thread = 8;
        let a = run_event(&panel, &targets, &cfg1);
        let b = run_event(&panel, &targets, &cfg8);
        assert_eq!(a.dosages, b.dosages, "mapping must not change numerics");
        assert!(a.sim_seconds != b.sim_seconds, "timing should differ");
    }
}
