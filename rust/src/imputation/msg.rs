//! Event payloads for the imputation applications.
//!
//! Every variant fits the 64-byte Tinsel event budget (asserted by the
//! simulator at load time).  Events carry the target-haplotype index so the
//! pipelined waves of different targets can be disentangled — and so the
//! vertices can *assert* no cross-target contamination, the hazard the
//! paper's synchronised stepping exists to prevent.

/// Maximum linear-interpolation section length (1 HMM state + 11 interp
/// states) such that a per-section hit-vector still fits one event.
pub const MAX_SECTION: usize = 12;

/// Raw-model event (paper Algorithm 1: msgType ∈ {alpha, beta, posterior}).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RawMsg {
    /// Forward variable of the sending vertex (receiver applies `a_ij`).
    Alpha { target: u32, val: f32 },
    /// Backward variable of the sender, pre-multiplied by the sender's own
    /// emission `b_j(O_{m+1})` (receiver applies `a_ij`).
    Beta { target: u32, val: f32 },
    /// Posterior probability of one state, labelled with its allele, unicast
    /// down the column to the accumulating vertex.
    Post { target: u32, allele1: bool, val: f32 },
}

/// Linear-interpolation event (paper §5.3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InterpMsg {
    /// As in the raw model, but over the anchor (annotated-marker) grid.
    Alpha { target: u32, val: f32 },
    Beta { target: u32, val: f32 },
    Post { target: u32, allele1: bool, val: f32 },
    /// Anchor posterior of vertex (h, k), sent right→left so the section
    /// owner (h, k-1) can interpolate its intermediate states.
    Section { target: u32, val: f32 },
    /// Per-intermediate-marker allele-1 posterior contributions of one
    /// haplotype's section, packed into a single event.
    HitVec {
        target: u32,
        n: u8,
        vals: [f32; MAX_SECTION],
    },
    /// Column posterior total of anchor k, sent right→left between
    /// accumulators so intermediate totals can be interpolated.
    Tot { target: u32, val: f32 },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_msg_fits_event_budget() {
        assert!(std::mem::size_of::<RawMsg>() <= 56);
    }

    #[test]
    fn interp_msg_fits_event_budget() {
        assert!(
            std::mem::size_of::<InterpMsg>() <= 56,
            "InterpMsg is {} bytes",
            std::mem::size_of::<InterpMsg>()
        );
    }
}
