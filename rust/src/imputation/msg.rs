//! Event payloads for the imputation applications — SoA wave batching.
//!
//! Every variant fits the 64-byte Tinsel event budget: 8 bytes of routing
//! metadata leave **56 bytes of payload** (asserted by the simulator at load
//! time via [`crate::poets::event::assert_event_fits`]).
//!
//! # SoA message layout (the 56-byte budget, spent)
//!
//! Since PR 5 the event plane is *wave-batched*: one event carries the values
//! of up to [`LANES`] in-flight targets as a structure-of-arrays slab —
//! `base` names the first target, `n` the occupied lane count, and
//! `vals[0..n]` the per-target payloads.  A wave wider than `LANES` targets
//! is *chunked* into `ceil(width / LANES)` events per sender (see
//! [`for_each_chunk`]); `n == 1` degenerates to the original one event per
//! (vertex, target, wave) traffic, which is how the per-target plane is still
//! expressible (batch width 1) and why batched runs are bit-identical to it.
//!
//! Budget arithmetic for `LANES = 8` (f32 lanes, 4-byte alignment, 1-byte
//! discriminant packed with the small fields):
//!
//! * `AlphaVec`/`BetaVec`/`SectionVec`/`TotVec`: tag + n + base + 8×f32 ≈ 40 B
//! * `PostVec`: tag + n + allele flag + base + 8×f32 ≈ 40 B
//! * `HitVec`: tag + n + target + 12×f32 = 56 B — already full, so hit
//!   vectors stay **per-target** (one event per target per section); only the
//!   scalar α/β/posterior/section/total traffic batches across lanes.
//!
//! `LANES = 12` would need 60 B for the slab alone — 8 is the widest SoA slab
//! the event budget admits.

/// Lane width of one SoA event: how many targets' values a single α/β/
/// posterior event carries.  Fixed by the 56-byte payload budget (see the
/// module docs); wider waves are chunked by [`for_each_chunk`].
pub const LANES: usize = 8;

/// Maximum linear-interpolation section length (1 HMM state + 11 interp
/// states) such that a per-section hit-vector still fits one event.
pub const MAX_SECTION: usize = 12;

/// Chunk one wave's per-target slab into `LANES`-wide SoA pieces and hand
/// each `(base, n, vals)` chunk to `emit` — the one place the event budget
/// is enforced on the send path.
pub fn for_each_chunk(vals: &[f32], mut emit: impl FnMut(u32, u8, [f32; LANES])) {
    let mut base = 0usize;
    while base < vals.len() {
        let n = (vals.len() - base).min(LANES);
        let mut slab = [0.0f32; LANES];
        slab[..n].copy_from_slice(&vals[base..base + n]);
        emit(base as u32, n as u8, slab);
        base += n;
    }
}

/// Raw-model event (paper Algorithm 1: msgType ∈ {alpha, beta, posterior}),
/// wave-batched: one event per sender per wave chunk instead of per target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RawMsg {
    /// Forward variables of the sending vertex for targets `base..base+n`
    /// (receiver applies `a_ij` lane-by-lane).
    AlphaVec { base: u32, n: u8, vals: [f32; LANES] },
    /// Backward variables of the sender, each pre-multiplied by the sender's
    /// own emission `b_j(O_{m+1})` (receiver applies `a_ij`).
    BetaVec { base: u32, n: u8, vals: [f32; LANES] },
    /// Posterior probabilities of one state for `n` targets, labelled with
    /// the sending state's allele, unicast down the column to the
    /// accumulating vertex.
    PostVec {
        base: u32,
        n: u8,
        allele1: bool,
        vals: [f32; LANES],
    },
}

impl RawMsg {
    /// Occupied lane count (targets serviced by this one event).
    pub fn lanes(&self) -> u32 {
        match *self {
            RawMsg::AlphaVec { n, .. } | RawMsg::BetaVec { n, .. } | RawMsg::PostVec { n, .. } => {
                n as u32
            }
        }
    }
}

/// Linear-interpolation event (paper §5.3), wave-batched like [`RawMsg`];
/// only the hit vector stays per-target (its 12-value slab already fills the
/// event budget — see the module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InterpMsg {
    /// As in the raw model, but over the anchor (annotated-marker) grid.
    AlphaVec { base: u32, n: u8, vals: [f32; LANES] },
    BetaVec { base: u32, n: u8, vals: [f32; LANES] },
    PostVec {
        base: u32,
        n: u8,
        allele1: bool,
        vals: [f32; LANES],
    },
    /// Anchor posteriors of vertex (h, k) for `n` targets, sent right→left
    /// so the section owner (h, k-1) can interpolate its intermediates.
    SectionVec { base: u32, n: u8, vals: [f32; LANES] },
    /// Per-intermediate-marker allele-1 posterior contributions of one
    /// haplotype's section for ONE target, packed into a single event.
    HitVec {
        target: u32,
        n: u8,
        vals: [f32; MAX_SECTION],
    },
    /// Column posterior totals of anchor k for `n` targets, sent right→left
    /// between accumulators so intermediate totals can be interpolated.
    TotVec { base: u32, n: u8, vals: [f32; LANES] },
}

impl InterpMsg {
    /// Occupied lane count (targets serviced by this one event).
    pub fn lanes(&self) -> u32 {
        match *self {
            InterpMsg::AlphaVec { n, .. }
            | InterpMsg::BetaVec { n, .. }
            | InterpMsg::PostVec { n, .. }
            | InterpMsg::SectionVec { n, .. }
            | InterpMsg::TotVec { n, .. } => n as u32,
            InterpMsg::HitVec { .. } => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_msg_fits_event_budget() {
        assert!(
            std::mem::size_of::<RawMsg>() <= 56,
            "RawMsg is {} bytes",
            std::mem::size_of::<RawMsg>()
        );
    }

    #[test]
    fn interp_msg_fits_event_budget() {
        assert!(
            std::mem::size_of::<InterpMsg>() <= 56,
            "InterpMsg is {} bytes",
            std::mem::size_of::<InterpMsg>()
        );
    }

    #[test]
    fn chunking_covers_every_lane_once() {
        let vals: Vec<f32> = (0..LANES + 3).map(|i| i as f32).collect();
        let mut seen = Vec::new();
        for_each_chunk(&vals, |base, n, slab| {
            for i in 0..n as usize {
                seen.push((base as usize + i, slab[i]));
            }
        });
        assert_eq!(seen.len(), LANES + 3);
        for (i, &(lane, v)) in seen.iter().enumerate() {
            assert_eq!(lane, i);
            assert_eq!(v, i as f32);
        }
    }

    #[test]
    fn chunking_respects_the_lane_budget() {
        let vals = vec![1.0f32; 3 * LANES + 1];
        let mut chunks = Vec::new();
        for_each_chunk(&vals, |base, n, _| chunks.push((base, n)));
        assert_eq!(chunks.len(), 4);
        assert!(chunks.iter().all(|&(_, n)| n as usize <= LANES));
        assert_eq!(chunks.last().unwrap().1, 1);
    }

    #[test]
    fn lane_counts_reported() {
        let a = RawMsg::AlphaVec {
            base: 0,
            n: 5,
            vals: [0.0; LANES],
        };
        assert_eq!(a.lanes(), 5);
        let h = InterpMsg::HitVec {
            target: 3,
            n: 9,
            vals: [0.0; MAX_SECTION],
        };
        assert_eq!(h.lanes(), 1, "hit vectors are per-target events");
    }
}
