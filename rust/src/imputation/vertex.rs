//! The raw-model state vertex — paper Algorithm 1, one HMM state per vertex,
//! wave-batched across targets (PR 5).
//!
//! Ports (fixed order, empty destination lists at the panel edges):
//! * `PORT_FWD` (0) — multicast α to every vertex of the next column.
//! * `PORT_BWD` (1) — multicast β·b to every vertex of the previous column.
//! * `PORT_DOWN` (2) — unicast posteriors to the column's accumulating vertex
//!   (the "final haplotype" vertex, h = H−1), which tallies allele-labelled
//!   posterior mass and makes the major/minor call.
//!
//! # Wave batching + pipelined lane groups
//!
//! The targets of one engine run are split into contiguous **lane groups**
//! of at most [`LANES`](super::msg::LANES) targets each (the SoA event
//! budget — see `imputation::msg` and `imputation::wave`).  Column 0
//! injects group *g*'s α (and column M−1 its β) as one chunk event at
//! superstep `g·stagger`, so successive groups *pipeline* through the
//! panel inside a single run: while group 0's wavefront crosses column
//! *k*, group 1 is crossing column *k−stagger*, and so on.  One `recv`
//! handler services a whole group chunk, so per-target event counts drop
//! by ~the lane width relative to the per-target plane the paper describes
//! (which is exactly lane width 1), and the staggered injections keep
//! every column busy instead of idling between sequential group runs.
//!
//! # Canonical reduce ⇒ batch-width invariance
//!
//! Arrivals are buffered per **(lane group, sender haplotype)**
//! (`GroupWaves`) and each group is reduced in ascending sender order once
//! its slab completes.  The f32 sum order is therefore a property of the
//! model, not of event timing or of which groups happen to be in flight:
//! dosages are bit-identical for every batch width and every host thread
//! count (enforced by `tests/parallel_equivalence.rs`), which is what lets
//! the serve layer merge coalesced requests' targets into one wave and
//! still answer each request exactly as a solo run would.
//!
//! Cost: a group in flight holds O(H · group width) f32 at the vertices
//! its wavefront is currently crossing (each group's `WaveBuf` allocates
//! on first arrival and frees on completion — idle columns and drained
//! groups hold nothing).  On panels where even that bites, bound the batch
//! with `ImputeSession::batch` — numerics are width invariant, so
//! splitting has no accuracy consequences.

// Canonical-order reductions index several parallel slabs by lane/sender —
// explicit index loops keep the summation order visibly fixed.
#![allow(clippy::needless_range_loop)]

use std::sync::Arc;

use crate::graph::device::{Ctx, Device, PortId, VertexId};
use crate::poets::fault::{SnapReader, SnapWriter};

use super::msg::{RawMsg, for_each_chunk};
use super::obs::ObsMatrix;
use super::wave::{
    GroupWaves, group_start, group_width, inject_at, n_groups, reduce_hit_tot, reduce_same_diff,
};

pub const PORT_FWD: PortId = 0;
pub const PORT_BWD: PortId = 1;
pub const PORT_DOWN: PortId = 2;

/// One HMM state (reference haplotype `h`, marker `m`).
pub struct RawVertex {
    pub h: u32,
    pub m: u32,
    h_n: u32,
    m_n: u32,
    /// Reference allele labelling this state.
    allele: u8,
    /// Transition factors *into this column* (τ_m): stay / jump.
    a_same: f32,
    a_diff: f32,
    /// Transition factors into the previous column (τ_{m+1} as seen from
    /// m; used when receiving β from column m+1 — β recurrence uses the
    /// sender column's τ). Zero at the last column.
    a_same_next: f32,
    a_diff_next: f32,
    err: f32,
    n_targets: u32,
    /// Supersteps between successive lane-group injections at the edges.
    stagger: u64,
    obs: Arc<ObsMatrix>,

    // In-flight waves, keyed by (lane group, sender haplotype).
    alpha_wave: GroupWaves,
    beta_wave: GroupWaves,
    // Completed per-group α/β slabs awaiting their partner wave.
    alpha: Vec<Vec<f32>>,
    alpha_done: Vec<bool>,
    beta: Vec<Vec<f32>>,
    beta_done: Vec<bool>,
    posterior_done: Vec<bool>,
    // Injection bookkeeping (edge columns): next group to inject.
    injected_alpha: usize,
    injected_beta: usize,
    // Accumulator role (h == H−1 only): posterior contributions keyed by
    // (group, sender haplotype), plus each sender's allele label (static
    // per sender, shared across groups).
    post_wave: GroupWaves,
    post_allele1: Vec<bool>,
    /// Finished dosages (target-indexed), accumulator vertices only.
    pub dosage: Vec<f32>,
}

impl RawVertex {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: u32,
        m: u32,
        h_n: u32,
        m_n: u32,
        allele: u8,
        tau_m: f64,
        tau_next: f64,
        err: f64,
        n_targets: u32,
        stagger: u64,
        obs: Arc<ObsMatrix>,
    ) -> RawVertex {
        let hn = h_n as f64;
        let is_acc = h == h_n - 1;
        let n_g = n_groups(n_targets as usize);
        RawVertex {
            h,
            m,
            h_n,
            m_n,
            allele,
            a_same: ((1.0 - tau_m) + tau_m / hn) as f32,
            a_diff: (tau_m / hn) as f32,
            a_same_next: ((1.0 - tau_next) + tau_next / hn) as f32,
            a_diff_next: (tau_next / hn) as f32,
            err: err as f32,
            n_targets,
            stagger,
            obs,
            alpha_wave: GroupWaves::new(),
            beta_wave: GroupWaves::new(),
            alpha: vec![Vec::new(); n_g],
            alpha_done: vec![false; n_g],
            beta: vec![Vec::new(); n_g],
            beta_done: vec![false; n_g],
            posterior_done: vec![false; n_g],
            injected_alpha: 0,
            injected_beta: 0,
            post_wave: GroupWaves::new(),
            post_allele1: if is_acc { vec![false; h_n as usize] } else { Vec::new() },
            dosage: if is_acc {
                vec![f32::NAN; n_targets as usize]
            } else {
                Vec::new()
            },
        }
    }

    #[inline]
    fn is_accumulator(&self) -> bool {
        self.h == self.h_n - 1
    }

    /// Emission `b_h(O_m)` for one target at this vertex's marker.
    #[inline]
    fn emission(&self, target: u32) -> f32 {
        let o = self.obs.get(target, self.m);
        if o < 0 {
            1.0
        } else if o == self.allele as i8 {
            1.0 - self.err
        } else {
            self.err
        }
    }

    /// Store one α chunk; reduce and propagate once its group is complete.
    fn take_alpha(&mut self, base: usize, vals: &[f32], src: VertexId, ctx: &mut Ctx<RawMsg>) {
        let c = self.n_targets as usize;
        let src_h = (src % self.h_n) as usize;
        if let Some(g) = self.alpha_wave.store(self.h_n as usize, c, src_h, base, vals, "α") {
            let buf = self.alpha_wave.take(g);
            let w = group_width(g, c);
            // Canonical reduce (wave::reduce_same_diff): Σ_h a_ij·α_h in
            // ascending sender order, then the emission — identical
            // arithmetic for every batch width and group schedule.
            let mut alpha =
                reduce_same_diff(&buf, self.h_n as usize, w, self.h as usize, self.a_same, self.a_diff);
            for (t, a) in alpha.iter_mut().enumerate() {
                ctx.flop(2 * self.h_n as u64);
                *a *= self.emission((group_start(g) + t) as u32);
                ctx.flop(1);
            }
            self.finish_alpha(g, alpha, ctx);
        }
    }

    /// Store one β chunk; reduce and propagate once its group is complete.
    fn take_beta(&mut self, base: usize, vals: &[f32], src: VertexId, ctx: &mut Ctx<RawMsg>) {
        let c = self.n_targets as usize;
        let src_h = (src % self.h_n) as usize;
        if let Some(g) = self.beta_wave.store(self.h_n as usize, c, src_h, base, vals, "β") {
            let buf = self.beta_wave.take(g);
            let w = group_width(g, c);
            let beta = reduce_same_diff(
                &buf,
                self.h_n as usize,
                w,
                self.h as usize,
                self.a_same_next,
                self.a_diff_next,
            );
            ctx.flop(2 * self.h_n as u64 * w as u64);
            self.finish_beta(g, beta, ctx);
        }
    }

    /// Group `g`'s α complete → forward its chunk, try to pair.
    fn finish_alpha(&mut self, g: usize, alpha: Vec<f32>, ctx: &mut Ctx<RawMsg>) {
        if self.m + 1 < self.m_n {
            let start = group_start(g) as u32;
            for_each_chunk(&alpha, |base, n, vals| {
                ctx.send(PORT_FWD, RawMsg::AlphaVec { base: base + start, n, vals });
            });
        }
        self.alpha[g] = alpha;
        self.alpha_done[g] = true;
        self.try_posterior(g, ctx);
    }

    /// Group `g`'s β complete → forward β·b backward (emission folded in),
    /// try to pair.
    fn finish_beta(&mut self, g: usize, beta: Vec<f32>, ctx: &mut Ctx<RawMsg>) {
        if self.m > 0 {
            let start = group_start(g);
            let folded: Vec<f32> = beta
                .iter()
                .enumerate()
                .map(|(t, &b)| {
                    ctx.flop(1);
                    b * self.emission((start + t) as u32)
                })
                .collect();
            for_each_chunk(&folded, |base, n, vals| {
                ctx.send(PORT_BWD, RawMsg::BetaVec { base: base + start as u32, n, vals });
            });
        }
        self.beta[g] = beta;
        self.beta_done[g] = true;
        self.try_posterior(g, ctx);
    }

    /// Both of group `g`'s waves in → posteriors for its lanes → unicast /
    /// local tally (Algorithm 1 lines 9–11 / 18–20, the whole group at once).
    fn try_posterior(&mut self, g: usize, ctx: &mut Ctx<RawMsg>) {
        if self.posterior_done[g] || !self.alpha_done[g] || !self.beta_done[g] {
            return;
        }
        self.posterior_done[g] = true;
        let w = group_width(g, self.n_targets as usize);
        let mut post = vec![0.0f32; w];
        for t in 0..w {
            post[t] = self.alpha[g][t] * self.beta[g][t];
            ctx.flop(1);
        }
        self.alpha[g] = Vec::new();
        self.beta[g] = Vec::new();
        let allele1 = self.allele == 1;
        let start = group_start(g) as u32;
        if self.is_accumulator() {
            let h = self.h;
            self.take_posts(h, allele1, start as usize, &post, ctx);
        } else {
            for_each_chunk(&post, |base, n, vals| {
                ctx.send(
                    PORT_DOWN,
                    RawMsg::PostVec {
                        base: base + start,
                        n,
                        allele1,
                        vals,
                    },
                );
            });
        }
    }

    /// Accumulate one sender's posterior lanes (line 23–25); finish a
    /// group's dosages once every sender haplotype has contributed every
    /// lane of that group.
    fn take_posts(&mut self, src_h: u32, allele1: bool, base: usize, vals: &[f32], ctx: &mut Ctx<RawMsg>) {
        debug_assert!(self.is_accumulator());
        let c = self.n_targets as usize;
        self.post_allele1[src_h as usize] = allele1;
        ctx.flop(2 * vals.len() as u64);
        if let Some(g) = self
            .post_wave
            .store(self.h_n as usize, c, src_h as usize, base, vals, "posterior")
        {
            let buf = self.post_wave.take(g);
            let w = group_width(g, c);
            let sums = reduce_hit_tot(&buf, self.h_n as usize, w, &self.post_allele1);
            let start = group_start(g);
            for (t, &(hit, tot)) in sums.iter().enumerate() {
                self.dosage[start + t] = if tot > 0.0 { hit / tot } else { 0.0 };
                ctx.flop(1);
            }
        }
    }
}

impl Device for RawVertex {
    type Msg = RawMsg;

    fn init(&mut self, _ctx: &mut Ctx<RawMsg>) {
        // Injection happens in the step handler so that init stays cheap on
        // every vertex (the real cluster broadcasts one 'start' event).
    }

    fn recv(&mut self, msg: &RawMsg, src: VertexId, ctx: &mut Ctx<RawMsg>) {
        match *msg {
            RawMsg::AlphaVec { base, n, ref vals } => {
                self.take_alpha(base as usize, &vals[..n as usize], src, ctx)
            }
            RawMsg::BetaVec { base, n, ref vals } => {
                self.take_beta(base as usize, &vals[..n as usize], src, ctx)
            }
            RawMsg::PostVec {
                base,
                n,
                allele1,
                ref vals,
            } => {
                let src_h = src % self.h_n;
                self.take_posts(src_h, allele1, base as usize, &vals[..n as usize], ctx)
            }
        }
    }

    fn step(&mut self, ctx: &mut Ctx<RawMsg>) -> bool {
        // Algorithm 1 lines 26–28, pipelined: edge columns inject lane
        // group g's α/β wave once the superstep reaches g·stagger, so
        // successive groups enter the panel while their predecessors are
        // still sweeping it.  Vote to continue while groups remain
        // uninjected — liveness must not depend on in-flight traffic.
        let c = self.n_targets as usize;
        let n_g = n_groups(c);
        let mut active = false;
        if self.m == 0 {
            while self.injected_alpha < n_g && ctx.step >= inject_at(self.injected_alpha, self.stagger)
            {
                let g = self.injected_alpha;
                self.injected_alpha += 1;
                // Uniform prior, no emission at the run's first marker
                // (matches the per-target plane and the windowing docs in
                // genomics).
                self.finish_alpha(g, vec![1.0 / self.h_n as f32; group_width(g, c)], ctx);
                active = true;
            }
            active |= self.injected_alpha < n_g;
        }
        if self.m == self.m_n - 1 {
            while self.injected_beta < n_g && ctx.step >= inject_at(self.injected_beta, self.stagger)
            {
                let g = self.injected_beta;
                self.injected_beta += 1;
                self.finish_beta(g, vec![1.0; group_width(g, c)], ctx);
                active = true;
            }
            active |= self.injected_beta < n_g;
        }
        active
    }

    fn lanes(msg: &RawMsg) -> u32 {
        msg.lanes()
    }

    /// Serialise every mutable field (the model constants are rebuilt with
    /// the graph) so the fault plane can checkpoint mid-sweep — partial
    /// waves included.
    fn snapshot(&self, out: &mut Vec<u8>) -> bool {
        let mut w = SnapWriter::new(out);
        self.alpha_wave.snapshot(&mut w);
        self.beta_wave.snapshot(&mut w);
        w.u32(self.alpha.len() as u32);
        for a in &self.alpha {
            w.f32s(a);
        }
        w.bools(&self.alpha_done);
        for b in &self.beta {
            w.f32s(b);
        }
        w.bools(&self.beta_done);
        w.bools(&self.posterior_done);
        w.u32(self.injected_alpha as u32);
        w.u32(self.injected_beta as u32);
        self.post_wave.snapshot(&mut w);
        w.bools(&self.post_allele1);
        w.f32s(&self.dosage);
        true
    }

    fn restore(&mut self, bytes: &[u8]) {
        let mut r = SnapReader::new(bytes);
        self.alpha_wave = GroupWaves::restore(&mut r);
        self.beta_wave = GroupWaves::restore(&mut r);
        let n_g = r.u32() as usize;
        self.alpha = (0..n_g).map(|_| r.f32s()).collect();
        self.alpha_done = r.bools();
        self.beta = (0..n_g).map(|_| r.f32s()).collect();
        self.beta_done = r.bools();
        self.posterior_done = r.bools();
        self.injected_alpha = r.u32() as usize;
        self.injected_beta = r.u32() as usize;
        self.post_wave = GroupWaves::restore(&mut r);
        self.post_allele1 = r.bools();
        self.dosage = r.f32s();
        assert!(r.exhausted(), "raw-vertex snapshot not fully consumed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imputation::msg::LANES;
    use crate::model::panel::TargetHaplotype;

    fn mk(h: u32, m: u32) -> RawVertex {
        let obs = ObsMatrix::from_targets(&[TargetHaplotype::new(vec![1, -1, 0])]);
        RawVertex::new(h, m, 2, 3, 1, 0.1, 0.2, 1e-4, 1, 1, obs)
    }

    #[test]
    fn emission_uses_own_marker() {
        let v = mk(0, 0);
        assert!((v.emission(0) - (1.0 - 1e-4)).abs() < 1e-9); // obs 1, allele 1
        let v = mk(0, 1);
        assert_eq!(v.emission(0), 1.0); // unannotated
        let v = mk(0, 2);
        assert!((v.emission(0) - 1e-4).abs() < 1e-9); // obs 0 vs allele 1
    }

    #[test]
    fn transition_factors_normalised() {
        let v = mk(0, 1);
        let row = v.a_same as f64 + v.a_diff as f64; // H=2: one same + one diff
        assert!((row - 1.0).abs() < 1e-6);
    }

    #[test]
    fn accumulator_is_last_haplotype() {
        assert!(!mk(0, 0).is_accumulator());
        assert!(mk(1, 0).is_accumulator());
    }

    #[test]
    fn step_injects_the_lane_group_once() {
        let mut v = mk(0, 0); // column 0 vertex
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx)); // injects the whole (1-target) α wave
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1);
        assert!(matches!(
            sends[0],
            (PORT_FWD, RawMsg::AlphaVec { base: 0, n: 1, .. })
        ));
        assert!(!v.step(&mut ctx)); // the group is injected exactly once
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    fn wide_batches_pipeline_one_group_per_stagger() {
        // LANES+3 targets -> two lane groups injected at supersteps 0 and
        // stagger (= 1): one chunk event each, addressed by global base.
        let targets: Vec<TargetHaplotype> =
            (0..LANES + 3).map(|_| TargetHaplotype::new(vec![1, -1, 0])).collect();
        let obs = ObsMatrix::from_targets(&targets);
        let mut v = RawVertex::new(0, 0, 2, 3, 1, 0.1, 0.2, 1e-4, (LANES + 3) as u32, 1, obs);
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx), "group 1 still pending -> keep running");
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1, "superstep 0 injects group 0 only");
        assert!(matches!(
            sends[0],
            (PORT_FWD, RawMsg::AlphaVec { base: 0, n, .. }) if n as usize == LANES
        ));
        let mut ctx = Ctx::new(0, 1);
        assert!(v.step(&mut ctx), "superstep 1 injects group 1");
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1);
        assert!(matches!(
            sends[0],
            (PORT_FWD, RawMsg::AlphaVec { base, n, .. }) if base as usize == LANES && n == 3
        ));
        let mut ctx = Ctx::new(0, 2);
        assert!(!v.step(&mut ctx), "every group injected exactly once");
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    fn stagger_zero_injects_every_group_at_once() {
        // stagger = 0 degenerates to PR 5's single-superstep injection:
        // both chunks leave at superstep 0.
        let targets: Vec<TargetHaplotype> =
            (0..LANES + 3).map(|_| TargetHaplotype::new(vec![1, -1, 0])).collect();
        let obs = ObsMatrix::from_targets(&targets);
        let mut v = RawVertex::new(0, 0, 2, 3, 1, 0.1, 0.2, 1e-4, (LANES + 3) as u32, 0, obs);
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx));
        assert_eq!(ctx.take_sends().len(), 2);
        assert!(!v.step(&mut ctx));
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    #[should_panic(expected = "lane range")]
    fn detects_out_of_range_lanes() {
        let mut v = mk(0, 1);
        let mut ctx = Ctx::new(0, 0);
        v.recv(
            &RawMsg::AlphaVec {
                base: 5,
                n: 1,
                vals: [0.1; LANES],
            },
            0,
            &mut ctx,
        );
    }

    #[test]
    fn snapshot_roundtrips_injection_state() {
        // A column-0 vertex that already injected its wave must NOT inject
        // again after checkpoint/restore — replay would double the wave.
        let mut v = mk(0, 0);
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx));
        drop(ctx.take_sends());
        let mut bytes = Vec::new();
        assert!(Device::snapshot(&v, &mut bytes));
        let mut fresh = mk(0, 0);
        fresh.restore(&bytes);
        let mut ctx = Ctx::new(0, 1);
        assert!(!fresh.step(&mut ctx), "restored vertex re-injects nothing");
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    #[should_panic(expected = "duplicate α wave")]
    fn detects_duplicate_waves() {
        let mut v = mk(0, 1); // H=2: the wave completes after both senders
        let mut ctx = Ctx::new(0, 0);
        let msg = RawMsg::AlphaVec {
            base: 0,
            n: 1,
            vals: [0.1; LANES],
        };
        v.recv(&msg, 0, &mut ctx); // sender h=0
        v.recv(&msg, 1, &mut ctx); // sender h=1 → wave complete
        drop(ctx.take_sends());
        v.recv(&msg, 0, &mut ctx); // a second wave must trip the assert
    }
}
