//! The raw-model state vertex — paper Algorithm 1, one HMM state per vertex.
//!
//! Ports (fixed order, empty destination lists at the panel edges):
//! * `PORT_FWD` (0) — multicast α to every vertex of the next column.
//! * `PORT_BWD` (1) — multicast β·b to every vertex of the previous column.
//! * `PORT_DOWN` (2) — unicast posterior to the column's accumulating vertex
//!   (the "final haplotype" vertex, h = H−1), which tallies allele-labelled
//!   posterior mass and makes the major/minor call.
//!
//! Target-haplotype pipelining: column 0 / column M−1 vertices inject the
//! next target's α/β at every global step (lines 26–28), so consecutive
//! targets travel the panel one column apart.  Computed α values wait in a
//! per-vertex ring until the matching β wave arrives (and vice versa); the
//! rings are keyed by target index and every arrival asserts target ordering
//! — the cross-contamination hazard the synchronised stepping prevents.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::graph::device::{Ctx, Device, PortId, VertexId};

use super::msg::RawMsg;
use super::obs::ObsMatrix;

pub const PORT_FWD: PortId = 0;
pub const PORT_BWD: PortId = 1;
pub const PORT_DOWN: PortId = 2;

/// Per-target posterior tally at an accumulating vertex.
#[derive(Clone, Copy, Debug, Default)]
struct PostAcc {
    target: u32,
    hit: f32,
    tot: f32,
    cnt: u32,
}

/// One HMM state (reference haplotype `h`, marker `m`).
pub struct RawVertex {
    pub h: u32,
    pub m: u32,
    h_n: u32,
    m_n: u32,
    /// Reference allele labelling this state.
    allele: u8,
    /// Transition factors *into this column* (τ_m): stay / jump.
    a_same: f32,
    a_diff: f32,
    /// Transition factors into the previous column (τ_{m+1} as seen from
    /// m; used when receiving β from column m+1 — β recurrence uses the
    /// sender column's τ). Zero at the last column.
    a_same_next: f32,
    a_diff_next: f32,
    err: f32,
    n_targets: u32,
    obs: Arc<ObsMatrix>,

    // Forward accumulation (Algorithm 1 lines 4–13).
    acc_alpha: f32,
    cnt_alpha: u32,
    tgt_alpha: u32,
    // Backward accumulation (lines 14–22).
    acc_beta: f32,
    cnt_beta: u32,
    tgt_beta: u32,
    // Injection bookkeeping (edge columns).
    injected: u32,
    // Computed values awaiting their partner, ordered by target.
    pending_alpha: VecDeque<(u32, f32)>,
    pending_beta: VecDeque<(u32, f32)>,
    // Accumulator role (h == H−1 only).
    post: VecDeque<PostAcc>,
    /// Finished dosages (target-indexed), accumulator vertices only.
    pub dosage: Vec<f32>,
}

impl RawVertex {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: u32,
        m: u32,
        h_n: u32,
        m_n: u32,
        allele: u8,
        tau_m: f64,
        tau_next: f64,
        err: f64,
        n_targets: u32,
        obs: Arc<ObsMatrix>,
    ) -> RawVertex {
        let hn = h_n as f64;
        RawVertex {
            h,
            m,
            h_n,
            m_n,
            allele,
            a_same: ((1.0 - tau_m) + tau_m / hn) as f32,
            a_diff: (tau_m / hn) as f32,
            a_same_next: ((1.0 - tau_next) + tau_next / hn) as f32,
            a_diff_next: (tau_next / hn) as f32,
            err: err as f32,
            n_targets,
            obs,
            acc_alpha: 0.0,
            cnt_alpha: 0,
            tgt_alpha: 0,
            acc_beta: 0.0,
            cnt_beta: 0,
            tgt_beta: 0,
            injected: 0,
            pending_alpha: VecDeque::new(),
            pending_beta: VecDeque::new(),
            post: VecDeque::new(),
            dosage: if h == h_n - 1 {
                vec![f32::NAN; n_targets as usize]
            } else {
                Vec::new()
            },
        }
    }

    #[inline]
    fn is_accumulator(&self) -> bool {
        self.h == self.h_n - 1
    }

    /// Emission `b_h(O_m)` for one target at this vertex's marker.
    #[inline]
    fn emission(&self, target: u32) -> f32 {
        let o = self.obs.get(target, self.m);
        if o < 0 {
            1.0
        } else if o == self.allele as i8 {
            1.0 - self.err
        } else {
            self.err
        }
    }

    /// α complete for `target` → forward it, then try to pair a posterior.
    fn alpha_done(&mut self, target: u32, alpha: f32, ctx: &mut Ctx<RawMsg>) {
        if self.m + 1 < self.m_n {
            ctx.send(PORT_FWD, RawMsg::Alpha { target, val: alpha });
        }
        self.pending_alpha.push_back((target, alpha));
        self.try_posterior(ctx);
    }

    /// β complete for `target` → forward β·b backward, then try to pair.
    fn beta_done(&mut self, target: u32, beta: f32, ctx: &mut Ctx<RawMsg>) {
        if self.m > 0 {
            let folded = beta * self.emission(target);
            ctx.flop(1);
            ctx.send(PORT_BWD, RawMsg::Beta { target, val: folded });
        }
        self.pending_beta.push_back((target, beta));
        self.try_posterior(ctx);
    }

    /// Pair matching (α, β) fronts → posterior → unicast / local tally
    /// (Algorithm 1 lines 9–11 / 18–20).
    fn try_posterior(&mut self, ctx: &mut Ctx<RawMsg>) {
        while let (Some(&(ta, a)), Some(&(tb, b))) =
            (self.pending_alpha.front(), self.pending_beta.front())
        {
            if ta != tb {
                // Rings are target-ordered; the smaller one waits for its
                // partner. (They can differ by many targets mid-panel.)
                if ta < tb {
                    debug_assert!(
                        self.pending_beta.iter().all(|&(t, _)| t > ta),
                        "cross-target contamination at v=({},{})",
                        self.h,
                        self.m
                    );
                }
                break;
            }
            self.pending_alpha.pop_front();
            self.pending_beta.pop_front();
            let p = a * b;
            ctx.flop(1);
            if self.is_accumulator() {
                self.tally(ta, self.allele == 1, p, ctx);
            } else {
                ctx.send(
                    PORT_DOWN,
                    RawMsg::Post {
                        target: ta,
                        allele1: self.allele == 1,
                        val: p,
                    },
                );
            }
        }
    }

    /// Accumulate one posterior contribution (line 23–25 + step-four call).
    fn tally(&mut self, target: u32, allele1: bool, val: f32, ctx: &mut Ctx<RawMsg>) {
        debug_assert!(self.is_accumulator());
        let acc = match self.post.iter_mut().find(|p| p.target == target) {
            Some(acc) => acc,
            None => {
                self.post.push_back(PostAcc {
                    target,
                    ..Default::default()
                });
                self.post.back_mut().unwrap()
            }
        };
        if allele1 {
            acc.hit += val;
        }
        acc.tot += val;
        acc.cnt += 1;
        ctx.flop(2);
        if acc.cnt == self.h_n {
            let dosage = if acc.tot > 0.0 { acc.hit / acc.tot } else { 0.0 };
            ctx.flop(1);
            self.dosage[target as usize] = dosage;
            let t = acc.target;
            self.post.retain(|p| p.target != t);
        }
    }
}

impl Device for RawVertex {
    type Msg = RawMsg;

    fn init(&mut self, _ctx: &mut Ctx<RawMsg>) {
        // Injection happens in the step handler so that init stays cheap on
        // every vertex (the real cluster broadcasts one 'start' event).
    }

    fn recv(&mut self, msg: &RawMsg, src: VertexId, ctx: &mut Ctx<RawMsg>) {
        match *msg {
            RawMsg::Alpha { target, val } => {
                assert_eq!(
                    target, self.tgt_alpha,
                    "α wave out of order at ({}, {})",
                    self.h, self.m
                );
                // a_ij depends on whether sender and receiver share a haplotype.
                let same = src % self.h_n == self.h;
                let a_ij = if same { self.a_same } else { self.a_diff };
                self.acc_alpha += a_ij * val;
                self.cnt_alpha += 1;
                ctx.flop(2);
                if self.cnt_alpha == self.h_n {
                    let alpha = self.acc_alpha * self.emission(target);
                    ctx.flop(1);
                    self.acc_alpha = 0.0;
                    self.cnt_alpha = 0;
                    self.tgt_alpha += 1;
                    self.alpha_done(target, alpha, ctx);
                }
            }
            RawMsg::Beta { target, val } => {
                assert_eq!(
                    target, self.tgt_beta,
                    "β wave out of order at ({}, {})",
                    self.h, self.m
                );
                let same = src % self.h_n == self.h;
                let a_ij = if same { self.a_same_next } else { self.a_diff_next };
                self.acc_beta += a_ij * val;
                self.cnt_beta += 1;
                ctx.flop(2);
                if self.cnt_beta == self.h_n {
                    let beta = self.acc_beta;
                    self.acc_beta = 0.0;
                    self.cnt_beta = 0;
                    self.tgt_beta += 1;
                    self.beta_done(target, beta, ctx);
                }
            }
            RawMsg::Post {
                target,
                allele1,
                val,
            } => self.tally(target, allele1, val, ctx),
        }
    }

    fn step(&mut self, ctx: &mut Ctx<RawMsg>) -> bool {
        // Algorithm 1 lines 26–28: inject the next target haplotype.
        if self.m == 0 && self.injected < self.n_targets {
            let target = self.injected;
            self.injected += 1;
            let alpha = 1.0 / self.h_n as f32;
            self.tgt_alpha = target + 1; // α is known, never received
            self.alpha_done(target, alpha, ctx);
            return true;
        }
        if self.m == self.m_n - 1 && self.injected < self.n_targets {
            let target = self.injected;
            self.injected += 1;
            self.tgt_beta = target + 1;
            self.beta_done(target, 1.0, ctx);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::panel::TargetHaplotype;

    fn mk(h: u32, m: u32) -> RawVertex {
        let obs = ObsMatrix::from_targets(&[TargetHaplotype::new(vec![1, -1, 0])]);
        RawVertex::new(h, m, 2, 3, 1, 0.1, 0.2, 1e-4, 1, obs)
    }

    #[test]
    fn emission_uses_own_marker() {
        let v = mk(0, 0);
        assert!((v.emission(0) - (1.0 - 1e-4)).abs() < 1e-9); // obs 1, allele 1
        let v = mk(0, 1);
        assert_eq!(v.emission(0), 1.0); // unannotated
        let v = mk(0, 2);
        assert!((v.emission(0) - 1e-4).abs() < 1e-9); // obs 0 vs allele 1
    }

    #[test]
    fn transition_factors_normalised() {
        let v = mk(0, 1);
        let row = v.a_same as f64 + v.a_diff as f64; // H=2: one same + one diff
        assert!((row - 1.0).abs() < 1e-6);
    }

    #[test]
    fn accumulator_is_last_haplotype() {
        assert!(!mk(0, 0).is_accumulator());
        assert!(mk(1, 0).is_accumulator());
    }

    #[test]
    fn step_injects_each_target_once() {
        let mut v = mk(0, 0); // column 0 vertex
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx)); // injects target 0
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1);
        assert!(matches!(
            sends[0],
            (PORT_FWD, RawMsg::Alpha { target: 0, .. })
        ));
        assert!(!v.step(&mut ctx)); // only 1 target configured
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn detects_wave_disorder() {
        let mut v = mk(0, 1);
        let mut ctx = Ctx::new(0, 0);
        v.recv(
            &RawMsg::Alpha {
                target: 5,
                val: 0.1,
            },
            0,
            &mut ctx,
        );
    }
}
