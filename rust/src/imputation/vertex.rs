//! The raw-model state vertex — paper Algorithm 1, one HMM state per vertex,
//! wave-batched across targets (PR 5).
//!
//! Ports (fixed order, empty destination lists at the panel edges):
//! * `PORT_FWD` (0) — multicast α to every vertex of the next column.
//! * `PORT_BWD` (1) — multicast β·b to every vertex of the previous column.
//! * `PORT_DOWN` (2) — unicast posteriors to the column's accumulating vertex
//!   (the "final haplotype" vertex, h = H−1), which tallies allele-labelled
//!   posterior mass and makes the major/minor call.
//!
//! # Wave batching
//!
//! All targets of one engine run form a single **lane group**: column 0
//! injects every target's α (and column M−1 every β) in one wave, carried as
//! SoA events of up to [`LANES`](super::msg::LANES) targets each (wider
//! groups are chunked — see `imputation::msg`).  One `recv` handler services
//! a whole chunk, so per-event overhead is amortised over the lane width:
//! per-target event counts drop by ~the lane width relative to the
//! per-target plane the paper describes (which is exactly lane width 1).
//!
//! # Canonical reduce ⇒ batch-width invariance
//!
//! Arrivals are buffered per **sender haplotype** (`WaveBuf`) and reduced
//! in ascending sender order once the wave is complete.  The f32 sum order
//! is therefore a property of the model, not of event timing: dosages are
//! bit-identical for every batch width and every host thread count (enforced
//! by `tests/parallel_equivalence.rs`), which is what lets the serve layer
//! merge coalesced requests' targets into one wave and still answer each
//! request exactly as a solo run would.
//!
//! Cost: a wave in flight holds O(H · width) f32 at the vertices it is
//! currently crossing (`WaveBuf` allocates on first arrival and frees on
//! completion — idle columns hold nothing).  On panels where even that
//! bites, bound the width with `ImputeSession::batch` — numerics are width
//! invariant, so splitting has no accuracy consequences.

// Canonical-order reductions index several parallel slabs by lane/sender —
// explicit index loops keep the summation order visibly fixed.
#![allow(clippy::needless_range_loop)]

use std::sync::Arc;

use crate::graph::device::{Ctx, Device, PortId, VertexId};

use super::msg::{RawMsg, for_each_chunk};
use super::obs::ObsMatrix;
use super::wave::{WaveBuf, reduce_hit_tot, reduce_same_diff};

pub const PORT_FWD: PortId = 0;
pub const PORT_BWD: PortId = 1;
pub const PORT_DOWN: PortId = 2;

/// One HMM state (reference haplotype `h`, marker `m`).
pub struct RawVertex {
    pub h: u32,
    pub m: u32,
    h_n: u32,
    m_n: u32,
    /// Reference allele labelling this state.
    allele: u8,
    /// Transition factors *into this column* (τ_m): stay / jump.
    a_same: f32,
    a_diff: f32,
    /// Transition factors into the previous column (τ_{m+1} as seen from
    /// m; used when receiving β from column m+1 — β recurrence uses the
    /// sender column's τ). Zero at the last column.
    a_same_next: f32,
    a_diff_next: f32,
    err: f32,
    n_targets: u32,
    obs: Arc<ObsMatrix>,

    // In-flight waves, keyed by sender haplotype (canonical reduce).
    alpha_wave: WaveBuf,
    beta_wave: WaveBuf,
    // Completed α/β slabs awaiting their partner wave.
    alpha: Vec<f32>,
    alpha_done: bool,
    beta: Vec<f32>,
    beta_done: bool,
    posterior_done: bool,
    // Injection bookkeeping (edge columns).
    injected_alpha: bool,
    injected_beta: bool,
    // Accumulator role (h == H−1 only): posterior contributions keyed by
    // sender haplotype, plus each sender's allele label.
    post_wave: WaveBuf,
    post_allele1: Vec<bool>,
    /// Finished dosages (target-indexed), accumulator vertices only.
    pub dosage: Vec<f32>,
}

impl RawVertex {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: u32,
        m: u32,
        h_n: u32,
        m_n: u32,
        allele: u8,
        tau_m: f64,
        tau_next: f64,
        err: f64,
        n_targets: u32,
        obs: Arc<ObsMatrix>,
    ) -> RawVertex {
        let hn = h_n as f64;
        let is_acc = h == h_n - 1;
        RawVertex {
            h,
            m,
            h_n,
            m_n,
            allele,
            a_same: ((1.0 - tau_m) + tau_m / hn) as f32,
            a_diff: (tau_m / hn) as f32,
            a_same_next: ((1.0 - tau_next) + tau_next / hn) as f32,
            a_diff_next: (tau_next / hn) as f32,
            err: err as f32,
            n_targets,
            obs,
            alpha_wave: WaveBuf::new(),
            beta_wave: WaveBuf::new(),
            alpha: Vec::new(),
            alpha_done: false,
            beta: Vec::new(),
            beta_done: false,
            posterior_done: false,
            injected_alpha: false,
            injected_beta: false,
            post_wave: WaveBuf::new(),
            post_allele1: if is_acc { vec![false; h_n as usize] } else { Vec::new() },
            dosage: if is_acc {
                vec![f32::NAN; n_targets as usize]
            } else {
                Vec::new()
            },
        }
    }

    #[inline]
    fn is_accumulator(&self) -> bool {
        self.h == self.h_n - 1
    }

    /// Emission `b_h(O_m)` for one target at this vertex's marker.
    #[inline]
    fn emission(&self, target: u32) -> f32 {
        let o = self.obs.get(target, self.m);
        if o < 0 {
            1.0
        } else if o == self.allele as i8 {
            1.0 - self.err
        } else {
            self.err
        }
    }

    /// Store one α chunk; reduce and propagate once the wave is complete.
    fn take_alpha(&mut self, base: usize, vals: &[f32], src: VertexId, ctx: &mut Ctx<RawMsg>) {
        let c = self.n_targets as usize;
        let src_h = (src % self.h_n) as usize;
        if self.alpha_wave.store(self.h_n as usize, c, src_h, base, vals, "α") {
            let buf = self.alpha_wave.take();
            // Canonical reduce (wave::reduce_same_diff): Σ_h a_ij·α_h in
            // ascending sender order, then the emission — identical
            // arithmetic for every batch width.
            let mut alpha =
                reduce_same_diff(&buf, self.h_n as usize, c, self.h as usize, self.a_same, self.a_diff);
            for (t, a) in alpha.iter_mut().enumerate() {
                ctx.flop(2 * self.h_n as u64);
                *a *= self.emission(t as u32);
                ctx.flop(1);
            }
            self.finish_alpha(alpha, ctx);
        }
    }

    /// Store one β chunk; reduce and propagate once the wave is complete.
    fn take_beta(&mut self, base: usize, vals: &[f32], src: VertexId, ctx: &mut Ctx<RawMsg>) {
        let c = self.n_targets as usize;
        let src_h = (src % self.h_n) as usize;
        if self.beta_wave.store(self.h_n as usize, c, src_h, base, vals, "β") {
            let buf = self.beta_wave.take();
            let beta = reduce_same_diff(
                &buf,
                self.h_n as usize,
                c,
                self.h as usize,
                self.a_same_next,
                self.a_diff_next,
            );
            ctx.flop(2 * self.h_n as u64 * c as u64);
            self.finish_beta(beta, ctx);
        }
    }

    /// α complete for the whole lane group → forward the wave, try to pair.
    fn finish_alpha(&mut self, alpha: Vec<f32>, ctx: &mut Ctx<RawMsg>) {
        if self.m + 1 < self.m_n {
            for_each_chunk(&alpha, |base, n, vals| {
                ctx.send(PORT_FWD, RawMsg::AlphaVec { base, n, vals });
            });
        }
        self.alpha = alpha;
        self.alpha_done = true;
        self.try_posterior(ctx);
    }

    /// β complete → forward β·b backward (emission folded in), try to pair.
    fn finish_beta(&mut self, beta: Vec<f32>, ctx: &mut Ctx<RawMsg>) {
        if self.m > 0 {
            let folded: Vec<f32> = beta
                .iter()
                .enumerate()
                .map(|(t, &b)| {
                    ctx.flop(1);
                    b * self.emission(t as u32)
                })
                .collect();
            for_each_chunk(&folded, |base, n, vals| {
                ctx.send(PORT_BWD, RawMsg::BetaVec { base, n, vals });
            });
        }
        self.beta = beta;
        self.beta_done = true;
        self.try_posterior(ctx);
    }

    /// Both waves in → posteriors for every lane → unicast / local tally
    /// (Algorithm 1 lines 9–11 / 18–20, all targets at once).
    fn try_posterior(&mut self, ctx: &mut Ctx<RawMsg>) {
        if self.posterior_done || !self.alpha_done || !self.beta_done {
            return;
        }
        self.posterior_done = true;
        let c = self.n_targets as usize;
        let mut post = vec![0.0f32; c];
        for t in 0..c {
            post[t] = self.alpha[t] * self.beta[t];
            ctx.flop(1);
        }
        self.alpha = Vec::new();
        self.beta = Vec::new();
        let allele1 = self.allele == 1;
        if self.is_accumulator() {
            let h = self.h;
            self.take_posts(h, allele1, 0, &post, ctx);
        } else {
            for_each_chunk(&post, |base, n, vals| {
                ctx.send(
                    PORT_DOWN,
                    RawMsg::PostVec {
                        base,
                        n,
                        allele1,
                        vals,
                    },
                );
            });
        }
    }

    /// Accumulate one sender's posterior lanes (line 23–25); finish dosages
    /// once every sender haplotype has contributed every lane.
    fn take_posts(&mut self, src_h: u32, allele1: bool, base: usize, vals: &[f32], ctx: &mut Ctx<RawMsg>) {
        debug_assert!(self.is_accumulator());
        let c = self.n_targets as usize;
        self.post_allele1[src_h as usize] = allele1;
        ctx.flop(2 * vals.len() as u64);
        if self
            .post_wave
            .store(self.h_n as usize, c, src_h as usize, base, vals, "posterior")
        {
            let buf = self.post_wave.take();
            let sums = reduce_hit_tot(&buf, self.h_n as usize, c, &self.post_allele1);
            for (t, &(hit, tot)) in sums.iter().enumerate() {
                self.dosage[t] = if tot > 0.0 { hit / tot } else { 0.0 };
                ctx.flop(1);
            }
        }
    }
}

impl Device for RawVertex {
    type Msg = RawMsg;

    fn init(&mut self, _ctx: &mut Ctx<RawMsg>) {
        // Injection happens in the step handler so that init stays cheap on
        // every vertex (the real cluster broadcasts one 'start' event).
    }

    fn recv(&mut self, msg: &RawMsg, src: VertexId, ctx: &mut Ctx<RawMsg>) {
        match *msg {
            RawMsg::AlphaVec { base, n, ref vals } => {
                self.take_alpha(base as usize, &vals[..n as usize], src, ctx)
            }
            RawMsg::BetaVec { base, n, ref vals } => {
                self.take_beta(base as usize, &vals[..n as usize], src, ctx)
            }
            RawMsg::PostVec {
                base,
                n,
                allele1,
                ref vals,
            } => {
                let src_h = src % self.h_n;
                self.take_posts(src_h, allele1, base as usize, &vals[..n as usize], ctx)
            }
        }
    }

    fn step(&mut self, ctx: &mut Ctx<RawMsg>) -> bool {
        // Algorithm 1 lines 26–28, wave-batched: the edge columns inject the
        // whole lane group's α/β in one wave at the first step.
        let c = self.n_targets as usize;
        let mut injected = false;
        if self.m == 0 && !self.injected_alpha {
            self.injected_alpha = true;
            // Uniform prior, no emission at the run's first marker (matches
            // the per-target plane and the windowing docs in genomics).
            self.finish_alpha(vec![1.0 / self.h_n as f32; c], ctx);
            injected = true;
        }
        if self.m == self.m_n - 1 && !self.injected_beta {
            self.injected_beta = true;
            self.finish_beta(vec![1.0; c], ctx);
            injected = true;
        }
        injected
    }

    fn lanes(msg: &RawMsg) -> u32 {
        msg.lanes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imputation::msg::LANES;
    use crate::model::panel::TargetHaplotype;

    fn mk(h: u32, m: u32) -> RawVertex {
        let obs = ObsMatrix::from_targets(&[TargetHaplotype::new(vec![1, -1, 0])]);
        RawVertex::new(h, m, 2, 3, 1, 0.1, 0.2, 1e-4, 1, obs)
    }

    #[test]
    fn emission_uses_own_marker() {
        let v = mk(0, 0);
        assert!((v.emission(0) - (1.0 - 1e-4)).abs() < 1e-9); // obs 1, allele 1
        let v = mk(0, 1);
        assert_eq!(v.emission(0), 1.0); // unannotated
        let v = mk(0, 2);
        assert!((v.emission(0) - 1e-4).abs() < 1e-9); // obs 0 vs allele 1
    }

    #[test]
    fn transition_factors_normalised() {
        let v = mk(0, 1);
        let row = v.a_same as f64 + v.a_diff as f64; // H=2: one same + one diff
        assert!((row - 1.0).abs() < 1e-6);
    }

    #[test]
    fn accumulator_is_last_haplotype() {
        assert!(!mk(0, 0).is_accumulator());
        assert!(mk(1, 0).is_accumulator());
    }

    #[test]
    fn step_injects_the_lane_group_once() {
        let mut v = mk(0, 0); // column 0 vertex
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx)); // injects the whole (1-target) α wave
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1);
        assert!(matches!(
            sends[0],
            (PORT_FWD, RawMsg::AlphaVec { base: 0, n: 1, .. })
        ));
        assert!(!v.step(&mut ctx)); // the group is injected exactly once
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    fn wide_groups_are_chunked_to_the_event_budget() {
        let targets: Vec<TargetHaplotype> =
            (0..LANES + 3).map(|_| TargetHaplotype::new(vec![1, -1, 0])).collect();
        let obs = ObsMatrix::from_targets(&targets);
        let mut v = RawVertex::new(0, 0, 2, 3, 1, 0.1, 0.2, 1e-4, (LANES + 3) as u32, obs);
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx));
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 2, "LANES+3 lanes need two chunk events");
        assert!(matches!(
            sends[0],
            (PORT_FWD, RawMsg::AlphaVec { base: 0, n, .. }) if n as usize == LANES
        ));
        assert!(matches!(
            sends[1],
            (PORT_FWD, RawMsg::AlphaVec { base, n, .. }) if base as usize == LANES && n == 3
        ));
    }

    #[test]
    #[should_panic(expected = "lane range")]
    fn detects_out_of_range_lanes() {
        let mut v = mk(0, 1);
        let mut ctx = Ctx::new(0, 0);
        v.recv(
            &RawMsg::AlphaVec {
                base: 5,
                n: 1,
                vals: [0.1; LANES],
            },
            0,
            &mut ctx,
        );
    }

    #[test]
    #[should_panic(expected = "duplicate α wave")]
    fn detects_duplicate_waves() {
        let mut v = mk(0, 1); // H=2: the wave completes after both senders
        let mut ctx = Ctx::new(0, 0);
        let msg = RawMsg::AlphaVec {
            base: 0,
            n: 1,
            vals: [0.1; LANES],
        };
        v.recv(&msg, 0, &mut ctx); // sender h=0
        v.recv(&msg, 1, &mut ctx); // sender h=1 → wave complete
        drop(ctx.take_sends());
        v.recv(&msg, 0, &mut ctx); // a second wave must trip the assert
    }
}
