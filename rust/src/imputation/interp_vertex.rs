//! Linear-interpolation state-section vertex — paper §5.3 / §6.3.
//!
//! One vertex per *state section*: a single HMM state at an annotated-marker
//! anchor plus the run of interpolation states up to (not including) the next
//! anchor ("a single HMM state and 9 linear interpolation states").  The HMM
//! part behaves exactly like [`super::vertex::RawVertex`] over the anchor
//! grid (with accumulated genetic distances); the interpolation part blends
//! the vertex's own anchor posterior with its right neighbour's and reduces
//! each intermediate marker with that marker's own panel allele.
//!
//! Extra ports beyond the raw model:
//! * `PORT_SECTION` (3) — unicast own anchor posterior to the *left*
//!   neighbour `(h, k-1)`, which owns the section between the two anchors.
//! * `PORT_TOT` (4) — accumulator-only: anchor-column posterior total to the
//!   left accumulator (interpolated totals normalise intermediate columns).
//!
//! Message economics (the paper's §6.3 argument): a section of `L` states
//! costs 2 multicasts + ≤3 unicasts per target instead of `L`·(2 multicasts +
//! 1 unicast) — the ~10× message reduction that lifts the fan-in bottleneck.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::graph::device::{Ctx, Device, PortId, VertexId};

use super::msg::{InterpMsg, MAX_SECTION};
use super::obs::ObsMatrix;

pub const PORT_FWD: PortId = 0;
pub const PORT_BWD: PortId = 1;
pub const PORT_DOWN: PortId = 2;
pub const PORT_SECTION: PortId = 3;
pub const PORT_TOT: PortId = 4;

#[derive(Clone, Copy, Debug, Default)]
struct PostAcc {
    target: u32,
    hit: f32,
    tot: f32,
    cnt: u32,
}

#[derive(Clone, Copy, Debug)]
struct HitAcc {
    target: u32,
    vals: [f32; MAX_SECTION],
    cnt: u32,
}

/// One state section (anchor `k`, haplotype `h`).
pub struct InterpVertex {
    pub h: u32,
    pub k: u32,
    h_n: u32,
    k_n: u32,
    /// Absolute marker index of the anchor.
    m_abs: u32,
    /// Allele at the anchor state.
    allele: u8,
    /// Alleles of the section's intermediate markers (may be empty).
    sec_alleles: Vec<u8>,
    /// Blend fraction per intermediate marker (paper Fig 10 apportioning).
    sec_fracs: Vec<f32>,
    a_same: f32,
    a_diff: f32,
    a_same_next: f32,
    a_diff_next: f32,
    err: f32,
    n_targets: u32,
    obs: Arc<ObsMatrix>,

    acc_alpha: f32,
    cnt_alpha: u32,
    tgt_alpha: u32,
    acc_beta: f32,
    cnt_beta: u32,
    tgt_beta: u32,
    injected: u32,
    pending_alpha: VecDeque<(u32, f32)>,
    pending_beta: VecDeque<(u32, f32)>,
    /// Own anchor posterior awaiting the right neighbour's Section message.
    pending_p: VecDeque<(u32, f32)>,
    pending_right: VecDeque<(u32, f32)>,

    // Accumulator (h == H−1) state:
    post: VecDeque<PostAcc>,
    hits: VecDeque<HitAcc>,
    /// Own anchor totals T_k per target (kept until section dosages done).
    pending_t: VecDeque<(u32, f32)>,
    /// Right accumulator's totals T_{k+1}.
    pending_t_right: VecDeque<(u32, f32)>,
    /// Anchor dosage per target (accumulators only).
    pub anchor_dosage: Vec<f32>,
    /// Section dosages, `[target * sec_len + i]` (accumulators only).
    pub section_dosage: Vec<f32>,
}

impl InterpVertex {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: u32,
        k: u32,
        h_n: u32,
        k_n: u32,
        m_abs: u32,
        allele: u8,
        sec_alleles: Vec<u8>,
        sec_fracs: Vec<f32>,
        tau_k: f64,
        tau_next: f64,
        err: f64,
        n_targets: u32,
        obs: Arc<ObsMatrix>,
    ) -> InterpVertex {
        assert_eq!(sec_alleles.len(), sec_fracs.len());
        assert!(
            sec_alleles.len() <= MAX_SECTION,
            "section of {} exceeds the {MAX_SECTION}-state event budget",
            sec_alleles.len()
        );
        let hn = h_n as f64;
        let is_acc = h == h_n - 1;
        let sec_len = sec_alleles.len();
        InterpVertex {
            h,
            k,
            h_n,
            k_n,
            m_abs,
            allele,
            sec_alleles,
            sec_fracs,
            a_same: ((1.0 - tau_k) + tau_k / hn) as f32,
            a_diff: (tau_k / hn) as f32,
            a_same_next: ((1.0 - tau_next) + tau_next / hn) as f32,
            a_diff_next: (tau_next / hn) as f32,
            err: err as f32,
            n_targets,
            obs,
            acc_alpha: 0.0,
            cnt_alpha: 0,
            tgt_alpha: 0,
            acc_beta: 0.0,
            cnt_beta: 0,
            tgt_beta: 0,
            injected: 0,
            pending_alpha: VecDeque::new(),
            pending_beta: VecDeque::new(),
            pending_p: VecDeque::new(),
            pending_right: VecDeque::new(),
            post: VecDeque::new(),
            hits: VecDeque::new(),
            pending_t: VecDeque::new(),
            pending_t_right: VecDeque::new(),
            anchor_dosage: if is_acc {
                vec![f32::NAN; n_targets as usize]
            } else {
                Vec::new()
            },
            section_dosage: if is_acc {
                vec![f32::NAN; n_targets as usize * sec_len]
            } else {
                Vec::new()
            },
        }
    }

    #[inline]
    fn is_accumulator(&self) -> bool {
        self.h == self.h_n - 1
    }

    pub fn sec_len(&self) -> usize {
        self.sec_alleles.len()
    }

    #[inline]
    fn emission(&self, target: u32) -> f32 {
        let o = self.obs.get(target, self.m_abs);
        if o < 0 {
            1.0
        } else if o == self.allele as i8 {
            1.0 - self.err
        } else {
            self.err
        }
    }

    fn alpha_done(&mut self, target: u32, alpha: f32, ctx: &mut Ctx<InterpMsg>) {
        if self.k + 1 < self.k_n {
            ctx.send(PORT_FWD, InterpMsg::Alpha { target, val: alpha });
        }
        self.pending_alpha.push_back((target, alpha));
        self.try_posterior(ctx);
    }

    fn beta_done(&mut self, target: u32, beta: f32, ctx: &mut Ctx<InterpMsg>) {
        if self.k > 0 {
            let folded = beta * self.emission(target);
            ctx.flop(1);
            ctx.send(PORT_BWD, InterpMsg::Beta { target, val: folded });
        }
        self.pending_beta.push_back((target, beta));
        self.try_posterior(ctx);
    }

    fn try_posterior(&mut self, ctx: &mut Ctx<InterpMsg>) {
        while let (Some(&(ta, a)), Some(&(tb, b))) =
            (self.pending_alpha.front(), self.pending_beta.front())
        {
            if ta != tb {
                break;
            }
            self.pending_alpha.pop_front();
            self.pending_beta.pop_front();
            let p = a * b;
            ctx.flop(1);
            if self.is_accumulator() {
                self.tally(ta, self.allele == 1, p, ctx);
            } else {
                ctx.send(
                    PORT_DOWN,
                    InterpMsg::Post {
                        target: ta,
                        allele1: self.allele == 1,
                        val: p,
                    },
                );
            }
            if self.k > 0 {
                // Our anchor posterior is the right endpoint of the left
                // neighbour's section.
                ctx.send(PORT_SECTION, InterpMsg::Section { target: ta, val: p });
            }
            if self.k + 1 < self.k_n {
                self.pending_p.push_back((ta, p));
                self.try_section(ctx);
            }
        }
    }

    /// Blend own + right anchor posteriors over the section (Fig 10).
    fn try_section(&mut self, ctx: &mut Ctx<InterpMsg>) {
        while let (Some(&(tp, p)), Some(&(tr, pr))) =
            (self.pending_p.front(), self.pending_right.front())
        {
            if tp != tr {
                break;
            }
            self.pending_p.pop_front();
            self.pending_right.pop_front();
            if self.sec_alleles.is_empty() {
                continue;
            }
            let mut vals = [0.0f32; MAX_SECTION];
            for (i, (&a, &f)) in self.sec_alleles.iter().zip(&self.sec_fracs).enumerate() {
                let blended = p + f * (pr - p);
                vals[i] = if a == 1 { blended } else { 0.0 };
                ctx.flop(3);
            }
            if self.is_accumulator() {
                let n = self.sec_alleles.len() as u8;
                self.take_hits(tp, n, &vals, ctx);
            } else {
                ctx.send(
                    PORT_DOWN,
                    InterpMsg::HitVec {
                        target: tp,
                        n: self.sec_alleles.len() as u8,
                        vals,
                    },
                );
            }
        }
    }

    fn tally(&mut self, target: u32, allele1: bool, val: f32, ctx: &mut Ctx<InterpMsg>) {
        debug_assert!(self.is_accumulator());
        let acc = match self.post.iter_mut().find(|p| p.target == target) {
            Some(acc) => acc,
            None => {
                self.post.push_back(PostAcc {
                    target,
                    ..Default::default()
                });
                self.post.back_mut().unwrap()
            }
        };
        if allele1 {
            acc.hit += val;
        }
        acc.tot += val;
        acc.cnt += 1;
        ctx.flop(2);
        if acc.cnt == self.h_n {
            let (hit, tot) = (acc.hit, acc.tot);
            self.post.retain(|p| p.target != target);
            self.anchor_dosage[target as usize] = if tot > 0.0 { hit / tot } else { 0.0 };
            ctx.flop(1);
            if self.k > 0 {
                ctx.send(PORT_TOT, InterpMsg::Tot { target, val: tot });
            }
            if self.k + 1 < self.k_n {
                self.pending_t.push_back((target, tot));
                self.try_finish_section(ctx);
            }
        }
    }

    fn take_hits(
        &mut self,
        target: u32,
        n: u8,
        vals: &[f32; MAX_SECTION],
        ctx: &mut Ctx<InterpMsg>,
    ) {
        debug_assert!(self.is_accumulator());
        assert_eq!(n as usize, self.sec_alleles.len(), "hit-vector length");
        let acc = match self.hits.iter_mut().find(|a| a.target == target) {
            Some(acc) => acc,
            None => {
                self.hits.push_back(HitAcc {
                    target,
                    vals: [0.0; MAX_SECTION],
                    cnt: 0,
                });
                self.hits.back_mut().unwrap()
            }
        };
        for i in 0..n as usize {
            acc.vals[i] += vals[i];
            ctx.flop(1);
        }
        acc.cnt += 1;
        self.try_finish_section(ctx);
    }

    /// Finish intermediate-marker dosages once hit sums and both anchor
    /// totals are available for the front target.
    fn try_finish_section(&mut self, ctx: &mut Ctx<InterpMsg>) {
        loop {
            let Some(hit) = self.hits.front() else { break };
            if hit.cnt < self.h_n {
                break;
            }
            let target = hit.target;
            let Some(&(tt, t_own)) = self.pending_t.front() else { break };
            let Some(&(ttr, t_right)) = self.pending_t_right.front() else {
                break;
            };
            if tt != target || ttr != target {
                break;
            }
            let vals = hit.vals;
            self.hits.pop_front();
            self.pending_t.pop_front();
            self.pending_t_right.pop_front();
            let sec_len = self.sec_alleles.len();
            for i in 0..sec_len {
                let tot = t_own + self.sec_fracs[i] * (t_right - t_own);
                ctx.flop(3);
                self.section_dosage[target as usize * sec_len + i] =
                    if tot > 0.0 { vals[i] / tot } else { 0.0 };
            }
        }
    }
}

impl Device for InterpVertex {
    type Msg = InterpMsg;

    fn init(&mut self, _ctx: &mut Ctx<InterpMsg>) {}

    fn recv(&mut self, msg: &InterpMsg, src: VertexId, ctx: &mut Ctx<InterpMsg>) {
        match *msg {
            InterpMsg::Alpha { target, val } => {
                assert_eq!(target, self.tgt_alpha, "α wave out of order");
                let same = src % self.h_n == self.h;
                let a_ij = if same { self.a_same } else { self.a_diff };
                self.acc_alpha += a_ij * val;
                self.cnt_alpha += 1;
                ctx.flop(2);
                if self.cnt_alpha == self.h_n {
                    let alpha = self.acc_alpha * self.emission(target);
                    ctx.flop(1);
                    self.acc_alpha = 0.0;
                    self.cnt_alpha = 0;
                    self.tgt_alpha += 1;
                    self.alpha_done(target, alpha, ctx);
                }
            }
            InterpMsg::Beta { target, val } => {
                assert_eq!(target, self.tgt_beta, "β wave out of order");
                let same = src % self.h_n == self.h;
                let a_ij = if same {
                    self.a_same_next
                } else {
                    self.a_diff_next
                };
                self.acc_beta += a_ij * val;
                self.cnt_beta += 1;
                ctx.flop(2);
                if self.cnt_beta == self.h_n {
                    let beta = self.acc_beta;
                    self.acc_beta = 0.0;
                    self.cnt_beta = 0;
                    self.tgt_beta += 1;
                    self.beta_done(target, beta, ctx);
                }
            }
            InterpMsg::Post {
                target,
                allele1,
                val,
            } => self.tally(target, allele1, val, ctx),
            InterpMsg::Section { target, val } => {
                self.pending_right.push_back((target, val));
                self.try_section(ctx);
            }
            InterpMsg::HitVec { target, n, vals } => self.take_hits(target, n, &vals, ctx),
            InterpMsg::Tot { target, val } => {
                self.pending_t_right.push_back((target, val));
                self.try_finish_section(ctx);
            }
        }
    }

    fn step(&mut self, ctx: &mut Ctx<InterpMsg>) -> bool {
        if self.k == 0 && self.injected < self.n_targets {
            let target = self.injected;
            self.injected += 1;
            self.tgt_alpha = target + 1;
            self.alpha_done(target, 1.0 / self.h_n as f32, ctx);
            return true;
        }
        if self.k == self.k_n - 1 && self.injected < self.n_targets {
            let target = self.injected;
            self.injected += 1;
            self.tgt_beta = target + 1;
            self.beta_done(target, 1.0, ctx);
            return true;
        }
        false
    }
}
