//! Linear-interpolation state-section vertex — paper §5.3 / §6.3,
//! wave-batched across targets (PR 5).
//!
//! One vertex per *state section*: a single HMM state at an annotated-marker
//! anchor plus the run of interpolation states up to (not including) the next
//! anchor ("a single HMM state and 9 linear interpolation states").  The HMM
//! part behaves exactly like [`super::vertex::RawVertex`] over the anchor
//! grid (with accumulated genetic distances); the interpolation part blends
//! the vertex's own anchor posteriors with its right neighbour's and reduces
//! each intermediate marker with that marker's own panel allele.
//!
//! Extra ports beyond the raw model:
//! * `PORT_SECTION` (3) — unicast own anchor posteriors to the *left*
//!   neighbour `(h, k-1)`, which owns the section between the two anchors.
//! * `PORT_TOT` (4) — accumulator-only: anchor-column posterior totals to the
//!   left accumulator (interpolated totals normalise intermediate columns).
//!
//! # Wave batching + pipelined lane groups
//!
//! Like the raw plane, the targets of one run are split into lane groups of
//! at most [`LANES`](super::msg::LANES) targets, each injected at the edge
//! anchors `stagger` supersteps after its predecessor: the
//! α/β/posterior/Section/Tot traffic carries per-group SoA slabs addressed
//! by global lane base (one recv handler per group chunk instead of per
//! target), with arrivals buffered per (group, sender haplotype)
//! (`GroupWaves` — allocated on first arrival, freed on completion) and
//! each group reduced in canonical sender order — dosages are bit-identical
//! for every batch width and host thread count.  Two accumulator-side
//! reductions span all groups and simply complete when the last group's
//! traffic lands: the **hit vector** (its 12-value section slab already
//! fills the 56-byte event budget, so `HitVec` stays one event per
//! (haplotype, target) with a canonicalised fan-in sum) and the section
//! **total** blend that consumes it (its lane space is targets × section
//! states, which does not tile into lane groups).
//!
//! Message economics (the paper's §6.3 argument, updated): a section of `L`
//! states costs 2 multicast chunks + ≲3 unicast chunks per *wave* instead of
//! per target — the anchor-grid shrink (K ≪ M columns) still lifts the
//! fan-in bottleneck, but because hit vectors cannot lane-batch, the raw
//! plane narrows the per-message gap as the lane width grows.

// Canonical-order reductions index several parallel slabs by lane/sender —
// explicit index loops keep the summation order visibly fixed.
#![allow(clippy::needless_range_loop)]

use std::sync::Arc;

use crate::graph::device::{Ctx, Device, PortId, VertexId};
use crate::poets::fault::{SnapReader, SnapWriter};

use super::msg::{InterpMsg, MAX_SECTION, for_each_chunk};
use super::obs::ObsMatrix;
use super::wave::{
    GroupWaves, WaveBuf, group_start, group_width, inject_at, n_groups, reduce_hit_tot,
    reduce_same_diff,
};

pub const PORT_FWD: PortId = 0;
pub const PORT_BWD: PortId = 1;
pub const PORT_DOWN: PortId = 2;
pub const PORT_SECTION: PortId = 3;
pub const PORT_TOT: PortId = 4;

/// One state section (anchor `k`, haplotype `h`).
pub struct InterpVertex {
    pub h: u32,
    pub k: u32,
    h_n: u32,
    k_n: u32,
    /// Absolute marker index of the anchor.
    m_abs: u32,
    /// Allele at the anchor state.
    allele: u8,
    /// Alleles of the section's intermediate markers (may be empty).
    sec_alleles: Vec<u8>,
    /// Blend fraction per intermediate marker (paper Fig 10 apportioning).
    sec_fracs: Vec<f32>,
    a_same: f32,
    a_diff: f32,
    a_same_next: f32,
    a_diff_next: f32,
    err: f32,
    n_targets: u32,
    /// Supersteps between successive lane-group injections at the edges.
    stagger: u64,
    obs: Arc<ObsMatrix>,

    // α/β waves keyed by (lane group, sender haplotype) — canonical
    // per-group reduce, see super::vertex module docs; same invariance
    // argument.
    alpha_wave: GroupWaves,
    beta_wave: GroupWaves,
    alpha: Vec<Vec<f32>>,
    alpha_done: Vec<bool>,
    beta: Vec<Vec<f32>>,
    beta_done: Vec<bool>,
    posterior_done: Vec<bool>,
    // Injection bookkeeping (edge anchors): next group to inject.
    injected_alpha: usize,
    injected_beta: usize,

    // Section interpolation (k+1 < k_n): per-group own anchor posteriors
    // await the right neighbour's per-group Section wave.
    own_p: Vec<Vec<f32>>,
    own_p_done: Vec<bool>,
    right_p_wave: GroupWaves,
    right_p: Vec<Vec<f32>>,
    right_p_complete: Vec<bool>,
    section_done: Vec<bool>,

    // Accumulator (h == H−1) state:
    post_wave: GroupWaves,
    post_allele1: Vec<bool>,
    /// Hit contributions keyed by (sender haplotype, target × section):
    /// a `[h_n × (n_targets · sec_len)]` canonical summation buffer
    /// spanning all lane groups (section lanes don't tile into groups).
    hit_wave: WaveBuf,
    hits_complete: bool,
    /// Own anchor totals T_k per target, assembled group by group (kept
    /// until section dosages done).
    own_tot: Vec<f32>,
    own_tot_groups: usize,
    own_tot_done: bool,
    /// Right accumulator's totals T_{k+1} — chunks arrive per group, the
    /// wave completes when the last group's lanes land.
    right_tot_wave: WaveBuf,
    right_tot_complete: bool,
    sections_finished: bool,
    /// Anchor dosage per target (accumulators only).
    pub anchor_dosage: Vec<f32>,
    /// Section dosages, `[target * sec_len + i]` (accumulators only).
    pub section_dosage: Vec<f32>,
}

impl InterpVertex {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: u32,
        k: u32,
        h_n: u32,
        k_n: u32,
        m_abs: u32,
        allele: u8,
        sec_alleles: Vec<u8>,
        sec_fracs: Vec<f32>,
        tau_k: f64,
        tau_next: f64,
        err: f64,
        n_targets: u32,
        stagger: u64,
        obs: Arc<ObsMatrix>,
    ) -> InterpVertex {
        assert_eq!(sec_alleles.len(), sec_fracs.len());
        assert!(
            sec_alleles.len() <= MAX_SECTION,
            "section of {} exceeds the {MAX_SECTION}-state event budget",
            sec_alleles.len()
        );
        let hn = h_n as f64;
        let is_acc = h == h_n - 1;
        let sec_len = sec_alleles.len();
        let c = n_targets as usize;
        let n_g = n_groups(c);
        InterpVertex {
            h,
            k,
            h_n,
            k_n,
            m_abs,
            allele,
            sec_alleles,
            sec_fracs,
            a_same: ((1.0 - tau_k) + tau_k / hn) as f32,
            a_diff: (tau_k / hn) as f32,
            a_same_next: ((1.0 - tau_next) + tau_next / hn) as f32,
            a_diff_next: (tau_next / hn) as f32,
            err: err as f32,
            n_targets,
            stagger,
            obs,
            alpha_wave: GroupWaves::new(),
            beta_wave: GroupWaves::new(),
            alpha: vec![Vec::new(); n_g],
            alpha_done: vec![false; n_g],
            beta: vec![Vec::new(); n_g],
            beta_done: vec![false; n_g],
            posterior_done: vec![false; n_g],
            injected_alpha: 0,
            injected_beta: 0,
            own_p: vec![Vec::new(); n_g],
            own_p_done: vec![false; n_g],
            right_p_wave: GroupWaves::new(),
            right_p: vec![Vec::new(); n_g],
            right_p_complete: vec![false; n_g],
            section_done: vec![false; n_g],
            post_wave: GroupWaves::new(),
            post_allele1: if is_acc { vec![false; h_n as usize] } else { Vec::new() },
            hit_wave: WaveBuf::new(),
            hits_complete: false,
            own_tot: Vec::new(),
            own_tot_groups: 0,
            own_tot_done: false,
            right_tot_wave: WaveBuf::new(),
            right_tot_complete: false,
            sections_finished: false,
            anchor_dosage: if is_acc { vec![f32::NAN; c] } else { Vec::new() },
            section_dosage: if is_acc {
                vec![f32::NAN; c * sec_len]
            } else {
                Vec::new()
            },
        }
    }

    #[inline]
    fn is_accumulator(&self) -> bool {
        self.h == self.h_n - 1
    }

    pub fn sec_len(&self) -> usize {
        self.sec_alleles.len()
    }

    #[inline]
    fn emission(&self, target: u32) -> f32 {
        let o = self.obs.get(target, self.m_abs);
        if o < 0 {
            1.0
        } else if o == self.allele as i8 {
            1.0 - self.err
        } else {
            self.err
        }
    }

    fn take_alpha(&mut self, base: usize, vals: &[f32], src: VertexId, ctx: &mut Ctx<InterpMsg>) {
        let c = self.n_targets as usize;
        let src_h = (src % self.h_n) as usize;
        if let Some(g) = self.alpha_wave.store(self.h_n as usize, c, src_h, base, vals, "α") {
            let buf = self.alpha_wave.take(g);
            let w = group_width(g, c);
            let mut alpha =
                reduce_same_diff(&buf, self.h_n as usize, w, self.h as usize, self.a_same, self.a_diff);
            for (t, a) in alpha.iter_mut().enumerate() {
                ctx.flop(2 * self.h_n as u64);
                *a *= self.emission((group_start(g) + t) as u32);
                ctx.flop(1);
            }
            self.finish_alpha(g, alpha, ctx);
        }
    }

    fn take_beta(&mut self, base: usize, vals: &[f32], src: VertexId, ctx: &mut Ctx<InterpMsg>) {
        let c = self.n_targets as usize;
        let src_h = (src % self.h_n) as usize;
        if let Some(g) = self.beta_wave.store(self.h_n as usize, c, src_h, base, vals, "β") {
            let buf = self.beta_wave.take(g);
            let w = group_width(g, c);
            let beta = reduce_same_diff(
                &buf,
                self.h_n as usize,
                w,
                self.h as usize,
                self.a_same_next,
                self.a_diff_next,
            );
            ctx.flop(2 * self.h_n as u64 * w as u64);
            self.finish_beta(g, beta, ctx);
        }
    }

    fn finish_alpha(&mut self, g: usize, alpha: Vec<f32>, ctx: &mut Ctx<InterpMsg>) {
        if self.k + 1 < self.k_n {
            let start = group_start(g) as u32;
            for_each_chunk(&alpha, |base, n, vals| {
                ctx.send(PORT_FWD, InterpMsg::AlphaVec { base: base + start, n, vals });
            });
        }
        self.alpha[g] = alpha;
        self.alpha_done[g] = true;
        self.try_posterior(g, ctx);
    }

    fn finish_beta(&mut self, g: usize, beta: Vec<f32>, ctx: &mut Ctx<InterpMsg>) {
        if self.k > 0 {
            let start = group_start(g);
            let folded: Vec<f32> = beta
                .iter()
                .enumerate()
                .map(|(t, &b)| {
                    ctx.flop(1);
                    b * self.emission((start + t) as u32)
                })
                .collect();
            for_each_chunk(&folded, |base, n, vals| {
                ctx.send(PORT_BWD, InterpMsg::BetaVec { base: base + start as u32, n, vals });
            });
        }
        self.beta[g] = beta;
        self.beta_done[g] = true;
        self.try_posterior(g, ctx);
    }

    /// Both of group `g`'s waves in → per-lane anchor posteriors →
    /// tally/unicast, Section wave to the left neighbour, and the section
    /// blend when ready.
    fn try_posterior(&mut self, g: usize, ctx: &mut Ctx<InterpMsg>) {
        if self.posterior_done[g] || !self.alpha_done[g] || !self.beta_done[g] {
            return;
        }
        self.posterior_done[g] = true;
        let w = group_width(g, self.n_targets as usize);
        let start = group_start(g) as u32;
        let mut post = vec![0.0f32; w];
        for t in 0..w {
            post[t] = self.alpha[g][t] * self.beta[g][t];
            ctx.flop(1);
        }
        self.alpha[g] = Vec::new();
        self.beta[g] = Vec::new();
        if self.is_accumulator() {
            let h = self.h;
            let allele1 = self.allele == 1;
            self.take_posts(h, allele1, start as usize, &post, ctx);
        } else {
            let allele1 = self.allele == 1;
            for_each_chunk(&post, |base, n, vals| {
                ctx.send(
                    PORT_DOWN,
                    InterpMsg::PostVec {
                        base: base + start,
                        n,
                        allele1,
                        vals,
                    },
                );
            });
        }
        if self.k > 0 {
            // Our anchor posteriors are the right endpoints of the left
            // neighbour's section.
            for_each_chunk(&post, |base, n, vals| {
                ctx.send(PORT_SECTION, InterpMsg::SectionVec { base: base + start, n, vals });
            });
        }
        if self.k + 1 < self.k_n {
            self.own_p[g] = post;
            self.own_p_done[g] = true;
            self.try_section(g, ctx);
        }
    }

    /// Blend own + right anchor posteriors over the section (Fig 10), for
    /// every lane of group `g` at once.
    fn try_section(&mut self, g: usize, ctx: &mut Ctx<InterpMsg>) {
        if self.section_done[g] || !self.own_p_done[g] || !self.right_p_complete[g] {
            return;
        }
        self.section_done[g] = true;
        let own_p = std::mem::take(&mut self.own_p[g]);
        let right_p = std::mem::take(&mut self.right_p[g]);
        if self.sec_alleles.is_empty() {
            return;
        }
        let w = group_width(g, self.n_targets as usize);
        let start = group_start(g);
        let sec_len = self.sec_alleles.len();
        for t in 0..w {
            let (p, pr) = (own_p[t], right_p[t]);
            let mut vals = [0.0f32; MAX_SECTION];
            for i in 0..sec_len {
                let blended = p + self.sec_fracs[i] * (pr - p);
                vals[i] = if self.sec_alleles[i] == 1 { blended } else { 0.0 };
                ctx.flop(3);
            }
            let target = (start + t) as u32;
            if self.is_accumulator() {
                let h = self.h;
                self.take_hits(h, target, sec_len as u8, &vals, ctx);
            } else {
                ctx.send(
                    PORT_DOWN,
                    InterpMsg::HitVec {
                        target,
                        n: sec_len as u8,
                        vals,
                    },
                );
            }
        }
    }

    /// Accumulate one sender's posterior lanes; once a group completes,
    /// finish its anchor dosages and launch its Tot chunk.
    fn take_posts(
        &mut self,
        src_h: u32,
        allele1: bool,
        base: usize,
        vals: &[f32],
        ctx: &mut Ctx<InterpMsg>,
    ) {
        debug_assert!(self.is_accumulator());
        let c = self.n_targets as usize;
        self.post_allele1[src_h as usize] = allele1;
        ctx.flop(2 * vals.len() as u64);
        if let Some(g) = self
            .post_wave
            .store(self.h_n as usize, c, src_h as usize, base, vals, "posterior")
        {
            let buf = self.post_wave.take(g);
            let w = group_width(g, c);
            let start = group_start(g);
            let sums = reduce_hit_tot(&buf, self.h_n as usize, w, &self.post_allele1);
            let mut tots = vec![0.0f32; w];
            for (t, &(hit, tot)) in sums.iter().enumerate() {
                self.anchor_dosage[start + t] = if tot > 0.0 { hit / tot } else { 0.0 };
                ctx.flop(1);
                tots[t] = tot;
            }
            if self.k > 0 {
                for_each_chunk(&tots, |base, n, vals| {
                    ctx.send(PORT_TOT, InterpMsg::TotVec { base: base + start as u32, n, vals });
                });
            }
            if self.k + 1 < self.k_n {
                if self.own_tot.is_empty() {
                    self.own_tot = vec![0.0; c];
                }
                self.own_tot[start..start + w].copy_from_slice(&tots);
                self.own_tot_groups += 1;
                if self.own_tot_groups == n_groups(c) {
                    self.own_tot_done = true;
                    self.try_finish_section(ctx);
                }
            }
        }
    }

    /// Store one (sender, target) hit vector into the canonical buffer.
    fn take_hits(
        &mut self,
        src_h: u32,
        target: u32,
        n: u8,
        vals: &[f32; MAX_SECTION],
        ctx: &mut Ctx<InterpMsg>,
    ) {
        debug_assert!(self.is_accumulator());
        let sec_len = self.sec_alleles.len();
        assert_eq!(n as usize, sec_len, "hit-vector length");
        let c = self.n_targets as usize;
        assert!((target as usize) < c, "hit-vector target out of range");
        ctx.flop(sec_len as u64);
        if self.hit_wave.store(
            self.h_n as usize,
            c * sec_len,
            src_h as usize,
            target as usize * sec_len,
            &vals[..sec_len],
            "hit",
        ) {
            self.hits_complete = true;
            self.try_finish_section(ctx);
        }
    }

    /// Finish intermediate-marker dosages once every hit vector and both
    /// anchor-total waves are in — reduced in canonical sender order.
    fn try_finish_section(&mut self, ctx: &mut Ctx<InterpMsg>) {
        let sec_len = self.sec_alleles.len();
        if self.sections_finished
            || sec_len == 0
            || !self.hits_complete
            || !self.own_tot_done
            || !self.right_tot_complete
        {
            return;
        }
        self.sections_finished = true;
        let c = self.n_targets as usize;
        let hits = self.hit_wave.take();
        let right_tot = self.right_tot_wave.take();
        let own_tot = std::mem::take(&mut self.own_tot);
        for t in 0..c {
            for i in 0..sec_len {
                let tot = own_tot[t] + self.sec_fracs[i] * (right_tot[t] - own_tot[t]);
                ctx.flop(3);
                let mut sum = 0.0f32;
                for hh in 0..self.h_n as usize {
                    sum += hits[(hh * c + t) * sec_len + i];
                }
                self.section_dosage[t * sec_len + i] = if tot > 0.0 { sum / tot } else { 0.0 };
                ctx.flop(1);
            }
        }
    }
}

impl Device for InterpVertex {
    type Msg = InterpMsg;

    fn init(&mut self, _ctx: &mut Ctx<InterpMsg>) {}

    fn recv(&mut self, msg: &InterpMsg, src: VertexId, ctx: &mut Ctx<InterpMsg>) {
        match *msg {
            InterpMsg::AlphaVec { base, n, ref vals } => {
                self.take_alpha(base as usize, &vals[..n as usize], src, ctx)
            }
            InterpMsg::BetaVec { base, n, ref vals } => {
                self.take_beta(base as usize, &vals[..n as usize], src, ctx)
            }
            InterpMsg::PostVec {
                base,
                n,
                allele1,
                ref vals,
            } => {
                let src_h = src % self.h_n;
                self.take_posts(src_h, allele1, base as usize, &vals[..n as usize], ctx)
            }
            InterpMsg::SectionVec { base, n, ref vals } => {
                let c = self.n_targets as usize;
                if let Some(g) = self
                    .right_p_wave
                    .store(1, c, 0, base as usize, &vals[..n as usize], "Section")
                {
                    self.right_p[g] = self.right_p_wave.take(g);
                    self.right_p_complete[g] = true;
                    self.try_section(g, ctx);
                }
            }
            InterpMsg::HitVec { target, n, ref vals } => {
                let src_h = src % self.h_n;
                self.take_hits(src_h, target, n, vals, ctx)
            }
            InterpMsg::TotVec { base, n, ref vals } => {
                let c = self.n_targets as usize;
                if self
                    .right_tot_wave
                    .store(1, c, 0, base as usize, &vals[..n as usize], "Tot")
                {
                    self.right_tot_complete = true;
                    self.try_finish_section(ctx);
                }
            }
        }
    }

    fn step(&mut self, ctx: &mut Ctx<InterpMsg>) -> bool {
        let c = self.n_targets as usize;
        let n_g = n_groups(c);
        let mut active = false;
        if self.k == 0 {
            while self.injected_alpha < n_g
                && ctx.step >= inject_at(self.injected_alpha, self.stagger)
            {
                let g = self.injected_alpha;
                self.injected_alpha += 1;
                self.finish_alpha(g, vec![1.0 / self.h_n as f32; group_width(g, c)], ctx);
                active = true;
            }
            active |= self.injected_alpha < n_g;
        }
        if self.k == self.k_n - 1 {
            while self.injected_beta < n_g
                && ctx.step >= inject_at(self.injected_beta, self.stagger)
            {
                let g = self.injected_beta;
                self.injected_beta += 1;
                self.finish_beta(g, vec![1.0; group_width(g, c)], ctx);
                active = true;
            }
            active |= self.injected_beta < n_g;
        }
        active
    }

    fn lanes(msg: &InterpMsg) -> u32 {
        msg.lanes()
    }

    // Checkpoint support (fault plane): every mutable field, in declaration
    // order.  Constants (alleles, transition weights, obs) are rebuilt by the
    // graph constructor and are not serialised.
    fn snapshot(&self, out: &mut Vec<u8>) -> bool {
        let mut w = SnapWriter::new(out);
        self.alpha_wave.snapshot(&mut w);
        self.beta_wave.snapshot(&mut w);
        w.u32(self.alpha.len() as u32);
        for a in &self.alpha {
            w.f32s(a);
        }
        w.bools(&self.alpha_done);
        for b in &self.beta {
            w.f32s(b);
        }
        w.bools(&self.beta_done);
        w.bools(&self.posterior_done);
        w.u32(self.injected_alpha as u32);
        w.u32(self.injected_beta as u32);
        for p in &self.own_p {
            w.f32s(p);
        }
        w.bools(&self.own_p_done);
        self.right_p_wave.snapshot(&mut w);
        for p in &self.right_p {
            w.f32s(p);
        }
        w.bools(&self.right_p_complete);
        w.bools(&self.section_done);
        self.post_wave.snapshot(&mut w);
        w.bools(&self.post_allele1);
        self.hit_wave.snapshot(&mut w);
        w.bool(self.hits_complete);
        w.f32s(&self.own_tot);
        w.u32(self.own_tot_groups as u32);
        w.bool(self.own_tot_done);
        self.right_tot_wave.snapshot(&mut w);
        w.bool(self.right_tot_complete);
        w.bool(self.sections_finished);
        w.f32s(&self.anchor_dosage);
        w.f32s(&self.section_dosage);
        true
    }

    fn restore(&mut self, bytes: &[u8]) {
        let mut r = SnapReader::new(bytes);
        self.alpha_wave = GroupWaves::restore(&mut r);
        self.beta_wave = GroupWaves::restore(&mut r);
        let n_g = r.u32() as usize;
        self.alpha = (0..n_g).map(|_| r.f32s()).collect();
        self.alpha_done = r.bools();
        self.beta = (0..n_g).map(|_| r.f32s()).collect();
        self.beta_done = r.bools();
        self.posterior_done = r.bools();
        self.injected_alpha = r.u32() as usize;
        self.injected_beta = r.u32() as usize;
        self.own_p = (0..n_g).map(|_| r.f32s()).collect();
        self.own_p_done = r.bools();
        self.right_p_wave = GroupWaves::restore(&mut r);
        self.right_p = (0..n_g).map(|_| r.f32s()).collect();
        self.right_p_complete = r.bools();
        self.section_done = r.bools();
        self.post_wave = GroupWaves::restore(&mut r);
        self.post_allele1 = r.bools();
        self.hit_wave = WaveBuf::restore(&mut r);
        self.hits_complete = r.bool();
        self.own_tot = r.f32s();
        self.own_tot_groups = r.u32() as usize;
        self.own_tot_done = r.bool();
        self.right_tot_wave = WaveBuf::restore(&mut r);
        self.right_tot_complete = r.bool();
        self.sections_finished = r.bool();
        self.anchor_dosage = r.f32s();
        self.section_dosage = r.f32s();
        assert!(r.exhausted(), "interp-vertex snapshot not fully consumed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imputation::msg::LANES;
    use crate::model::panel::TargetHaplotype;

    fn mk(h: u32, k: u32, n_targets: u32) -> InterpVertex {
        let targets: Vec<TargetHaplotype> = (0..n_targets)
            .map(|_| TargetHaplotype::new(vec![1, -1, -1, -1, 0]))
            .collect();
        let obs = ObsMatrix::from_targets(&targets);
        InterpVertex::new(
            h,
            k,
            2,
            2,
            if k == 0 { 0 } else { 4 },
            1,
            if k == 0 { vec![1, 0, 1] } else { Vec::new() },
            if k == 0 { vec![0.25, 0.5, 0.75] } else { Vec::new() },
            0.1,
            0.2,
            1e-4,
            n_targets,
            1,
            obs,
        )
    }

    #[test]
    fn last_anchor_owns_no_section() {
        assert_eq!(mk(0, 0, 1).sec_len(), 3);
        assert_eq!(mk(0, 1, 1).sec_len(), 0);
    }

    #[test]
    fn injection_staggers_one_group_per_superstep() {
        let mut v = mk(0, 0, LANES as u32 + 3);
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx));
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1, "step 0 injects group 0 only");
        assert!(matches!(sends[0], (PORT_FWD, InterpMsg::AlphaVec { base: 0, n, .. }) if n == LANES as u32));
        let mut ctx = Ctx::new(0, 1);
        assert!(v.step(&mut ctx));
        let sends = ctx.take_sends();
        assert_eq!(sends.len(), 1, "step 1 injects group 1");
        assert!(
            matches!(sends[0], (PORT_FWD, InterpMsg::AlphaVec { base, n, .. }) if base == LANES as u32 && n == 3)
        );
        let mut ctx = Ctx::new(0, 2);
        assert!(!v.step(&mut ctx), "all groups injected — go quiescent");
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    fn snapshot_roundtrips_injection_and_wave_state() {
        // A left-edge section vertex that already injected its α wave must
        // not inject again after checkpoint/restore, and its buffered
        // mid-flight state survives the round trip byte-exactly.
        let mut v = mk(0, 0, 1);
        let mut ctx = Ctx::new(0, 0);
        assert!(v.step(&mut ctx));
        drop(ctx.take_sends());
        let mut bytes = Vec::new();
        assert!(Device::snapshot(&v, &mut bytes));
        let mut fresh = mk(0, 0, 1);
        fresh.restore(&bytes);
        let mut ctx = Ctx::new(0, 1);
        assert!(!fresh.step(&mut ctx), "restored vertex re-injects nothing");
        assert!(ctx.take_sends().is_empty());
        let mut again = Vec::new();
        assert!(Device::snapshot(&fresh, &mut again));
        assert_eq!(bytes, again, "snapshot → restore → snapshot is stable");
    }

    #[test]
    #[should_panic(expected = "duplicate Section wave")]
    fn detects_duplicate_section_waves() {
        let mut v = mk(0, 0, 1);
        let mut ctx = Ctx::new(0, 0);
        let msg = InterpMsg::SectionVec {
            base: 0,
            n: 1,
            vals: [0.5; LANES],
        };
        v.recv(&msg, 1, &mut ctx);
        v.recv(&msg, 1, &mut ctx);
    }
}
