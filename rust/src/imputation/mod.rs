//! The paper's contribution: event-driven genotype imputation (§5).
//!
//! * [`msg`] — 64-byte event payloads (α/β/posterior plus interpolation),
//!   wave-batched: SoA slabs of up to [`msg::LANES`] targets per event.
//! * [`obs`] — shared target-observation storage (board-DRAM model).
//! * [`vertex`] / [`app`] — the raw model: one vertex per HMM state,
//!   Algorithm 1 handlers, multi-target wave sweeps, soft-scheduling.
//! * [`interp_vertex`] / [`interp_app`] — the linear-interpolation variant:
//!   one vertex per state *section* (1 HMM state + N interpolation states).
//! * [`analytic`] — closed-form step-time predictor, cross-validated against
//!   the DES and used to extrapolate figure sweeps to full paper scale.
//!
//! Execution goes through the unified pipeline in [`crate::session`] —
//! build a workload, pick [`EngineSpec::Event`](crate::session::EngineSpec)
//! or `Interp`, run an `ImputeSession` (the old `run_raw` / `run_interp`
//! entry points are gone).

pub mod analytic;
pub mod app;
pub mod interp_app;
pub mod interp_vertex;
pub mod msg;
pub mod obs;
pub mod vertex;
pub(crate) mod wave;

pub use app::{EventRunResult, RawAppConfig, build_raw_graph};
