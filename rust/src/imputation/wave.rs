//! Arrival buffering for SoA waves — the one place chunk storage, lane
//! counting, range/duplicate assertions and completion detection live.
//!
//! Both event planes buffer a wave's contributions keyed by sender row
//! (haplotype) and reduce in canonical row order once complete (see
//! `imputation::vertex` module docs for the bit-invariance argument).  The
//! slab is allocated **lazily on the first arrival** and released by
//! [`WaveBuf::take`], so only the vertices a wavefront is currently
//! crossing hold O(rows × lanes) memory — idle columns hold none, which is
//! what keeps whole-graph memory flat however wide the lane group is.

/// One in-flight wave: a `rows × width` f32 slab filled by chunk arrivals.
pub(crate) struct WaveBuf {
    buf: Vec<f32>,
    lanes: u64,
    done: bool,
}

impl WaveBuf {
    pub fn new() -> WaveBuf {
        WaveBuf {
            buf: Vec::new(),
            lanes: 0,
            done: false,
        }
    }

    /// Store one chunk at `(row, base..base+vals.len())` of a
    /// `rows × width` slab; returns `true` when every lane of every row has
    /// arrived.  Panics on duplicate waves and out-of-range lanes — the
    /// cross-wave contamination hazards the synchronised stepping prevents.
    pub fn store(
        &mut self,
        rows: usize,
        width: usize,
        row: usize,
        base: usize,
        vals: &[f32],
        what: &str,
    ) -> bool {
        assert!(!self.done, "duplicate {what} wave");
        assert!(
            !vals.is_empty() && base + vals.len() <= width,
            "{what} lane range [{base}, {}) out of 0..{width}",
            base + vals.len()
        );
        debug_assert!(row < rows);
        if self.buf.is_empty() {
            self.buf = vec![0.0; rows * width];
        }
        self.buf[row * width + base..row * width + base + vals.len()].copy_from_slice(vals);
        self.lanes += vals.len() as u64;
        let total = (rows * width) as u64;
        // A wave that completed but has not been consumed yet must also
        // reject arrivals — completion may lag `take` when the consumer
        // waits on sibling waves (e.g. section totals).
        assert!(self.lanes <= total, "duplicate {what} wave (lane overflow)");
        self.lanes == total
    }

    /// Hand out the completed row-major slab and release the buffer.
    pub fn take(&mut self) -> Vec<f32> {
        self.done = true;
        self.lanes = 0;
        std::mem::take(&mut self.buf)
    }
}

/// Canonical same/diff reduce of a completed `rows × width` slab:
/// `out[lane] = Σ_row coeff(row) · slab[row][lane]` with the sum taken in
/// ascending row order and `coeff(row) = same` for `own` else `diff` — the
/// α/β transition fold shared by both event planes.  Keeping the loop here
/// keeps the bit-invariance contract (sum order fixed by the model, not by
/// event timing) in ONE place.
pub(crate) fn reduce_same_diff(
    buf: &[f32],
    rows: usize,
    width: usize,
    own: usize,
    same: f32,
    diff: f32,
) -> Vec<f32> {
    debug_assert_eq!(buf.len(), rows * width);
    let mut out = vec![0.0f32; width];
    for (t, slot) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for r in 0..rows {
            let coeff = if r == own { same } else { diff };
            acc += coeff * buf[r * width + t];
        }
        *slot = acc;
    }
    out
}

/// Canonical posterior reduce: per lane, `(hit, tot)` sums over rows in
/// ascending order, `hit` restricted to rows whose `allele1` flag is set —
/// the accumulator tally shared by both event planes.
pub(crate) fn reduce_hit_tot(
    buf: &[f32],
    rows: usize,
    width: usize,
    allele1: &[bool],
) -> Vec<(f32, f32)> {
    debug_assert_eq!(buf.len(), rows * width);
    debug_assert_eq!(allele1.len(), rows);
    let mut out = vec![(0.0f32, 0.0f32); width];
    for (t, slot) in out.iter_mut().enumerate() {
        let (mut hit, mut tot) = (0.0f32, 0.0f32);
        for r in 0..rows {
            let v = buf[r * width + t];
            if allele1[r] {
                hit += v;
            }
            tot += v;
        }
        *slot = (hit, tot);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_allocation_and_completion() {
        let mut w = WaveBuf::new();
        assert!(!w.store(2, 3, 0, 0, &[1.0, 2.0, 3.0], "t"));
        assert!(!w.store(2, 3, 1, 0, &[4.0, 5.0], "t"));
        assert!(w.store(2, 3, 1, 2, &[6.0], "t"));
        let slab = w.take();
        assert_eq!(slab, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(w.done);
    }

    #[test]
    #[should_panic(expected = "duplicate t wave")]
    fn rejects_post_completion_arrivals() {
        let mut w = WaveBuf::new();
        assert!(w.store(1, 1, 0, 0, &[1.0], "t"));
        w.take();
        w.store(1, 1, 0, 0, &[1.0], "t");
    }

    #[test]
    #[should_panic(expected = "lane range")]
    fn rejects_out_of_range_lanes() {
        let mut w = WaveBuf::new();
        w.store(1, 2, 0, 1, &[1.0, 2.0], "t");
    }

    #[test]
    #[should_panic(expected = "lane overflow")]
    fn rejects_arrivals_on_a_complete_untaken_wave() {
        let mut w = WaveBuf::new();
        assert!(w.store(1, 1, 0, 0, &[1.0], "t"));
        w.store(1, 1, 0, 0, &[2.0], "t"); // complete but not taken yet
    }

    #[test]
    fn no_memory_until_first_arrival() {
        let w = WaveBuf::new();
        assert_eq!(w.buf.capacity(), 0, "idle waves must hold no slab");
    }

    #[test]
    fn same_diff_reduce_is_row_ordered() {
        // 2 rows × 2 lanes; own row 1.
        let buf = [1.0, 10.0, 100.0, 1000.0];
        let out = reduce_same_diff(&buf, 2, 2, 1, 0.5, 0.25);
        assert_eq!(out, vec![0.25 * 1.0 + 0.5 * 100.0, 0.25 * 10.0 + 0.5 * 1000.0]);
    }

    #[test]
    fn hit_tot_reduce_respects_allele_flags() {
        let buf = [1.0, 10.0, 100.0, 1000.0];
        let out = reduce_hit_tot(&buf, 2, 2, &[true, false]);
        assert_eq!(out, vec![(1.0, 101.0), (10.0, 1010.0)]);
    }
}
