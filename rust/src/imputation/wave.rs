//! Arrival buffering for SoA waves — the one place chunk storage, lane
//! counting, range/duplicate assertions and completion detection live.
//!
//! Both event planes buffer a wave's contributions keyed by **(lane group,
//! sender row)** and reduce in canonical row order once a group completes
//! (see `imputation::vertex` module docs for the bit-invariance argument).
//! A batch wider than [`LANES`] is split into contiguous lane groups of at
//! most `LANES` targets; group *g* covers the global lane range
//! `[g·LANES, min((g+1)·LANES, n_targets))` and is injected at the edge
//! columns `stagger` supersteps after group *g−1*, so several groups
//! pipeline through one engine run with each column servicing at most one
//! chunk per group per sweep — exactly the event/copy/lane counts of
//! running the groups sequentially, at a fraction of the supersteps.
//!
//! Every chunk on the wire is addressed by its **global** lane base; the
//! receiver derives `(group, local base)` via [`GroupWaves::store`], which
//! keeps the per-group [`WaveBuf`] discipline of PR 5: each group's slab is
//! allocated **lazily on the first arrival** and released by `take` when
//! the group's reduce fires, so only the groups whose wavefront is
//! currently crossing a vertex hold O(rows × group width) memory — idle
//! columns (and drained groups) hold none, which is what keeps whole-graph
//! memory flat however many groups are in flight.
//!
//! Because each group reduces independently over the same sender rows and
//! the same coefficients as a sequential `batch = LANES` run of that group,
//! the pipelined dosages are bit-identical to the sequential-groups result
//! at every batch width and host thread count.  The same argument extends
//! to the opt-in DES trace (`SimConfig::trace`): at a fixed wave width the
//! per-superstep delivery schedule is a function of the graph and injection
//! schedule alone, so the recorded trace is bit-identical across host
//! thread counts (`tests/trace_determinism.rs`); different widths pipeline
//! different lane groups and legitimately trace different schedules, while
//! each width's trace stays deterministic run to run.
//!
//! [`LANES`]: super::msg::LANES

use crate::poets::fault::{SnapReader, SnapWriter};

use super::msg::LANES;

/// Number of lane groups a batch of `n_targets` splits into.
pub(crate) fn n_groups(n_targets: usize) -> usize {
    n_targets.div_ceil(LANES)
}

/// First global lane of group `g`.
pub(crate) fn group_start(g: usize) -> usize {
    g * LANES
}

/// Lane count of group `g` within a batch of `n_targets` (the last group
/// may be narrower than `LANES`).
pub(crate) fn group_width(g: usize, n_targets: usize) -> usize {
    n_targets.min(group_start(g) + LANES) - group_start(g)
}

/// Which group a global lane index belongs to.
pub(crate) fn group_of(global_lane: usize) -> usize {
    global_lane / LANES
}

/// Superstep at which group `g` is injected at the edge columns.
pub(crate) fn inject_at(g: usize, stagger: u64) -> u64 {
    g as u64 * stagger
}

/// One in-flight wave: a `rows × width` f32 slab filled by chunk arrivals.
pub(crate) struct WaveBuf {
    buf: Vec<f32>,
    lanes: u64,
    done: bool,
}

impl WaveBuf {
    pub fn new() -> WaveBuf {
        WaveBuf {
            buf: Vec::new(),
            lanes: 0,
            done: false,
        }
    }

    /// Store one chunk at `(row, base..base+vals.len())` of a
    /// `rows × width` slab; returns `true` when every lane of every row has
    /// arrived.  Panics on duplicate waves and out-of-range lanes — the
    /// cross-wave contamination hazards the synchronised stepping prevents.
    pub fn store(
        &mut self,
        rows: usize,
        width: usize,
        row: usize,
        base: usize,
        vals: &[f32],
        what: &str,
    ) -> bool {
        assert!(!self.done, "duplicate {what} wave");
        assert!(
            !vals.is_empty() && base + vals.len() <= width,
            "{what} lane range [{base}, {}) out of 0..{width}",
            base + vals.len()
        );
        debug_assert!(row < rows);
        if self.buf.is_empty() {
            self.buf = vec![0.0; rows * width];
        }
        self.buf[row * width + base..row * width + base + vals.len()].copy_from_slice(vals);
        self.lanes += vals.len() as u64;
        let total = (rows * width) as u64;
        // A wave that completed but has not been consumed yet must also
        // reject arrivals — completion may lag `take` when the consumer
        // waits on sibling waves (e.g. section totals).
        assert!(self.lanes <= total, "duplicate {what} wave (lane overflow)");
        self.lanes == total
    }

    /// Hand out the completed row-major slab and release the buffer.
    pub fn take(&mut self) -> Vec<f32> {
        self.done = true;
        self.lanes = 0;
        std::mem::take(&mut self.buf)
    }

    /// Serialise the in-flight state for a fault-plane checkpoint
    /// (`poets::fault`) — the partial slab round-trips exactly.
    pub fn snapshot(&self, w: &mut SnapWriter<'_>) {
        w.f32s(&self.buf);
        w.u64(self.lanes);
        w.bool(self.done);
    }

    pub fn restore(r: &mut SnapReader<'_>) -> WaveBuf {
        WaveBuf {
            buf: r.f32s(),
            lanes: r.u64(),
            done: r.bool(),
        }
    }
}

/// A family of in-flight waves keyed by lane group: one lazily-allocated
/// [`WaveBuf`] per group, each `rows × group_width(g)`.  Chunks arrive
/// addressed by their *global* lane base (senders offset
/// `msg::for_each_chunk` bases by the group start); `store` routes each to
/// its group slab and reports which group, if any, just completed.  The
/// group vector itself is allocated on the first arrival, so idle vertices
/// hold no per-group state at all.
pub(crate) struct GroupWaves {
    waves: Vec<WaveBuf>,
}

impl GroupWaves {
    pub fn new() -> GroupWaves {
        GroupWaves { waves: Vec::new() }
    }

    /// Store one chunk at `(row, global_base..global_base+vals.len())` of
    /// the batch-wide lane space; returns `Some(group)` when that group's
    /// slab completes.  Chunks never straddle a group boundary (each group
    /// is at most one chunk wide), and the per-group [`WaveBuf`] keeps the
    /// duplicate/range panics of the single-group plane.
    pub fn store(
        &mut self,
        rows: usize,
        n_targets: usize,
        row: usize,
        global_base: usize,
        vals: &[f32],
        what: &str,
    ) -> Option<usize> {
        let g = group_of(global_base);
        assert!(
            g < n_groups(n_targets),
            "{what} lane range [{global_base}, {}) out of 0..{n_targets}",
            global_base + vals.len()
        );
        if self.waves.is_empty() {
            let n = n_groups(n_targets);
            self.waves = (0..n).map(|_| WaveBuf::new()).collect();
        }
        let local = global_base - group_start(g);
        let width = group_width(g, n_targets);
        if self.waves[g].store(rows, width, row, local, vals, what) {
            Some(g)
        } else {
            None
        }
    }

    /// Hand out group `g`'s completed slab and release its buffer.
    pub fn take(&mut self, g: usize) -> Vec<f32> {
        self.waves[g].take()
    }

    /// Serialise every group slab for a fault-plane checkpoint.
    pub fn snapshot(&self, w: &mut SnapWriter<'_>) {
        w.u32(self.waves.len() as u32);
        for wave in &self.waves {
            wave.snapshot(w);
        }
    }

    pub fn restore(r: &mut SnapReader<'_>) -> GroupWaves {
        let n = r.u32() as usize;
        GroupWaves {
            waves: (0..n).map(|_| WaveBuf::restore(r)).collect(),
        }
    }
}

/// Canonical same/diff reduce of a completed `rows × width` slab:
/// `out[lane] = Σ_row coeff(row) · slab[row][lane]` with the sum taken in
/// ascending row order and `coeff(row) = same` for `own` else `diff` — the
/// α/β transition fold shared by both event planes.  Keeping the loop here
/// keeps the bit-invariance contract (sum order fixed by the model, not by
/// event timing) in ONE place.
pub(crate) fn reduce_same_diff(
    buf: &[f32],
    rows: usize,
    width: usize,
    own: usize,
    same: f32,
    diff: f32,
) -> Vec<f32> {
    debug_assert_eq!(buf.len(), rows * width);
    let mut out = vec![0.0f32; width];
    for (t, slot) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for r in 0..rows {
            let coeff = if r == own { same } else { diff };
            acc += coeff * buf[r * width + t];
        }
        *slot = acc;
    }
    out
}

/// Canonical posterior reduce: per lane, `(hit, tot)` sums over rows in
/// ascending order, `hit` restricted to rows whose `allele1` flag is set —
/// the accumulator tally shared by both event planes.
pub(crate) fn reduce_hit_tot(
    buf: &[f32],
    rows: usize,
    width: usize,
    allele1: &[bool],
) -> Vec<(f32, f32)> {
    debug_assert_eq!(buf.len(), rows * width);
    debug_assert_eq!(allele1.len(), rows);
    let mut out = vec![(0.0f32, 0.0f32); width];
    for (t, slot) in out.iter_mut().enumerate() {
        let (mut hit, mut tot) = (0.0f32, 0.0f32);
        for r in 0..rows {
            let v = buf[r * width + t];
            if allele1[r] {
                hit += v;
            }
            tot += v;
        }
        *slot = (hit, tot);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_allocation_and_completion() {
        let mut w = WaveBuf::new();
        assert!(!w.store(2, 3, 0, 0, &[1.0, 2.0, 3.0], "t"));
        assert!(!w.store(2, 3, 1, 0, &[4.0, 5.0], "t"));
        assert!(w.store(2, 3, 1, 2, &[6.0], "t"));
        let slab = w.take();
        assert_eq!(slab, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(w.done);
    }

    #[test]
    #[should_panic(expected = "duplicate t wave")]
    fn rejects_post_completion_arrivals() {
        let mut w = WaveBuf::new();
        assert!(w.store(1, 1, 0, 0, &[1.0], "t"));
        w.take();
        w.store(1, 1, 0, 0, &[1.0], "t");
    }

    #[test]
    #[should_panic(expected = "lane range")]
    fn rejects_out_of_range_lanes() {
        let mut w = WaveBuf::new();
        w.store(1, 2, 0, 1, &[1.0, 2.0], "t");
    }

    #[test]
    #[should_panic(expected = "lane overflow")]
    fn rejects_arrivals_on_a_complete_untaken_wave() {
        let mut w = WaveBuf::new();
        assert!(w.store(1, 1, 0, 0, &[1.0], "t"));
        w.store(1, 1, 0, 0, &[2.0], "t"); // complete but not taken yet
    }

    #[test]
    fn no_memory_until_first_arrival() {
        let w = WaveBuf::new();
        assert_eq!(w.buf.capacity(), 0, "idle waves must hold no slab");
    }

    #[test]
    fn same_diff_reduce_is_row_ordered() {
        // 2 rows × 2 lanes; own row 1.
        let buf = [1.0, 10.0, 100.0, 1000.0];
        let out = reduce_same_diff(&buf, 2, 2, 1, 0.5, 0.25);
        assert_eq!(out, vec![0.25 * 1.0 + 0.5 * 100.0, 0.25 * 10.0 + 0.5 * 1000.0]);
    }

    #[test]
    fn hit_tot_reduce_respects_allele_flags() {
        let buf = [1.0, 10.0, 100.0, 1000.0];
        let out = reduce_hit_tot(&buf, 2, 2, &[true, false]);
        assert_eq!(out, vec![(1.0, 101.0), (10.0, 1010.0)]);
    }

    #[test]
    fn group_geometry_covers_the_batch_exactly() {
        // LANES+3 targets -> two groups: [0, LANES) and [LANES, LANES+3).
        let t = LANES + 3;
        assert_eq!(n_groups(t), 2);
        assert_eq!(group_width(0, t), LANES);
        assert_eq!(group_width(1, t), 3);
        assert_eq!(group_start(1), LANES);
        assert_eq!(group_of(LANES - 1), 0);
        assert_eq!(group_of(LANES), 1);
        assert_eq!((0..n_groups(t)).map(|g| group_width(g, t)).sum::<usize>(), t);
        // One full group stays a single-group batch.
        assert_eq!(n_groups(LANES), 1);
        assert_eq!(n_groups(1), 1);
        // Staggered injection schedule.
        assert_eq!(inject_at(0, 1), 0);
        assert_eq!(inject_at(3, 2), 6);
    }

    #[test]
    fn group_waves_complete_per_group_and_free_slabs() {
        // 2 rows, LANES+2 targets: group 0 is LANES wide, group 1 is 2 wide.
        let t = LANES + 2;
        let mut gw = GroupWaves::new();
        let full = vec![1.0f32; LANES];
        // Group 1 can complete while group 0 has seen nothing.
        assert_eq!(gw.store(2, t, 0, LANES, &[5.0, 6.0], "t"), None);
        assert_eq!(gw.store(2, t, 1, LANES, &[7.0, 8.0], "t"), Some(1));
        assert_eq!(gw.take(1), vec![5.0, 6.0, 7.0, 8.0]);
        // Group 0 then fills independently.
        assert_eq!(gw.store(2, t, 0, 0, &full, "t"), None);
        assert_eq!(gw.store(2, t, 1, 0, &full, "t"), Some(0));
        assert_eq!(gw.take(0).len(), 2 * LANES);
    }

    #[test]
    #[should_panic(expected = "lane range")]
    fn group_waves_reject_lanes_past_the_batch() {
        let mut gw = GroupWaves::new();
        gw.store(1, 1, 0, LANES + 1, &[1.0], "t");
    }

    #[test]
    #[should_panic(expected = "duplicate t wave")]
    fn group_waves_keep_per_group_duplicate_detection() {
        let mut gw = GroupWaves::new();
        assert_eq!(gw.store(1, 1, 0, 0, &[1.0], "t"), Some(0));
        gw.take(0);
        gw.store(1, 1, 0, 0, &[2.0], "t");
    }

    #[test]
    fn snapshots_roundtrip_partial_waves() {
        // A half-filled group family survives checkpoint/restore exactly:
        // the missing chunk still completes the restored copy.
        let t = LANES + 2;
        let mut gw = GroupWaves::new();
        assert_eq!(gw.store(2, t, 0, LANES, &[5.0, 6.0], "t"), None);
        let mut bytes = Vec::new();
        gw.snapshot(&mut SnapWriter::new(&mut bytes));
        let mut r = SnapReader::new(&bytes);
        let mut back = GroupWaves::restore(&mut r);
        assert!(r.exhausted());
        assert_eq!(back.store(2, t, 1, LANES, &[7.0, 8.0], "t"), Some(1));
        assert_eq!(back.take(1), vec![5.0, 6.0, 7.0, 8.0]);
        // Untouched (lazily unallocated) families restore to nothing.
        let mut bytes = Vec::new();
        GroupWaves::new().snapshot(&mut SnapWriter::new(&mut bytes));
        let mut r = SnapReader::new(&bytes);
        let back = GroupWaves::restore(&mut r);
        assert!(r.exhausted());
        assert!(back.waves.is_empty());
    }
}
