//! Linear-interpolation application assembly — paper §5.3/§6.3.
//!
//! The anchor grid is the target set's annotated-marker grid (all targets
//! share it: chips type the same loci for every sample).  Vertex ids are
//! column-major over the K×H anchor grid; each vertex owns the section of
//! intermediate panel states between its anchor and the next.

use std::sync::Arc;

use crate::graph::builder::{Graph, GraphBuilder};
use crate::graph::device::VertexId;
use crate::model::interpolation::blends;
use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::poets::desim::Simulator;

use super::app::{EventRunResult, RawAppConfig};
use super::interp_vertex::InterpVertex;
use super::obs::ObsMatrix;

/// Build the interpolation application graph.
///
/// All targets must share the same annotation grid (`anchors`).
pub fn build_interp_graph(
    panel: &ReferencePanel,
    targets: &[TargetHaplotype],
    anchors: &[usize],
    cfg: &RawAppConfig,
) -> Graph<InterpVertex> {
    let h_n = panel.n_hap();
    let k_n = anchors.len();
    assert!(k_n >= 2, "interpolation needs >= 2 anchors");
    for t in targets {
        assert_eq!(
            t.annotated(),
            anchors,
            "all targets must share the annotation grid"
        );
    }
    let obs = ObsMatrix::from_targets(targets);
    let n_targets = targets.len() as u32;

    // Anchor subproblem taus (accumulated genetic distances).
    let sub = panel.select_markers(anchors);
    let taus: Vec<f64> = (0..k_n)
        .map(|k| {
            if k == 0 {
                0.0
            } else {
                cfg.params.tau(sub.gen_dist(k), h_n)
            }
        })
        .collect();

    // Per-marker blend weights over the full grid (paper Fig 10).
    let weights = blends(panel, anchors);

    let mut b = GraphBuilder::new();
    for (k, &anchor_m) in anchors.iter().enumerate() {
        let sec_range = if k + 1 < k_n {
            anchor_m + 1..anchors[k + 1]
        } else {
            anchor_m + 1..anchor_m + 1 // empty: last anchor owns no section
        };
        let sec_fracs: Vec<f32> = sec_range
            .clone()
            .map(|m| {
                debug_assert_eq!(weights[m].left, k);
                weights[m].frac as f32
            })
            .collect();
        let tau_k = taus[k];
        let tau_next = if k + 1 < k_n { taus[k + 1] } else { 0.0 };
        for h in 0..h_n {
            let sec_alleles: Vec<u8> = sec_range.clone().map(|m| panel.allele(h, m)).collect();
            b.add_vertex(InterpVertex::new(
                h as u32,
                k as u32,
                h_n as u32,
                k_n as u32,
                anchor_m as u32,
                panel.allele(h, anchor_m),
                sec_alleles,
                sec_fracs.clone(),
                tau_k,
                tau_next,
                cfg.params.err,
                n_targets,
                cfg.stagger,
                Arc::clone(&obs),
            ));
        }
    }

    let col_ids: Vec<Vec<VertexId>> = (0..k_n)
        .map(|k| (0..h_n).map(|h| (k * h_n + h) as VertexId).collect())
        .collect();
    let col_lists: Vec<_> = col_ids.iter().map(|c| b.intern_dests(c.clone())).collect();
    let down_lists: Vec<_> = (0..k_n)
        .map(|k| b.intern_dests(vec![(k * h_n + h_n - 1) as VertexId]))
        .collect();
    let empty = b.intern_dests(vec![]);

    for k in 0..k_n {
        for h in 0..h_n {
            let v = (k * h_n + h) as VertexId;
            let is_acc = h == h_n - 1;
            // PORT_FWD / PORT_BWD over the anchor grid.
            b.add_port(v, if k + 1 < k_n { col_lists[k + 1] } else { empty });
            b.add_port(v, if k > 0 { col_lists[k - 1] } else { empty });
            // PORT_DOWN: posterior + hit-vector unicasts to the accumulator.
            b.add_port(v, if is_acc { empty } else { down_lists[k] });
            // PORT_SECTION: own anchor posterior to the left neighbour.
            let left = if k > 0 {
                b.intern_dests(vec![((k - 1) * h_n + h) as VertexId])
            } else {
                empty
            };
            b.add_port(v, left);
            // PORT_TOT: accumulator→left accumulator.
            b.add_port(v, if is_acc && k > 0 { down_lists[k - 1] } else { empty });
        }
    }
    b.build()
}

/// Reassemble per-target full-grid dosages from the accumulator vertices.
pub fn extract_interp_results(
    sim: &Simulator<InterpVertex>,
    panel: &ReferencePanel,
    anchors: &[usize],
    n_targets: usize,
) -> EventRunResult {
    let h_n = panel.n_hap();
    let m_n = panel.n_mark();
    let mut dosages = vec![vec![f32::NAN; m_n]; n_targets];
    for (k, &anchor_m) in anchors.iter().enumerate() {
        let acc = &sim.graph.devices[k * h_n + (h_n - 1)];
        let sec_len = acc.sec_len();
        for (t, row) in dosages.iter_mut().enumerate() {
            let d = acc.anchor_dosage[t];
            assert!(d.is_finite(), "anchor dosage missing (t={t}, k={k})");
            row[anchor_m] = d;
            for i in 0..sec_len {
                let d = acc.section_dosage[t * sec_len + i];
                assert!(d.is_finite(), "section dosage missing (t={t}, k={k}, i={i})");
                row[anchor_m + 1 + i] = d;
            }
        }
    }
    let mut metrics = sim.metrics.clone();
    metrics.max_groups_in_flight = super::wave::n_groups(n_targets) as u64;
    EventRunResult {
        dosages,
        metrics,
        sim_seconds: sim.sim_seconds(),
        trace: None,
    }
}

// The interp plane's canonical checks, driven through the session pipeline
// (the only entry point since the deprecated `run_interp` shim was removed).
#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::baseline::{Baseline, ImputeOut, Method};
    use crate::model::interpolation::impute_interp;
    use crate::poets::topology::ClusterConfig;
    use crate::session::{EngineSpec, ImputeSession, Workload};
    use crate::util::rng::Rng;
    use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

    /// Run one event plane on a bare workload (what the removed
    /// `run_raw`/`run_interp` shims did).
    fn run_plane(
        spec: EngineSpec,
        panel: &ReferencePanel,
        targets: &[TargetHaplotype],
        cfg: &RawAppConfig,
    ) -> EventRunResult {
        let report = ImputeSession::new(Workload::from_parts(panel.clone(), targets.to_vec()))
            .engine(spec)
            .app_config(cfg.clone())
            .run()
            .expect("event planes are always available");
        EventRunResult {
            dosages: report.dosages,
            metrics: report.metrics.expect("event planes report metrics"),
            sim_seconds: report.sim_seconds.expect("event planes report simulated time"),
            trace: None,
        }
    }

    fn run_interp(
        panel: &ReferencePanel,
        targets: &[TargetHaplotype],
        cfg: &RawAppConfig,
    ) -> EventRunResult {
        run_plane(EngineSpec::Interp, panel, targets, cfg)
    }

    fn cfg() -> RawAppConfig {
        RawAppConfig {
            cluster: ClusterConfig::with_boards(2),
            states_per_thread: 10,
            ..RawAppConfig::default()
        }
    }

    fn problem(seed: u64, n_hap: usize, n_mark: usize, n_targets: usize)
        -> (ReferencePanel, Vec<TargetHaplotype>) {
        let pcfg = PanelConfig {
            n_hap,
            n_mark,
            maf: 0.25,
            annot_ratio: 0.1,
            seed,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&pcfg);
        let mut rng = Rng::new(seed ^ 0xFEED);
        let targets = generate_targets(&panel, &pcfg, n_targets, &mut rng)
            .into_iter()
            .map(|c| c.masked)
            .collect();
        (panel, targets)
    }

    #[test]
    fn graph_is_sectioned() {
        let (panel, targets) = problem(1, 6, 41, 1);
        let anchors = targets[0].annotated();
        let g = build_interp_graph(&panel, &targets, &anchors, &cfg());
        // 41 markers at ratio 0.1 → anchors {0,10,20,30,40}: 5 anchor columns.
        assert_eq!(anchors.len(), 5);
        assert_eq!(g.n_vertices(), 5 * 6);
        // Sections: anchors 0..3 own 9 intermediates each; last owns none.
        let v0 = &g.devices[0];
        assert_eq!(v0.sec_len(), 9);
        let vlast = &g.devices[4 * 6];
        assert_eq!(vlast.sec_len(), 0);
    }

    #[test]
    fn interp_event_matches_interp_baseline() {
        let (panel, targets) = problem(2, 8, 41, 1);
        let out = run_interp(&panel, &targets, &cfg());
        let b = Baseline::default();
        let want: ImputeOut<f32> = impute_interp(&b, &panel, &targets[0], Method::DenseThreeLoop);
        for m in 0..panel.n_mark() {
            assert!(
                (out.dosages[0][m] - want.dosage[m]).abs() < 2e-3,
                "marker {m}: event {} vs baseline {}",
                out.dosages[0][m],
                want.dosage[m]
            );
        }
    }

    #[test]
    fn interp_event_pipelined_targets_match() {
        let (panel, targets) = problem(3, 6, 31, 4);
        let out = run_interp(&panel, &targets, &cfg());
        let b = Baseline::default();
        for (t, target) in targets.iter().enumerate() {
            let want: ImputeOut<f32> = impute_interp(&b, &panel, target, Method::DenseThreeLoop);
            for m in 0..panel.n_mark() {
                assert!(
                    (out.dosages[t][m] - want.dosage[m]).abs() < 2e-3,
                    "target {t} marker {m}: {} vs {}",
                    out.dosages[t][m],
                    want.dosage[m]
                );
            }
        }
    }

    #[test]
    fn interp_pipelined_groups_match_sequential_groups() {
        use crate::imputation::msg::LANES;
        let t = LANES + 3;
        let (panel, targets) = problem(7, 6, 31, t);
        let run = |batch: usize| {
            ImputeSession::new(Workload::from_parts(panel.clone(), targets.clone()))
                .engine(EngineSpec::Interp)
                .app_config(cfg())
                .batch(batch)
                .run()
                .expect("interp plane is always available")
        };
        let pipelined = run(t);
        let sequential = run(LANES);
        assert_eq!(
            pipelined.dosages, sequential.dosages,
            "pipelined lane groups changed interp numerics"
        );
        let pm = pipelined.metrics.expect("metrics");
        assert_eq!(pm.max_groups_in_flight, 2);
    }

    #[test]
    fn interp_host_threads_do_not_change_results() {
        let (panel, targets) = problem(6, 6, 31, 2);
        let serial = run_interp(&panel, &targets, &cfg());
        let parallel = run_interp(&panel, &targets, &cfg().with_threads(8));
        assert_eq!(serial.dosages, parallel.dosages, "thread count changed numerics");
        assert_eq!(serial.metrics.sim_cycles, parallel.metrics.sim_cycles);
        assert_eq!(serial.metrics.steps, parallel.metrics.steps);
    }

    #[test]
    fn message_reduction_vs_raw() {
        // The §6.3 claim: sectioning cuts messages by roughly the section
        // size.  Both planes are wave-batched now, and hit vectors cannot
        // lane-batch (their 12-value slab fills the event budget), so the
        // per-message gap narrows with the lane width — at T=2 the anchor
        // grid must still win by well over the ~5x it had per target.
        let (panel, targets) = problem(4, 8, 101, 2);
        let raw = run_plane(EngineSpec::Event, &panel, &targets, &cfg());
        let itp = run_interp(&panel, &targets, &cfg());
        let ratio = raw.metrics.sends as f64 / itp.metrics.sends as f64;
        assert!(
            ratio > 4.0,
            "message reduction only {ratio:.1}x (raw {} vs interp {})",
            raw.metrics.sends,
            itp.metrics.sends
        );
        // Lane-for-lane (per-target work units) the sectioning win is intact.
        let lane_ratio = raw.metrics.lanes_delivered as f64 / itp.metrics.lanes_delivered as f64;
        assert!(lane_ratio > 4.0, "lane reduction only {lane_ratio:.1}x");
    }

    #[test]
    fn interp_faster_than_raw_in_sim_time() {
        let (panel, targets) = problem(5, 8, 101, 2);
        let raw = run_plane(EngineSpec::Event, &panel, &targets, &cfg());
        let itp = run_interp(&panel, &targets, &cfg());
        assert!(
            itp.sim_seconds < raw.sim_seconds,
            "interp {} vs raw {}",
            itp.sim_seconds,
            raw.sim_seconds
        );
    }
}
