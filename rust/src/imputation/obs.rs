//! Shared target-observation storage.
//!
//! On the real cluster the host injects each target haplotype's annotated
//! bases into the vertices step by step (Algorithm 1 line 26, "Inject next
//! target haplotype").  In the simulator the full observation matrix lives in
//! one shared allocation (it models the board DRAM the panel/targets are
//! staged in) and vertices read their own marker's column on demand.

use std::sync::Arc;

use crate::model::panel::TargetHaplotype;

/// Dense `[n_targets × n_mark]` observation matrix: -1 unannotated, else 0/1.
#[derive(Debug)]
pub struct ObsMatrix {
    n_targets: usize,
    n_mark: usize,
    obs: Vec<i8>,
}

impl ObsMatrix {
    pub fn from_targets(targets: &[TargetHaplotype]) -> Arc<ObsMatrix> {
        assert!(!targets.is_empty(), "need at least one target");
        let n_mark = targets[0].n_mark();
        let mut obs = Vec::with_capacity(targets.len() * n_mark);
        for t in targets {
            assert_eq!(t.n_mark(), n_mark, "ragged target set");
            obs.extend_from_slice(&t.obs);
        }
        Arc::new(ObsMatrix {
            n_targets: targets.len(),
            n_mark,
            obs,
        })
    }

    #[inline]
    pub fn get(&self, target: u32, mark: u32) -> i8 {
        debug_assert!((target as usize) < self.n_targets);
        debug_assert!((mark as usize) < self.n_mark);
        self.obs[target as usize * self.n_mark + mark as usize]
    }

    pub fn n_targets(&self) -> usize {
        self.n_targets
    }

    pub fn n_mark(&self) -> usize {
        self.n_mark
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t1 = TargetHaplotype::new(vec![-1, 0, 1]);
        let t2 = TargetHaplotype::new(vec![1, -1, -1]);
        let m = ObsMatrix::from_targets(&[t1, t2]);
        assert_eq!(m.n_targets(), 2);
        assert_eq!(m.n_mark(), 3);
        assert_eq!(m.get(0, 0), -1);
        assert_eq!(m.get(0, 2), 1);
        assert_eq!(m.get(1, 0), 1);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged() {
        ObsMatrix::from_targets(&[
            TargetHaplotype::new(vec![0]),
            TargetHaplotype::new(vec![0, 1]),
        ]);
    }
}
