//! Closed-form step-time predictor.
//!
//! The DES is exact (within its cost model) but its host run-time grows with
//! total message count, which caps the panel sizes it can sweep.  The paper's
//! largest configurations (49,152+ threads, 10,000 targets) are reached by
//! this analytic model instead: a steady-state bottleneck analysis of one
//! superstep, cross-validated against the DES on every panel the DES can run
//! (see rust/tests/cluster_invariants.rs and the calibrate bench) and
//! documented in EXPERIMENTS.md.
//!
//! Two execution regimes, selected by [`Workload::lane_width`]:
//!
//! * **`lane_width <= 1` — the paper's per-target pipeline.**  Per superstep
//!   every active column's vertices each receive the full fan-in, so the
//!   *busiest core* and the *busiest mailbox* process
//!
//!   - core:    v/core · [(fan_in+extra)·handler + sends·send_req + step]
//!   - mailbox: v/tile · (fan_in+extra) · ingress
//!
//!   and total time = (pipeline fill + targets + drain) · step.  This is the
//!   regime the calibration anchor (Fig 12, ≈270×) is stated in.
//!
//! * **`lane_width > 1` — the wave-batched plane (PR 5), pipelined lane
//!   groups (PR 6).**  A batch splits into `G = ceil(width / LANES)` lane
//!   groups of one SoA chunk each, injected one superstep apart into the
//!   same graph, so `G` wavefronts ride the column pipeline concurrently.
//!   Per superstep an active column's vertices each ingest one group's
//!   chunk (`fan_in` events of ≤ `LANES` lanes) and do that group's FP
//!   work, on top of the all-vertex step-handler floor; the extra
//!   wavefronts overlap in *space* (different columns, hence different
//!   cores under the column-major mapping), not on the busiest core.
//!   steps ≈ waves · ((G−1)·stagger + columns + slack) with the engine's
//!   default stagger of 1 — the pipeline-fill term is additive, which is
//!   exactly why a 64-wide batch takes ~`columns + 11` supersteps instead
//!   of 8 sequential sweeps of `columns` each.  Fewer, fatter events —
//!   the per-message overhead amortisation the DES measures as
//!   `lanes_delivered / copies_delivered`.

use crate::imputation::msg::LANES;
use crate::poets::costmodel::CostModel;
use crate::poets::scenario::ScenarioSpec;
use crate::poets::topology::ClusterConfig;

/// Which application variant to predict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppKind {
    Raw,
    /// Linear interpolation with the given mean section length (markers per
    /// anchor, e.g. 10 at ratio 1/10).
    Interp { section: usize },
}

/// Workload description for the predictor.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub n_hap: usize,
    pub n_mark: usize,
    pub n_targets: usize,
    pub states_per_thread: usize,
    /// Targets per wave (the session's batch width).  `1` models the
    /// paper's per-target pipelined plane; larger widths model the
    /// wave-batched plane (each engine batch sweeps the panel as one SoA
    /// wave) — see the module docs for the two regimes.
    pub lane_width: usize,
    pub kind: AppKind,
}

/// Predicted timing decomposition.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub steps: u64,
    pub core_cycles_per_step: u64,
    pub mailbox_cycles_per_step: u64,
    /// Busiest inter-board link occupancy on a boundary-crossing superstep
    /// (0 when the workload fits on one board).  In the per-target regime
    /// every steady-state superstep crosses, so this joins the per-step
    /// bottleneck max; in the wave regime crossings are sparse (twice per
    /// board boundary per wave) and are charged as an additive total
    /// instead.
    pub link_cycles_per_step: u64,
    pub barrier_cycles: u64,
    pub step_cycles: u64,
    pub total_cycles: u64,
    pub seconds: f64,
}

/// Predict the simulated wall-clock of one event-driven run on a
/// homogeneous cluster (every link at the cost model's base rate).
pub fn predict(w: &Workload, cluster: &ClusterConfig, cost: &CostModel) -> Prediction {
    predict_with_link(w, cluster, cost, (cost.board_link_serialize, cost.board_link_latency))
}

/// Predict on a heterogeneous what-if cluster ([`ScenarioSpec`]): the
/// scenario's shape knobs set the cluster and the *worst surviving link*
/// sets the link-bound term — a pessimistic bound that tracks the DES
/// because dimension-ordered routing funnels boundary traffic through the
/// slowest column cut.  The spec must be valid (specs built via
/// `ScenarioSpec::parse` already are).
pub fn predict_scenario(w: &Workload, spec: &ScenarioSpec, cost: &CostModel) -> Prediction {
    let cluster = spec.cluster();
    let link = spec.worst_link_cost(&cluster, cost);
    let mut p = predict_with_link(w, &cluster, cost, link);
    let recovery = fault_overhead(spec, p.steps, p.step_cycles);
    if recovery > 0 {
        p.total_cycles += recovery;
        p.seconds = p.total_cycles as f64 / cluster.clock_hz;
    }
    p
}

/// First-order recovery-cost regime for deterministic fault schedules
/// (PR 10): the additive cycles a fault plan charges on top of the
/// fault-free run, mirroring the DES accounting in `poets::fault`.
///
/// * A tile failure at superstep `s` replays `s mod K` supersteps from the
///   last barrier-aligned checkpoint (checkpoint capture is free — modelled
///   as background DMA — so only the replay and the restore scatter cost
///   cycles).  State bytes are workload-dependent; the constant restore
///   base is the analytic stand-in, which keeps the model a lower bound and
///   well inside the topology gate band.
/// * A lossy link drops each crossing with probability `p`; every drop is
///   retransmitted at the next barrier (NACK round trip) and stalls the
///   waiting wave column for about one superstep.  Expected drops ≈
///   `p × steps` — for the small `p` the scenario lab sweeps, a sub-percent
///   stretch.  Duplicates are suppressed at the mailbox and only pay a
///   second traversal, which is below this model's resolution.
fn fault_overhead(spec: &ScenarioSpec, steps: u64, step_cycles: u64) -> u64 {
    use crate::poets::fault::{DEFAULT_CKPT_INTERVAL, NACK_PENALTY_CYCLES, RESTORE_BASE_CYCLES};
    if !spec.has_faults() {
        return 0;
    }
    let k = spec.ckpt_interval.unwrap_or(DEFAULT_CKPT_INTERVAL).max(1);
    let mut extra = 0u64;
    for f in &spec.fail_tiles {
        let replayed = (f.step % k).min(steps);
        extra += replayed * step_cycles + RESTORE_BASE_CYCLES;
    }
    for l in &spec.drop_links {
        let expected = (l.p * steps as f64).ceil() as u64;
        extra += expected * (step_cycles + NACK_PENALTY_CYCLES);
    }
    extra
}

/// Shared core: `link = (serialize, latency)` of the slowest link that
/// cross-board traffic can be forced through.
fn predict_with_link(
    w: &Workload,
    cluster: &ClusterConfig,
    cost: &CostModel,
    link: (u64, u64),
) -> Prediction {
    let h = w.n_hap as u64;
    // Graph columns and per-vertex per-target traffic by app kind.
    let (columns, fan_in, sends_per_vertex, flops_per_msg, section) = match w.kind {
        // Raw: α fan-in H, β fan-in H, ~1 posterior unicast in, 3 sends out.
        AppKind::Raw => (w.n_mark as u64, 2 * h + 1, 3u64, 2u64, 0u64),
        // Interp: anchor grid columns; extra Section/HitVec/Tot traffic ≈ 3
        // unicasts in/out per vertex wave.
        AppKind::Interp { section } => (
            (w.n_mark / section.max(1)).max(2) as u64,
            2 * h + 4,
            6u64,
            2u64,
            section as u64,
        ),
    };
    let n_vertices = columns * h;

    // Occupied threads under soft-scheduling.
    let threads_used = (n_vertices as usize)
        .div_ceil(w.states_per_thread)
        .min(cluster.total_threads()) as u64;
    let threads_per_core = cluster.threads_per_core as u64;
    let cores_used = threads_used.div_ceil(threads_per_core).max(1);
    let tiles_used = threads_used
        .div_ceil(cluster.threads_per_tile() as u64)
        .max(1);

    let v_per_core = n_vertices.div_ceil(cores_used);
    let v_per_tile = n_vertices.div_ceil(tiles_used);
    let barrier = cost.barrier(threads_used as usize);

    // Link-bound term: boards the mapping actually occupies, and how one
    // wavefront column's traffic groups onto destination tiles (column-major
    // mapping → a board boundary separates two adjacent columns, and the
    // boundary link serialises h senders × col_tiles multicast groups).
    let boards_used = threads_used
        .div_ceil(cluster.threads_per_board() as u64)
        .max(1);
    let col_threads = h.div_ceil(w.states_per_thread as u64).max(1);
    let col_tiles = col_threads
        .div_ceil(cluster.threads_per_tile() as u64)
        .max(1);
    let (link_ser, link_lat) = link;
    let link_cycles = if boards_used > 1 { h * col_tiles * link_ser } else { 0 };

    let (steps, core_cycles, mailbox_cycles, link_step, link_extra) = if w.lane_width <= 1 {
        // ----- per-target pipelined regime (the paper's design) ----------
        // Steady state: every column is mid-wave, so each vertex handles one
        // full fan-in per superstep (×2 while α and β waves overlap — they
        // do, so fan_in already counts both directions).
        let handler = cost.handler(flops_per_msg);
        let core_cycles = v_per_core
            * (fan_in * handler + sends_per_vertex * cost.send_request
                + cost.handler(0) /* step handler */);
        let mailbox_cycles = v_per_tile * fan_in * cost.mailbox_ingress;
        // Pipeline: fill takes `columns` steps, then ~1 target completes per
        // step, plus a drain tail of `columns`.
        let steps = columns + w.n_targets as u64 + columns;
        // Steady state keeps every column (hence every board boundary)
        // streaming, so the worst link competes with core and mailbox for
        // the per-step bottleneck.
        (steps, core_cycles, mailbox_cycles, link_cycles, 0)
    } else {
        // ----- wave-batched regime (PR 5), pipelined groups (PR 6) -------
        let lanes = w.lane_width.min(w.n_targets.max(1)) as u64;
        let waves = (w.n_targets.max(1) as u64).div_ceil(lanes);
        // A batch wider than one SoA chunk splits into G lane groups
        // injected `stagger` supersteps apart; each wavefront column then
        // carries ONE group's chunk per superstep (≤ LANES lanes), and the
        // G concurrent wavefronts occupy G *different* columns.
        let groups = lanes.div_ceil(LANES as u64);
        let group_lanes = lanes.min(LANES as u64);
        let stagger = 1u64; // the engine's RawAppConfig::default() stagger
        // Only the wavefront columns are active per superstep.  How many of
        // an active column's H vertices share one core / one tile under the
        // column-major manual mapping (`col_threads`/`col_tiles` hoisted
        // above for the link term):
        let col_cores = col_threads.div_ceil(threads_per_core).max(1);
        let v_active_per_core = h.div_ceil(col_cores);
        let v_active_per_tile = h.div_ceil(col_tiles);
        // Per active vertex per superstep: one group's wave = H senders ×
        // one chunk event each; that group's FP work (reduce + emission +
        // posterior ≈ group_lanes·(2H+2), plus the section blend on the
        // interp plane); sends = own chunk (+ per-target hit vectors on
        // interp).
        let events_in = fan_in;
        let flops = group_lanes * (2 * h + 2) + group_lanes * 3 * section;
        let sends = sends_per_vertex.min(3) + if section > 0 { group_lanes } else { 0 };
        let core_active = v_active_per_core
            * (events_in * cost.handler(0) + flops * cost.flop + sends * cost.send_request);
        // Idle floor: every resident vertex's step handler runs each
        // superstep (the DES bulk-charges count·handler(0) per core).
        let step_floor = v_per_core * cost.handler(0);
        let core_cycles = core_active + step_floor;
        let mailbox_cycles = v_active_per_tile * events_in * cost.mailbox_ingress;
        // One wave of G staggered groups sweeps in ~(G−1)·stagger + columns
        // supersteps (+ pairing/drain slack): the pipeline fill is additive,
        // not multiplicative.
        let steps = waves * ((groups - 1) * stagger + columns + 4);
        // A wavefront crosses each board boundary twice per sweep (α
        // forward, β backward) and pays the boundary serialisation plus
        // one link latency there — sparse events, so an additive total
        // rather than a per-step bottleneck term.
        let wave_link_total = if boards_used > 1 {
            waves * 2 * (boards_used - 1) * (link_cycles + link_lat)
        } else {
            0
        };
        (steps, core_cycles, mailbox_cycles, 0, wave_link_total)
    };

    let step = core_cycles.max(mailbox_cycles).max(link_step) + barrier;
    let total = steps * step + link_extra;
    Prediction {
        steps,
        core_cycles_per_step: core_cycles,
        mailbox_cycles_per_step: mailbox_cycles,
        link_cycles_per_step: link_cycles,
        barrier_cycles: barrier,
        step_cycles: step,
        total_cycles: total,
        seconds: total as f64 / cluster.clock_hz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imputation::app::RawAppConfig;
    use crate::poets::desim::SimConfig;
    use crate::session::{EngineSpec, ImputeSession};
    use crate::util::rng::Rng;
    use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

    #[test]
    fn predictor_tracks_des_on_small_panel() {
        // Wave regime: the session runs all T targets as one lane group, so
        // the predictor is checked at lane_width = n_targets.
        let pcfg = PanelConfig {
            n_hap: 8,
            n_mark: 24,
            annot_ratio: 0.2,
            maf: 0.2,
            seed: 11,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&pcfg);
        let mut rng = Rng::new(99);
        let targets: Vec<_> = generate_targets(&panel, &pcfg, 60, &mut rng)
            .into_iter()
            .map(|c| c.masked)
            .collect();
        let cluster = crate::poets::topology::ClusterConfig::with_boards(1);
        let cfg = RawAppConfig {
            cluster,
            states_per_thread: 1,
            sim: SimConfig::default(),
            ..RawAppConfig::default()
        };
        // DES cross-check through the session pipeline (analytic::Workload
        // is this module's own shape descriptor, hence the full path).
        let des = ImputeSession::new(crate::session::Workload::from_parts(panel, targets))
            .engine(EngineSpec::Event)
            .app_config(cfg)
            .run()
            .expect("event plane is always available");
        let pred = predict(
            &Workload {
                n_hap: 8,
                n_mark: 24,
                n_targets: 60,
                states_per_thread: 1,
                lane_width: 60,
                kind: AppKind::Raw,
            },
            &cluster,
            &CostModel::default(),
        );
        let des_seconds = des.sim_seconds.expect("event plane reports simulated time");
        let ratio = pred.seconds / des_seconds;
        assert!(
            (0.3..3.0).contains(&ratio),
            "analytic {}s vs DES {}s (ratio {ratio})",
            pred.seconds,
            des_seconds
        );
    }

    #[test]
    fn predictor_monotone_in_targets_and_size() {
        let cluster = crate::poets::topology::ClusterConfig::poets_48();
        let cost = CostModel::default();
        for lane_width in [1usize, 1000] {
            let base = Workload {
                n_hap: 22,
                n_mark: 2234,
                n_targets: 100,
                states_per_thread: 1,
                lane_width: lane_width.min(100),
                kind: AppKind::Raw,
            };
            let p0 = predict(&base, &cluster, &cost);
            let more_targets = predict(
                &Workload {
                    n_targets: 1000,
                    lane_width,
                    ..base
                },
                &cluster,
                &cost,
            );
            assert!(
                more_targets.seconds > p0.seconds,
                "lane_width {lane_width}: more targets must cost more"
            );
        }
        let base = Workload {
            n_hap: 22,
            n_mark: 2234,
            n_targets: 100,
            states_per_thread: 1,
            lane_width: 1,
            kind: AppKind::Raw,
        };
        let p0 = predict(&base, &cluster, &cost);
        let more_soft = predict(
            &Workload {
                states_per_thread: 10,
                n_hap: 70,
                n_mark: 7022,
                ..base
            },
            &cluster,
            &cost,
        );
        assert!(more_soft.step_cycles > p0.step_cycles);
    }

    #[test]
    fn wave_batching_predicts_fewer_cycles_when_targets_dominate() {
        // In the T ≳ M regime a single wave (M+slack steps, amortised
        // events) beats the per-target pipeline (2M+T steps).  The trade
        // flips at chromosome scale with T ≪ M·LANES — only the wavefront
        // columns are busy per superstep — which is why the paper-anchor
        // figures keep lane_width = 1 (see the calibrate bench).
        let cluster = crate::poets::topology::ClusterConfig::with_boards(1);
        let cost = CostModel::default();
        let shape = Workload {
            n_hap: 8,
            n_mark: 24,
            n_targets: 60,
            states_per_thread: 1,
            lane_width: 1,
            kind: AppKind::Raw,
        };
        let per_target = predict(&shape, &cluster, &cost);
        let batched = predict(
            &Workload {
                lane_width: 60,
                ..shape
            },
            &cluster,
            &cost,
        );
        assert!(
            batched.total_cycles < per_target.total_cycles,
            "batched {} vs per-target {}",
            batched.total_cycles,
            per_target.total_cycles
        );
    }

    #[test]
    fn pipelined_groups_beat_sequential_waves_in_steps() {
        // 64 targets on a 1000-column panel: one 64-wide batch is 8 lane
        // groups pipelined one superstep apart through a single sweep
        // (~columns + 11 steps), while batch(LANES) is 8 sequential sweeps
        // of ~columns each.  The analytic step counts must reflect the
        // ≥ 2x superstep cut the desim_hotpath smoke gate enforces on the
        // DES at exactly this shape.
        let cluster = crate::poets::topology::ClusterConfig::with_boards(1);
        let cost = CostModel::default();
        let shape = Workload {
            n_hap: 8,
            n_mark: 1000,
            n_targets: 64,
            states_per_thread: 8,
            lane_width: 64,
            kind: AppKind::Raw,
        };
        let pipelined = predict(&shape, &cluster, &cost);
        let sequential = predict(
            &Workload {
                lane_width: LANES,
                ..shape
            },
            &cluster,
            &cost,
        );
        assert!(
            pipelined.steps * 2 <= sequential.steps,
            "pipelined {} steps vs sequential {}",
            pipelined.steps,
            sequential.steps
        );
        // Same per-superstep cost (one chunk per wavefront column either
        // way), so the step cut carries straight through to total cycles.
        assert_eq!(pipelined.step_cycles, sequential.step_cycles);
        assert!(pipelined.total_cycles < sequential.total_cycles);
    }

    #[test]
    fn scenario_baseline_matches_homogeneous_predict() {
        let spec = ScenarioSpec::parse("name=base,boards=4").expect("spec");
        let w = Workload {
            n_hap: 22,
            n_mark: 2234,
            n_targets: 100,
            states_per_thread: 1,
            lane_width: 1,
            kind: AppKind::Raw,
        };
        let cost = CostModel::default();
        let a = predict(&w, &spec.cluster(), &cost);
        let b = predict_scenario(&w, &spec, &cost);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.link_cycles_per_step, b.link_cycles_per_step);
    }

    #[test]
    fn degraded_links_push_the_predictor_link_bound() {
        // Small boards force cross-board traffic; 500x-slower links must
        // both dominate the per-step bottleneck and raise the total.
        let base = ScenarioSpec::parse("name=base,boards=4,tiles=2,cores=1,threads=2")
            .expect("spec");
        let slow = ScenarioSpec::parse("name=slow,boards=4,tiles=2,cores=1,threads=2,bw=0.002")
            .expect("spec");
        let w = Workload {
            n_hap: 8,
            n_mark: 24,
            n_targets: 60,
            states_per_thread: 4,
            lane_width: 1,
            kind: AppKind::Raw,
        };
        let cost = CostModel::default();
        let p_base = predict_scenario(&w, &base, &cost);
        let p_slow = predict_scenario(&w, &slow, &cost);
        assert!(p_base.link_cycles_per_step > 0, "multi-board run must cross links");
        // serialize: round(11 / 0.002) = 5500 = 500 x the base 11.
        assert_eq!(p_slow.link_cycles_per_step, p_base.link_cycles_per_step * 500);
        assert!(p_slow.total_cycles > p_base.total_cycles);
        assert!(
            p_slow.link_cycles_per_step
                > p_slow.core_cycles_per_step.max(p_slow.mailbox_cycles_per_step),
            "500x degradation must be link-bound: link {} core {} mailbox {}",
            p_slow.link_cycles_per_step,
            p_slow.core_cycles_per_step,
            p_slow.mailbox_cycles_per_step
        );
        // Wave regime: the link charge is additive, so degrading links
        // still raises the total.
        let wv = Workload { lane_width: 60, ..w };
        let w_base = predict_scenario(&wv, &base, &cost);
        let w_slow = predict_scenario(&wv, &slow, &cost);
        assert!(w_slow.total_cycles > w_base.total_cycles);
    }

    #[test]
    fn fault_schedules_charge_recovery_on_top_of_the_clean_run() {
        let w = Workload {
            n_hap: 8,
            n_mark: 24,
            n_targets: 60,
            states_per_thread: 4,
            lane_width: 1,
            kind: AppKind::Raw,
        };
        let cost = CostModel::default();
        let shape = "boards=2,tiles=2,cores=1,threads=2";
        let clean = ScenarioSpec::parse(&format!("name=clean,{shape}")).expect("spec");
        let faulty = ScenarioSpec::parse(&format!(
            "name=faulty,{shape},failtile=0.1@40,ckpt=16,drop=0E:0.01@7"
        ))
        .expect("spec");
        let p_clean = predict_scenario(&w, &clean, &cost);
        let p_fault = predict_scenario(&w, &faulty, &cost);
        assert_eq!(
            p_clean.step_cycles, p_fault.step_cycles,
            "faults are additive — the steady-state step is unchanged"
        );
        assert!(p_fault.total_cycles > p_clean.total_cycles);
        // Replay is bounded by the checkpoint interval (40 % 16 = 8
        // supersteps + the restore base) and the drop stretch is ~p of the
        // run — together well inside the topology gate band.
        assert!(
            p_fault.total_cycles < p_clean.total_cycles * 4,
            "recovery {} vs clean {}",
            p_fault.total_cycles,
            p_clean.total_cycles
        );
        // A tighter checkpoint cadence bounds replay to zero supersteps:
        // cheaper than ckpt=16 but still above fault-free (restore + drops).
        let tight = ScenarioSpec::parse(&format!(
            "name=tight,{shape},failtile=0.1@40,ckpt=1,drop=0E:0.01@7"
        ))
        .expect("spec");
        let p_tight = predict_scenario(&w, &tight, &cost);
        assert!(p_tight.total_cycles < p_fault.total_cycles);
        assert!(p_tight.total_cycles > p_clean.total_cycles);
    }

    #[test]
    fn interp_predicts_fewer_cycles_than_raw() {
        let cluster = crate::poets::topology::ClusterConfig::poets_48();
        let cost = CostModel::default();
        for lane_width in [1usize, 1000] {
            let raw = predict(
                &Workload {
                    n_hap: 70,
                    n_mark: 7000,
                    n_targets: 1000,
                    states_per_thread: 10,
                    lane_width,
                    kind: AppKind::Raw,
                },
                &cluster,
                &cost,
            );
            let itp = predict(
                &Workload {
                    n_hap: 70,
                    n_mark: 7000,
                    n_targets: 1000,
                    states_per_thread: 10,
                    lane_width,
                    kind: AppKind::Interp { section: 10 },
                },
                &cluster,
                &cost,
            );
            assert!(
                itp.total_cycles * 4 < raw.total_cycles,
                "lane_width {lane_width}: interp {} vs raw {}",
                itp.total_cycles,
                raw.total_cycles
            );
        }
    }
}
