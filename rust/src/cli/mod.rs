//! Command-line interface (leader entrypoint).
//!
//! No `clap` in the offline environment — [`args`] is a small typed flag
//! parser, [`commands`] implements the subcommands.  `poets-impute help`
//! prints usage.

pub mod args;
pub mod commands;

use args::Args;

/// Run the CLI; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    match dispatch(&argv) {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("error: {msg}");
            2
        }
    }
}

fn dispatch(argv: &[String]) -> Result<i32, String> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(String::as_str) {
        Some("impute") => commands::cmd_impute(&args),
        Some("panel") => commands::cmd_panel(&args),
        Some("validate") => commands::cmd_validate(&args),
        Some("trace") => commands::cmd_trace(&args),
        Some("serve") => commands::cmd_serve(&args),
        Some("bench-serve") => commands::cmd_bench_serve(&args),
        Some("bench") => commands::cmd_bench(&args),
        Some("ablate") => commands::cmd_ablate(&args),
        Some("project") => commands::cmd_project(&args),
        Some("info") => commands::cmd_info(&args),
        Some("help") | None => {
            println!("{}", commands::USAGE);
            Ok(0)
        }
        Some(other) => Err(format!("unknown command {other:?}\n{}", commands::USAGE)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn help_succeeds() {
        assert_eq!(run(argv(&["help"])), 0);
        assert_eq!(run(argv(&[])), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(argv(&["frobnicate"])), 2);
    }

    #[test]
    fn unknown_flag_fails() {
        assert_eq!(run(argv(&["impute", "--bogus", "1"])), 2);
    }

    #[test]
    fn impute_event_small_runs() {
        assert_eq!(
            run(argv(&[
                "impute", "--hap", "8", "--mark", "31", "--targets", "2", "--engine", "event",
                "--boards", "1", "--spt", "8", "--json"
            ])),
            0
        );
    }

    #[test]
    fn info_runs() {
        assert_eq!(run(argv(&["info"])), 0);
    }
}
