//! CLI subcommand implementations.

use crate::bench::{self, FigOpts, X86Cost};
use crate::imputation::app::{RawAppConfig, run_raw};
use crate::imputation::interp_app::run_interp;
use crate::model::accuracy;
use crate::model::baseline::{Baseline, ImputeOut, Method};
use crate::model::interpolation::impute_interp;
use crate::poets::desim::SimConfig;
use crate::poets::topology::ClusterConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::{Table, fmt_count, fmt_secs};
use crate::util::timed;
use crate::workload::panelgen::{PanelConfig, TargetCase, generate_panel, generate_targets};

use super::args::Args;

pub const USAGE: &str = "\
poets-impute — event-driven genotype imputation on a simulated POETS cluster

USAGE:
  poets-impute <COMMAND> [FLAGS]

COMMANDS:
  impute     run imputation on a synthetic workload and score accuracy
             --hap N --mark N --targets N --seed S --annot-ratio R
             --engine baseline|rank1|event|interp|xla --boards B --spt N
             --threads N (host workers for the DES deliver/step phases;
             results are thread-count invariant) [--json]
  validate   run ALL engines on one workload and cross-check dosages
             --hap N --mark N --targets N --seed S
  bench      regenerate a paper experiment:
             fig11|fig12|fig13|calibrate|sync-overhead
             [--boards 1,2,..] [--spt 1,2,..] [--full-targets N]
             [--des-targets N] [--des-states N] [--skip-des] [--json]
  ablate     design-choice ablations (mapping locality, hardware multicast)
             [--hap N] [--mark N] [--targets N] [--boards B] [--spt N]
  project    capacity + next-gen (Stratix-10) cluster projection (paper §6.3)
             [--states N]
  info       print cluster topology + artifact inventory
  help       this text
";

fn panel_cfg(args: &Args) -> Result<PanelConfig, String> {
    Ok(PanelConfig {
        n_hap: args.get("hap", 16usize)?,
        n_mark: args.get("mark", 101usize)?,
        maf: args.get("maf", 0.05f64)?,
        annot_ratio: args.get("annot-ratio", 0.1f64)?,
        seed: args.get("seed", 2023u64)?,
        ..PanelConfig::default()
    })
}

fn make_workload(cfg: &PanelConfig, n_targets: usize) -> (crate::model::panel::ReferencePanel, Vec<TargetCase>) {
    let panel = generate_panel(cfg);
    let mut rng = Rng::new(cfg.seed ^ 0x7A96);
    let cases = generate_targets(&panel, cfg, n_targets, &mut rng);
    (panel, cases)
}

pub fn cmd_impute(args: &Args) -> Result<i32, String> {
    let cfg = panel_cfg(args)?;
    let n_targets = args.get("targets", 4usize)?;
    let engine = args.get_str("engine", "event");
    let boards = args.get("boards", 4usize)?;
    let spt = args.get("spt", 8usize)?;
    let threads = args.get("threads", 1usize)?;
    let as_json = args.has("json");
    args.reject_unknown()?;

    let (panel, cases) = make_workload(&cfg, n_targets);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();

    let app = RawAppConfig {
        cluster: ClusterConfig::with_boards(boards),
        states_per_thread: spt,
        sim: SimConfig::default(),
        ..RawAppConfig::default()
    }
    .with_threads(threads);
    let b = Baseline::default();

    let (dosages, host_secs, sim_secs): (Vec<Vec<f32>>, f64, Option<f64>) = match engine.as_str() {
        "baseline" => {
            let (outs, t) = timed(|| b.impute_batch::<f32>(&panel, &targets, Method::DenseThreeLoop));
            (outs.into_iter().map(|o| o.dosage).collect(), t, None)
        }
        "rank1" => {
            let (outs, t) = timed(|| b.impute_batch::<f32>(&panel, &targets, Method::Rank1));
            (outs.into_iter().map(|o| o.dosage).collect(), t, None)
        }
        "interp" => {
            let (outs, t) = timed(|| {
                targets
                    .iter()
                    .map(|t| impute_interp::<f32>(&b, &panel, t, Method::Rank1).dosage)
                    .collect::<Vec<_>>()
            });
            (outs, t, None)
        }
        "event" => {
            let (out, t) = timed(|| run_raw(&panel, &targets, &app));
            (out.dosages.clone(), t, Some(out.sim_seconds))
        }
        "event-interp" => {
            let (out, t) = timed(|| run_interp(&panel, &targets, &app));
            (out.dosages.clone(), t, Some(out.sim_seconds))
        }
        "xla" => {
            let rt = crate::runtime::Runtime::open_default().map_err(|e| e.to_string())?;
            let mut imp = crate::runtime::XlaImputer::new(rt, app.params);
            let (outs, t) = timed(|| imp.impute_batch(&panel, &targets));
            (outs.map_err(|e| e.to_string())?, t, None)
        }
        other => return Err(format!("unknown engine {other:?}\n{USAGE}")),
    };

    let accs: Vec<_> = cases
        .iter()
        .zip(&dosages)
        .map(|(c, d)| accuracy::score(d, &c.truth, &c.masked))
        .collect();
    let agg = accuracy::aggregate(&accs);

    if as_json {
        let mut j = Json::obj();
        j.set("engine", engine.clone())
            .set("panel", format!("{}x{}", panel.n_hap(), panel.n_mark()))
            .set("targets", n_targets)
            .set("host_seconds", host_secs)
            .set("concordance", agg.concordance)
            .set("minor_concordance", agg.minor_concordance)
            .set("dosage_r2", agg.dosage_r2);
        if let Some(s) = sim_secs {
            j.set("poets_sim_seconds", s);
        }
        println!("{}", j.pretty());
    } else {
        println!(
            "engine={engine} panel={}x{} ({} states) targets={n_targets}",
            panel.n_hap(),
            panel.n_mark(),
            fmt_count(panel.n_states() as u64)
        );
        println!(
            "accuracy: concordance={:.4} minor={:.4} dosage_r2={:.4} (scored {} markers)",
            agg.concordance,
            agg.minor_concordance,
            agg.dosage_r2,
            fmt_count(agg.n_scored as u64)
        );
        println!("host wall-clock: {}", fmt_secs(host_secs));
        if let Some(s) = sim_secs {
            println!("simulated POETS wall-clock: {}", fmt_secs(s));
        }
    }
    Ok(0)
}

pub fn cmd_validate(args: &Args) -> Result<i32, String> {
    let cfg = panel_cfg(args)?;
    let n_targets = args.get("targets", 3usize)?;
    args.reject_unknown()?;
    let (panel, cases) = make_workload(&cfg, n_targets);
    let targets: Vec<_> = cases.iter().map(|c| c.masked.clone()).collect();
    let b = Baseline::default();
    let app = RawAppConfig {
        cluster: ClusterConfig::with_boards(2),
        states_per_thread: 16,
        ..RawAppConfig::default()
    };

    let dense: Vec<ImputeOut<f32>> = b.impute_batch(&panel, &targets, Method::DenseThreeLoop);
    let rank1: Vec<ImputeOut<f32>> = b.impute_batch(&panel, &targets, Method::Rank1);
    let event = run_raw(&panel, &targets, &app);
    let xla = crate::runtime::Runtime::open_default()
        .ok()
        .map(|rt| crate::runtime::XlaImputer::new(rt, app.params))
        .and_then(|mut i| i.impute_batch(&panel, &targets).ok());

    let mut t = Table::new(&["pair", "max |Δdosage|"]);
    let maxdiff = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs() as f64)
            .fold(0.0, f64::max)
    };
    let mut worst: f64 = 0.0;
    for ti in 0..n_targets {
        let d = maxdiff(&dense[ti].dosage, &rank1[ti].dosage);
        worst = worst.max(d);
    }
    t.row(vec!["dense vs rank1".into(), format!("{worst:.2e}")]);
    let mut w2: f64 = 0.0;
    for ti in 0..n_targets {
        w2 = w2.max(maxdiff(&dense[ti].dosage, &event.dosages[ti]));
    }
    t.row(vec!["dense vs event-driven".into(), format!("{w2:.2e}")]);
    let mut w3 = f64::NAN;
    if let Some(x) = &xla {
        w3 = 0.0;
        for ti in 0..n_targets {
            w3 = w3.max(maxdiff(&dense[ti].dosage, &x[ti]));
        }
        t.row(vec!["dense vs XLA artifact".into(), format!("{w3:.2e}")]);
    } else {
        t.row(vec!["dense vs XLA artifact".into(), "skipped (no artifacts / H not canonical)".into()]);
    }
    println!("{}", t.render());
    let ok = worst < 1e-4 && w2 < 1e-3 && (w3.is_nan() || w3 < 1e-3);
    println!("validate: {}", if ok { "OK" } else { "MISMATCH" });
    Ok(if ok { 0 } else { 1 })
}

pub fn cmd_bench(args: &Args) -> Result<i32, String> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| format!("bench needs a figure name\n{USAGE}"))?;
    let opts = FigOpts {
        des_states_per_board: args.get("des-states", 128usize)?,
        des_targets: args.get("des-targets", 12usize)?,
        full_targets: args.get("full-targets", 10_000usize)?,
        skip_des: args.has("skip-des"),
        seed: args.get("seed", 2023u64)?,
    };
    let as_json = args.has("json");
    let boards = args.get_list("boards", &[1, 2, 4, 8, 16, 32, 48])?;
    let spt = args.get_list("spt", &[1, 2, 5, 10, 20, 40])?;
    args.reject_unknown()?;

    let needs_x86 = which != "sync-overhead";
    let x86 = if needs_x86 {
        eprintln!("calibrating x86 baseline throughput...");
        X86Cost::measure_default()
    } else {
        X86Cost {
            dense_macs_per_s: 1.0,
            rank1_macs_per_s: 1.0,
        }
    };

    let report = match which.as_str() {
        "fig11" => Some(bench::fig11(&boards, &opts, &x86)),
        "fig12" => Some(bench::fig12(&spt, &opts, &x86)),
        "fig13" => Some(bench::fig13(&boards, &opts, &x86)),
        "calibrate" => {
            println!("{}", bench::calibrate::report(&x86));
            None
        }
        "sync-overhead" => {
            println!("{}", bench::sync_overhead(&opts));
            None
        }
        other => return Err(format!("unknown bench {other:?}\n{USAGE}")),
    };
    if let Some(r) = report {
        if as_json {
            println!("{}", r.to_json().pretty());
        } else {
            println!("{}", r.render());
            println!(
                "notes: 'full' columns are the analytic model at paper scale \
                 (aspect 100:1, {} targets); '~' marks extrapolated x86 time; \
                 DES columns are exact simulation at reduced scale.",
                opts.full_targets
            );
        }
    }
    Ok(0)
}

pub fn cmd_ablate(args: &Args) -> Result<i32, String> {
    let n_hap = args.get("hap", 8usize)?;
    let n_mark = args.get("mark", 80usize)?;
    let n_targets = args.get("targets", 4usize)?;
    let boards = args.get("boards", 4usize)?;
    let spt = args.get("spt", 2usize)?;
    let seed = args.get("seed", 2023u64)?;
    args.reject_unknown()?;
    let rows = crate::bench::ablation::mapping_ablation(n_hap, n_mark, n_targets, boards, spt, seed);
    let mcast = crate::bench::ablation::multicast_ablation(n_hap, n_mark, n_targets);
    println!("{}", crate::bench::ablation::report(&rows, mcast));
    Ok(0)
}

pub fn cmd_project(args: &Args) -> Result<i32, String> {
    use crate::poets::capacity::{GENUINE_PANEL_STATES, MemoryModel, capacity, stratix10_next_gen};
    let states = args.get("states", GENUINE_PANEL_STATES)?;
    args.reject_unknown()?;
    let mem = MemoryModel::default();
    let mut t = Table::new(&["cluster", "threads", "DRAM total", "fits?", "scale needed"]);
    for (name, c) in [
        ("POETS 48x Stratix-V", ClusterConfig::poets_48()),
        ("next-gen Stratix-10", stratix10_next_gen()),
    ] {
        let r = capacity(states, &c, &mem);
        t.row(vec![
            name.into(),
            fmt_count(c.total_threads() as u64),
            format!("{} GiB", c.dram_per_board as u64 * c.n_boards as u64 >> 30),
            if r.fits { "yes".into() } else { "NO".into() },
            format!("{:.1}x", r.scale_factor_needed),
        ]);
    }
    println!(
        "capacity projection for {} panel states (paper §6.3: genuine panels \
         need ~16x the current cluster):\n{}",
        fmt_count(states),
        t.render()
    );
    Ok(0)
}

pub fn cmd_info(args: &Args) -> Result<i32, String> {
    args.reject_unknown()?;
    let c = ClusterConfig::poets_48();
    println!(
        "POETS cluster model: {} boards ({}x{} grid), {} tiles/board, \
         {} cores/tile, {} threads/core = {} hardware threads @ {:.0} MHz",
        c.n_boards,
        c.board_grid.0,
        c.board_grid.1,
        c.tiles_per_board,
        c.cores_per_tile,
        c.threads_per_core,
        fmt_count(c.total_threads() as u64),
        c.clock_hz / 1e6
    );
    match crate::runtime::Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts ({}):", rt.manifest().artifacts.len());
            for a in &rt.manifest().artifacts {
                let ins: Vec<String> = a
                    .inputs
                    .iter()
                    .map(|t| format!("{}:{:?}", t.name, t.shape))
                    .collect();
                println!("  {} [{}]", a.name, ins.join(", "));
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(0)
}
