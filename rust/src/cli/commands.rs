//! CLI subcommand implementations — thin argument plumbing over the
//! [`session`](crate::session) pipeline.

use std::sync::Arc;
use std::time::Duration;

use crate::bench::{self, FigOpts, X86Cost};
use crate::genomics::gmap::GeneticMap;
use crate::genomics::packed::PackedPanel;
use crate::genomics::stream::run_streamed;
use crate::genomics::window::{WindowPlan, run_windowed_threads};
use crate::genomics::vcf::{self, VcfOptions};
use crate::model::baseline::{Baseline, Method};
use crate::model::interpolation::impute_interp;
use crate::obs::{TraceConfig, TraceFile};
use crate::poets::ScenarioSpec;
use crate::poets::topology::ClusterConfig;
use crate::serve::bench::{BenchServeOpts, OpenLoopOpts};
use crate::serve::{CoalescePolicy, PanelRegistry, ServeConfig, ShardedService, jsonl, net};
use crate::session::{EngineSpec, ImputeReport, ImputeSession, Workload};
use crate::util::json::Json;
use crate::util::table::{Table, fmt_count};
use crate::workload::panelgen::PanelConfig;

use super::args::Args;

pub const USAGE: &str = "\
poets-impute — event-driven genotype imputation on a simulated POETS cluster

All commands drive the unified session pipeline (rust/src/session/): one
Workload, one EngineSpec, one ImputeSession, one ImputeReport.  The serve
commands stack the multi-tenant service layer (rust/src/serve/) on top.

USAGE:
  poets-impute <COMMAND> [FLAGS]

COMMANDS:
  impute       run one engine on a workload and score accuracy
               --hap N --mark N --maf F (synthetic panel shape; ignored
               when --panel is given) --targets N --annot-ratio R
               --seed S (panel generation and target-minting seed)
               --panel SPEC (real panels: vcf:<path>, packed:<path>, a
               bare .vcf/.ppnl path, or a synth: spec — targets are
               minted as Li & Stephens mosaics of the panel, masked to
               the --annot-ratio grid, truth retained for accuracy
               scoring; --seed picks the mosaic draw)
               --window W --overlap V (slice the marker axis into
               overlapping W-marker windows, impute each, stitch dosages
               at overlap midpoints; 0 = unwindowed)
               --window-threads N (run windows on N host threads —
               windows are independent, stitch order is deterministic;
               multi-window interp plans are validated against the chip
               grid and misaligned geometry is a hard error)
               --stream (chromosome-scale execution of a --window plan:
               slice each window on a builder thread while the engine
               drains its predecessor, rendezvous backpressure bounds
               the working set to two windows / one live graph; dosages
               stay bit-identical to the materialised windowed run and
               the manifest gains a \"stream\" block with the measured
               peak_resident_windows / windows_streamed)
               --engine baseline|rank1|event|interp|xla (EngineSpec;
               interp is the event-driven linear-interpolation plane —
               the old spelling event-interp still parses, with a
               deprecation note; the x86 interpolation pipeline remains
               the interp plane's oracle in validate)
               --boards B --spt N (soft-scheduling states/thread)
               --scenario SPEC (run on a heterogeneous scenario cluster —
               shape + link-plane overlay, see SCENARIO LAB below; the
               spec's shape replaces --boards)
               --batch B (targets per engine batch; batches wider than
               the 8-lane wave split into lane groups pipelined through
               the SAME graph one superstep apart — default all at once.
               Dosages are batch-width invariant — width 1 reproduces
               per-target events.  sim_metrics reports the pipeline
               occupancy: busy_tile_steps / max_busy_tiles (tiles
               delivering events per superstep) and
               max_groups_in_flight)
               --threads N (host workers for the DES deliver/step phases;
               results are thread-count invariant)
               --trace PATH (observability: record the per-superstep,
               per-tile DES trace and write it to PATH as
               poets-impute/trace/v1 JSONL — provenance-stamped header
               line, then one compact record per superstep.  The trace
               is captured in the simulator's deterministic serial shard
               reduce, so at a fixed --batch width it is bit-identical
               for any --threads value; windowed/streamed plans absorb
               per-window traces into one multi-segment file.  Only the
               event plane records; other engines produce no trace and
               a warning is printed.  A recorded trace also puts a
               \"trace\" summary block in the manifest)
               [--json]  (emit the ImputeReport run manifest,
               schema poets-impute/impute-report/v1)
  panel        real-panel tooling (rust/src/genomics/):
               panel ingest <in.vcf> [out.ppnl]  parse a phased bi-allelic
                 VCF and write the bit-packed .ppnl panel (1 bit/allele,
                 checksummed; site metadata retained)
                 [--morgans-per-bp R]  physical->genetic rate (default 1e-8)
                 [--genetic-map PATH]  replace the flat rate with a real
                 genetic map (PLINK 'chr id cM bp' or HapMap 'bp rate cM'
                 layout, auto-detected): genetic distances become the map's
                 interpolated cM deltas, so hotspot structure survives into
                 the Li & Stephens transitions
               panel info <spec|path>  shape, memory and site summary of
                 any panel spec (vcf:/packed:/synth:; bare .vcf and .ppnl
                 paths are recognised)
  validate     run ALL engines on one workload and report per-engine
               max |Δdosage| against each engine's oracle
               --hap N --mark N --targets N --seed S
  trace        observability tooling over poets-impute/trace/v1 files
               (written by impute --trace PATH):
               trace summarize <file>  per-tile utilisation table,
                 queue-depth percentiles, a superstep activity histogram
                 and the per-link NoC table (events, busy cycles,
                 utilisation, queue high-water, top congested links);
                 truncated rings report steps_dropped explicitly;
                 malformed files fail with the offending line number
                 [--json]  machine-readable summary instead
                 (schema poets-impute/trace-summary/v1)
               trace export <file> --chrome [--out PATH]  convert to
                 Chrome trace_event JSON (loadable in Perfetto /
                 chrome://tracing; segments laid end-to-end on one
                 clock, one track per tile); prints to stdout unless
                 --out is given
  serve        multi-tenant imputation service: one JSON request per input
               line (stdin JSONL) or per length-framed TCP frame, one
               response per request, in request order (responses:
               serve-report/v1 on success, serve-error/v1 in-band on
               failure — error prefixes admission:/quota:/deadline: are the
               shed taxonomy).  Request:
               {\"id\":1, \"panel\":\"synth:hap=8,mark=21,annot=0.2,seed=7\",
                \"engine\":\"event\", \"synth_targets\":2, \"target_seed\":9}
               (or \"targets\":[[-1,0,1,..],..] for explicit observations;
               \"panel\" also accepts vcf:<path> / packed:<path> — a
               missing or corrupt file fails that request in-band)
               optional request fields: \"tenant\":\"name\" (token-bucket
               quota account), \"deadline_ms\":D (shed when the queue-age
               estimate or true age busts the budget), \"window\":W
               [\"overlap\":V] (stream per-window dosage rows as
               serve-report-part/v1 frames, then a terminal manifest),
               \"spans\":true (observability: the response's serve
               block gains a \"spans\" phase timeline — monotone µs
               offsets admitted/dequeued/minted/prepared/run/responded
               from the submit instant, plus coalesced_with and
               merged_wave; {\"stats\":true} snapshots also carry
               engine-cache hit/miss/eviction counters and log2-µs
               queue-wait / service-time histograms per shard)
               admin verbs: {\"stats\":true} -> serve-stats/v1 snapshot;
               {\"shutdown\":true} -> ack, stop accepting, drain, exit
               (closing stdin / the socket is the transport-level
               equivalent)
               --tcp ADDR (listen on ADDR, e.g. 127.0.0.1:7777 or :0 for
               an ephemeral port — logged to stderr; frames are a
               big-endian u32 length + the JSON document, byte-identical
               to the stdin line)
               --connect ADDR (client bridge: stdin lines -> frames,
               frames -> stdout lines; pipes work against a --tcp server.
               A connection lost mid-stream is re-established under capped
               exponential backoff and ONLY the unanswered requests are
               resubmitted — answered ones never re-execute)
               --shards N (panel-sharded worker pools: panel name hashes
               to a shard with its own queue, workers and engine cache)
               --quota-rate R --quota-burst B (per-tenant token buckets,
               R tokens/s, burst B; omit --quota-rate for no quotas)
               --workers N (pool threads per shard, default 2)
               --max-batch T (coalescer target budget; 1 = no coalescing.
               Coalesced event-plane groups merge member targets into ONE
               wave sweep — responses stay bit-identical to solo runs;
               synth_targets minting runs in the workers, so a slow
               file-backed panel never blocks the request stream)
               --linger-ms L (coalescer wait for batch-mates, default 2)
               --queue-cap N (admission bound per shard, default 1024)
               --boards B --spt N --threads N (engine knobs, as impute)
  bench-serve  closed-loop load generator: sweeps worker pool sizes x
               client counts x coalescing on/off and writes BENCH_serve.json
               (requests/s, p50/p99 latency, mean coalesce width)
               --workers 1,4 --clients 1,4,8 --requests N (per client)
               --targets-per-request K --engine E
               --hap N --mark N --annot-ratio R --seed S
               --max-batch T --linger-ms L
               --open-loop  Poisson open-loop mode instead: sweeps offered
               load x shards x coalescing, writes BENCH_serve_load.json
               (achieved req/s, sojourn p50/p99/p999, shed rate per point)
               and cross-checks measured mean queue waits against the
               M/M/c prediction in the uncongested regime (disagreement
               fails the run)
               --offered 25,100,400 (req/s) --shards 1,2 --workers N
               --requests N (arrivals per point) --queue-cap N --seed S
  bench        regenerate a paper experiment:
               fig11|fig12|fig13|calibrate|sync-overhead|topology
               [--boards 1,2,..] [--spt 1,2,..] [--full-targets N]
               [--des-targets N] [--des-states N] [--skip-des] [--json]
               (bench topology is the scenario-lab sweep — flags under
               SCENARIO LAB below)
  ablate       design-choice ablations (mapping locality, hardware multicast)
               [--hap N] [--mark N] [--targets N] [--boards B] [--spt N]
  project      capacity + next-gen (Stratix-10) cluster projection (paper §6.3)
               [--states N]
  info         print cluster topology + artifact inventory
  help         this text

OBSERVABILITY (all opt-in; disabled paths cost one branch on an Option):
  DES traces   impute --trace PATH records per-superstep, per-tile DES
               telemetry as poets-impute/trace/v1 JSONL; analyse with
               'trace summarize' or 'trace export --chrome' (Perfetto).
               Bit-identical across --threads at a fixed --batch width.
  serve spans  request key \"spans\":true adds a phase timeline to that
               response's serve block; {\"stats\":true} snapshots carry
               engine-cache hit/miss/eviction counters and log2-us
               queue-wait / service-time histograms per shard.

SCENARIO LAB (heterogeneous clusters + NoC link telemetry):
  scenarios    a ScenarioSpec is a cluster shape plus a link-plane overlay:
                 name=slow,boards=8,tiles=2,cores=1,threads=4,bw=0.25,
                 lat=2,link=3E:bw=0.5:lat=1.5,fail=0E,reroute=90
               bw is a bandwidth scale (0.25 => 4x the serialize cycles),
               lat a latency multiplier; link=<board><dir>:... overrides
               one link, composed on the globals; fail=<board><dir> fails
               a link — traffic reroutes on the deterministic BFS shortest
               surviving path and every rerouted crossing pays the reroute
               penalty (default 180 cycles).  Specs starting '{' parse as
               the equivalent JSON form.  impute --scenario SPEC runs one
               scenario end-to-end.
  telemetry    sim_metrics always carries the link plane, tracing on or
               off: the per-board intra-tile/inter-tile/inter-board copy
               split (board_traffic), link_events_total, link_busy_total,
               max_link_utilisation and rerouted_sends.  With --trace,
               each superstep record adds per-link samples
               ([link, events, busy, queue_hw]) captured in the
               deterministic serial reduce — still bit-identical across
               --threads — and 'trace export --chrome' gains a noc
               counter track.
  bench topology  workload x topology x fault-model sweep: each point
               runs the same workload on the DES under one scenario and
               cross-checks measured cycles against the analytic
               link-bound predictor.  The cross-check is a hard gate
               (ratio must stay within 0.25..4.0 at every point); the
               provenance-stamped BENCH_topology.json is written BEFORE
               the gate verdict so CI archives failing sweeps too.
               --smoke (the 6-scenario CI set: baseline, slow links,
               hotspot link, failed link, failed tile, lossy links;
               without it the full set adds a 16-board cluster and a
               compound degraded+failed scenario)
               --scenario 'SPEC;SPEC;...' (replace the built-in set;
               ';'-separated because ',' belongs to the spec grammar)
               [--hap N] [--mark N] [--targets N] [--spt N] [--seed S]
               [--out PATH] [--json]

FAULT TOLERANCE (deterministic fault schedules + recovery):
  schedules    a ScenarioSpec may also carry a fault schedule:
                 failtile=<board>.<tile>@<step>  kill that tile's compute
                 at superstep <step> (its threads stop; its vertices are
                 deterministically remapped onto the survivors and
                 replayed from the last checkpoint).  A board whose tiles
                 ALL die is powered off, switch included; schedules that
                 would strand a surviving board are rejected up front.
                 drop=<board><dir>:<p>@<seed>  each inter-board crossing
                 on that link is lost with probability p (deterministic
                 seeded draw); losses are detected at the superstep
                 barrier and NACK/retransmitted, each retransmit paying a
                 fixed penalty.
                 dup=<board><dir>:<p>@<seed>  crossings are duplicated
                 with probability p; mailbox sequence numbers suppress
                 the copies.
                 ckpt=K  barrier-aligned device checkpoints every K
                 supersteps (default 16) bound replay after a tile death.
               Dosages under ANY schedule are bit-identical to the
               fault-free run at every --threads and --batch width —
               recovery shows up only in simulated time and telemetry.
  telemetry    sim_metrics grows failed_tiles, replayed_supersteps,
               recovery_cycles, checkpoint_bytes, dropped_events,
               retransmits and dup_events; bench topology carries two
               fault-model cells (failed-tile, lossy-links) under the
               same analytic gate.
  serving      a worker whose run dies is retried ONCE on a fresh engine
               (serve-stats/v1 counts 'retried'); event runs that
               recovered from tile deaths mark the service 'degraded'
               (recovered_runs / recovery_cycles in serve-stats/v1) and
               admission stretches its queue-wait estimates 2x until a
               clean run clears the flag.  serve --connect survives a
               dropped server connection (see --connect above).
";

fn panel_cfg(args: &Args) -> Result<PanelConfig, String> {
    Ok(PanelConfig {
        n_hap: args.get("hap", 16usize)?,
        n_mark: args.get("mark", 101usize)?,
        maf: args.get("maf", 0.05f64)?,
        annot_ratio: args.get("annot-ratio", 0.1f64)?,
        seed: args.get("seed", 2023u64)?,
        ..PanelConfig::default()
    })
}

pub fn cmd_impute(args: &Args) -> Result<i32, String> {
    let cfg = panel_cfg(args)?;
    let panel_spec = args.get_str("panel", "");
    let n_targets = args.get("targets", 4usize)?;
    let engine: EngineSpec = args.get_str("engine", "event").parse()?;
    let boards = args.get("boards", 4usize)?;
    let spt = args.get("spt", 8usize)?;
    let threads = args.get("threads", 1usize)?;
    let batch = args.get("batch", 0usize)?;
    let window = args.get("window", 0usize)?;
    let overlap = args.get("overlap", 0usize)?;
    let window_threads = args.get("window-threads", 1usize)?;
    let stream = args.has("stream");
    let trace_path = args.get_str("trace", "");
    let scenario_arg = args.get_str("scenario", "");
    let as_json = args.has("json");
    args.reject_unknown()?;

    let scenario = if scenario_arg.is_empty() {
        None
    } else {
        Some(ScenarioSpec::parse(&scenario_arg)?)
    };

    if stream && window == 0 {
        return Err("--stream needs a --window W plan to stream (W > 0)".into());
    }

    let workload = if panel_spec.is_empty() {
        Workload::synthetic(&cfg, n_targets)
    } else {
        // A named panel source: resolve it, then mint mosaic targets from
        // the panel itself (truth retained, so accuracy is still scored).
        // The CLI is a trusted caller — no serve-style size caps, so
        // chromosome-scale panels load (that is what --window is for).
        let registry = PanelRegistry::unbounded();
        let panel = registry.resolve(&normalize_panel_spec(&panel_spec))?;
        let cases = panel.mosaic_targets(n_targets, cfg.annot_ratio, cfg.seed)?;
        Workload::from_shared_cases(panel.panel_arc(), cases)?
    };

    let configure = |mut session: ImputeSession| {
        session = session
            .boards(boards)
            .states_per_thread(spt)
            .threads(threads);
        // After .boards(): the scenario's cluster shape wins when given.
        if let Some(spec) = &scenario {
            session = session.scenario(spec.clone());
        }
        if batch > 0 {
            session = session.batch(batch);
        }
        if !trace_path.is_empty() {
            session = session.trace(TraceConfig::default());
        }
        session
    };
    let mut report = if window > 0 {
        let plan = WindowPlan::new(workload.panel().n_mark(), window, overlap)?;
        if stream {
            run_streamed(&workload, &plan, engine, configure)?
        } else {
            run_windowed_threads(&workload, &plan, engine, window_threads, configure)?
        }
    } else {
        configure(ImputeSession::new(workload)).engine(engine).run()?
    };
    if !panel_spec.is_empty() {
        report.panel = Some(panel_spec);
    }

    if !trace_path.is_empty() {
        match &report.trace {
            Some(t) => {
                // The trace header's run_config mirrors the manifest's run
                // section, so a trace file is self-describing on its own.
                let mut rc = Json::obj();
                rc.set("engine", engine.name())
                    .set("n_hap", report.n_hap)
                    .set("n_mark", report.n_mark)
                    .set("n_targets", report.n_targets)
                    .set("boards", report.boards)
                    .set("states_per_thread", report.states_per_thread)
                    .set("threads", report.threads)
                    .set("batch_size", report.batch_size);
                std::fs::write(&trace_path, t.to_jsonl(rc))
                    .map_err(|e| format!("could not write {trace_path}: {e}"))?;
                eprintln!(
                    "impute: wrote {trace_path} ({} segment(s), {} superstep record(s))",
                    t.segments,
                    t.steps.len()
                );
            }
            // Not an error: the flag is honoured wherever a DES ran, and a
            // host-plane run simply has no supersteps to record.
            None => eprintln!(
                "impute: --trace given but engine {} records no DES trace; \
                 nothing written",
                engine.name()
            ),
        }
    }

    if as_json {
        println!("{}", report.to_json().pretty());
    } else {
        println!("{}", report.render());
    }
    Ok(0)
}

/// `panel ingest <in.vcf> [out.ppnl]` / `panel info <spec|path>`.
pub fn cmd_panel(args: &Args) -> Result<i32, String> {
    match args.positional.get(1).map(String::as_str) {
        Some("ingest") => cmd_panel_ingest(args),
        Some("info") => cmd_panel_info(args),
        other => Err(format!(
            "panel needs a subcommand (ingest|info), got {other:?}\n{USAGE}"
        )),
    }
}

fn cmd_panel_ingest(args: &Args) -> Result<i32, String> {
    let input = args
        .positional
        .get(2)
        .cloned()
        .ok_or_else(|| format!("panel ingest needs an input .vcf path\n{USAGE}"))?;
    let output = match args.positional.get(3) {
        Some(o) => o.clone(),
        None => match input.strip_suffix(".vcf") {
            Some(stem) => format!("{stem}.ppnl"),
            None => format!("{input}.ppnl"),
        },
    };
    let rate = args.get("morgans-per-bp", 1e-8f64)?;
    let map_path = args.get_str("genetic-map", "");
    args.reject_unknown()?;

    let parsed = vcf::load_with(&input, &VcfOptions { morgans_per_bp: rate })?;
    // A real map supersedes the flat-rate distances the parser derived.
    let parsed = if map_path.is_empty() {
        parsed
    } else {
        let map = GeneticMap::load(&map_path)?;
        let (lo, hi) = map.span();
        println!(
            "applied genetic map {map_path}: {} knots spanning {lo}..{hi} bp",
            map.len()
        );
        map.apply(&parsed)
    };
    let packed = PackedPanel::from_vcf(&parsed);
    packed.write(&output)?;
    let raw_bytes = parsed.panel.n_hap() * parsed.panel.n_mark();
    println!(
        "ingested {input}: {} sites x {} haplotypes ({} samples), {}..{} on chromosome {}",
        parsed.panel.n_mark(),
        parsed.panel.n_hap(),
        parsed.n_samples(),
        parsed.sites[0].pos,
        parsed.sites.last().expect(">= 2 sites").pos,
        parsed.sites[0].chrom,
    );
    println!(
        "wrote {output}: allele matrix {} B packed vs {} B unpacked ({:.1}x), \
         {} B on disk",
        packed.packed_allele_bytes(),
        raw_bytes,
        raw_bytes as f64 / packed.packed_allele_bytes() as f64,
        packed.encode().len()
    );
    Ok(0)
}

/// Bare paths are sugar for their spec prefix: `x.vcf` → `vcf:x.vcf`,
/// `x.ppnl` → `packed:x.ppnl` — applied consistently by `panel info` and
/// `impute --panel` (serve request lines stay strict).
fn normalize_panel_spec(arg: &str) -> String {
    if arg.contains(':') {
        arg.to_string()
    } else if arg.ends_with(".vcf") {
        format!("vcf:{arg}")
    } else if arg.ends_with(".ppnl") {
        format!("packed:{arg}")
    } else {
        arg.to_string()
    }
}

fn cmd_panel_info(args: &Args) -> Result<i32, String> {
    let arg = args
        .positional
        .get(2)
        .cloned()
        .ok_or_else(|| format!("panel info needs a spec or path\n{USAGE}"))?;
    args.reject_unknown()?;
    let spec = normalize_panel_spec(&arg);
    let registry = PanelRegistry::unbounded(); // trusted caller: no size cap
    let panel = registry.resolve(&spec)?;
    let p = panel.panel();

    let mut t = Table::new(&["property", "value"]);
    t.row(vec!["panel".into(), spec.clone()]);
    t.row(vec!["haplotypes".into(), fmt_count(p.n_hap() as u64)]);
    t.row(vec!["markers".into(), fmt_count(p.n_mark() as u64)]);
    t.row(vec!["states".into(), fmt_count(p.n_states() as u64)]);
    t.row(vec![
        "memory (unpacked)".into(),
        format!("{} B", p.mem_bytes()),
    ]);
    t.row(vec![
        "alleles (1 bit each)".into(),
        format!("{} B", p.n_hap() * p.n_mark().div_ceil(8)),
    ]);
    let mean_af: f64 =
        (0..p.n_mark()).map(|m| p.allele_freq(m)).sum::<f64>() / p.n_mark() as f64;
    t.row(vec!["mean allele-1 freq".into(), format!("{mean_af:.4}")]);
    if let Some(recipe) = panel.recipe() {
        t.row(vec![
            "synthetic recipe".into(),
            format!(
                "maf={} annot={} seed={}",
                recipe.maf, recipe.annot_ratio, recipe.seed
            ),
        ]);
    }
    if let Some(sites) = panel.sites() {
        let (first, last) = (&sites[0], &sites[sites.len() - 1]);
        t.row(vec![
            "sites".into(),
            format!(
                "{}:{}..{} ({} records)",
                first.chrom,
                first.pos,
                last.pos,
                sites.len()
            ),
        ]);
    }
    println!("{}", t.render());
    Ok(0)
}

/// One `validate` table row: an engine checked against its oracle.
struct ValidateRow {
    engine: EngineSpec,
    outcome: Result<f64, String>,
}

pub fn cmd_validate(args: &Args) -> Result<i32, String> {
    let cfg = panel_cfg(args)?;
    let n_targets = args.get("targets", 3usize)?;
    args.reject_unknown()?;

    let workload = Workload::synthetic(&cfg, n_targets);
    let session = |spec: EngineSpec| {
        ImputeSession::new(workload.clone())
            .engine(spec)
            .cluster(ClusterConfig::with_boards(2))
            .states_per_thread(16)
            .run()
    };

    let dense: ImputeReport = session(EngineSpec::Baseline)?;
    // The interpolated plane approximates the HMM by design: its oracle is
    // the x86 interpolation pipeline, not the dense baseline.
    let b = Baseline::default();
    let interp_oracle: Vec<Vec<f32>> = workload
        .targets()
        .iter()
        .map(|t| impute_interp::<f32>(&b, workload.panel(), t, Method::DenseThreeLoop).dosage)
        .collect();

    let mut rows = Vec::new();
    for spec in EngineSpec::ALL {
        if spec == EngineSpec::Baseline {
            continue; // the oracle itself
        }
        let outcome = session(spec).map(|report| match spec {
            EngineSpec::Interp => report.max_abs_diff(&interp_oracle),
            _ => report.max_abs_diff(&dense.dosages),
        });
        rows.push(ValidateRow {
            engine: spec,
            outcome,
        });
    }

    let mut t = Table::new(&["engine", "vs oracle", "max |Δdosage|", "tolerance", "status"]);
    let mut all_ok = true;
    for row in &rows {
        let tol = row.engine.tolerance();
        let (diff, status) = match &row.outcome {
            Ok(d) if *d <= tol => (format!("{d:.2e}"), "ok".to_string()),
            Ok(d) => {
                all_ok = false;
                (format!("{d:.2e}"), "MISMATCH".to_string())
            }
            // Only the XLA plane may legitimately be absent (no `pjrt`
            // feature / artifacts not built); any other engine erroring is a
            // validation failure, not a skip.
            Err(e) if row.engine == EngineSpec::Xla => {
                ("-".to_string(), format!("skipped ({e})"))
            }
            Err(e) => {
                all_ok = false;
                ("-".to_string(), format!("ERROR ({e})"))
            }
        };
        t.row(vec![
            row.engine.name().into(),
            row.engine.oracle_name().into(),
            diff,
            format!("{tol:.0e}"),
            status,
        ]);
    }
    println!("{}", t.render());
    println!("validate: {}", if all_ok { "OK" } else { "MISMATCH" });
    Ok(if all_ok { 0 } else { 1 })
}

/// `trace summarize <file>` / `trace export <file> --chrome [--out PATH]` —
/// analysis front end for `poets-impute/trace/v1` JSONL files.
pub fn cmd_trace(args: &Args) -> Result<i32, String> {
    let sub = args.positional.get(1).map(String::as_str);
    let path = args.positional.get(2).cloned();
    match sub {
        Some("summarize") => {
            let path =
                path.ok_or_else(|| format!("trace summarize needs a trace file\n{USAGE}"))?;
            let as_json = args.has("json");
            args.reject_unknown()?;
            let file = load_trace(&path)?;
            if as_json {
                println!("{}", crate::obs::trace::summarize_json(&file).pretty());
            } else {
                println!("{}", crate::obs::trace::summarize(&file).trim_end());
            }
            Ok(0)
        }
        Some("export") => {
            let path = path.ok_or_else(|| format!("trace export needs a trace file\n{USAGE}"))?;
            let chrome = args.has("chrome");
            let out = args.get_str("out", "");
            args.reject_unknown()?;
            if !chrome {
                return Err(
                    "trace export: --chrome is the only export format (trace_event JSON)".into(),
                );
            }
            let file = load_trace(&path)?;
            let doc = crate::obs::chrome::to_chrome(&file).pretty();
            if out.is_empty() {
                println!("{doc}");
            } else {
                std::fs::write(&out, doc)
                    .map_err(|e| format!("could not write {out}: {e}"))?;
                println!("wrote {out}");
            }
            Ok(0)
        }
        other => Err(format!(
            "trace needs a subcommand (summarize|export), got {other:?}\n{USAGE}"
        )),
    }
}

/// Read + parse a trace file; parse errors carry the offending line number.
fn load_trace(path: &str) -> Result<TraceFile, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("trace: cannot read {path}: {e}"))?;
    TraceFile::parse(&text).map_err(|e| format!("trace: {path}: {e}"))
}

/// The coalescing policy shared by `serve` and `bench-serve` flags.
fn coalesce_from_args(args: &Args, default_batch: usize) -> Result<CoalescePolicy, String> {
    let max_batch = args.get("max-batch", default_batch)?;
    let linger_ms = args.get("linger-ms", 2u64)?;
    Ok(CoalescePolicy {
        max_batch_targets: max_batch.max(1),
        max_linger: Duration::from_millis(linger_ms),
    })
}

pub fn cmd_serve(args: &Args) -> Result<i32, String> {
    let mut cfg = ServeConfig::default()
        .workers(args.get("workers", 2usize)?)
        .coalesce(coalesce_from_args(args, 16)?)
        .queue_capacity(args.get("queue-cap", 1024usize)?)
        .boards(args.get("boards", 2usize)?)
        .states_per_thread(args.get("spt", 8usize)?)
        .threads(args.get("threads", 1usize)?);
    // A negative rate (the default) means "no quotas configured".
    let quota_rate = args.get("quota-rate", -1.0f64)?;
    let quota_burst = args.get("quota-burst", 8.0f64)?;
    if quota_rate >= 0.0 {
        cfg = cfg.tenant_quota(quota_rate, quota_burst);
    }
    let shards = args.get("shards", 1usize)?;
    let tcp = args.get_str("tcp", "");
    let connect = args.get_str("connect", "");
    args.reject_unknown()?;

    if !connect.is_empty() {
        if !tcp.is_empty() {
            return Err("serve: --tcp and --connect are mutually exclusive".into());
        }
        return serve_connect(&connect);
    }

    let service = ShardedService::start(Arc::new(PanelRegistry::new()), cfg, shards);
    if tcp.is_empty() {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let summary = jsonl::serve_stream(&service, stdin.lock(), stdout.lock())?;
        let stats = service.shutdown();
        eprintln!(
            "serve: {} requests ({} ok, {} failed); drained: {} completed, \
             {} batches, mean width {:.2}",
            summary.requests,
            summary.ok,
            summary.failed,
            stats.completed,
            stats.batches,
            stats.mean_batch_width()
        );
    } else {
        let listener = std::net::TcpListener::bind(&tcp)
            .map_err(|e| format!("serve: cannot bind {tcp}: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("serve: local_addr: {e}"))?;
        // Stderr, so scripts binding :0 can scrape the real port while
        // stdout stays free for piped use.
        eprintln!("serve: listening on {addr} ({shards} shard(s))");
        let summary = net::serve_tcp(&service, listener)?;
        let stats = service.shutdown();
        eprintln!(
            "serve: {} connections, {} requests ({} ok, {} failed); drained: \
             {} accepted, {} completed, {} failed in service",
            summary.connections,
            summary.requests,
            summary.ok,
            summary.failed,
            stats.accepted,
            stats.completed,
            stats.failed
        );
        // The drain guarantee: shutdown completes every admitted request.
        if stats.accepted != stats.completed + stats.failed {
            return Err(format!(
                "serve: shutdown leaked tickets ({} accepted vs {} resolved)",
                stats.accepted,
                stats.completed + stats.failed
            ));
        }
    }
    // Per-request failures are reported in-band on stdout; a clean stream
    // (read to EOF, every response written) exits 0.
    Ok(0)
}

/// `serve --connect ADDR`: bridge stdin/stdout JSONL onto the framed TCP
/// transport, so shell pipelines can drive a remote server exactly like a
/// local `serve` process.  The bridge ([`net::bridge_jsonl`]) survives a
/// dropped server connection: it reconnects under capped exponential
/// backoff and resubmits only the requests whose responses never arrived.
fn serve_connect(addr: &str) -> Result<i32, String> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let summary = net::bridge_jsonl(std::io::BufReader::new(stdin), &mut out, addr)?;
    if summary.reconnects > 0 {
        eprintln!(
            "serve: bridged {} response(s) from {addr} across {} reconnect(s)",
            summary.responses, summary.reconnects
        );
    }
    Ok(0)
}

pub fn cmd_bench_serve(args: &Args) -> Result<i32, String> {
    if args.has("open-loop") {
        return cmd_bench_serve_open_loop(args);
    }
    let defaults = BenchServeOpts::default();
    let panel = format!(
        "synth:hap={},mark={},annot={},seed={}",
        args.get("hap", 16usize)?,
        args.get("mark", 101usize)?,
        args.get("annot-ratio", 0.1f64)?,
        args.get("seed", 2023u64)?
    );
    let opts = BenchServeOpts {
        clients: args.get_list("clients", &defaults.clients)?,
        workers: args.get_list("workers", &defaults.workers)?,
        requests_per_client: args.get("requests", defaults.requests_per_client)?,
        targets_per_request: args.get("targets-per-request", defaults.targets_per_request)?,
        engine: args.get_str("engine", defaults.engine.name()).parse()?,
        panel,
        coalesce: coalesce_from_args(args, defaults.coalesce.max_batch_targets)?,
    };
    args.reject_unknown()?;

    let (table, json) = crate::serve::bench::run(&opts)?;
    println!(
        "## serve throughput baseline (engine {}, panel {})\n{table}",
        opts.engine.name(),
        opts.panel
    );
    let path = "BENCH_serve.json";
    std::fs::write(path, json.pretty()).map_err(|e| format!("could not write {path}: {e}"))?;
    println!("wrote {path}");
    Ok(0)
}

/// `bench-serve --open-loop`: Poisson open-loop load sweep with the M/M/c
/// cross-check.  A failed cross-check (measured wait far from the queueing
/// model in the uncongested regime) fails the run.
fn cmd_bench_serve_open_loop(args: &Args) -> Result<i32, String> {
    let defaults = OpenLoopOpts::default();
    let opts = OpenLoopOpts {
        offered_rps: args.get_list_t("offered", &defaults.offered_rps)?,
        shards: args.get_list("shards", &defaults.shards)?,
        workers: args.get("workers", defaults.workers)?,
        requests: args.get("requests", defaults.requests)?,
        targets_per_request: args.get("targets-per-request", defaults.targets_per_request)?,
        engine: args.get_str("engine", defaults.engine.name()).parse()?,
        panel_hap: args.get("hap", defaults.panel_hap)?,
        panel_mark: args.get("mark", defaults.panel_mark)?,
        panel_annot: args.get("annot-ratio", defaults.panel_annot)?,
        coalesce: coalesce_from_args(args, defaults.coalesce.max_batch_targets)?,
        queue_capacity: args.get("queue-cap", defaults.queue_capacity)?,
        seed: args.get("seed", defaults.seed)?,
    };
    args.reject_unknown()?;

    let (table, json) = crate::serve::bench::run_open_loop(&opts)?;
    println!(
        "## serve open-loop load sweep (engine {}, {} req/point)\n{table}",
        opts.engine.name(),
        opts.requests
    );
    let path = "BENCH_serve_load.json";
    std::fs::write(path, json.pretty()).map_err(|e| format!("could not write {path}: {e}"))?;
    println!("wrote {path}");
    Ok(0)
}

pub fn cmd_bench(args: &Args) -> Result<i32, String> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| format!("bench needs a figure name\n{USAGE}"))?;
    if which == "topology" {
        // The scenario lab takes none of the figure flags; branch before
        // FigOpts parsing so its flag set stays self-contained.
        return cmd_bench_topology(args);
    }
    let opts = FigOpts {
        des_states_per_board: args.get("des-states", 128usize)?,
        des_targets: args.get("des-targets", 12usize)?,
        full_targets: args.get("full-targets", 10_000usize)?,
        skip_des: args.has("skip-des"),
        seed: args.get("seed", 2023u64)?,
    };
    let as_json = args.has("json");
    let boards = args.get_list("boards", &[1, 2, 4, 8, 16, 32, 48])?;
    let spt = args.get_list("spt", &[1, 2, 5, 10, 20, 40])?;
    args.reject_unknown()?;

    let needs_x86 = which != "sync-overhead";
    let x86 = if needs_x86 {
        eprintln!("calibrating x86 baseline throughput...");
        X86Cost::measure_default()
    } else {
        X86Cost {
            dense_macs_per_s: 1.0,
            rank1_macs_per_s: 1.0,
        }
    };

    let report = match which.as_str() {
        "fig11" => Some(bench::fig11(&boards, &opts, &x86)),
        "fig12" => Some(bench::fig12(&spt, &opts, &x86)),
        "fig13" => Some(bench::fig13(&boards, &opts, &x86)),
        "calibrate" => {
            println!("{}", bench::calibrate::report(&x86));
            None
        }
        "sync-overhead" => {
            println!("{}", bench::sync_overhead(&opts));
            None
        }
        other => return Err(format!("unknown bench {other:?}\n{USAGE}")),
    };
    if let Some(r) = report {
        if as_json {
            println!("{}", r.to_json().pretty());
        } else {
            println!("{}", r.render());
            println!(
                "notes: 'full' columns are the analytic model at paper scale \
                 (aspect 100:1, {} targets); '~' marks extrapolated x86 time; \
                 DES columns are exact simulation at reduced scale.",
                opts.full_targets
            );
        }
    }
    Ok(0)
}

/// `bench topology` — the scenario lab's workload × topology × fault-model
/// sweep.  The JSON artifact is written BEFORE the gate verdict is enforced,
/// so a failing sweep still archives the offending numbers for CI.
fn cmd_bench_topology(args: &Args) -> Result<i32, String> {
    let mut opts = if args.has("smoke") {
        bench::TopologyOpts::smoke()
    } else {
        bench::TopologyOpts::full()
    };
    opts.n_hap = args.get("hap", opts.n_hap)?;
    opts.n_mark = args.get("mark", opts.n_mark)?;
    opts.n_targets = args.get("targets", opts.n_targets)?;
    opts.states_per_thread = args.get("spt", opts.states_per_thread)?;
    opts.seed = args.get("seed", opts.seed)?;
    let scenario_arg = args.get_str("scenario", "");
    if !scenario_arg.is_empty() {
        // A user-supplied topology set replaces the built-ins.  ';'
        // separates specs — ',' belongs to the scenario grammar itself.
        opts.scenarios = scenario_arg
            .split(';')
            .filter(|s| !s.trim().is_empty())
            .map(ScenarioSpec::parse)
            .collect::<Result<Vec<_>, _>>()?;
        if opts.scenarios.is_empty() {
            return Err("bench topology: --scenario parsed to an empty set".into());
        }
    }
    let out = args.get_str("out", "BENCH_topology.json");
    let as_json = args.has("json");
    args.reject_unknown()?;

    let report = bench::topology::run(opts)?;
    let doc = report.to_json().pretty();
    std::fs::write(&out, &doc).map_err(|e| format!("could not write {out}: {e}"))?;
    if as_json {
        println!("{doc}");
    } else {
        println!("{}", report.render());
        println!("wrote {out}");
    }
    if !report.gate_passed() {
        return Err(format!(
            "bench topology: analytic-vs-DES gate failed (band {:.2}..{:.2}); rows in {out}",
            bench::topology::GATE_BAND.0,
            bench::topology::GATE_BAND.1
        ));
    }
    Ok(0)
}

pub fn cmd_ablate(args: &Args) -> Result<i32, String> {
    let n_hap = args.get("hap", 8usize)?;
    let n_mark = args.get("mark", 80usize)?;
    let n_targets = args.get("targets", 4usize)?;
    let boards = args.get("boards", 4usize)?;
    let spt = args.get("spt", 2usize)?;
    let seed = args.get("seed", 2023u64)?;
    args.reject_unknown()?;
    let rows = crate::bench::ablation::mapping_ablation(n_hap, n_mark, n_targets, boards, spt, seed);
    let mcast = crate::bench::ablation::multicast_ablation(n_hap, n_mark, n_targets);
    println!("{}", crate::bench::ablation::report(&rows, mcast));
    Ok(0)
}

pub fn cmd_project(args: &Args) -> Result<i32, String> {
    use crate::poets::capacity::{GENUINE_PANEL_STATES, MemoryModel, capacity, stratix10_next_gen};
    let states = args.get("states", GENUINE_PANEL_STATES)?;
    args.reject_unknown()?;
    let mem = MemoryModel::default();
    let mut t = Table::new(&["cluster", "threads", "DRAM total", "fits?", "scale needed"]);
    for (name, c) in [
        ("POETS 48x Stratix-V", ClusterConfig::poets_48()),
        ("next-gen Stratix-10", stratix10_next_gen()),
    ] {
        let r = capacity(states, &c, &mem);
        t.row(vec![
            name.into(),
            fmt_count(c.total_threads() as u64),
            format!("{} GiB", c.dram_per_board as u64 * c.n_boards as u64 >> 30),
            if r.fits { "yes".into() } else { "NO".into() },
            format!("{:.1}x", r.scale_factor_needed),
        ]);
    }
    println!(
        "capacity projection for {} panel states (paper §6.3: genuine panels \
         need ~16x the current cluster):\n{}",
        fmt_count(states),
        t.render()
    );
    Ok(0)
}

pub fn cmd_info(args: &Args) -> Result<i32, String> {
    args.reject_unknown()?;
    let c = ClusterConfig::poets_48();
    println!(
        "POETS cluster model: {} boards ({}x{} grid), {} tiles/board, \
         {} cores/tile, {} threads/core = {} hardware threads @ {:.0} MHz",
        c.n_boards,
        c.board_grid.0,
        c.board_grid.1,
        c.tiles_per_board,
        c.cores_per_tile,
        c.threads_per_core,
        fmt_count(c.total_threads() as u64),
        c.clock_hz / 1e6
    );
    match crate::runtime::Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts ({}):", rt.manifest().artifacts.len());
            for a in &rt.manifest().artifacts {
                let ins: Vec<String> = a
                    .inputs
                    .iter()
                    .map(|t| format!("{}:{:?}", t.name, t.shape))
                    .collect();
                println!("  {} [{}]", a.name, ins.join(", "));
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn impute_json_emits_manifest_schema() {
        // The schema itself is asserted in tests/engine_equivalence.rs; here
        // just prove the command path accepts every EngineSpec spelling.
        for engine in ["baseline", "rank1", "event", "interp"] {
            let args = argv(&[
                "impute", "--hap", "8", "--mark", "21", "--annot-ratio", "0.2", "--targets",
                "2", "--engine", engine, "--boards", "1", "--spt", "8", "--json",
            ]);
            assert_eq!(cmd_impute(&args).unwrap(), 0, "engine {engine}");
        }
    }

    #[test]
    fn impute_rejects_unknown_engine() {
        let args = argv(&["impute", "--engine", "warp-drive"]);
        assert!(cmd_impute(&args).is_err());
    }

    #[test]
    fn validate_reports_per_engine_rows() {
        let args = argv(&[
            "validate", "--hap", "8", "--mark", "41", "--targets", "2",
        ]);
        // Offline builds skip the XLA row; everything else must agree.
        assert_eq!(cmd_validate(&args).unwrap(), 0);
    }

    const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/data/tiny.vcf");

    #[test]
    fn panel_ingest_info_and_windowed_real_impute() {
        let out = std::env::temp_dir().join(format!(
            "poets-cli-tiny-{}.ppnl",
            std::process::id()
        ));
        let out = out.to_str().unwrap().to_string();
        assert_eq!(
            cmd_panel(&argv(&["panel", "ingest", FIXTURE, out.as_str()])).unwrap(),
            0
        );
        let spec = format!("packed:{out}");
        assert_eq!(cmd_panel(&argv(&["panel", "info", spec.as_str()])).unwrap(), 0);
        // Bare-path sugar resolves the same file.
        assert_eq!(cmd_panel(&argv(&["panel", "info", out.as_str()])).unwrap(), 0);
        // Windowed impute against the packed real panel, manifest emitted.
        let args = argv(&[
            "impute", "--panel", spec.as_str(), "--targets", "2", "--annot-ratio",
            "0.25", "--engine", "baseline", "--window", "30", "--overlap", "20",
            "--json",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
        // Bare-path sugar works for impute too, like panel info.
        let args = argv(&[
            "impute", "--panel", out.as_str(), "--targets", "1", "--annot-ratio",
            "0.25", "--engine", "baseline",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
        let _ = std::fs::remove_file(&out);
    }

    const MAP_FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/data/tiny.map");

    #[test]
    fn panel_ingest_applies_a_genetic_map() {
        let out = std::env::temp_dir().join(format!(
            "poets-cli-tiny-gmap-{}.ppnl",
            std::process::id()
        ));
        let out = out.to_str().unwrap().to_string();
        assert_eq!(
            cmd_panel(&argv(&[
                "panel",
                "ingest",
                FIXTURE,
                out.as_str(),
                "--genetic-map",
                MAP_FIXTURE,
            ]))
            .unwrap(),
            0
        );
        // The mapped panel stays fully usable downstream.
        let spec = format!("packed:{out}");
        assert_eq!(cmd_panel(&argv(&["panel", "info", spec.as_str()])).unwrap(), 0);
        let args = argv(&[
            "impute", "--panel", spec.as_str(), "--targets", "1", "--annot-ratio",
            "0.25", "--engine", "baseline",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);

        // And it is genuinely different from the flat-rate ingest: the map's
        // hotspot gaps carry ~1.5 cM where the flat conversion gives ~10 cM.
        let flat = crate::genomics::vcf::load(FIXTURE).unwrap();
        let mapped = crate::genomics::gmap::GeneticMap::load(MAP_FIXTURE)
            .unwrap()
            .apply(&flat);
        let sum = |p: &crate::genomics::vcf::VcfPanel| -> f64 {
            (0..p.panel.n_mark()).map(|m| p.panel.gen_dist(m)).sum()
        };
        assert!(sum(&mapped) < sum(&flat));
        let _ = std::fs::remove_file(&out);

        // A missing map file fails the ingest loudly.
        assert!(
            cmd_panel(&argv(&[
                "panel",
                "ingest",
                FIXTURE,
                "/tmp/never-written.ppnl",
                "--genetic-map",
                "/nonexistent.map",
            ]))
            .is_err()
        );
    }

    #[test]
    fn panel_command_rejects_bad_usage() {
        assert!(cmd_panel(&argv(&["panel"])).is_err());
        assert!(cmd_panel(&argv(&["panel", "frobnicate"])).is_err());
        assert!(cmd_panel(&argv(&["panel", "ingest"])).is_err());
        assert!(cmd_panel(&argv(&["panel", "info"])).is_err());
        assert!(cmd_panel(&argv(&["panel", "info", "vcf:/nonexistent.vcf"])).is_err());
        assert!(
            cmd_panel(&argv(&["panel", "ingest", "/nonexistent.vcf", "/tmp/x.ppnl"])).is_err()
        );
    }

    #[test]
    fn impute_trace_summarize_and_chrome_export_roundtrip() {
        let pid = std::process::id();
        let trace = std::env::temp_dir().join(format!("poets-cli-trace-{pid}.jsonl"));
        let trace = trace.to_str().unwrap().to_string();
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--annot-ratio", "0.2", "--targets",
            "2", "--engine", "event", "--boards", "1", "--spt", "8", "--trace",
            trace.as_str(),
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
        let text = std::fs::read_to_string(&trace).unwrap();
        assert!(
            text.contains("\"schema\":\"poets-impute/trace/v1\""),
            "header carries the schema (compact render): {}",
            text.lines().next().unwrap_or("")
        );
        assert_eq!(
            cmd_trace(&argv(&["trace", "summarize", trace.as_str()])).unwrap(),
            0
        );
        assert_eq!(
            cmd_trace(&argv(&["trace", "summarize", trace.as_str(), "--json"])).unwrap(),
            0
        );
        let out = std::env::temp_dir().join(format!("poets-cli-chrome-{pid}.json"));
        let out = out.to_str().unwrap().to_string();
        assert_eq!(
            cmd_trace(&argv(&[
                "trace", "export", trace.as_str(), "--chrome", "--out", out.as_str(),
            ]))
            .unwrap(),
            0
        );
        let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert!(
            !doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty(),
            "chrome export has events"
        );
        let _ = std::fs::remove_file(&trace);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn impute_trace_on_a_host_plane_warns_and_writes_nothing() {
        let t = std::env::temp_dir().join(format!(
            "poets-cli-notrace-{}.jsonl",
            std::process::id()
        ));
        let t = t.to_str().unwrap().to_string();
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--targets", "1", "--engine",
            "baseline", "--trace", t.as_str(),
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
        assert!(
            !std::path::Path::new(&t).exists(),
            "host planes record no trace, so no file appears"
        );
    }

    #[test]
    fn trace_verb_rejects_bad_usage_and_malformed_files() {
        assert!(cmd_trace(&argv(&["trace"])).is_err());
        assert!(cmd_trace(&argv(&["trace", "frobnicate", "x"])).is_err());
        assert!(cmd_trace(&argv(&["trace", "summarize"])).is_err());
        assert!(cmd_trace(&argv(&["trace", "summarize", "/nonexistent.jsonl"])).is_err());
        let bad = std::env::temp_dir().join(format!(
            "poets-cli-badtrace-{}.jsonl",
            std::process::id()
        ));
        let bad = bad.to_str().unwrap().to_string();
        std::fs::write(
            &bad,
            "{\"kind\":\"header\",\"schema\":\"poets-impute/trace/v1\",\"n_tiles\":1,\
             \"max_steps\":0,\"dropped_steps\":0,\"total_steps\":0,\"segments\":1,\
             \"steps_recorded\":0}\nnot json\n",
        )
        .unwrap();
        let err = cmd_trace(&argv(&["trace", "summarize", bad.as_str()])).unwrap_err();
        assert!(err.contains("line 2"), "line-numbered rejection: {err}");
        // export demands an explicit format even before reading the file.
        assert!(
            cmd_trace(&argv(&["trace", "export", bad.as_str()]))
                .unwrap_err()
                .contains("--chrome")
        );
        let _ = std::fs::remove_file(&bad);
    }

    #[test]
    fn impute_runs_a_heterogeneous_scenario() {
        // 8x21 panel at spt=4 needs 42 threads; the scenario's boards hold
        // 32 each, so the run spans both and exercises the link plane.
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--annot-ratio", "0.2", "--targets",
            "2", "--engine", "event", "--spt", "4", "--scenario",
            "name=lab,boards=2,tiles=4,cores=2,threads=4,bw=0.5", "--json",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
        // A malformed spec is rejected before any engine runs.
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--targets", "1", "--scenario",
            "boards=2,frobnicate=1",
        ]);
        assert!(cmd_impute(&args).unwrap_err().contains("frobnicate"));
    }

    #[test]
    fn bench_topology_writes_gated_artifact() {
        let out = std::env::temp_dir().join(format!(
            "poets-cli-topology-{}.json",
            std::process::id()
        ));
        let out = out.to_str().unwrap().to_string();
        let args = argv(&["bench", "topology", "--smoke", "--out", out.as_str()]);
        assert_eq!(cmd_bench(&args).unwrap(), 0, "smoke sweep passes the gate");
        let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(crate::bench::topology::TOPOLOGY_SCHEMA)
        );
        assert_eq!(doc.get("gate_passed"), Some(&Json::Bool(true)));
        assert!(doc.get("rows").and_then(Json::as_arr).unwrap().len() >= 3);
        let _ = std::fs::remove_file(&out);
        // Bad scenario lists fail fast, before any sweep runs.
        let args = argv(&["bench", "topology", "--scenario", "boards=2,fail=0E"]);
        assert!(cmd_bench(&args).is_err(), "disconnecting spec is rejected");
    }

    #[test]
    fn impute_rejects_bad_window_geometry() {
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--targets", "1", "--engine",
            "baseline", "--window", "8", "--overlap", "8",
        ]);
        assert!(cmd_impute(&args).unwrap_err().contains("overlap"));
    }

    #[test]
    fn impute_supports_batching() {
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--annot-ratio", "0.2", "--targets",
            "3", "--engine", "event", "--boards", "1", "--spt", "8", "--batch", "2",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
    }

    #[test]
    fn impute_supports_window_threads() {
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "40", "--annot-ratio", "0.25", "--targets",
            "2", "--engine", "baseline", "--window", "26", "--overlap", "19",
            "--window-threads", "3",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
    }

    #[test]
    fn impute_streams_a_window_plan() {
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "40", "--annot-ratio", "0.25", "--targets",
            "2", "--engine", "event", "--boards", "1", "--spt", "8", "--window", "26",
            "--overlap", "19", "--stream", "--json",
        ]);
        assert_eq!(cmd_impute(&args).unwrap(), 0);
    }

    #[test]
    fn impute_stream_requires_a_window_plan() {
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "21", "--targets", "1", "--stream",
        ]);
        assert!(cmd_impute(&args).unwrap_err().contains("--window"));
    }

    #[test]
    fn impute_rejects_misaligned_interp_windows() {
        // Chip grid every 10th marker; this geometry leaves a window core
        // ahead of its first anchor — must be a hard error, not silent
        // partial coverage.
        let args = argv(&[
            "impute", "--hap", "8", "--mark", "41", "--annot-ratio", "0.1", "--targets",
            "1", "--engine", "interp", "--boards", "1", "--spt", "1", "--window", "21",
            "--overlap", "3",
        ]);
        let err = cmd_impute(&args).unwrap_err();
        assert!(err.contains("chip"), "{err}");
    }
}
