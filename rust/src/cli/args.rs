//! Minimal flag parser (offline substitute for `clap`).
//!
//! Grammar: `prog <command> [<subcommand>] [--flag value | --switch]...`.
//! Values never start with `--`; unknown flags are an error (surfaced with
//! the command's usage string).

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    a.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                a.positional.push(tok.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        self.consumed.borrow_mut().push(name.to_string());
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: cannot parse {v:?}")),
        }
    }

    /// String flag with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Comma-separated list flag (`usize` elements — the common case).
    pub fn get_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        self.get_list_t(name, default)
    }

    /// Comma-separated list flag with typed elements (e.g. `f64` offered
    /// rates for `bench-serve --open-loop`).
    pub fn get_list_t<T>(&self, name: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T: std::str::FromStr + Clone,
    {
        self.consumed.borrow_mut().push(name.to_string());
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad element {x:?}"))
                })
                .collect(),
        }
    }

    /// Boolean switch (present or absent).
    pub fn has(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.switches.iter().any(|s| s == name)
    }

    /// Error if any flag/switch was provided but never consumed.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        for k in &self.switches {
            if !seen.contains(k) {
                return Err(format!("unknown switch --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_positional_flags_switches() {
        let a = Args::parse(&argv(&["bench", "fig11", "--boards", "1,2", "--skip-des"])).unwrap();
        assert_eq!(a.positional, vec!["bench", "fig11"]);
        assert_eq!(a.get_list("boards", &[]).unwrap(), vec![1, 2]);
        assert!(a.has("skip-des"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(&argv(&["x", "--n", "42"])).unwrap();
        assert_eq!(a.get("n", 0usize).unwrap(), 42);
        assert_eq!(a.get("m", 7usize).unwrap(), 7);
        assert_eq!(a.get_str("s", "d"), "d");
    }

    #[test]
    fn bad_value_is_error() {
        let a = Args::parse(&argv(&["x", "--n", "oops"])).unwrap();
        assert!(a.get("n", 0usize).is_err());
    }

    #[test]
    fn typed_lists_parse_floats() {
        let a = Args::parse(&argv(&["x", "--offered", "25,100.5,400"])).unwrap();
        assert_eq!(
            a.get_list_t("offered", &[1.0f64]).unwrap(),
            vec![25.0, 100.5, 400.0]
        );
        assert_eq!(a.get_list_t("missing", &[7.5f64]).unwrap(), vec![7.5]);
        let bad = Args::parse(&argv(&["x", "--offered", "25,zap"])).unwrap();
        assert!(bad.get_list_t("offered", &[1.0f64]).is_err());
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = Args::parse(&argv(&["x", "--mystery", "1"])).unwrap();
        let _ = a.get("n", 0usize);
        assert!(a.reject_unknown().is_err());
    }
}
