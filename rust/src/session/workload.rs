//! Workload assembly: the panel + target set every engine consumes.
//!
//! A [`Workload`] owns the reference panel, the target haplotypes to impute
//! and (when the targets are synthetic) the withheld truth used for accuracy
//! scoring.  [`TargetBatch`] is the unit of work handed to an [`Engine`]
//! — the session splits a workload's targets into batches, and the batch is
//! the seam where panel-level batching across targets lands (engines must
//! accept multi-target batches, never assume one target per call).
//!
//! [`Engine`]: super::Engine

use std::sync::Arc;

use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::util::rng::Rng;
use crate::workload::panelgen::{PanelConfig, TargetCase, generate_panel, generate_targets};

/// A fully-assembled imputation problem: one reference panel plus the target
/// haplotypes to impute against it.
///
/// The panel is shared (`Arc`), so cloning a workload — and binding engines
/// to it — never copies panel data; only the target vectors are deep-cloned.
#[derive(Clone, Debug)]
pub struct Workload {
    panel: Arc<ReferencePanel>,
    targets: Vec<TargetHaplotype>,
    /// Withheld truth per target (synthetic workloads only) — enables
    /// accuracy scoring in the report.
    truth: Option<Vec<Vec<u8>>>,
    /// Generation recipe, when the workload is synthetic (recorded in the
    /// run manifest for reproducibility).
    provenance: Option<PanelConfig>,
}

impl Workload {
    /// Generate a synthetic workload with the paper's §6.2 recipe: panel from
    /// `cfg`, `n_targets` Li & Stephens mosaic targets with truth retained.
    pub fn synthetic(cfg: &PanelConfig, n_targets: usize) -> Workload {
        let panel = generate_panel(cfg);
        let mut rng = Rng::new(cfg.seed ^ 0x7A96);
        let cases = generate_targets(&panel, cfg, n_targets, &mut rng);
        let mut wl = Workload::from_cases(panel, cases);
        wl.provenance = Some(*cfg);
        wl
    }

    /// Wrap an existing panel + generated cases (truth retained for scoring).
    pub fn from_cases(panel: ReferencePanel, cases: Vec<TargetCase>) -> Workload {
        let mut targets = Vec::with_capacity(cases.len());
        let mut truth = Vec::with_capacity(cases.len());
        for c in cases {
            targets.push(c.masked);
            truth.push(c.truth);
        }
        Workload {
            panel: Arc::new(panel),
            targets,
            truth: Some(truth),
            provenance: None,
        }
    }

    /// Wrap an existing panel + target set with no withheld truth (real
    /// cohorts): the report carries dosages and timings but no accuracy.
    pub fn from_parts(panel: ReferencePanel, targets: Vec<TargetHaplotype>) -> Workload {
        for t in &targets {
            assert_eq!(
                t.n_mark(),
                panel.n_mark(),
                "target/panel marker count mismatch"
            );
        }
        Workload {
            panel: Arc::new(panel),
            targets,
            truth: None,
            provenance: None,
        }
    }

    /// Like [`Workload::from_cases`] but sharing an existing panel handle —
    /// how registry-resolved panels (`vcf:`/`packed:`/`synth:` specs) take
    /// minted mosaic targets with truth retained, without copying panel
    /// data.  Shape mismatches are recoverable errors (specs and counts
    /// arrive from flags and requests).
    pub fn from_shared_cases(
        panel: Arc<ReferencePanel>,
        cases: Vec<TargetCase>,
    ) -> Result<Workload, String> {
        let mut targets = Vec::with_capacity(cases.len());
        let mut truth = Vec::with_capacity(cases.len());
        for (i, c) in cases.into_iter().enumerate() {
            if c.masked.n_mark() != panel.n_mark() || c.truth.len() != panel.n_mark() {
                return Err(format!(
                    "case {i} has {} markers, panel has {}",
                    c.masked.n_mark(),
                    panel.n_mark()
                ));
            }
            targets.push(c.masked);
            truth.push(c.truth);
        }
        Ok(Workload {
            panel,
            targets,
            truth: Some(truth),
            provenance: None,
        })
    }

    /// Wrap an already-shared panel handle + target set with no withheld
    /// truth — the serve path: [`crate::serve::PanelRegistry`] hands out one
    /// `Arc` per panel and every request's workload shares it, so neither
    /// workload assembly nor engine binding ever copies panel data.  Unlike
    /// [`Workload::from_parts`] a shape mismatch is a recoverable error, not
    /// a panic (requests are untrusted input).
    pub fn from_shared(
        panel: Arc<ReferencePanel>,
        targets: Vec<TargetHaplotype>,
    ) -> Result<Workload, String> {
        for (i, t) in targets.iter().enumerate() {
            if t.n_mark() != panel.n_mark() {
                return Err(format!(
                    "target {i} has {} markers, panel has {}",
                    t.n_mark(),
                    panel.n_mark()
                ));
            }
        }
        Ok(Workload {
            panel,
            targets,
            truth: None,
            provenance: None,
        })
    }

    pub fn panel(&self) -> &ReferencePanel {
        &self.panel
    }

    /// Shared handle to the panel — what engines bind in `prepare` (cheap;
    /// no panel data is copied).
    pub fn panel_arc(&self) -> Arc<ReferencePanel> {
        Arc::clone(&self.panel)
    }

    pub fn targets(&self) -> &[TargetHaplotype] {
        &self.targets
    }

    pub fn n_targets(&self) -> usize {
        self.targets.len()
    }

    /// Withheld truth per target, when known.
    pub fn truth(&self) -> Option<&[Vec<u8>]> {
        self.truth.as_deref()
    }

    /// Generation recipe, when synthetic.
    pub fn provenance(&self) -> Option<&PanelConfig> {
        self.provenance.as_ref()
    }

    /// One batch covering every target.
    pub fn full_batch(&self) -> TargetBatch<'_> {
        TargetBatch {
            targets: &self.targets,
            start: 0,
        }
    }

    /// Split the targets into batches of at most `batch_size`, in order.
    pub fn batches(&self, batch_size: usize) -> impl Iterator<Item = TargetBatch<'_>> {
        assert!(batch_size >= 1, "batch size must be >= 1");
        self.targets
            .chunks(batch_size)
            .enumerate()
            .map(move |(i, chunk)| TargetBatch {
                targets: chunk,
                start: i * batch_size,
            })
    }
}

/// A contiguous slice of a workload's targets — the unit of work an
/// [`Engine`](super::Engine) executes.  Always potentially multi-target:
/// engines service every target in the batch in one call.
#[derive(Clone, Copy, Debug)]
pub struct TargetBatch<'a> {
    targets: &'a [TargetHaplotype],
    start: usize,
}

impl<'a> TargetBatch<'a> {
    /// A standalone batch over a target slice (index origin 0).
    pub fn new(targets: &'a [TargetHaplotype]) -> TargetBatch<'a> {
        TargetBatch { targets, start: 0 }
    }

    pub fn targets(&self) -> &'a [TargetHaplotype] {
        self.targets
    }

    /// Index of this batch's first target within the parent workload.
    pub fn start(&self) -> usize {
        self.start
    }

    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PanelConfig {
        PanelConfig {
            n_hap: 8,
            n_mark: 21,
            maf: 0.2,
            annot_ratio: 0.2,
            seed: 5,
            ..PanelConfig::default()
        }
    }

    #[test]
    fn synthetic_keeps_truth_and_provenance() {
        let wl = Workload::synthetic(&cfg(), 3);
        assert_eq!(wl.n_targets(), 3);
        assert_eq!(wl.truth().unwrap().len(), 3);
        assert_eq!(wl.provenance().unwrap().n_hap, 8);
        assert_eq!(wl.panel().n_mark(), 21);
    }

    #[test]
    fn from_parts_has_no_truth() {
        let wl = Workload::synthetic(&cfg(), 2);
        let bare = Workload::from_parts(wl.panel().clone(), wl.targets().to_vec());
        assert!(bare.truth().is_none());
        assert!(bare.provenance().is_none());
    }

    #[test]
    fn batches_cover_all_targets_in_order() {
        let wl = Workload::synthetic(&cfg(), 5);
        let batches: Vec<_> = wl.batches(2).collect();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].len(), 2);
        assert_eq!(batches[2].len(), 1);
        assert_eq!(batches[1].start(), 2);
        assert_eq!(batches[2].start(), 4);
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn full_batch_spans_everything() {
        let wl = Workload::synthetic(&cfg(), 4);
        let b = wl.full_batch();
        assert_eq!(b.len(), 4);
        assert_eq!(b.start(), 0);
        assert!(!b.is_empty());
    }

    #[test]
    #[should_panic(expected = "marker count mismatch")]
    fn from_parts_rejects_ragged_targets() {
        let wl = Workload::synthetic(&cfg(), 1);
        let bad = TargetHaplotype::new(vec![-1; 7]);
        Workload::from_parts(wl.panel().clone(), vec![bad]);
    }

    #[test]
    fn from_shared_shares_the_panel_arc() {
        let wl = Workload::synthetic(&cfg(), 2);
        let arc = wl.panel_arc();
        let shared = Workload::from_shared(Arc::clone(&arc), wl.targets().to_vec()).unwrap();
        assert!(Arc::ptr_eq(&arc, &shared.panel_arc()));
        assert!(shared.truth().is_none());
    }

    #[test]
    fn from_shared_cases_keeps_truth_and_shares_the_panel() {
        let cfg = cfg();
        let base = Workload::synthetic(&cfg, 1);
        let panel = base.panel_arc();
        let mut rng = crate::util::rng::Rng::new(3);
        let cases = crate::workload::panelgen::generate_targets(base.panel(), &cfg, 2, &mut rng);
        let wl = Workload::from_shared_cases(Arc::clone(&panel), cases).unwrap();
        assert!(Arc::ptr_eq(&panel, &wl.panel_arc()));
        assert_eq!(wl.n_targets(), 2);
        assert_eq!(wl.truth().unwrap().len(), 2);
        // Ragged cases are a recoverable error.
        let bad = crate::workload::panelgen::TargetCase {
            truth: vec![0; 7],
            masked: TargetHaplotype::new(vec![-1; 7]),
        };
        let err = Workload::from_shared_cases(panel, vec![bad]).unwrap_err();
        assert!(err.contains("7 markers"), "{err}");
    }

    #[test]
    fn from_shared_rejects_ragged_targets_without_panicking() {
        let wl = Workload::synthetic(&cfg(), 1);
        let bad = TargetHaplotype::new(vec![-1; 7]);
        let err = Workload::from_shared(wl.panel_arc(), vec![bad]).unwrap_err();
        assert!(err.contains("7 markers"), "{err}");
    }
}
