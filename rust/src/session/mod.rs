//! The unified imputation pipeline — one typed API over all five compute
//! planes.
//!
//! Every execution strategy the paper evaluates (x86 dense baseline, x86
//! rank-1, event-driven raw graph, event-driven linear interpolation, and
//! the AOT JAX/Pallas XLA plane) is an [`Engine`], selected by the
//! [`EngineSpec`] enum.  [`ImputeSession`] owns everything around the
//! engine: workload assembly ([`Workload`]), target batching
//! ([`TargetBatch`] — the seam where panel-level batching across targets
//! lands), per-engine configuration, accuracy scoring and the serialisable
//! [`ImputeReport`] with its `BENCH_*.json`-style run manifest.
//!
//! End to end:
//!
//! ```
//! use poets_impute::session::{EngineSpec, ImputeSession, Workload};
//! use poets_impute::workload::panelgen::PanelConfig;
//!
//! let cfg = PanelConfig { n_hap: 8, n_mark: 21, annot_ratio: 0.2, seed: 1,
//!                         ..PanelConfig::default() };
//! let report = ImputeSession::new(Workload::synthetic(&cfg, 2))
//!     .engine(EngineSpec::Event)   // any of the five planes
//!     .boards(1)
//!     .states_per_thread(8)        // soft-scheduling (Fig 12)
//!     .threads(2)                  // host workers; results invariant
//!     .batch(2)                    // targets per engine batch
//!     .run()
//!     .expect("event plane is always available");
//! assert_eq!(report.dosages.len(), 2);
//! println!("{}", report.to_json().pretty());
//! ```
//!
//! This is the only execution entry point: the legacy per-engine functions
//! (`run_raw`, `run_interp`) were deprecated shims over this API and have
//! been removed.

pub mod engine;
pub mod report;
pub mod workload;

pub use engine::{
    BaselineEngine, Engine, EngineOutput, EngineSpec, EventEngine, InterpEngine, XlaEngine,
    build_engine,
};
pub use report::{ImputeReport, StreamTelemetry, max_abs_dosage_diff};
pub use workload::{TargetBatch, Workload};

use crate::graph::mapping::MappingStrategy;
use crate::imputation::app::RawAppConfig;
use crate::model::accuracy;
use crate::model::params::ModelParams;
use crate::poets::costmodel::CostModel;
use crate::poets::desim::SimConfig;
use crate::poets::metrics::SimMetrics;
use crate::poets::topology::ClusterConfig;

/// Builder for one imputation run: workload in, [`ImputeReport`] out.
///
/// Defaults: the event-driven plane on the full 48-board cluster, one state
/// per thread, serial host delivery, all targets in a single batch.
#[derive(Clone)]
pub struct ImputeSession {
    workload: Workload,
    spec: EngineSpec,
    app: RawAppConfig,
    mapping: MappingStrategy,
    /// Targets per engine batch; `None` = all in one batch.
    batch: Option<usize>,
}

impl ImputeSession {
    pub fn new(workload: Workload) -> ImputeSession {
        ImputeSession {
            workload,
            spec: EngineSpec::Event,
            app: RawAppConfig::default(),
            mapping: MappingStrategy::Manual2d,
            batch: None,
        }
    }

    /// Select the compute plane.
    pub fn engine(mut self, spec: EngineSpec) -> Self {
        self.spec = spec;
        self
    }

    /// The currently selected compute plane (what [`ImputeSession::run`]
    /// will execute) — lets orchestration layers like
    /// `genomics::window::run_windowed` apply engine-specific validation
    /// without running anything.
    pub fn engine_spec(&self) -> EngineSpec {
        self.spec
    }

    /// Replace the whole engine configuration at once (cluster, params,
    /// soft-scheduling, cost model, sim switches).
    pub fn app_config(mut self, app: RawAppConfig) -> Self {
        self.app = app;
        self
    }

    /// Model constants (Ne, error rate) shared by every plane.
    pub fn params(mut self, params: ModelParams) -> Self {
        self.app.params = params;
        self
    }

    /// Simulated cluster shape for the event planes.
    pub fn cluster(mut self, cluster: ClusterConfig) -> Self {
        self.app.cluster = cluster;
        self
    }

    /// Shorthand: an `n`-board cluster ([`ClusterConfig::with_boards`]).
    pub fn boards(mut self, n: usize) -> Self {
        self.app.cluster = ClusterConfig::with_boards(n);
        self
    }

    /// Heterogeneous what-if cluster model for the event planes
    /// ([`crate::poets::ScenarioSpec`]): shape overrides plus degraded /
    /// failed inter-board links.  Sets the cluster shape from the spec, so
    /// it composes like [`ImputeSession::cluster`] — last caller wins.
    pub fn scenario(mut self, spec: crate::poets::ScenarioSpec) -> Self {
        self.app.cluster = spec.cluster();
        self.app.scenario = Some(spec);
        self
    }

    /// Soft-scheduling factor: panel states per hardware thread (Fig 12).
    pub fn states_per_thread(mut self, n: usize) -> Self {
        self.app.states_per_thread = n.max(1);
        self
    }

    /// Host worker threads for the DES deliver/step phases.  Results are
    /// thread-count invariant (superstep barrier); only host time changes.
    pub fn threads(mut self, n: usize) -> Self {
        self.app.sim.threads = Some(n.max(1));
        self
    }

    /// DES cost model override.
    pub fn cost(mut self, cost: CostModel) -> Self {
        self.app.cost = cost;
        self
    }

    /// DES switches (step cap, step recording) override.
    pub fn sim(mut self, sim: SimConfig) -> Self {
        self.app.sim = sim;
        self
    }

    /// Opt-in per-superstep DES trace capture ([`crate::obs`]).  The event
    /// planes fill the wavefront column stride from the panel shape; the
    /// captured trace lands in [`ImputeReport::trace`] (batch and window
    /// runs fold into one trace as successive segments).  Host planes
    /// ignore it.
    pub fn trace(mut self, trace: crate::obs::TraceConfig) -> Self {
        self.app.sim.trace = Some(trace);
        self
    }

    /// Vertex→thread mapping strategy for the event planes.
    pub fn mapping(mut self, mapping: MappingStrategy) -> Self {
        self.mapping = mapping;
        self
    }

    /// Targets per engine batch (default: all targets in one batch).
    ///
    /// On the event planes a batch runs as one engine invocation: it is
    /// split into **lane groups** of at most `LANES` targets, each sweeping
    /// the panel as one SoA wave (`imputation::msg`), with successive groups
    /// injected `stagger` supersteps apart so they *pipeline* through the
    /// columns.  Width 1 reproduces the per-target event plane the paper
    /// describes; dosages are bit-identical for every width and injection
    /// schedule (`tests/parallel_equivalence.rs`).
    ///
    /// A size larger than the target count clamps to it; `0` is rejected by
    /// [`ImputeSession::run`] as an error (not a panic — batch sizes often
    /// arrive from flags and requests, i.e. untrusted input).
    pub fn batch(mut self, batch_size: usize) -> Self {
        self.batch = Some(batch_size);
        self
    }

    /// Execute: prepare the engine, run every batch in order, score accuracy
    /// when truth is available, and assemble the report.
    pub fn run(self) -> Result<ImputeReport, String> {
        let n_targets = self.workload.n_targets();
        if n_targets == 0 {
            return Err("workload has no targets".into());
        }
        let batch_size = match self.batch {
            Some(0) => {
                return Err(
                    "batch size 0 (must be >= 1; omit .batch() to run all targets at once)"
                        .into(),
                );
            }
            Some(n) => n.min(n_targets),
            None => n_targets,
        };
        let mut engine = build_engine(self.spec, &self.app, self.mapping);

        engine.prepare(&self.workload)?;
        // Time only the batch runs: one-time preparation (panel binding,
        // XLA artifact loading) is excluded so `host_seconds` stays
        // comparable across planes and with the pre-session harnesses.
        let start = std::time::Instant::now();
        let mut dosages: Vec<Vec<f32>> = Vec::with_capacity(n_targets);
        let mut sim_seconds: Option<f64> = None;
        let mut metrics: Option<SimMetrics> = None;
        let mut trace: Option<crate::obs::RunTrace> = None;
        let mut n_batches = 0usize;
        for batch in self.workload.batches(batch_size) {
            let out = engine.run(&batch)?;
            if out.dosages.len() != batch.len() {
                return Err(format!(
                    "{} engine returned {} dosage rows for a {}-target batch",
                    self.spec.name(),
                    out.dosages.len(),
                    batch.len()
                ));
            }
            dosages.extend(out.dosages);
            if let Some(s) = out.sim_seconds {
                *sim_seconds.get_or_insert(0.0) += s;
            }
            if let Some(m) = out.metrics {
                match &mut metrics {
                    None => metrics = Some(m),
                    Some(acc) => acc.absorb(&m),
                }
            }
            if let Some(t) = out.trace {
                match &mut trace {
                    None => trace = Some(t),
                    Some(acc) => acc.absorb(t),
                }
            }
            n_batches += 1;
        }
        let host_seconds = start.elapsed().as_secs_f64();

        let accuracy = self
            .workload
            .truth()
            .map(|truth| accuracy::score_set(&dosages, truth, self.workload.targets()));

        Ok(ImputeReport {
            engine: self.spec,
            n_hap: self.workload.panel().n_hap(),
            n_mark: self.workload.panel().n_mark(),
            n_targets,
            panel: None,
            provenance: self.workload.provenance().copied(),
            batch_size,
            n_batches,
            windows: None,
            boards: self.app.cluster.n_boards,
            states_per_thread: self.app.states_per_thread,
            threads: self.app.sim.threads.unwrap_or(1),
            mapping: self.mapping,
            dosages,
            accuracy,
            host_seconds,
            sim_seconds,
            metrics,
            stream: None,
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::panelgen::PanelConfig;

    fn wl(n_targets: usize) -> Workload {
        let cfg = PanelConfig {
            n_hap: 8,
            n_mark: 21,
            maf: 0.2,
            annot_ratio: 0.2,
            seed: 31,
            ..PanelConfig::default()
        };
        Workload::synthetic(&cfg, n_targets)
    }

    #[test]
    fn baseline_session_scores_accuracy() {
        let report = ImputeSession::new(wl(3))
            .engine(EngineSpec::Baseline)
            .run()
            .unwrap();
        assert_eq!(report.dosages.len(), 3);
        assert_eq!(report.n_batches, 1);
        assert_eq!(report.batch_size, 3);
        let acc = report.accuracy.expect("synthetic workload has truth");
        assert!(acc.n_scored > 0);
        assert!(report.sim_seconds.is_none());
    }

    #[test]
    fn event_session_reports_sim_plane() {
        let report = ImputeSession::new(wl(2))
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .run()
            .unwrap();
        assert!(report.sim_seconds.unwrap() > 0.0);
        let m = report.metrics.expect("event plane reports metrics");
        assert!(m.sends > 0);
        assert_eq!(report.boards, 1);
        assert_eq!(report.states_per_thread, 8);
    }

    #[test]
    fn batching_splits_and_accumulates() {
        let report = ImputeSession::new(wl(5))
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .batch(2)
            .run()
            .unwrap();
        assert_eq!(report.n_batches, 3);
        assert_eq!(report.batch_size, 2);
        assert_eq!(report.dosages.len(), 5);
        // Metrics accumulate across batches: 3 sequential runs' steps.
        let m = report.metrics.unwrap();
        assert_eq!(m.step_durations.len() as u64, m.steps);
    }

    #[test]
    fn traced_event_session_folds_batches_into_segments() {
        let report = ImputeSession::new(wl(4))
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .batch(2)
            .trace(crate::obs::TraceConfig::default())
            .run()
            .unwrap();
        let t = report.trace.as_ref().expect("trace was requested");
        assert_eq!(t.segments, 2, "one segment per engine batch");
        assert!(t.total_steps > 0);
        // Engines fill the wavefront column stride from the panel shape.
        assert_eq!(t.col_stride, Some(8));
        assert!(report.to_json().get("trace").is_some(), "manifest summary block");
        // Untraced runs carry (and pay) nothing.
        let plain = ImputeSession::new(wl(1))
            .engine(EngineSpec::Event)
            .boards(1)
            .states_per_thread(8)
            .run()
            .unwrap();
        assert!(plain.trace.is_none());
    }

    #[test]
    fn scenario_session_reports_link_telemetry_without_tracing() {
        use crate::util::json::Json;
        let spec = crate::poets::ScenarioSpec::parse(
            "name=lab,boards=2,tiles=4,cores=2,threads=4,bw=0.5",
        )
        .expect("spec");
        let report = ImputeSession::new(wl(2))
            .engine(EngineSpec::Event)
            .scenario(spec)
            .states_per_thread(4)
            .run()
            .unwrap();
        assert_eq!(report.boards, 2, "scenario sets the cluster shape");
        let m = report.metrics.as_ref().expect("event plane reports metrics");
        assert!(m.inter_board_copies > 0, "42 threads must span both boards");
        assert!(m.link_events_total > 0);
        assert_eq!(
            m.intra_tile_copies + m.inter_tile_copies + m.inter_board_copies,
            m.copies_delivered
        );
        // Link totals land in the manifest even with tracing off.
        let j = report.to_json();
        let sm = j.get("sim_metrics").expect("sim_metrics block");
        assert!(sm.get("link_events_total").and_then(Json::as_i64).unwrap() > 0);
        assert!(sm.get("max_link_utilisation").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(sm.get("board_traffic").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn oversized_batch_clamps_to_target_count() {
        let report = ImputeSession::new(wl(2))
            .engine(EngineSpec::Rank1)
            .batch(64)
            .run()
            .unwrap();
        assert_eq!(report.batch_size, 2);
        assert_eq!(report.n_batches, 1);
    }

    #[test]
    fn zero_batch_is_an_error_not_a_panic() {
        let err = ImputeSession::new(wl(2))
            .engine(EngineSpec::Baseline)
            .batch(0)
            .run()
            .unwrap_err();
        assert!(err.contains("batch size 0"), "{err}");
    }

    #[test]
    fn empty_workload_is_an_error() {
        let base = wl(1);
        let empty = Workload::from_parts(base.panel().clone(), Vec::new());
        let err = ImputeSession::new(empty).run().unwrap_err();
        assert!(err.contains("no targets"), "{err}");
    }

    #[test]
    fn workload_without_truth_skips_scoring() {
        let base = wl(2);
        let bare = Workload::from_parts(base.panel().clone(), base.targets().to_vec());
        let report = ImputeSession::new(bare)
            .engine(EngineSpec::Rank1)
            .run()
            .unwrap();
        assert!(report.accuracy.is_none());
        assert_eq!(report.dosages.len(), 2);
    }
}
