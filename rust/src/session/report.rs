//! The serialisable result of one [`ImputeSession`](super::ImputeSession)
//! run: dosages, accuracy, host/simulated timings, DES counters and the run
//! manifest emitted as the `BENCH_*.json`-style JSON schema
//! (`poets-impute/impute-report/v1`).
//!
//! The serving layer derives its per-request response schema
//! (`poets-impute/serve-report/v1`) from this manifest: same `workload` /
//! `run` / `timing` (/ `accuracy` / `sim_metrics`) sections, plus a `serve`
//! section (queue wait, coalesce width, batch id, worker) and the dosages —
//! see [`crate::serve::report`] for the delta.  Tooling that reads one
//! schema reads both.

use crate::graph::mapping::MappingStrategy;
use crate::model::accuracy::Accuracy;
use crate::poets::metrics::SimMetrics;
use crate::util::json::Json;
use crate::util::table::{fmt_count, fmt_secs};
use crate::workload::panelgen::PanelConfig;

use super::engine::EngineSpec;

/// Telemetry from a streamed windowed run
/// ([`crate::genomics::stream::run_streamed`]): how bounded the pipeline's
/// working set actually stayed.
#[derive(Clone, Copy, Debug)]
pub struct StreamTelemetry {
    /// Peak number of window workloads resident at once (sliced by the
    /// builder thread but not yet drained through the engine).  The
    /// rendezvous channel bounds this at 2 — the window in the engine plus
    /// the one prefetched behind it — whatever the plan length.
    pub peak_resident_windows: usize,
    /// Total windows streamed (the plan length).
    pub windows_streamed: usize,
}

/// Everything one session run produced.
#[derive(Clone, Debug)]
pub struct ImputeReport {
    pub engine: EngineSpec,
    // Workload shape.
    pub n_hap: usize,
    pub n_mark: usize,
    pub n_targets: usize,
    /// Registry spec / panel name the workload ran against, when it came
    /// from a named source (`synth:` / `vcf:` / `packed:`) rather than
    /// inline generation.
    pub panel: Option<String>,
    /// Generation recipe when the workload was synthetic.
    pub provenance: Option<PanelConfig>,
    // Run configuration.
    pub batch_size: usize,
    pub n_batches: usize,
    /// How many marker windows produced this report, when it was stitched
    /// by [`crate::genomics::window::run_windowed`] (absent: one full-width
    /// run).
    pub windows: Option<usize>,
    pub boards: usize,
    pub states_per_thread: usize,
    /// Host worker threads for the DES deliver/step phases.
    pub threads: usize,
    pub mapping: MappingStrategy,
    // Results.
    /// `dosages[target][marker]`, in workload target order.
    pub dosages: Vec<Vec<f32>>,
    /// Aggregate accuracy against withheld truth (synthetic workloads only).
    pub accuracy: Option<Accuracy>,
    /// Host wall-clock seconds spent running all batches (one-time engine
    /// preparation — panel binding, XLA artifact loading — excluded).
    pub host_seconds: f64,
    /// Total simulated POETS wall-clock seconds (event planes only).
    pub sim_seconds: Option<f64>,
    /// DES counters accumulated over all batches (event planes only).
    pub metrics: Option<SimMetrics>,
    /// Streaming telemetry, when the report came from a streamed windowed
    /// run (absent: all windows were materialised up front or there was no
    /// windowing at all).
    pub stream: Option<StreamTelemetry>,
    /// Per-superstep DES trace (event planes, opt-in via
    /// `ImputeSession::trace` / `impute --trace`).  The manifest serialises
    /// only a summary block; the full `poets-impute/trace/v1` JSONL is
    /// written by the CLI's `--trace PATH`.
    pub trace: Option<crate::obs::RunTrace>,
}

impl ImputeReport {
    /// The run manifest (schema `poets-impute/impute-report/v1`).  Dosages
    /// are deliberately not serialised — the manifest is the provenance +
    /// metrics record benches archive as `BENCH_*.json`.
    pub fn to_json(&self) -> Json {
        let mut workload = Json::obj();
        workload
            .set("n_hap", self.n_hap)
            .set("n_mark", self.n_mark)
            .set("n_targets", self.n_targets);
        if let Some(name) = &self.panel {
            workload.set("panel", name.as_str());
        }
        if let Some(p) = &self.provenance {
            workload
                .set("maf", p.maf)
                .set("annot_ratio", p.annot_ratio)
                .set("seed", p.seed);
        }

        let mut run = Json::obj();
        run.set("batch_size", self.batch_size)
            .set("n_batches", self.n_batches);
        if let Some(w) = self.windows {
            run.set("windows", w);
        }
        run.set("boards", self.boards)
            .set("states_per_thread", self.states_per_thread)
            .set("threads", self.threads)
            .set("mapping", self.mapping.name());

        let mut timing = Json::obj();
        timing.set("host_seconds", self.host_seconds);
        if let Some(s) = self.sim_seconds {
            timing.set("poets_sim_seconds", s);
        }

        let mut j = Json::obj();
        j.set("schema", "poets-impute/impute-report/v1")
            .set("engine", self.engine.name())
            .set("workload", workload)
            .set("run", run)
            .set("timing", timing);
        if let Some(a) = &self.accuracy {
            let mut acc = Json::obj();
            acc.set("concordance", a.concordance)
                .set("minor_concordance", a.minor_concordance)
                .set("dosage_r2", a.dosage_r2)
                .set("n_scored", a.n_scored);
            j.set("accuracy", acc);
        }
        if let Some(m) = &self.metrics {
            j.set("sim_metrics", m.to_json());
        }
        if let Some(s) = &self.stream {
            let mut stream = Json::obj();
            stream
                .set("peak_resident_windows", s.peak_resident_windows)
                .set("windows_streamed", s.windows_streamed);
            j.set("stream", stream);
        }
        if let Some(t) = &self.trace {
            let mut trace = Json::obj();
            trace
                .set("n_tiles", t.n_tiles as u64)
                .set("segments", t.segments as u64)
                .set("total_steps", t.total_steps)
                .set("steps_recorded", t.steps.len())
                .set("dropped_steps", t.dropped_steps)
                .set("truncated", t.dropped_steps > 0);
            j.set("trace", trace);
        }
        j
    }

    /// Human-readable summary (the CLI's non-`--json` output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "engine={} panel={}x{} ({} states) targets={}",
            self.engine.name(),
            self.n_hap,
            self.n_mark,
            fmt_count((self.n_hap * self.n_mark) as u64),
            self.n_targets
        );
        if self.n_batches > 1 {
            out.push_str(&format!(
                " batches={} (size {})",
                self.n_batches, self.batch_size
            ));
        }
        out.push('\n');
        if let Some(a) = &self.accuracy {
            out.push_str(&format!(
                "accuracy: concordance={:.4} minor={:.4} dosage_r2={:.4} (scored {} markers)\n",
                a.concordance,
                a.minor_concordance,
                a.dosage_r2,
                fmt_count(a.n_scored as u64)
            ));
        }
        out.push_str(&format!("host wall-clock: {}", fmt_secs(self.host_seconds)));
        if let Some(s) = self.sim_seconds {
            out.push_str(&format!(
                "\nsimulated POETS wall-clock: {}",
                fmt_secs(s)
            ));
        }
        out
    }

    /// Max |Δdosage| between this report and another dosage set (the
    /// `validate` currency).
    pub fn max_abs_diff(&self, other: &[Vec<f32>]) -> f64 {
        max_abs_dosage_diff(&self.dosages, other)
    }
}

/// Max |Δdosage| over two equally-shaped dosage sets.
pub fn max_abs_dosage_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert_eq!(a.len(), b.len(), "dosage sets have different target counts");
    let mut worst = 0.0f64;
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.len(), rb.len(), "dosage rows have different lengths");
        for (x, y) in ra.iter().zip(rb) {
            worst = worst.max((x - y).abs() as f64);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ImputeReport {
        ImputeReport {
            engine: EngineSpec::Event,
            n_hap: 8,
            n_mark: 21,
            n_targets: 2,
            panel: None,
            provenance: None,
            batch_size: 2,
            n_batches: 1,
            windows: None,
            boards: 2,
            states_per_thread: 4,
            threads: 1,
            mapping: MappingStrategy::Manual2d,
            dosages: vec![vec![0.5; 21], vec![0.25; 21]],
            accuracy: None,
            host_seconds: 0.1,
            sim_seconds: Some(0.01),
            metrics: Some(SimMetrics::default()),
            stream: None,
            trace: None,
        }
    }

    #[test]
    fn manifest_has_schema_and_sections() {
        let j = report().to_json();
        assert_eq!(
            j.get("schema"),
            Some(&Json::Str("poets-impute/impute-report/v1".into()))
        );
        assert_eq!(j.get("engine"), Some(&Json::Str("event".into())));
        for key in ["workload", "run", "timing", "sim_metrics"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert!(j.get("accuracy").is_none(), "no truth, no accuracy");
        let run = j.get("run").unwrap();
        assert_eq!(run.get("n_batches"), Some(&Json::Int(1)));
        assert_eq!(run.get("mapping"), Some(&Json::Str("manual-2d".into())));
        // Optional source/windowing/streaming keys are absent unless set.
        assert!(j.get("workload").unwrap().get("panel").is_none());
        assert!(run.get("windows").is_none());
        assert!(j.get("stream").is_none());
        assert!(j.get("trace").is_none(), "trace block is opt-in");
    }

    #[test]
    fn trace_summary_serialises_when_present() {
        let mut r = report();
        let mut t = crate::obs::RunTrace::new(crate::obs::TraceConfig::default(), 4);
        t.total_steps = 9;
        t.dropped_steps = 2;
        r.trace = Some(t);
        let j = r.to_json();
        let block = j.get("trace").expect("trace block");
        assert_eq!(block.get("n_tiles"), Some(&Json::Int(4)));
        assert_eq!(block.get("total_steps"), Some(&Json::Int(9)));
        assert_eq!(block.get("dropped_steps"), Some(&Json::Int(2)));
        assert_eq!(block.get("steps_recorded"), Some(&Json::Int(0)));
    }

    #[test]
    fn stream_telemetry_serialises_when_present() {
        let mut r = report();
        r.stream = Some(StreamTelemetry {
            peak_resident_windows: 2,
            windows_streamed: 7,
        });
        let j = r.to_json();
        let s = j.get("stream").expect("stream block");
        assert_eq!(s.get("peak_resident_windows"), Some(&Json::Int(2)));
        assert_eq!(s.get("windows_streamed"), Some(&Json::Int(7)));
    }

    #[test]
    fn panel_and_windows_serialise_when_present() {
        let mut r = report();
        r.panel = Some("packed:chr20.ppnl".into());
        r.windows = Some(3);
        let j = r.to_json();
        assert_eq!(
            j.get("workload").unwrap().get("panel"),
            Some(&Json::Str("packed:chr20.ppnl".into()))
        );
        assert_eq!(j.get("run").unwrap().get("windows"), Some(&Json::Int(3)));
    }

    #[test]
    fn render_mentions_engine_and_timing() {
        let text = report().render();
        assert!(text.contains("engine=event"));
        assert!(text.contains("host wall-clock"));
        assert!(text.contains("simulated POETS wall-clock"));
    }

    #[test]
    fn diff_is_symmetric_max() {
        let a = vec![vec![0.0f32, 0.5], vec![1.0, 0.25]];
        let b = vec![vec![0.1f32, 0.5], vec![1.0, 0.75]];
        assert!((max_abs_dosage_diff(&a, &b) - 0.5).abs() < 1e-9);
        assert!((max_abs_dosage_diff(&b, &a) - 0.5).abs() < 1e-9);
    }
}
