//! The [`Engine`] trait and its five implementations — one per compute plane.
//!
//! Every execution strategy the paper evaluates is an `Engine`: bind it to a
//! workload with [`Engine::prepare`], then feed it [`TargetBatch`]es with
//! [`Engine::run`].  The trait is object-safe so the session can treat all
//! planes uniformly; engines are stateful (prepare stores the bound panel,
//! and the XLA plane opens its PJRT runtime there).
//!
//! The event-driven planes specialise their application graph per batch (the
//! observation matrix and target count are baked into vertex state), so graph
//! construction happens inside `run`, not `prepare`.

use std::sync::Arc;

use crate::graph::mapping::MappingStrategy;
use crate::imputation::app::{EventRunResult, RawAppConfig, build_raw_graph, extract_results};
use crate::imputation::interp_app::{build_interp_graph, extract_interp_results};
use crate::model::baseline::{Baseline, ImputeOut, Method};
use crate::model::panel::ReferencePanel;
use crate::obs::trace::RunTrace;
use crate::poets::desim::{SimConfig, Simulator};
use crate::poets::metrics::SimMetrics;
use crate::runtime::{Runtime, XlaImputer};

use super::workload::{TargetBatch, Workload};

/// Which compute plane to run — the typed replacement for the stringly
/// `--engine` flag.  All five planes compute Li & Stephens dosages; they
/// differ in arithmetic formulation and execution substrate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineSpec {
    /// x86-style dense three-loop baseline (the paper's §6.1 comparison
    /// point, and the oracle the other planes are checked against).
    Baseline,
    /// x86 baseline using the rank-1 transition structure (the "further
    /// optimised x86"; also the arithmetic the Pallas kernels implement).
    Rank1,
    /// Event-driven raw graph on the simulated POETS cluster (§5.2).
    Event,
    /// Event-driven linear-interpolation graph (§5.3): HMM at annotated
    /// anchors only, linear interpolation in between.
    Interp,
    /// AOT JAX/Pallas artifacts executed through PJRT (the fast compute
    /// plane; unavailable without the `pjrt` feature + built artifacts).
    Xla,
}

impl EngineSpec {
    /// Every plane, in oracle-first order.
    pub const ALL: [EngineSpec; 5] = [
        EngineSpec::Baseline,
        EngineSpec::Rank1,
        EngineSpec::Event,
        EngineSpec::Interp,
        EngineSpec::Xla,
    ];

    /// The `--engine` spelling.
    pub fn name(self) -> &'static str {
        match self {
            EngineSpec::Baseline => "baseline",
            EngineSpec::Rank1 => "rank1",
            EngineSpec::Event => "event",
            EngineSpec::Interp => "interp",
            EngineSpec::Xla => "xla",
        }
    }

    /// Max |Δdosage| this plane is allowed against its oracle (see
    /// [`EngineSpec::oracle_name`]); the tolerances the repo's equivalence
    /// tests have always enforced.
    pub fn tolerance(self) -> f64 {
        match self {
            EngineSpec::Baseline => 0.0,
            EngineSpec::Rank1 => 1e-4,
            EngineSpec::Event => 1e-3,
            EngineSpec::Interp => 2e-3,
            EngineSpec::Xla => 1e-3,
        }
    }

    /// What this plane's dosages are compared against.  The interpolated
    /// plane approximates the HMM by design, so its oracle is the x86
    /// interpolation pipeline, not the dense baseline.
    pub fn oracle_name(self) -> &'static str {
        match self {
            EngineSpec::Interp => "x86 interp",
            _ => "dense baseline",
        }
    }
}

impl std::str::FromStr for EngineSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<EngineSpec, String> {
        match s {
            "baseline" | "dense" => Ok(EngineSpec::Baseline),
            "rank1" => Ok(EngineSpec::Rank1),
            "event" => Ok(EngineSpec::Event),
            "interp" => Ok(EngineSpec::Interp),
            // The pre-session CLI spelling: accepted so old scripts keep
            // working, but deprecated in favour of "interp" (the parser-level
            // analogue of a #[deprecated] item — there is no attribute for
            // match arms, so the nudge goes to stderr).
            "event-interp" => {
                eprintln!(
                    "warning: engine spelling \"event-interp\" is deprecated; use \"interp\""
                );
                Ok(EngineSpec::Interp)
            }
            "xla" => Ok(EngineSpec::Xla),
            other => Err(format!(
                "unknown engine {other:?} (expected baseline|rank1|event|interp|xla)"
            )),
        }
    }
}

impl std::fmt::Display for EngineSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What an engine produces for one batch.
#[derive(Clone, Debug)]
pub struct EngineOutput {
    /// `dosages[target_in_batch][marker]`.
    pub dosages: Vec<Vec<f32>>,
    /// Simulated POETS wall-clock seconds (event planes only).
    pub sim_seconds: Option<f64>,
    /// DES counters (event planes only).
    pub metrics: Option<SimMetrics>,
    /// Per-superstep trace (event planes with `SimConfig::trace` set only).
    pub trace: Option<RunTrace>,
}

impl EngineOutput {
    fn host_only(dosages: Vec<Vec<f32>>) -> EngineOutput {
        EngineOutput {
            dosages,
            sim_seconds: None,
            metrics: None,
            trace: None,
        }
    }

    fn from_event(res: EventRunResult) -> EngineOutput {
        EngineOutput {
            dosages: res.dosages,
            sim_seconds: Some(res.sim_seconds),
            metrics: Some(res.metrics),
            trace: res.trace,
        }
    }
}

/// The event planes lay vertices out column-major (`v = col·H + h` for the
/// raw plane, anchor-major with the same haplotype stride for the interp
/// plane), so the wavefront column of a vertex is `v / n_hap`.  Fill that
/// stride into an enabled trace config unless the caller already set one.
fn trace_cfg_for_panel(mut sim: SimConfig, panel: &ReferencePanel) -> SimConfig {
    if let Some(tc) = sim.trace.as_mut() {
        if tc.col_stride.is_none() {
            tc.col_stride = Some(panel.n_hap() as u32);
        }
    }
    sim
}

/// A compute plane bound to (at most) one workload at a time.
///
/// Lifecycle: `prepare` binds the workload (shares the panel via `Arc`,
/// opens runtimes, validates shapes), then `run` services target batches
/// against it.  `run` before `prepare` is an error.
pub trait Engine {
    /// Which plane this is (for reports and error messages).
    fn spec(&self) -> EngineSpec;

    /// Bind the engine to a workload.
    fn prepare(&mut self, workload: &Workload) -> Result<(), String>;

    /// Whether [`Engine::prepare`] inspects the workload's *targets* (and so
    /// must be re-run for every distinct target set), or only binds shared
    /// state like the panel.  Batching layers (the serve worker pool) use
    /// this to bind target-independent engines once per coalesced group
    /// instead of once per request.  Default: targets are not inspected.
    fn prepare_inspects_targets(&self) -> bool {
        false
    }

    /// Impute every target in `batch`, in order.
    fn run(&mut self, batch: &TargetBatch<'_>) -> Result<EngineOutput, String>;
}

/// Instantiate the engine for a spec.  `app` carries the shared knobs (model
/// params, cluster shape, soft-scheduling, host threads); `mapping` selects
/// the vertex→thread strategy for the event planes.
pub fn build_engine(
    spec: EngineSpec,
    app: &RawAppConfig,
    mapping: MappingStrategy,
) -> Box<dyn Engine> {
    match spec {
        EngineSpec::Baseline => Box::new(BaselineEngine::new(Method::DenseThreeLoop, app.clone())),
        EngineSpec::Rank1 => Box::new(BaselineEngine::new(Method::Rank1, app.clone())),
        EngineSpec::Event => Box::new(EventEngine::new(app.clone(), mapping)),
        EngineSpec::Interp => Box::new(InterpEngine::new(app.clone(), mapping)),
        EngineSpec::Xla => Box::new(XlaEngine::new(app.clone())),
    }
}

fn bound_panel<'a>(
    panel: &'a Option<Arc<ReferencePanel>>,
    spec: EngineSpec,
) -> Result<&'a ReferencePanel, String> {
    panel
        .as_deref()
        .ok_or_else(|| format!("{} engine: run() before prepare()", spec.name()))
}

/// The x86 baseline planes (dense three-loop and rank-1), run sequentially —
/// exactly the paper's single-threaded comparison point.
pub struct BaselineEngine {
    method: Method,
    baseline: Baseline,
    panel: Option<Arc<ReferencePanel>>,
}

impl BaselineEngine {
    pub fn new(method: Method, app: RawAppConfig) -> BaselineEngine {
        BaselineEngine {
            method,
            baseline: Baseline::new(app.params),
            panel: None,
        }
    }
}

impl Engine for BaselineEngine {
    fn spec(&self) -> EngineSpec {
        match self.method {
            Method::DenseThreeLoop => EngineSpec::Baseline,
            Method::Rank1 => EngineSpec::Rank1,
        }
    }

    fn prepare(&mut self, workload: &Workload) -> Result<(), String> {
        self.panel = Some(workload.panel_arc());
        Ok(())
    }

    fn run(&mut self, batch: &TargetBatch<'_>) -> Result<EngineOutput, String> {
        let panel = bound_panel(&self.panel, self.spec())?;
        let outs: Vec<ImputeOut<f32>> =
            self.baseline.impute_batch(panel, batch.targets(), self.method);
        Ok(EngineOutput::host_only(
            outs.into_iter().map(|o| o.dosage).collect(),
        ))
    }
}

/// The event-driven raw plane: one vertex per HMM state on the simulated
/// POETS cluster.
///
/// `run` consumes the whole [`TargetBatch`] in one engine invocation: the
/// batch is split into lane groups of at most `LANES` targets, each group
/// travelling the panel as one SoA wave (chunked to the 56-byte event budget
/// — see `imputation::msg`), with group *g* injected at the edge columns
/// `g·stagger` supersteps after its predecessor so successive groups
/// *pipeline* through the columns instead of running back-to-back engine
/// invocations.  Per-target numerics are batch-width, group-schedule and
/// thread-count invariant (canonical per-group sender-order reduce in
/// `imputation::vertex`), which is what lets the serve coalescer merge
/// several requests' targets into one batch and still answer each request
/// bit-identically to a solo run.
pub struct EventEngine {
    cfg: RawAppConfig,
    mapping: MappingStrategy,
    panel: Option<Arc<ReferencePanel>>,
}

impl EventEngine {
    pub fn new(cfg: RawAppConfig, mapping: MappingStrategy) -> EventEngine {
        EventEngine {
            cfg,
            mapping,
            panel: None,
        }
    }
}

impl Engine for EventEngine {
    fn spec(&self) -> EngineSpec {
        EngineSpec::Event
    }

    fn prepare(&mut self, workload: &Workload) -> Result<(), String> {
        self.panel = Some(workload.panel_arc());
        Ok(())
    }

    fn run(&mut self, batch: &TargetBatch<'_>) -> Result<EngineOutput, String> {
        if batch.is_empty() {
            return Err("event engine: empty target batch".into());
        }
        let panel = bound_panel(&self.panel, EngineSpec::Event)?;
        let graph = build_raw_graph(panel, batch.targets(), &self.cfg);
        let mapping = self
            .mapping
            .build(&graph, self.cfg.states_per_thread, &self.cfg.cluster);
        let sim_cfg = trace_cfg_for_panel(self.cfg.sim, panel);
        let mut sim = Simulator::with_scenario(
            graph,
            mapping,
            self.cfg.cluster,
            self.cfg.cost,
            sim_cfg,
            self.cfg.scenario.as_ref(),
        );
        sim.run();
        let mut res = extract_results(&sim, panel, batch.len());
        res.trace = sim.take_trace();
        Ok(EngineOutput::from_event(res))
    }
}

/// The event-driven linear-interpolation plane: one vertex per anchor-state
/// section.
pub struct InterpEngine {
    cfg: RawAppConfig,
    mapping: MappingStrategy,
    panel: Option<Arc<ReferencePanel>>,
}

impl InterpEngine {
    pub fn new(cfg: RawAppConfig, mapping: MappingStrategy) -> InterpEngine {
        InterpEngine {
            cfg,
            mapping,
            panel: None,
        }
    }
}

impl Engine for InterpEngine {
    fn spec(&self) -> EngineSpec {
        EngineSpec::Interp
    }

    /// `prepare` validates the workload's annotation grid, so it must see
    /// each request's own targets (see [`Engine::prepare_inspects_targets`]).
    fn prepare_inspects_targets(&self) -> bool {
        true
    }

    fn prepare(&mut self, workload: &Workload) -> Result<(), String> {
        // All targets must share one annotation grid with >= 2 anchors
        // (chips type the same loci for every sample).
        let anchors = match workload.targets().first() {
            Some(t) => t.annotated(),
            None => Vec::new(),
        };
        if !workload.targets().is_empty() && anchors.len() < 2 {
            return Err("interp engine: targets have < 2 annotated markers".into());
        }
        for t in workload.targets() {
            if t.annotated() != anchors {
                return Err("interp engine: targets disagree on the annotation grid".into());
            }
        }
        self.panel = Some(workload.panel_arc());
        Ok(())
    }

    fn run(&mut self, batch: &TargetBatch<'_>) -> Result<EngineOutput, String> {
        if batch.is_empty() {
            return Err("interp engine: empty target batch".into());
        }
        let panel = bound_panel(&self.panel, EngineSpec::Interp)?;
        let anchors = batch.targets()[0].annotated();
        let graph = build_interp_graph(panel, batch.targets(), &anchors, &self.cfg);
        let mapping =
            self.mapping
                .build(&graph, self.cfg.states_per_thread.max(1), &self.cfg.cluster);
        let sim_cfg = trace_cfg_for_panel(self.cfg.sim, panel);
        let mut sim = Simulator::with_scenario(
            graph,
            mapping,
            self.cfg.cluster,
            self.cfg.cost,
            sim_cfg,
            self.cfg.scenario.as_ref(),
        );
        sim.run();
        let mut res = extract_interp_results(&sim, panel, &anchors, batch.len());
        res.trace = sim.take_trace();
        Ok(EngineOutput::from_event(res))
    }
}

/// The AOT JAX/Pallas plane through PJRT.  `prepare` opens the artifact
/// runtime — in offline builds (no `pjrt` feature) or without built
/// artifacts this fails with a clear message and the session surfaces it.
pub struct XlaEngine {
    cfg: RawAppConfig,
    imputer: Option<XlaImputer>,
    panel: Option<Arc<ReferencePanel>>,
}

impl XlaEngine {
    pub fn new(cfg: RawAppConfig) -> XlaEngine {
        XlaEngine {
            cfg,
            imputer: None,
            panel: None,
        }
    }
}

impl Engine for XlaEngine {
    fn spec(&self) -> EngineSpec {
        EngineSpec::Xla
    }

    fn prepare(&mut self, workload: &Workload) -> Result<(), String> {
        let rt = Runtime::open_default().map_err(|e| e.to_string())?;
        self.imputer = Some(XlaImputer::new(rt, self.cfg.params));
        self.panel = Some(workload.panel_arc());
        Ok(())
    }

    fn run(&mut self, batch: &TargetBatch<'_>) -> Result<EngineOutput, String> {
        let panel = bound_panel(&self.panel, EngineSpec::Xla)?;
        let imputer = self
            .imputer
            .as_mut()
            .ok_or("xla engine: run() before prepare()")?;
        let dosages = imputer
            .impute_batch(panel, batch.targets())
            .map_err(|e| e.to_string())?;
        Ok(EngineOutput::host_only(dosages))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::panelgen::PanelConfig;

    fn wl() -> Workload {
        let cfg = PanelConfig {
            n_hap: 6,
            n_mark: 21,
            maf: 0.25,
            annot_ratio: 0.2,
            seed: 9,
            ..PanelConfig::default()
        };
        Workload::synthetic(&cfg, 2)
    }

    #[test]
    fn spec_parse_roundtrip() {
        for spec in EngineSpec::ALL {
            assert_eq!(spec.name().parse::<EngineSpec>().unwrap(), spec);
        }
        assert!("frobnicate".parse::<EngineSpec>().is_err());
    }

    #[test]
    fn interp_and_deprecated_event_interp_both_parse() {
        // Both the current spelling and the pre-session alias must keep
        // working (the alias additionally prints a deprecation note to
        // stderr, which tests can't observe without capturing the stream).
        assert_eq!("interp".parse::<EngineSpec>().unwrap(), EngineSpec::Interp);
        assert_eq!(
            "event-interp".parse::<EngineSpec>().unwrap(),
            EngineSpec::Interp
        );
    }

    #[test]
    fn run_before_prepare_is_an_error() {
        let wl = wl();
        let mut e = BaselineEngine::new(Method::Rank1, RawAppConfig::default());
        let err = e.run(&wl.full_batch()).unwrap_err();
        assert!(err.contains("before prepare"), "{err}");
    }

    #[test]
    fn baseline_engine_runs_a_batch() {
        let wl = wl();
        let mut e = BaselineEngine::new(Method::DenseThreeLoop, RawAppConfig::default());
        e.prepare(&wl).unwrap();
        let out = e.run(&wl.full_batch()).unwrap();
        assert_eq!(out.dosages.len(), 2);
        assert_eq!(out.dosages[0].len(), 21);
        assert!(out.sim_seconds.is_none());
        assert!(out.metrics.is_none());
    }

    #[test]
    fn interp_engine_rejects_mismatched_grids() {
        let wl = wl();
        let mut odd = wl.targets()[0].clone();
        // Annotate one extra marker so the grids disagree.
        let extra = odd.obs.iter().position(|&o| o < 0).unwrap();
        odd.obs[extra] = 0;
        let bad = Workload::from_parts(
            wl.panel().clone(),
            vec![wl.targets()[0].clone(), odd],
        );
        let mut e = InterpEngine::new(RawAppConfig::default(), MappingStrategy::Manual2d);
        let err = e.prepare(&bad).unwrap_err();
        assert!(err.contains("annotation grid"), "{err}");
    }
}
