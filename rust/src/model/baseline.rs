//! The single-threaded baseline — paper §6.1.
//!
//! Two arithmetic formulations, same results:
//!
//! * [`Method::DenseThreeLoop`] — the paper's baseline verbatim: "three simple
//!   for loops", the innermost computing one alpha/beta from all |H| values of
//!   the neighbouring column via eqs. (2)/(3).  O(H²M) — the same number of
//!   multiply-accumulate terms the event-driven graph evaluates with one
//!   message each, so figure speedups compare matched optimisation levels.
//! * [`Method::Rank1`] — the O(HM) form using the rank-1 structure of the
//!   transition matrix (one column-sum per step).  This is the "further
//!   optimised x86" used for honesty checks and is the arithmetic the Pallas
//!   kernels/XLA plane implement.
//!
//! Arithmetic is generic over [`Real`] (f32 to match the event-driven
//! vertices' message payloads; f64 as the oracle).

use super::panel::{ReferencePanel, TargetHaplotype};
use super::params::ModelParams;

/// Minimal float abstraction so the same recursion checks f32 vs f64.
pub trait Real:
    Copy
    + PartialOrd
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::fmt::Debug
{
    fn from64(x: f64) -> Self;
    fn to64(self) -> f64;
    const ZERO: Self;
    const ONE: Self;
}

impl Real for f32 {
    #[inline]
    fn from64(x: f64) -> f32 {
        x as f32
    }
    #[inline]
    fn to64(self) -> f64 {
        self as f64
    }
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
}

impl Real for f64 {
    #[inline]
    fn from64(x: f64) -> f64 {
        x
    }
    #[inline]
    fn to64(self) -> f64 {
        self
    }
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
}

/// Which baseline formulation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    DenseThreeLoop,
    Rank1,
}

/// Imputation output for one target haplotype.
#[derive(Clone, Debug)]
pub struct ImputeOut<T = f32> {
    /// Allele-1 dosage per marker (column-normalised posterior mass on
    /// allele-1 states).
    pub dosage: Vec<T>,
}

impl<T: Real> ImputeOut<T> {
    /// Hard-called alleles (major/minor decision, paper §5.2 step four).
    pub fn hard_calls(&self) -> Vec<u8> {
        self.dosage
            .iter()
            .map(|d| u8::from(d.to64() > 0.5))
            .collect()
    }
}

/// The baseline imputation engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct Baseline {
    pub params: ModelParams,
}

impl Baseline {
    pub fn new(params: ModelParams) -> Self {
        Baseline { params }
    }

    /// τ per column (τ[0] unused, kept for regular indexing).
    pub fn taus(&self, panel: &ReferencePanel) -> Vec<f64> {
        (0..panel.n_mark())
            .map(|m| {
                if m == 0 {
                    0.0
                } else {
                    self.params.tau(panel.gen_dist(m), panel.n_hap())
                }
            })
            .collect()
    }

    /// Forward variables, flattened `[m * H + h]`.
    pub fn forward<T: Real>(
        &self,
        panel: &ReferencePanel,
        target: &TargetHaplotype,
        method: Method,
    ) -> Vec<T> {
        let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
        assert_eq!(target.n_mark(), m_n, "target/panel marker count mismatch");
        let taus = self.taus(panel);
        let mut alphas = vec![T::ZERO; h_n * m_n];
        let init = T::from64(1.0 / h_n as f64);
        for h in 0..h_n {
            alphas[h] = init; // Algorithm 1 line 2: alpha <- 1/|H| at m=1.
        }
        for m in 1..m_n {
            let tau = taus[m];
            let a_same = T::from64(self.params.a_same(tau, h_n));
            let a_diff = T::from64(self.params.a_diff(tau, h_n));
            let (prev, cur) = alphas.split_at_mut(m * h_n);
            let prev = &prev[(m - 1) * h_n..];
            let cur = &mut cur[..h_n];
            match method {
                Method::DenseThreeLoop => {
                    // Paper baseline: innermost loop gathers all |H| terms.
                    for j in 0..h_n {
                        let mut acc = T::ZERO;
                        for (i, &p) in prev.iter().enumerate() {
                            let a_ij = if i == j { a_same } else { a_diff };
                            acc = acc + p * a_ij;
                        }
                        let b = T::from64(self.params.emission(panel.allele(j, m), target.obs[m]));
                        cur[j] = acc * b;
                    }
                }
                Method::Rank1 => {
                    // a_same = (1-τ) + τ/H and a_diff = τ/H, so the gather is
                    // (1-τ)·prev[j] + (τ/H)·Σ prev.
                    let mut sum = T::ZERO;
                    for &p in prev.iter() {
                        sum = sum + p;
                    }
                    let keep = a_same - a_diff; // (1-τ)
                    let leak = a_diff * sum; // (τ/H)·Σ
                    for j in 0..h_n {
                        let b = T::from64(self.params.emission(panel.allele(j, m), target.obs[m]));
                        cur[j] = (keep * prev[j] + leak) * b;
                    }
                }
            }
        }
        alphas
    }

    /// Backward variables, flattened `[m * H + h]`.
    pub fn backward<T: Real>(
        &self,
        panel: &ReferencePanel,
        target: &TargetHaplotype,
        method: Method,
    ) -> Vec<T> {
        let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
        assert_eq!(target.n_mark(), m_n, "target/panel marker count mismatch");
        let taus = self.taus(panel);
        let mut betas = vec![T::ZERO; h_n * m_n];
        for h in 0..h_n {
            betas[(m_n - 1) * h_n + h] = T::ONE; // Algorithm 1: beta <- 1 at m=M.
        }
        for m in (0..m_n - 1).rev() {
            let tau = taus[m + 1];
            let a_same = T::from64(self.params.a_same(tau, h_n));
            let a_diff = T::from64(self.params.a_diff(tau, h_n));
            // g_j = b_j(O_{m+1}) * beta_{m+1}(j)
            let mut g = vec![T::ZERO; h_n];
            for (j, gj) in g.iter_mut().enumerate() {
                let b = T::from64(
                    self.params
                        .emission(panel.allele(j, m + 1), target.obs[m + 1]),
                );
                *gj = b * betas[(m + 1) * h_n + j];
            }
            match method {
                Method::DenseThreeLoop => {
                    for i in 0..h_n {
                        let mut acc = T::ZERO;
                        for (j, &gj) in g.iter().enumerate() {
                            let a_ij = if i == j { a_same } else { a_diff };
                            acc = acc + a_ij * gj;
                        }
                        betas[m * h_n + i] = acc;
                    }
                }
                Method::Rank1 => {
                    let mut sum = T::ZERO;
                    for &gj in g.iter() {
                        sum = sum + gj;
                    }
                    let keep = a_same - a_diff;
                    let leak = a_diff * sum;
                    for i in 0..h_n {
                        betas[m * h_n + i] = keep * g[i] + leak;
                    }
                }
            }
        }
        betas
    }

    /// Posterior allele-1 dosage per marker from precomputed sweeps.
    pub fn dosage<T: Real>(
        &self,
        panel: &ReferencePanel,
        alphas: &[T],
        betas: &[T],
    ) -> Vec<T> {
        let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
        assert_eq!(alphas.len(), h_n * m_n);
        assert_eq!(betas.len(), h_n * m_n);
        let mut out = Vec::with_capacity(m_n);
        for m in 0..m_n {
            let mut tot = T::ZERO;
            let mut hit = T::ZERO;
            for h in 0..h_n {
                let p = alphas[m * h_n + h] * betas[m * h_n + h];
                tot = tot + p;
                if panel.allele(h, m) == 1 {
                    hit = hit + p;
                }
            }
            out.push(if tot.to64() > 0.0 { hit / tot } else { T::ZERO });
        }
        out
    }

    /// Full pipeline for one target.
    pub fn impute<T: Real>(
        &self,
        panel: &ReferencePanel,
        target: &TargetHaplotype,
        method: Method,
    ) -> ImputeOut<T> {
        let alphas = self.forward::<T>(panel, target, method);
        let betas = self.backward::<T>(panel, target, method);
        ImputeOut {
            dosage: self.dosage(panel, &alphas, &betas),
        }
    }

    /// Batch of targets, sequentially — exactly what the paper's
    /// single-threaded x86 comparison point does.
    pub fn impute_batch<T: Real>(
        &self,
        panel: &ReferencePanel,
        targets: &[TargetHaplotype],
        method: Method,
    ) -> Vec<ImputeOut<T>> {
        targets
            .iter()
            .map(|t| self.impute(panel, t, method))
            .collect()
    }

    /// Floating-point multiply-accumulate count for one target (used by the
    /// calibration bench and the cost-model cross-check).
    pub fn flops_per_target(&self, panel: &ReferencePanel, method: Method) -> u64 {
        let h = panel.n_hap() as u64;
        let m = panel.n_mark() as u64;
        let sweeps = match method {
            // fwd: H MACs per state; bwd: same + emission multiply.
            Method::DenseThreeLoop => 2 * (m - 1) * h * (2 * h + 1),
            Method::Rank1 => 2 * (m - 1) * (5 * h),
        };
        let posterior = m * (3 * h);
        sweeps + posterior
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

    fn problem(seed: u64, n_hap: usize, n_mark: usize) -> (ReferencePanel, TargetHaplotype) {
        let cfg = PanelConfig {
            n_hap,
            n_mark,
            maf: 0.25,
            annot_ratio: 0.3,
            seed,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let targets = generate_targets(&panel, &cfg, 1, &mut rng);
        (panel, targets.into_iter().next().unwrap().masked)
    }

    #[test]
    fn dense_matches_rank1_forward() {
        for seed in 0..5 {
            let (panel, target) = problem(seed, 10, 20);
            let b = Baseline::default();
            let d: Vec<f64> = b.forward(&panel, &target, Method::DenseThreeLoop);
            let r: Vec<f64> = b.forward(&panel, &target, Method::Rank1);
            for (x, y) in d.iter().zip(&r) {
                assert!((x - y).abs() <= 1e-12 * x.abs().max(1e-30), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn dense_matches_rank1_backward() {
        for seed in 0..5 {
            let (panel, target) = problem(seed, 10, 20);
            let b = Baseline::default();
            let d: Vec<f64> = b.backward(&panel, &target, Method::DenseThreeLoop);
            let r: Vec<f64> = b.backward(&panel, &target, Method::Rank1);
            for (x, y) in d.iter().zip(&r) {
                assert!((x - y).abs() <= 1e-12 * x.abs().max(1e-30), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn f32_tracks_f64() {
        let (panel, target) = problem(3, 12, 30);
        let b = Baseline::default();
        let lo: ImputeOut<f32> = b.impute(&panel, &target, Method::Rank1);
        let hi: ImputeOut<f64> = b.impute(&panel, &target, Method::Rank1);
        for (x, y) in lo.dosage.iter().zip(&hi.dosage) {
            assert!((x.to64() - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn initialisation_matches_algorithm1() {
        let (panel, target) = problem(4, 8, 10);
        let b = Baseline::default();
        let alphas: Vec<f64> = b.forward(&panel, &target, Method::Rank1);
        let betas: Vec<f64> = b.backward(&panel, &target, Method::Rank1);
        for h in 0..8 {
            assert!((alphas[h] - 1.0 / 8.0).abs() < 1e-15);
            assert_eq!(betas[9 * 8 + h], 1.0);
        }
    }

    #[test]
    fn likelihood_constant_across_columns() {
        let (panel, target) = problem(5, 10, 25);
        let b = Baseline::default();
        let alphas: Vec<f64> = b.forward(&panel, &target, Method::Rank1);
        let betas: Vec<f64> = b.backward(&panel, &target, Method::Rank1);
        let h_n = panel.n_hap();
        let lik: Vec<f64> = (0..panel.n_mark())
            .map(|m| (0..h_n).map(|h| alphas[m * h_n + h] * betas[m * h_n + h]).sum())
            .collect();
        for l in &lik {
            assert!((l - lik[0]).abs() < 1e-9 * lik[0].abs(), "{l} vs {}", lik[0]);
        }
    }

    #[test]
    fn dosage_bounded_and_hard_calls_binary() {
        let (panel, target) = problem(6, 14, 40);
        let b = Baseline::default();
        let out: ImputeOut<f32> = b.impute(&panel, &target, Method::Rank1);
        assert_eq!(out.dosage.len(), 40);
        for &d in &out.dosage {
            assert!((0.0..=1.0).contains(&d), "dosage {d} out of range");
        }
        assert!(out.hard_calls().iter().all(|&a| a <= 1));
    }

    #[test]
    fn perfect_copy_recovered() {
        // Target = exact copy of reference haplotype 0, fully observed.
        let cfg = PanelConfig {
            n_hap: 16,
            n_mark: 32,
            maf: 0.5,
            seed: 7,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let obs: Vec<i8> = panel.haplotype(0).iter().map(|&a| a as i8).collect();
        let target = TargetHaplotype::new(obs);
        let b = Baseline::default();
        let out: ImputeOut<f64> = b.impute(&panel, &target, Method::DenseThreeLoop);
        assert_eq!(out.hard_calls(), panel.haplotype(0));
    }

    #[test]
    fn unannotated_target_gives_allele_frequencies() {
        let cfg = PanelConfig {
            n_hap: 12,
            n_mark: 20,
            maf: 0.4,
            seed: 8,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let target = TargetHaplotype::new(vec![-1; 20]);
        let b = Baseline::default();
        let out: ImputeOut<f64> = b.impute(&panel, &target, Method::Rank1);
        for m in 0..20 {
            assert!(
                (out.dosage[m] - panel.allele_freq(m)).abs() < 1e-9,
                "m={m}: {} vs {}",
                out.dosage[m],
                panel.allele_freq(m)
            );
        }
    }

    #[test]
    fn flop_count_orders() {
        let (panel, _) = problem(9, 16, 32);
        let b = Baseline::default();
        let dense = b.flops_per_target(&panel, Method::DenseThreeLoop);
        let r1 = b.flops_per_target(&panel, Method::Rank1);
        assert!(dense > r1 * 2, "dense {dense} should dwarf rank1 {r1}");
    }
}
