//! Model constants and formulas — paper §3.2, equations (1)–(7).

/// Parameters of the Li & Stephens model.
///
/// `ne` is the effective population size ("simply a constant in the model");
/// `err` is the genotyping error rate e (1/10000 in the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelParams {
    pub ne: f64,
    pub err: f64,
}

impl Default for ModelParams {
    fn default() -> Self {
        ModelParams {
            ne: 50_000.0,
            err: 1e-4,
        }
    }
}

impl ModelParams {
    /// Paper eq. (1): `tau_m = 1 - exp(-4 Ne d_m / |H|)`.
    #[inline]
    pub fn tau(&self, d_m: f64, n_hap: usize) -> f64 {
        1.0 - (-4.0 * self.ne * d_m / n_hap as f64).exp()
    }

    /// Paper eq. (2): probability of *staying* on the same haplotype.
    #[inline]
    pub fn a_same(&self, tau_m: f64, n_hap: usize) -> f64 {
        (1.0 - tau_m) + tau_m / n_hap as f64
    }

    /// Paper eq. (3): probability of *jumping* to one specific other haplotype.
    #[inline]
    pub fn a_diff(&self, tau_m: f64, n_hap: usize) -> f64 {
        tau_m / n_hap as f64
    }

    /// Paper eqs. (6)/(7): emission given an annotated observation.
    /// `None` observation (unannotated) → 1.0 (the term "falls out").
    #[inline]
    pub fn emission(&self, state_allele: u8, obs: i8) -> f64 {
        if obs < 0 {
            1.0
        } else if state_allele as i8 == obs {
            1.0 - self.err
        } else {
            self.err
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_matches_formula() {
        let p = ModelParams::default();
        let t = p.tau(1e-6, 100);
        let want = 1.0 - f64::exp(-4.0 * 50_000.0 * 1e-6 / 100.0);
        assert!((t - want).abs() < 1e-15);
    }

    #[test]
    fn tau_zero_distance_is_zero() {
        let p = ModelParams::default();
        assert_eq!(p.tau(0.0, 10), 0.0);
    }

    #[test]
    fn tau_monotone_in_distance() {
        let p = ModelParams::default();
        let mut prev = -1.0;
        for k in 0..20 {
            let t = p.tau(1e-8 * 2f64.powi(k), 50);
            assert!(t > prev);
            prev = t;
        }
        assert!(prev < 1.0);
    }

    #[test]
    fn transition_row_sums_to_one() {
        let p = ModelParams::default();
        for &n in &[2usize, 7, 100] {
            for &tau in &[0.0, 0.3, 0.99] {
                let total = p.a_same(tau, n) + (n - 1) as f64 * p.a_diff(tau, n);
                assert!((total - 1.0).abs() < 1e-12, "n={n} tau={tau}");
            }
        }
    }

    #[test]
    fn emission_cases() {
        let p = ModelParams::default();
        assert_eq!(p.emission(0, -1), 1.0);
        assert_eq!(p.emission(1, 1), 1.0 - 1e-4);
        assert_eq!(p.emission(0, 1), 1e-4);
        assert_eq!(p.emission(1, 0), 1e-4);
    }
}
