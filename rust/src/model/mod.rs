//! The Li & Stephens imputation model (paper §3) and the x86-style baseline
//! implementation (paper §6.1).
//!
//! * [`params`] — model constants and the τ / transition / emission formulas
//!   (paper eqs. (1)–(7)).
//! * [`panel`] — reference panel, target haplotypes, observation encoding.
//! * [`baseline`] — the single-threaded baseline in both the paper's literal
//!   "three simple for loops" form (`dense_*`, O(H²M) — the arithmetic the
//!   event-driven graph also performs, message per term) and the rank-1
//!   optimised form (`rank1_*`, O(HM)).
//! * [`interpolation`] — the linear-interpolation optimisation (paper §5.3).
//! * [`accuracy`] — imputation-quality metrics (concordance, dosage r²).

pub mod accuracy;
pub mod baseline;
pub mod interpolation;
pub mod panel;
pub mod params;

pub use baseline::{Baseline, ImputeOut};
pub use panel::{Obs, ReferencePanel, TargetHaplotype};
pub use params::ModelParams;
