//! Imputation-quality metrics: concordance and dosage r².
//!
//! Scored only at *masked* markers (the ones the engine had to infer) — the
//! annotated ones were given away.  Dosage r² (squared Pearson correlation
//! between dosage and truth) is the field-standard imputation quality metric.

use crate::util::stats;

use super::panel::TargetHaplotype;

/// Accuracy summary for one imputed target.
#[derive(Clone, Copy, Debug, Default)]
pub struct Accuracy {
    /// Fraction of masked markers whose hard call matches the truth.
    pub concordance: f64,
    /// Concordance restricted to markers where the truth is the minor allele
    /// (the hard part — majority-vote gets the major ones for free).
    pub minor_concordance: f64,
    /// Squared Pearson correlation between dosage and truth at masked markers.
    pub dosage_r2: f64,
    /// Number of masked (scored) markers.
    pub n_scored: usize,
}

/// Score one imputation against the withheld truth.
pub fn score(dosage: &[f32], truth: &[u8], target: &TargetHaplotype) -> Accuracy {
    assert_eq!(dosage.len(), truth.len());
    assert_eq!(dosage.len(), target.obs.len());
    let mut hits = 0usize;
    let mut minor_hits = 0usize;
    let mut minor_total = 0usize;
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for m in 0..dosage.len() {
        if target.obs[m] >= 0 {
            continue; // annotated: not imputed, not scored
        }
        let call = u8::from(dosage[m] > 0.5);
        hits += usize::from(call == truth[m]);
        if truth[m] == 1 {
            minor_total += 1;
            minor_hits += usize::from(call == 1);
        }
        xs.push(dosage[m] as f64);
        ys.push(truth[m] as f64);
    }
    let n_scored = xs.len();
    let r = stats::pearson(&xs, &ys);
    Accuracy {
        concordance: if n_scored > 0 {
            hits as f64 / n_scored as f64
        } else {
            0.0
        },
        minor_concordance: if minor_total > 0 {
            minor_hits as f64 / minor_total as f64
        } else {
            1.0
        },
        dosage_r2: r * r,
        n_scored,
    }
}

/// Score a whole run: per-target scores against the withheld truth,
/// aggregated with markers-scored weighting.  The single convention shared
/// by `ImputeSession::run` and the windowed pipeline
/// (`genomics::window::run_windowed`) — keep them on this helper so the
/// scoring rules cannot drift apart.
pub fn score_set(
    dosages: &[Vec<f32>],
    truth: &[Vec<u8>],
    targets: &[TargetHaplotype],
) -> Accuracy {
    let per: Vec<Accuracy> = truth
        .iter()
        .zip(dosages)
        .zip(targets)
        .map(|((t, d), target)| score(d, t, target))
        .collect();
    aggregate(&per)
}

/// Aggregate accuracies across a batch of targets (weighted by markers scored).
pub fn aggregate(accs: &[Accuracy]) -> Accuracy {
    let total: usize = accs.iter().map(|a| a.n_scored).sum();
    if total == 0 {
        return Accuracy::default();
    }
    let w = |f: fn(&Accuracy) -> f64| -> f64 {
        accs.iter()
            .map(|a| f(a) * a.n_scored as f64)
            .sum::<f64>()
            / total as f64
    };
    Accuracy {
        concordance: w(|a| a.concordance),
        minor_concordance: w(|a| a.minor_concordance),
        dosage_r2: w(|a| a.dosage_r2),
        n_scored: total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_imputation_scores_one() {
        let truth = vec![0, 1, 0, 1];
        let target = TargetHaplotype::new(vec![0, -1, -1, -1]);
        let dosage = vec![0.0, 0.9, 0.1, 0.8];
        let a = score(&dosage, &truth, &target);
        assert_eq!(a.n_scored, 3);
        assert_eq!(a.concordance, 1.0);
        assert_eq!(a.minor_concordance, 1.0);
        assert!(a.dosage_r2 > 0.9);
    }

    #[test]
    fn wrong_calls_counted() {
        let truth = vec![1, 1, 0, 0];
        let target = TargetHaplotype::new(vec![-1; 4]);
        let dosage = vec![0.1, 0.9, 0.2, 0.8]; // wrong at 0 and 3
        let a = score(&dosage, &truth, &target);
        assert_eq!(a.concordance, 0.5);
        assert_eq!(a.minor_concordance, 0.5);
    }

    #[test]
    fn annotated_markers_excluded() {
        let truth = vec![1, 0];
        let target = TargetHaplotype::new(vec![1, -1]);
        let dosage = vec![0.0 /* wrong but annotated */, 0.1];
        let a = score(&dosage, &truth, &target);
        assert_eq!(a.n_scored, 1);
        assert_eq!(a.concordance, 1.0);
    }

    #[test]
    fn aggregate_weights_by_count() {
        let a = Accuracy {
            concordance: 1.0,
            minor_concordance: 1.0,
            dosage_r2: 1.0,
            n_scored: 10,
        };
        let b = Accuracy {
            concordance: 0.0,
            minor_concordance: 0.0,
            dosage_r2: 0.0,
            n_scored: 30,
        };
        let agg = aggregate(&[a, b]);
        assert!((agg.concordance - 0.25).abs() < 1e-12);
        assert_eq!(agg.n_scored, 40);
    }

    #[test]
    fn empty_aggregate_is_default() {
        let agg = aggregate(&[]);
        assert_eq!(agg.n_scored, 0);
    }
}
