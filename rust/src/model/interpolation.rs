//! Linear-interpolation optimisation — paper §5.3 / Fig 10.
//!
//! Where the target haplotype has no annotated base the emission term falls
//! out of eqs. (4)/(5), so the HMM is evaluated only at annotated marker
//! locations (using *accumulated* genetic distance between them) and every
//! intermediate column's posterior is linearly interpolated, apportioned by
//! the component genetic distances making up `d_m`.
//!
//! This is the baseline-side implementation used (a) as the "similarly
//! optimised x86 solution" of Fig 13 and (b) as the oracle for the
//! event-driven interpolation app.

use super::baseline::{Baseline, ImputeOut, Method, Real};
use super::panel::{ReferencePanel, TargetHaplotype};

/// Interpolation weights for one output marker: blend `frac` of anchor
/// `left+1` into anchor `left`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Blend {
    pub left: usize,
    pub frac: f64,
}

/// Compute the anchor grid and per-marker blend weights.
///
/// `anchors` must be strictly increasing, non-empty, and the first/last
/// markers should be anchored to avoid extrapolation (markers outside the
/// anchored span clamp to the nearest anchor).
pub fn blends(panel: &ReferencePanel, anchors: &[usize]) -> Vec<Blend> {
    assert!(anchors.len() >= 2, "interpolation needs >= 2 anchors");
    assert!(anchors.windows(2).all(|w| w[0] < w[1]));
    assert!(*anchors.last().unwrap() < panel.n_mark());
    let mut out = Vec::with_capacity(panel.n_mark());
    let mut k = 0usize; // current anchor interval [anchors[k], anchors[k+1]]
    for m in 0..panel.n_mark() {
        while k + 2 < anchors.len() && m >= anchors[k + 1] {
            k += 1;
        }
        let (lo, hi) = (anchors[k], anchors[k + 1]);
        if m <= lo {
            out.push(Blend { left: k, frac: 0.0 });
        } else if m >= hi {
            out.push(Blend { left: k, frac: 1.0 });
        } else {
            // Apportion by component genetic distances (paper Fig 10):
            // frac = d(lo → m) / d(lo → hi), both accumulated.
            let covered: f64 = (lo + 1..=m).map(|i| panel.gen_dist(i)).sum();
            let total: f64 = (lo + 1..=hi).map(|i| panel.gen_dist(i)).sum();
            out.push(Blend {
                left: k,
                frac: covered / total,
            });
        }
    }
    out
}

/// Posterior state probabilities at the anchor columns, column-normalised,
/// flattened `[k * H + h]`.
pub fn anchor_posteriors<T: Real>(
    baseline: &Baseline,
    sub_panel: &ReferencePanel,
    sub_target: &TargetHaplotype,
    method: Method,
) -> Vec<T> {
    let alphas = baseline.forward::<T>(sub_panel, sub_target, method);
    let betas = baseline.backward::<T>(sub_panel, sub_target, method);
    let h_n = sub_panel.n_hap();
    let mut post = vec![T::ZERO; alphas.len()];
    for kcol in 0..sub_panel.n_mark() {
        let mut tot = T::ZERO;
        for h in 0..h_n {
            let p = alphas[kcol * h_n + h] * betas[kcol * h_n + h];
            post[kcol * h_n + h] = p;
            tot = tot + p;
        }
        if tot.to64() > 0.0 {
            for h in 0..h_n {
                post[kcol * h_n + h] = post[kcol * h_n + h] / tot;
            }
        }
    }
    post
}

/// Full interpolated imputation of one target haplotype.
///
/// Runs the HMM only on the target's annotated markers (the anchor
/// subproblem, with accumulated genetic distances via
/// [`ReferencePanel::select_markers`]) and interpolates per-state posteriors
/// everywhere else, reducing each column to an allele dosage with that
/// column's own panel labels.
pub fn impute_interp<T: Real>(
    baseline: &Baseline,
    panel: &ReferencePanel,
    target: &TargetHaplotype,
    method: Method,
) -> ImputeOut<T> {
    let anchors = target.annotated();
    assert!(
        anchors.len() >= 2,
        "interpolation needs >= 2 annotated markers"
    );
    let sub_panel = panel.select_markers(&anchors);
    let sub_obs: Vec<i8> = anchors.iter().map(|&m| target.obs[m]).collect();
    let sub_target = TargetHaplotype::new(sub_obs);
    let post = anchor_posteriors::<T>(baseline, &sub_panel, &sub_target, method);
    let weights = blends(panel, &anchors);

    let h_n = panel.n_hap();
    let mut dosage = Vec::with_capacity(panel.n_mark());
    for (m, w) in weights.iter().enumerate() {
        let frac = T::from64(w.frac);
        let lo = &post[w.left * h_n..(w.left + 1) * h_n];
        let hi = &post[(w.left + 1) * h_n..(w.left + 2) * h_n];
        let mut tot = T::ZERO;
        let mut hit = T::ZERO;
        for h in 0..h_n {
            let p = lo[h] + frac * (hi[h] - lo[h]);
            tot = tot + p;
            if panel.allele(h, m) == 1 {
                hit = hit + p;
            }
        }
        dosage.push(if tot.to64() > 0.0 { hit / tot } else { T::ZERO });
    }
    ImputeOut { dosage }
}

/// MAC count for the interpolated pipeline (anchor HMM + per-column blend).
pub fn flops_per_target(panel: &ReferencePanel, n_anchors: usize, method: Method) -> u64 {
    let h = panel.n_hap() as u64;
    let k = n_anchors as u64;
    let m = panel.n_mark() as u64;
    let hmm = match method {
        Method::DenseThreeLoop => 2 * (k - 1) * h * (2 * h + 1),
        Method::Rank1 => 2 * (k - 1) * (5 * h),
    };
    hmm + k * 3 * h /* anchor posteriors */ + m * 5 * h /* blends */
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::ModelParams;
    use crate::util::rng::Rng;
    use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

    fn problem(seed: u64, ratio: f64) -> (ReferencePanel, TargetHaplotype, Vec<u8>) {
        let cfg = PanelConfig {
            n_hap: 16,
            n_mark: 101,
            annot_ratio: ratio,
            maf: 0.2,
            seed,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(seed ^ 0x1234);
        let case = generate_targets(&panel, &cfg, 1, &mut rng)
            .into_iter()
            .next()
            .unwrap();
        (panel, case.masked, case.truth)
    }

    #[test]
    fn blends_exact_at_anchors() {
        let (panel, target, _) = problem(1, 0.1);
        let anchors = target.annotated();
        let ws = blends(&panel, &anchors);
        for (k, &a) in anchors.iter().enumerate() {
            let w = ws[a];
            let exact = (w.frac == 0.0 && anchors[w.left] == a)
                || (w.frac == 1.0 && anchors[w.left + 1] == a);
            assert!(exact, "anchor {a} (k={k}) got {w:?}");
        }
    }

    #[test]
    fn blends_monotone_within_interval() {
        let (panel, target, _) = problem(2, 0.1);
        let anchors = target.annotated();
        let ws = blends(&panel, &anchors);
        for pair in anchors.windows(2) {
            let (lo, hi) = (pair[0], pair[1]);
            let mut prev = 0.0;
            for m in lo + 1..hi {
                assert!(ws[m].frac > prev && ws[m].frac < 1.0);
                prev = ws[m].frac;
            }
        }
    }

    #[test]
    fn interp_matches_full_hmm_at_anchor_columns() {
        let (panel, target, _) = problem(3, 0.1);
        let b = Baseline::new(ModelParams::default());
        let interp: ImputeOut<f64> = impute_interp(&b, &panel, &target, Method::Rank1);
        // At annotated columns the interp pipeline evaluates the HMM over the
        // anchor grid with accumulated distances — the dosages there should be
        // very close to the full HMM (which also sees emission=1 in between).
        let full: ImputeOut<f64> = b.impute(&panel, &target, Method::Rank1);
        for &a in &target.annotated() {
            assert!(
                (interp.dosage[a] - full.dosage[a]).abs() < 5e-3,
                "anchor {a}: {} vs {}",
                interp.dosage[a],
                full.dosage[a]
            );
        }
    }

    #[test]
    fn interp_tracks_full_hmm_between_anchors() {
        let (panel, target, _) = problem(4, 0.1);
        let b = Baseline::new(ModelParams::default());
        let interp: ImputeOut<f64> = impute_interp(&b, &panel, &target, Method::Rank1);
        let full: ImputeOut<f64> = b.impute(&panel, &target, Method::Rank1);
        let mean_err: f64 = interp
            .dosage
            .iter()
            .zip(&full.dosage)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / panel.n_mark() as f64;
        assert!(mean_err < 0.05, "mean dosage error {mean_err}");
    }

    #[test]
    fn interp_dense_matches_rank1() {
        let (panel, target, _) = problem(5, 0.1);
        let b = Baseline::new(ModelParams::default());
        let x: ImputeOut<f64> = impute_interp(&b, &panel, &target, Method::Rank1);
        let y: ImputeOut<f64> = impute_interp(&b, &panel, &target, Method::DenseThreeLoop);
        for (a, c) in x.dosage.iter().zip(&y.dosage) {
            assert!((a - c).abs() < 1e-9);
        }
    }

    #[test]
    fn interp_accuracy_close_to_raw_on_masked_markers() {
        // The paper's claim: negligible accuracy impact for genuine upscale
        // factors. Compare hard-call concordance of raw vs interp.
        let mut raw_ok = 0usize;
        let mut itp_ok = 0usize;
        let mut total = 0usize;
        for seed in 0..5 {
            let (panel, target, truth) = problem(100 + seed, 0.1);
            let b = Baseline::new(ModelParams::default());
            let raw: ImputeOut<f64> = b.impute(&panel, &target, Method::Rank1);
            let itp: ImputeOut<f64> = impute_interp(&b, &panel, &target, Method::Rank1);
            for m in 0..panel.n_mark() {
                if target.obs[m] >= 0 {
                    continue; // score only the imputed (masked) markers
                }
                total += 1;
                raw_ok += usize::from(raw.hard_calls()[m] == truth[m]);
                itp_ok += usize::from(itp.hard_calls()[m] == truth[m]);
            }
        }
        let raw_acc = raw_ok as f64 / total as f64;
        let itp_acc = itp_ok as f64 / total as f64;
        assert!(raw_acc > 0.8, "raw accuracy {raw_acc}");
        assert!(
            itp_acc > raw_acc - 0.05,
            "interp accuracy {itp_acc} fell too far below raw {raw_acc}"
        );
    }

    #[test]
    fn flops_interp_much_cheaper_dense() {
        let (panel, target, _) = problem(6, 0.1);
        let k = target.annotated().len();
        let full = Baseline::default().flops_per_target(&panel, Method::DenseThreeLoop);
        let itp = flops_per_target(&panel, k, Method::DenseThreeLoop);
        assert!(itp * 2 < full, "interp {itp} vs full {full}");
    }

    #[test]
    #[should_panic(expected = ">= 2 annotated")]
    fn rejects_too_few_anchors() {
        let (panel, _, _) = problem(7, 0.1);
        let target = TargetHaplotype::new(vec![-1; panel.n_mark()]);
        let b = Baseline::default();
        let _: ImputeOut<f64> = impute_interp(&b, &panel, &target, Method::Rank1);
    }
}
