//! Reference panels and target haplotypes — paper §3.1 / Fig 1.
//!
//! The panel is the 2-D HMM state space: reference haplotypes stacked
//! vertically, sampled marker locations horizontally, each state labelled
//! with an allele.  Diallelic encoding: allele ∈ {0, 1} (major/minor).

/// Observation at one marker of a target haplotype: `-1` unannotated, else
/// the observed allele (0/1).
pub type Obs = i8;

/// The 2-D reference panel (HMM state space).
#[derive(Clone, Debug)]
pub struct ReferencePanel {
    n_hap: usize,
    n_mark: usize,
    /// Row-major alleles: `alleles[h * n_mark + m]`.
    alleles: Vec<u8>,
    /// Genetic distance `d_m` from marker `m-1` to marker `m`; `gen_dist[0] = 0`.
    gen_dist: Vec<f64>,
}

impl ReferencePanel {
    pub fn new(n_hap: usize, n_mark: usize, alleles: Vec<u8>, gen_dist: Vec<f64>) -> Self {
        assert!(n_hap >= 2, "need at least two reference haplotypes");
        assert!(n_mark >= 2, "need at least two markers");
        assert_eq!(alleles.len(), n_hap * n_mark, "allele buffer size mismatch");
        assert_eq!(gen_dist.len(), n_mark, "genetic distance length mismatch");
        assert_eq!(gen_dist[0], 0.0, "gen_dist[0] must be 0 (no left neighbour)");
        assert!(
            alleles.iter().all(|&a| a <= 1),
            "diallelic panels only (alleles 0/1)"
        );
        assert!(
            gen_dist[1..].iter().all(|&d| d > 0.0 && d.is_finite()),
            "genetic distances must be positive and finite"
        );
        ReferencePanel {
            n_hap,
            n_mark,
            alleles,
            gen_dist,
        }
    }

    #[inline]
    pub fn n_hap(&self) -> usize {
        self.n_hap
    }

    #[inline]
    pub fn n_mark(&self) -> usize {
        self.n_mark
    }

    /// Total number of HMM states (vertices in the raw application graph).
    #[inline]
    pub fn n_states(&self) -> usize {
        self.n_hap * self.n_mark
    }

    #[inline]
    pub fn allele(&self, hap: usize, mark: usize) -> u8 {
        debug_assert!(hap < self.n_hap && mark < self.n_mark);
        self.alleles[hap * self.n_mark + mark]
    }

    /// One reference haplotype row.
    pub fn haplotype(&self, hap: usize) -> &[u8] {
        &self.alleles[hap * self.n_mark..(hap + 1) * self.n_mark]
    }

    /// Column `m` as a fresh vector (marker-major views are not contiguous).
    pub fn column(&self, mark: usize) -> Vec<u8> {
        (0..self.n_hap).map(|h| self.allele(h, mark)).collect()
    }

    #[inline]
    pub fn gen_dist(&self, mark: usize) -> f64 {
        self.gen_dist[mark]
    }

    pub fn gen_dists(&self) -> &[f64] {
        &self.gen_dist
    }

    /// Per-column allele-1 frequency.
    pub fn allele_freq(&self, mark: usize) -> f64 {
        let ones: usize = (0..self.n_hap)
            .map(|h| self.allele(h, mark) as usize)
            .sum();
        ones as f64 / self.n_hap as f64
    }

    /// Memory footprint of the panel data in bytes (the paper's capacity
    /// limit is "the memory required to store the reference panel").
    pub fn mem_bytes(&self) -> usize {
        self.alleles.len() + self.gen_dist.len() * std::mem::size_of::<f64>()
    }

    /// Restrict to a subset of marker columns (used to build the annotated-
    /// anchor subproblem for linear interpolation).  Genetic distances are
    /// *accumulated* across the dropped columns — paper Fig 10.
    pub fn select_markers(&self, marks: &[usize]) -> ReferencePanel {
        assert!(marks.len() >= 2, "anchor subproblem needs >= 2 markers");
        assert!(
            marks.windows(2).all(|w| w[0] < w[1]),
            "marker subset must be strictly increasing"
        );
        assert!(*marks.last().unwrap() < self.n_mark);
        let mut alleles = Vec::with_capacity(self.n_hap * marks.len());
        for h in 0..self.n_hap {
            for &m in marks {
                alleles.push(self.allele(h, m));
            }
        }
        let mut gen_dist = Vec::with_capacity(marks.len());
        for (k, &m) in marks.iter().enumerate() {
            if k == 0 {
                gen_dist.push(0.0);
            } else {
                // Accumulate d over (marks[k-1], marks[k]].
                let lo = marks[k - 1];
                gen_dist.push((lo + 1..=m).map(|i| self.gen_dist[i]).sum());
            }
        }
        ReferencePanel::new(self.n_hap, marks.len(), alleles, gen_dist)
    }
}

/// A target haplotype to impute: observations aligned to the panel's markers.
#[derive(Clone, Debug)]
pub struct TargetHaplotype {
    pub obs: Vec<Obs>,
}

impl TargetHaplotype {
    pub fn new(obs: Vec<Obs>) -> Self {
        assert!(obs.iter().all(|&o| (-1..=1).contains(&o)));
        TargetHaplotype { obs }
    }

    pub fn n_mark(&self) -> usize {
        self.obs.len()
    }

    /// Indices of annotated (observed) markers, in order.
    pub fn annotated(&self) -> Vec<usize> {
        self.obs
            .iter()
            .enumerate()
            .filter(|(_, &o)| o >= 0)
            .map(|(m, _)| m)
            .collect()
    }

    pub fn n_annotated(&self) -> usize {
        self.obs.iter().filter(|&&o| o >= 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ReferencePanel {
        // 2 haplotypes x 3 markers.
        ReferencePanel::new(2, 3, vec![0, 1, 0, 1, 0, 1], vec![0.0, 1e-6, 2e-6])
    }

    #[test]
    fn accessors() {
        let p = tiny();
        assert_eq!(p.n_hap(), 2);
        assert_eq!(p.n_mark(), 3);
        assert_eq!(p.n_states(), 6);
        assert_eq!(p.allele(0, 1), 1);
        assert_eq!(p.allele(1, 0), 1);
        assert_eq!(p.haplotype(1), &[1, 0, 1]);
        assert_eq!(p.column(2), vec![0, 1]);
        assert_eq!(p.gen_dist(2), 2e-6);
    }

    #[test]
    fn allele_freq_per_column() {
        let p = tiny();
        assert_eq!(p.allele_freq(0), 0.5);
        assert_eq!(p.allele_freq(1), 0.5);
    }

    #[test]
    #[should_panic(expected = "allele buffer size mismatch")]
    fn rejects_bad_buffer() {
        ReferencePanel::new(2, 3, vec![0; 5], vec![0.0, 1e-6, 1e-6]);
    }

    #[test]
    #[should_panic(expected = "diallelic")]
    fn rejects_non_diallelic() {
        ReferencePanel::new(2, 2, vec![0, 1, 2, 0], vec![0.0, 1e-6]);
    }

    #[test]
    #[should_panic(expected = "gen_dist[0]")]
    fn rejects_nonzero_first_distance() {
        ReferencePanel::new(2, 2, vec![0, 1, 1, 0], vec![1e-6, 1e-6]);
    }

    #[test]
    fn select_markers_accumulates_distance() {
        let p = ReferencePanel::new(
            2,
            5,
            vec![0, 1, 0, 1, 0, 1, 0, 1, 0, 1],
            vec![0.0, 1e-6, 2e-6, 3e-6, 4e-6],
        );
        let q = p.select_markers(&[0, 2, 4]);
        assert_eq!(q.n_mark(), 3);
        assert_eq!(q.gen_dist(0), 0.0);
        assert!((q.gen_dist(1) - 3e-6).abs() < 1e-18); // 1e-6 + 2e-6
        assert!((q.gen_dist(2) - 7e-6).abs() < 1e-18); // 3e-6 + 4e-6
        assert_eq!(q.haplotype(0), &[0, 0, 0]);
        assert_eq!(q.haplotype(1), &[1, 1, 1]);
    }

    #[test]
    fn target_annotated_indices() {
        let t = TargetHaplotype::new(vec![-1, 0, -1, 1]);
        assert_eq!(t.annotated(), vec![1, 3]);
        assert_eq!(t.n_annotated(), 2);
    }

    #[test]
    #[should_panic]
    fn target_rejects_bad_obs() {
        TargetHaplotype::new(vec![2]);
    }
}
