//! AOT artifact registry: parse `artifacts/manifest.tsv`.
//!
//! The manifest is written by `python/compile/aot.py` (one row per artifact):
//!
//! ```text
//! name<TAB>file<TAB>in:NAME:DTYPE:d0xd1<TAB>...<TAB>out:NAME:DTYPE:d0xd1
//! ```
//!
//! TSV keeps the Rust side free of JSON machinery (offline environment) and
//! the signature explicit enough to validate every execute call.

use std::path::{Path, PathBuf};

use super::error::{Context, Result, bail};

/// Tensor element type (the subset the pipeline uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?} in manifest"),
        }
    }
}

/// One tensor signature (argument or result).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(kind: &str, col: &str) -> Result<TensorSig> {
        let parts: Vec<&str> = col.split(':').collect();
        if parts.len() != 4 || parts[0] != kind {
            bail!("bad manifest column {col:?} (expected {kind}:name:dtype:dims)");
        }
        let shape = parts[3]
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSig {
            name: parts[1].to_string(),
            dtype: DType::parse(parts[2])?,
            shape,
        })
    }
}

/// One artifact: an HLO-text file plus its entry signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.tsv` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() < 4 {
                bail!("manifest line {} too short: {line:?}", lineno + 1);
            }
            let mut inputs = Vec::new();
            let mut outputs = Vec::new();
            for col in &cols[2..] {
                if col.starts_with("in:") {
                    if !outputs.is_empty() {
                        bail!("manifest line {}: input after output", lineno + 1);
                    }
                    inputs.push(TensorSig::parse("in", col)?);
                } else if col.starts_with("out:") {
                    outputs.push(TensorSig::parse("out", col)?);
                } else {
                    bail!("manifest line {}: bad column {col:?}", lineno + 1);
                }
            }
            if outputs.is_empty() {
                bail!("manifest line {}: no outputs", lineno + 1);
            }
            artifacts.push(ArtifactSpec {
                name: cols[0].to_string(),
                path: dir.join(cols[1]),
                inputs,
                outputs,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest `impute_raw_h{H}_m{M}` artifact with exactly `h` haplotypes
    /// and at least `m` markers.  H must match exactly: the 1/|H| prior and
    /// τ/|H| leak are baked into the lowered HLO, so padding haplotype rows
    /// would change the model (padding markers with τ=0/emis=1 is inert —
    /// verified by rust/tests/runtime_artifacts.rs).
    pub fn find_raw(&self, h: usize, m: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.name.starts_with("impute_raw_h"))
            .filter(|a| {
                let emis = &a.inputs[1];
                emis.shape[1] == h && emis.shape[0] >= m
            })
            .min_by_key(|a| a.inputs[1].shape[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "impute_raw_h16_m32\timpute_raw_h16_m32.hlo.txt\tin:tau:float32:32\tin:emis:float32:32x16\tin:alleles:float32:32x16\tout:dosage:float32:32\n\
impute_raw_h64_m128\timpute_raw_h64_m128.hlo.txt\tin:tau:float32:128\tin:emis:float32:128x64\tin:alleles:float32:128x64\tout:dosage:float32:128\n\
fwd_h16_m32\tfwd_h16_m32.hlo.txt\tin:tau:float32:32\tin:emis:float32:32x16\tout:alphas:float32:32x16\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.get("impute_raw_h16_m32").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![32]);
        assert_eq!(a.inputs[1].shape, vec![32, 16]);
        assert_eq!(a.inputs[1].dtype, DType::F32);
        assert_eq!(a.outputs[0].name, "dosage");
        assert_eq!(a.path, Path::new("/tmp/a/impute_raw_h16_m32.hlo.txt"));
    }

    #[test]
    fn find_raw_matches_h_exactly_pads_m() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.find_raw(16, 20).unwrap().name, "impute_raw_h16_m32");
        assert_eq!(m.find_raw(16, 32).unwrap().name, "impute_raw_h16_m32");
        assert!(m.find_raw(16, 33).is_none()); // M too large for the menu
        assert!(m.find_raw(17, 10).is_none()); // H must match exactly
        assert_eq!(m.find_raw(64, 100).unwrap().name, "impute_raw_h64_m128");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("a\tb\n", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tb\tin:x:float32:4\n", Path::new("/")).is_err());
        assert!(Manifest::parse("a\tb\tin:x:float99:4\tout:y:float32:4\n", Path::new("/")).is_err());
        assert!(
            Manifest::parse(
                "a\tb\tout:y:float32:4\tin:x:float32:4\n",
                Path::new("/")
            )
            .is_err()
        );
    }

    #[test]
    fn tensor_sig_elems() {
        let t = TensorSig {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![3, 4, 5],
        };
        assert_eq!(t.n_elems(), 60);
    }
}
