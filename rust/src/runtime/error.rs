//! Std-only error plumbing for the runtime layer.
//!
//! The offline build environment has no crates.io access, so the `anyhow`
//! conveniences this layer originally leaned on are re-implemented here at
//! the scale the runtime needs: a string-backed error, a `Context` extension
//! trait for `Result`/`Option`, and a `bail!` macro.

use std::fmt;

/// A runtime-layer error: a human-readable message chain.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Runtime-layer result (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style message chaining for results and options.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`].
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::runtime::error::Error::msg(format!($($arg)*)))
    };
}
pub(crate) use bail;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let o: Option<u32> = Some(7);
        assert_eq!(o.with_context(|| "x".into()).unwrap(), 7);
    }

    #[test]
    fn bail_formats() {
        fn f(n: usize) -> Result<()> {
            if n > 3 {
                bail!("too many: {n}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(9).unwrap_err().to_string(), "too many: 9");
    }
}
