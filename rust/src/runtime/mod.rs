//! PJRT runtime: load and execute the AOT JAX/Pallas artifacts.
//!
//! * [`artifacts`] — `manifest.tsv` parsing and shape lookup.
//! * [`client`] — PJRT CPU client, lazy compile cache, checked execution.
//! * [`exec`] — typed imputation entry points with marker padding.
//!
//! The Rust binary is self-contained after `make artifacts`: Python/JAX run
//! once at build time, never on the request path.
//!
//! The PJRT-backed client is gated behind the `pjrt` cargo feature (the
//! offline build has no `xla` dependency closure); without it a std-only
//! stub with the identical API reports the plane as unavailable.  Error
//! handling is std-only throughout ([`error`]).

pub mod artifacts;
pub mod client;
pub mod error;
pub mod exec;

pub use artifacts::{ArtifactSpec, DType, Manifest, TensorSig};
pub use client::{HostTensor, Runtime};
pub use error::{Error, Result};
pub use exec::XlaImputer;
