//! PJRT runtime: load and execute the AOT JAX/Pallas artifacts.
//!
//! * [`artifacts`] — `manifest.tsv` parsing and shape lookup.
//! * [`client`] — PJRT CPU client, lazy compile cache, checked execution.
//! * [`exec`] — typed imputation entry points with marker padding.
//!
//! The Rust binary is self-contained after `make artifacts`: Python/JAX run
//! once at build time, never on the request path.

pub mod artifacts;
pub mod client;
pub mod exec;

pub use artifacts::{ArtifactSpec, DType, Manifest, TensorSig};
pub use client::{HostTensor, Runtime};
pub use exec::XlaImputer;
