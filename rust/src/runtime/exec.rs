//! Typed entry points over the artifact runtime, with marker padding.
//!
//! Padding strategy (see `Manifest::find_raw`): haplotype count must match a
//! canonical artifact exactly (|H| is baked into the lowered constants);
//! marker count pads up with inert columns — τ=0 (identity transition),
//! emission=1 (unannotated), allele=0 — appended on the right.  Inertness is
//! asserted against the native baseline in rust/tests/runtime_artifacts.rs.

use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::model::params::ModelParams;

use super::client::{HostTensor, Runtime};
use super::error::{Context, Result, bail};

/// High-level imputation façade over the XLA compute plane.
pub struct XlaImputer {
    pub runtime: Runtime,
    pub params: ModelParams,
}

impl XlaImputer {
    pub fn new(runtime: Runtime, params: ModelParams) -> XlaImputer {
        XlaImputer { runtime, params }
    }

    /// Canonical H values available for a given panel (sorted).
    pub fn supported_h(&self) -> Vec<usize> {
        let mut hs: Vec<usize> = self
            .runtime
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with("impute_raw_h"))
            .map(|a| a.inputs[1].shape[1])
            .collect();
        hs.sort_unstable();
        hs.dedup();
        hs
    }

    /// Build (tau, emis, alleles) padded to `m_pad` markers.
    fn build_inputs(
        &self,
        panel: &ReferencePanel,
        target: &TargetHaplotype,
        m_pad: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
        assert!(m_pad >= m_n);
        let mut tau = vec![0.0f32; m_pad];
        let mut emis = vec![1.0f32; m_pad * h_n];
        let mut alleles = vec![0.0f32; m_pad * h_n];
        for m in 0..m_n {
            if m > 0 {
                tau[m] = self.params.tau(panel.gen_dist(m), h_n) as f32;
            }
            for h in 0..h_n {
                let a = panel.allele(h, m);
                alleles[m * h_n + h] = a as f32;
                emis[m * h_n + h] = self.params.emission(a, target.obs[m]) as f32;
            }
        }
        (tau, emis, alleles)
    }

    /// Impute one target through the AOT `impute_raw` artifact.
    pub fn impute_raw(
        &mut self,
        panel: &ReferencePanel,
        target: &TargetHaplotype,
    ) -> Result<Vec<f32>> {
        let (h_n, m_n) = (panel.n_hap(), panel.n_mark());
        let spec = self
            .runtime
            .manifest()
            .find_raw(h_n, m_n)
            .with_context(|| {
                format!(
                    "no impute_raw artifact for H={h_n}, M<={m_n} \
                     (canonical H: {:?}; extend aot.py's RAW_SHAPES)",
                    self.supported_h()
                )
            })?
            .name
            .clone();
        let m_pad = self
            .runtime
            .manifest()
            .get(&spec)
            .expect("spec just found")
            .inputs[1]
            .shape[0];
        let (tau, emis, alleles) = self.build_inputs(panel, target, m_pad);
        let out = self.runtime.execute(
            &spec,
            &[
                HostTensor::F32(tau),
                HostTensor::F32(emis),
                HostTensor::F32(alleles),
            ],
        )?;
        let mut dosage = match out.into_iter().next().expect("one output") {
            HostTensor::F32(v) => v,
            _ => bail!("dosage dtype"),
        };
        dosage.truncate(m_n);
        Ok(dosage)
    }

    /// Impute a batch of targets sequentially through the artifact plane.
    pub fn impute_batch(
        &mut self,
        panel: &ReferencePanel,
        targets: &[TargetHaplotype],
    ) -> Result<Vec<Vec<f32>>> {
        targets.iter().map(|t| self.impute_raw(panel, t)).collect()
    }
}
