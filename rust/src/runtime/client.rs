//! PJRT execution of AOT artifacts (the L2/L1 compute plane).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `client.compile` → `execute`.  HLO *text* is the
//! interchange format (see python/compile/aot.py and DESIGN.md): the
//! xla_extension 0.5.1 proto parser rejects jax ≥ 0.5's 64-bit instruction
//! ids, the text parser reassigns them.
//!
//! Executables are compiled lazily on first use and cached for the process
//! lifetime — Python never runs at request time.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result, bail};

use super::artifacts::{ArtifactSpec, DType, Manifest};

/// A loaded artifact runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// A typed host tensor handed to / returned from [`Runtime::execute`].
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

impl Runtime {
    /// Open the artifacts directory (must contain `manifest.tsv`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Open the default `artifacts/` directory next to the workspace root.
    pub fn open_default() -> Result<Runtime> {
        Self::open(Path::new("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, spec: &ArtifactSpec) -> Result<()> {
        if self.cache.contains_key(&spec.name) {
            return Ok(());
        }
        let path = spec
            .path
            .to_str()
            .with_context(|| format!("non-utf8 path {:?}", spec.path))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", spec.name))?;
        self.cache.insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Execute an artifact by name with shape/dtype-checked host tensors.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, sig) in inputs.iter().zip(&spec.inputs) {
            if t.dtype() != sig.dtype {
                bail!("artifact {name} input {}: dtype mismatch", sig.name);
            }
            if t.len() != sig.n_elems() {
                bail!(
                    "artifact {name} input {}: {} elements given, {:?} expected",
                    sig.name,
                    t.len(),
                    sig.shape
                );
            }
            literals.push(t.to_literal(&sig.shape)?);
        }
        self.compile(&spec)?;
        let exe = self.cache.get(&spec.name).expect("just compiled");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: {} outputs returned, {} expected",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, sig) in parts.into_iter().zip(&spec.outputs) {
            let t = match sig.dtype {
                DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
                DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
            };
            if t.len() != sig.n_elems() {
                bail!("artifact {name} output {}: shape mismatch", sig.name);
            }
            out.push(t);
        }
        Ok(out)
    }

    /// Number of compiled executables currently cached.
    pub fn n_compiled(&self) -> usize {
        self.cache.len()
    }
}
