//! PJRT execution of AOT artifacts (the L2/L1 compute plane).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `client.compile` → `execute`.  HLO *text* is the
//! interchange format (see python/compile/aot.py and DESIGN.md): the
//! xla_extension 0.5.1 proto parser rejects jax ≥ 0.5's 64-bit instruction
//! ids, the text parser reassigns them.
//!
//! Executables are compiled lazily on first use and cached for the process
//! lifetime — Python never runs at request time.
//!
//! # The `pjrt` feature gate
//!
//! The default (offline) build has no `xla`/`anyhow` dependency closure, so
//! the PJRT-backed implementation is gated behind the `pjrt` cargo feature
//! and a std-only stub with the identical API takes its place: `open` fails
//! with a clear message and every caller degrades the same way it does when
//! `artifacts/` has not been built.  Enabling `pjrt` additionally requires
//! vendoring the `xla` crate and declaring it in Cargo.toml.

#[cfg(not(feature = "pjrt"))]
use std::path::Path;

use super::artifacts::DType;
#[cfg(not(feature = "pjrt"))]
use super::artifacts::Manifest;
#[cfg(not(feature = "pjrt"))]
use super::error::Result;

/// A typed host tensor handed to / returned from [`Runtime::execute`].
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::Path;

    use super::super::artifacts::{ArtifactSpec, DType, Manifest};
    use super::super::error::{Context, Result, bail};
    use super::HostTensor;

    impl HostTensor {
        fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = match self {
                HostTensor::F32(v) => xla::Literal::vec1(v),
                HostTensor::I32(v) => xla::Literal::vec1(v),
            };
            lit.reshape(&dims).context("reshaping literal")
        }
    }

    /// A loaded artifact runtime.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Open the artifacts directory (must contain `manifest.tsv`).
        pub fn open(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                manifest,
                cache: HashMap::new(),
            })
        }

        /// Open the default `artifacts/` directory next to the workspace root.
        pub fn open_default() -> Result<Runtime> {
            Self::open(Path::new("artifacts"))
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn compile(&mut self, spec: &ArtifactSpec) -> Result<()> {
            if self.cache.contains_key(&spec.name) {
                return Ok(());
            }
            let path = spec
                .path
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.path))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", spec.name))?;
            self.cache.insert(spec.name.clone(), exe);
            Ok(())
        }

        /// Execute an artifact by name with shape/dtype-checked host tensors.
        pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let spec = self
                .manifest
                .get(name)
                .with_context(|| format!("unknown artifact {name:?}"))?
                .clone();
            if inputs.len() != spec.inputs.len() {
                bail!(
                    "artifact {name}: {} inputs given, {} expected",
                    inputs.len(),
                    spec.inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (t, sig) in inputs.iter().zip(&spec.inputs) {
                if t.dtype() != sig.dtype {
                    bail!("artifact {name} input {}: dtype mismatch", sig.name);
                }
                if t.len() != sig.n_elems() {
                    bail!(
                        "artifact {name} input {}: {} elements given, {:?} expected",
                        sig.name,
                        t.len(),
                        sig.shape
                    );
                }
                literals.push(t.to_literal(&sig.shape)?);
            }
            self.compile(&spec)?;
            let exe = self.cache.get(&spec.name).expect("just compiled");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .context("executing artifact")?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // aot.py lowers with return_tuple=True.
            let parts = result.to_tuple().context("untupling result")?;
            if parts.len() != spec.outputs.len() {
                bail!(
                    "artifact {name}: {} outputs returned, {} expected",
                    parts.len(),
                    spec.outputs.len()
                );
            }
            let mut out = Vec::with_capacity(parts.len());
            for (lit, sig) in parts.into_iter().zip(&spec.outputs) {
                let t = match sig.dtype {
                    DType::F32 => HostTensor::F32(lit.to_vec::<f32>().context("reading f32 output")?),
                    DType::I32 => HostTensor::I32(lit.to_vec::<i32>().context("reading i32 output")?),
                };
                if t.len() != sig.n_elems() {
                    bail!("artifact {name} output {}: shape mismatch", sig.name);
                }
                out.push(t);
            }
            Ok(out)
        }

        /// Number of compiled executables currently cached.
        pub fn n_compiled(&self) -> usize {
            self.cache.len()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Runtime;

/// Offline stub: identical API, but opening always fails so every caller
/// takes its artifacts-unavailable path (the same one it takes when
/// `make artifacts` has not run).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Open the artifacts directory — always fails in the offline build.
    pub fn open(dir: &Path) -> Result<Runtime> {
        // Validate the manifest anyway so errors stay informative.
        let _ = Manifest::load(dir)?;
        Err(super::error::Error::msg(
            "PJRT compute plane not built: compiled without the `pjrt` feature \
             (the offline image lacks the xla dependency closure); \
             see rust/src/runtime/client.rs",
        ))
    }

    /// Open the default `artifacts/` directory next to the workspace root.
    pub fn open_default() -> Result<Runtime> {
        Self::open(Path::new("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Execute an artifact — unreachable in practice (open never succeeds)
    /// but present so callers compile unchanged.
    pub fn execute(&mut self, name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Err(super::error::Error::msg(format!(
            "cannot execute {name:?}: PJRT plane not built (enable the `pjrt` feature)"
        )))
    }

    /// Number of compiled executables currently cached.
    pub fn n_compiled(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0]);
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.as_f32(), &[1.0, 2.0]);
        let i = HostTensor::I32(vec![]);
        assert!(i.is_empty());
        assert_eq!(i.dtype(), DType::I32);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_open_fails_with_clear_message() {
        // Missing manifest: the manifest error surfaces first.
        let e = Runtime::open(Path::new("/nonexistent-artifacts")).unwrap_err();
        assert!(e.to_string().contains("manifest"), "{e}");
    }
}
