//! Application-graph construction.
//!
//! Destination lists are pooled and shared: in the imputation graph every
//! vertex of a column multicasts to the *same* set (the next/previous
//! column), so storing the list once per column instead of once per vertex
//! cuts edge memory by |H| — the same observation that makes Tinsel's
//! hardware multicast effective.

use super::device::{Device, PortId, VertexId};

/// Shared destination-list handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DestListId(pub u32);

/// An application graph: devices plus per-vertex output ports resolving to
/// pooled destination lists.
pub struct Graph<D: Device> {
    pub devices: Vec<D>,
    /// `ports[v][p]` → destination list of vertex `v`'s port `p`.
    ports: Vec<Vec<DestListId>>,
    pool: Vec<Vec<VertexId>>,
}

impl<D: Device> Graph<D> {
    /// Vertex count — derived from the port table so it stays correct while
    /// devices are temporarily moved out via [`Graph::take_devices`].
    pub fn n_vertices(&self) -> usize {
        self.ports.len()
    }

    #[inline]
    pub fn dest_list(&self, v: VertexId, p: PortId) -> DestListId {
        self.ports[v as usize][p as usize]
    }

    #[inline]
    pub fn dests(&self, id: DestListId) -> &[VertexId] {
        &self.pool[id.0 as usize]
    }

    pub fn n_dest_lists(&self) -> usize {
        self.pool.len()
    }

    pub fn ports_of(&self, v: VertexId) -> &[DestListId] {
        &self.ports[v as usize]
    }

    /// Move every device out of the graph (the delivery engine repartitions
    /// them into per-tile shards for the duration of a run).  The graph's
    /// ports and destination pool stay intact; `devices` is left empty until
    /// [`Graph::restore_devices`] puts the same devices back.
    pub fn take_devices(&mut self) -> Vec<D> {
        std::mem::take(&mut self.devices)
    }

    /// Restore devices previously moved out with [`Graph::take_devices`]
    /// (in vertex-id order).
    pub fn restore_devices(&mut self, devices: Vec<D>) {
        assert!(
            self.devices.is_empty(),
            "restore_devices on a graph that still owns devices"
        );
        assert_eq!(
            devices.len(),
            self.ports.len(),
            "restored device count does not match vertex count"
        );
        self.devices = devices;
    }

    /// Total directed edge count (sum of port fan-outs over vertices).
    pub fn n_edges(&self) -> u64 {
        self.ports
            .iter()
            .flat_map(|ps| ps.iter())
            .map(|&d| self.pool[d.0 as usize].len() as u64)
            .sum()
    }
}

/// Builder for [`Graph`].
pub struct GraphBuilder<D: Device> {
    devices: Vec<D>,
    ports: Vec<Vec<DestListId>>,
    pool: Vec<Vec<VertexId>>,
}

impl<D: Device> Default for GraphBuilder<D> {
    fn default() -> Self {
        GraphBuilder {
            devices: Vec::new(),
            ports: Vec::new(),
            pool: Vec::new(),
        }
    }
}

impl<D: Device> GraphBuilder<D> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a vertex; returns its id.
    pub fn add_vertex(&mut self, device: D) -> VertexId {
        let id = self.devices.len() as VertexId;
        self.devices.push(device);
        self.ports.push(Vec::new());
        id
    }

    /// Intern a destination list for sharing across vertices.
    pub fn intern_dests(&mut self, dests: Vec<VertexId>) -> DestListId {
        let id = DestListId(self.pool.len() as u32);
        self.pool.push(dests);
        id
    }

    /// Declare the next port of `v`, pointing at a shared destination list.
    /// Ports must be declared in order (0, 1, 2, ...).
    pub fn add_port(&mut self, v: VertexId, dests: DestListId) -> PortId {
        assert!((dests.0 as usize) < self.pool.len(), "unknown dest list");
        let ports = &mut self.ports[v as usize];
        let pid = ports.len() as PortId;
        ports.push(dests);
        pid
    }

    /// Convenience: declare a port with a private (non-shared) list.
    pub fn add_port_to(&mut self, v: VertexId, dests: Vec<VertexId>) -> PortId {
        let id = self.intern_dests(dests);
        self.add_port(v, id)
    }

    pub fn n_vertices(&self) -> usize {
        self.devices.len()
    }

    pub fn build(self) -> Graph<D> {
        // Validate every destination id.
        let n = self.devices.len() as u32;
        for list in &self.pool {
            for &d in list {
                assert!(d < n, "edge to unknown vertex {d}");
            }
        }
        Graph {
            devices: self.devices,
            ports: self.ports,
            pool: self.pool,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::device::Ctx;

    struct Null;
    impl Device for Null {
        type Msg = u8;
        fn init(&mut self, _ctx: &mut Ctx<u8>) {}
        fn recv(&mut self, _msg: &u8, _src: VertexId, _ctx: &mut Ctx<u8>) {}
        fn step(&mut self, _ctx: &mut Ctx<u8>) -> bool {
            false
        }
    }

    #[test]
    fn build_and_query() {
        let mut b = GraphBuilder::new();
        let v0 = b.add_vertex(Null);
        let v1 = b.add_vertex(Null);
        let v2 = b.add_vertex(Null);
        let shared = b.intern_dests(vec![v1, v2]);
        let p0 = b.add_port(v0, shared);
        let p1 = b.add_port(v1, shared); // same list shared by two vertices
        let p2 = b.add_port_to(v2, vec![v0]);
        let g = b.build();
        assert_eq!(g.n_vertices(), 3);
        assert_eq!(g.dests(g.dest_list(v0, p0)), &[v1, v2]);
        assert_eq!(g.dest_list(v0, p0), g.dest_list(v1, p1));
        assert_eq!(g.dests(g.dest_list(v2, p2)), &[v0]);
        assert_eq!(g.n_dest_lists(), 2);
        assert_eq!(g.n_edges(), 5);
    }

    #[test]
    fn devices_roundtrip_through_take_restore() {
        let mut b = GraphBuilder::new();
        b.add_vertex(Null);
        b.add_vertex(Null);
        let mut g = b.build();
        let devs = g.take_devices();
        assert_eq!(devs.len(), 2);
        assert!(g.devices.is_empty());
        assert_eq!(g.n_vertices(), 2, "vertex count survives the take");
        g.restore_devices(devs);
        assert_eq!(g.devices.len(), 2);
    }

    #[test]
    #[should_panic(expected = "does not match vertex count")]
    fn restore_rejects_wrong_count() {
        let mut b = GraphBuilder::new();
        b.add_vertex(Null);
        let mut g = b.build();
        g.take_devices();
        g.restore_devices(vec![]);
    }

    #[test]
    #[should_panic(expected = "edge to unknown vertex")]
    fn rejects_dangling_edge() {
        let mut b = GraphBuilder::new();
        let v0 = b.add_vertex(Null);
        b.add_port_to(v0, vec![99]);
        b.build();
    }

    #[test]
    #[should_panic(expected = "unknown dest list")]
    fn rejects_unknown_dest_list() {
        let mut b = GraphBuilder::new();
        let v0 = b.add_vertex(Null);
        b.add_port(v0, DestListId(5));
    }
}
