//! POLite-like application-graph framework — paper §4.2/§4.3.
//!
//! * [`device`] — the vertex abstraction: event handlers, ports, accounting.
//! * [`builder`] — graph construction with pooled (shared) multicast
//!   destination lists.
//! * [`mapping`] — vertex→hardware-thread assignment: the paper's manual 2-D
//!   mapping with soft-scheduling, plus the named [`mapping::MappingStrategy`]
//!   surface (manual / partitioned / shuffled) the session API exposes.
//! * [`partition`] — recursive-bisection auto-mapper (METIS substitute for
//!   the POLite path).

pub mod builder;
pub mod device;
pub mod mapping;
pub mod partition;

pub use builder::{DestListId, Graph, GraphBuilder};
pub use device::{Ctx, Device, PortId, VertexId};
pub use mapping::{Mapping, MappingStrategy};
