//! Vertex → hardware-thread mapping — paper §4.3.
//!
//! Two mapping paths, as in POETS:
//!
//! * **Manual 2-D** (the Tinsel path): the imputation graph is itself a 2-D
//!   array, so consecutive vertices (column-major) are packed onto
//!   consecutive threads, `states_per_thread` at a time — this is exactly the
//!   paper's soft-scheduling knob (Fig 12's x-axis).
//! * **Partitioned** (the POLite path): an automatic partitioner (our
//!   recursive-bisection METIS substitute, [`super::partition`]) assigns
//!   balanced, low-edge-cut parts to threads.

use crate::poets::topology::{ClusterConfig, ThreadId};
use crate::util::rng::Rng;

use super::builder::Graph;
use super::device::{Device, VertexId};

/// A complete vertex→thread assignment.
#[derive(Clone, Debug)]
pub struct Mapping {
    thread_of: Vec<ThreadId>,
    n_threads_used: usize,
}

impl Mapping {
    pub fn from_assignment(thread_of: Vec<ThreadId>, cluster: &ClusterConfig) -> Mapping {
        let total = cluster.total_threads() as u32;
        let mut used = std::collections::HashSet::new();
        for t in &thread_of {
            assert!(t.0 < total, "thread {} out of range", t.0);
            used.insert(t.0);
        }
        Mapping {
            thread_of,
            n_threads_used: used.len(),
        }
    }

    /// The paper's manual 2-D mapping with soft-scheduling.
    ///
    /// Vertices are assumed column-major (all |H| states of marker column 0,
    /// then column 1, ...).  Threads are filled in order, `states_per_thread`
    /// vertices each, so a column occupies a contiguous run of threads and
    /// adjacent columns are physically adjacent — minimising NoC distance for
    /// the column-to-column multicasts.
    pub fn manual_2d(
        n_vertices: usize,
        states_per_thread: usize,
        cluster: &ClusterConfig,
    ) -> Mapping {
        assert!(states_per_thread >= 1);
        let needed = n_vertices.div_ceil(states_per_thread);
        assert!(
            needed <= cluster.total_threads(),
            "graph needs {needed} threads, cluster has {} \
             (raise states_per_thread — soft-scheduling)",
            cluster.total_threads()
        );
        let thread_of = (0..n_vertices)
            .map(|v| ThreadId((v / states_per_thread) as u32))
            .collect();
        Mapping {
            thread_of,
            n_threads_used: needed,
        }
    }

    /// Round-robin across all threads (a deliberately locality-blind mapping,
    /// used in tests and as an ablation).
    pub fn round_robin(n_vertices: usize, cluster: &ClusterConfig) -> Mapping {
        let total = cluster.total_threads();
        let thread_of = (0..n_vertices)
            .map(|v| ThreadId((v % total) as u32))
            .collect();
        Mapping {
            thread_of,
            n_threads_used: n_vertices.min(total),
        }
    }

    #[inline]
    pub fn thread_of(&self, v: VertexId) -> ThreadId {
        self.thread_of[v as usize]
    }

    pub fn n_vertices(&self) -> usize {
        self.thread_of.len()
    }

    /// Number of distinct threads occupied.
    pub fn n_threads_used(&self) -> usize {
        self.n_threads_used
    }

    /// Maximum vertices on any one thread (the soft-scheduling factor
    /// actually achieved).
    pub fn max_load(&self) -> usize {
        let mut counts = std::collections::HashMap::new();
        for t in &self.thread_of {
            *counts.entry(t.0).or_insert(0usize) += 1;
        }
        counts.values().copied().max().unwrap_or(0)
    }
}

/// Named vertex→thread mapping strategies — the session-level configuration
/// surface over the mapping paths above (plus the locality-blind control the
/// ablation bench uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingStrategy {
    /// The paper's manual 2-D column-packed mapping (default).
    Manual2d,
    /// POLite-style recursive-bisection auto-partitioner.
    Partitioned,
    /// Locality-blind control: the manual packing randomly permuted, so
    /// column neighbourhoods scatter across boards.
    Shuffled { seed: u64 },
}

impl MappingStrategy {
    pub fn name(self) -> &'static str {
        match self {
            MappingStrategy::Manual2d => "manual-2d",
            MappingStrategy::Partitioned => "partitioned",
            MappingStrategy::Shuffled { .. } => "shuffled",
        }
    }

    /// Build the mapping for a graph under this strategy.
    pub fn build<D: Device>(
        self,
        graph: &Graph<D>,
        states_per_thread: usize,
        cluster: &ClusterConfig,
    ) -> Mapping {
        let n = graph.n_vertices();
        match self {
            MappingStrategy::Manual2d => Mapping::manual_2d(n, states_per_thread, cluster),
            MappingStrategy::Partitioned => {
                super::partition::partition_mapping(graph, states_per_thread, cluster)
            }
            MappingStrategy::Shuffled { seed } => {
                let mut assign: Vec<ThreadId> = (0..n)
                    .map(|v| ThreadId((v / states_per_thread) as u32))
                    .collect();
                let mut rng = Rng::new(seed);
                rng.shuffle(&mut assign);
                Mapping::from_assignment(assign, cluster)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_2d_packs_contiguously() {
        let c = ClusterConfig::tiny();
        let m = Mapping::manual_2d(10, 2, &c);
        assert_eq!(m.thread_of(0), ThreadId(0));
        assert_eq!(m.thread_of(1), ThreadId(0));
        assert_eq!(m.thread_of(2), ThreadId(1));
        assert_eq!(m.thread_of(9), ThreadId(4));
        assert_eq!(m.n_threads_used(), 5);
        assert_eq!(m.max_load(), 2);
    }

    #[test]
    fn manual_2d_keeps_columns_local() {
        // Column-major vertex ids: a column of H=8 at 4 states/thread must
        // span exactly 2 consecutive threads.
        let c = ClusterConfig::poets_48();
        let h = 8;
        let m = Mapping::manual_2d(h * 100, 4, &c);
        for col in 0..100u32 {
            let threads: std::collections::HashSet<u32> = (0..h as u32)
                .map(|i| m.thread_of(col * h as u32 + i).0)
                .collect();
            assert_eq!(threads.len(), 2, "column {col} spread {threads:?}");
        }
    }

    #[test]
    #[should_panic(expected = "soft-scheduling")]
    fn manual_2d_rejects_overflow() {
        let c = ClusterConfig::tiny(); // 2 boards x 4 tiles x 2 cores x 4 thr = 64
        Mapping::manual_2d(100, 1, &c);
    }

    #[test]
    fn round_robin_covers_threads() {
        let c = ClusterConfig::tiny();
        let m = Mapping::round_robin(200, &c);
        assert_eq!(m.n_threads_used(), c.total_threads());
        assert_eq!(m.thread_of(0), ThreadId(0));
        assert_eq!(m.thread_of(64), ThreadId(0));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn from_assignment_validates() {
        let c = ClusterConfig::tiny();
        Mapping::from_assignment(vec![ThreadId(9999)], &c);
    }
}
