//! Graph partitioner — offline substitute for METIS (paper §4.3: "POLite
//! ... automatically maps vertices to threads using the METIS algorithm").
//!
//! Recursive balanced bisection with BFS level structure: pick a peripheral
//! seed, BFS the whole part, split at the median BFS order.  This is the
//! classic Lipton-Tarjan-flavoured heuristic — not METIS-quality, but it
//! produces connected, balanced parts with materially lower edge-cut than
//! round-robin, which is all the mapping experiments need.  Quality is
//! measured (and asserted) by [`edge_cut`].

use super::builder::Graph;
use super::device::{Device, VertexId};
use crate::graph::mapping::Mapping;
use crate::poets::topology::{ClusterConfig, ThreadId};

/// Undirected adjacency built from a graph's ports.
pub fn adjacency<D: Device>(g: &Graph<D>) -> Vec<Vec<VertexId>> {
    let n = g.n_vertices();
    let mut adj: Vec<std::collections::BTreeSet<VertexId>> = vec![Default::default(); n];
    for v in 0..n as u32 {
        for &dl in g.ports_of(v) {
            for &d in g.dests(dl) {
                if d != v {
                    adj[v as usize].insert(d);
                    adj[d as usize].insert(v);
                }
            }
        }
    }
    adj.into_iter().map(|s| s.into_iter().collect()).collect()
}

/// Recursively bisect `0..n` into `n_parts` balanced parts.
/// Returns `part_of[v]`.
pub fn bisect(adj: &[Vec<VertexId>], n_parts: usize) -> Vec<u32> {
    assert!(n_parts >= 1);
    let n = adj.len();
    let mut part_of = vec![0u32; n];
    let all: Vec<VertexId> = (0..n as u32).collect();
    let mut next_part = 0u32;
    split(adj, &all, n_parts, &mut part_of, &mut next_part);
    part_of
}

fn split(
    adj: &[Vec<VertexId>],
    verts: &[VertexId],
    n_parts: usize,
    part_of: &mut [u32],
    next_part: &mut u32,
) {
    if n_parts == 1 || verts.len() <= 1 {
        let p = *next_part;
        *next_part += 1;
        for &v in verts {
            part_of[v as usize] = p;
        }
        return;
    }
    let order = bfs_order(adj, verts);
    // Split proportionally to the part counts on each side so uneven
    // n_parts (e.g. 3) stays balanced.
    let left_parts = n_parts / 2;
    let right_parts = n_parts - left_parts;
    let cut = verts.len() * left_parts / n_parts;
    let (left, right) = order.split_at(cut.max(1).min(verts.len() - 1));
    split(adj, left, left_parts.max(1), part_of, next_part);
    split(adj, right, right_parts, part_of, next_part);
}

/// BFS ordering of `verts` starting from a pseudo-peripheral seed; unreached
/// vertices (disconnected) are appended in id order.
fn bfs_order(adj: &[Vec<VertexId>], verts: &[VertexId]) -> Vec<VertexId> {
    let inset: std::collections::HashSet<VertexId> = verts.iter().copied().collect();
    // Double-BFS to approximate a peripheral seed.
    let seed = *verts.iter().min().unwrap();
    let far = bfs_last(adj, seed, &inset);
    let mut order = Vec::with_capacity(verts.len());
    let mut seen = std::collections::HashSet::new();
    let mut q = std::collections::VecDeque::new();
    q.push_back(far);
    seen.insert(far);
    while let Some(v) = q.pop_front() {
        order.push(v);
        for &w in &adj[v as usize] {
            if inset.contains(&w) && seen.insert(w) {
                q.push_back(w);
            }
        }
    }
    for &v in verts {
        if seen.insert(v) {
            order.push(v);
        }
    }
    order
}

fn bfs_last(
    adj: &[Vec<VertexId>],
    seed: VertexId,
    inset: &std::collections::HashSet<VertexId>,
) -> VertexId {
    let mut seen = std::collections::HashSet::new();
    let mut q = std::collections::VecDeque::new();
    q.push_back(seed);
    seen.insert(seed);
    let mut last = seed;
    while let Some(v) = q.pop_front() {
        last = v;
        for &w in &adj[v as usize] {
            if inset.contains(&w) && seen.insert(w) {
                q.push_back(w);
            }
        }
    }
    last
}

/// Number of undirected edges crossing part boundaries.
pub fn edge_cut(adj: &[Vec<VertexId>], part_of: &[u32]) -> u64 {
    let mut cut = 0u64;
    for (v, ns) in adj.iter().enumerate() {
        for &w in ns {
            if (w as usize) > v && part_of[v] != part_of[w as usize] {
                cut += 1;
            }
        }
    }
    cut
}

/// Partition a graph across the cluster's threads (POLite auto-mapping),
/// `states_per_thread` vertices per thread.
pub fn partition_mapping<D: Device>(
    g: &Graph<D>,
    states_per_thread: usize,
    cluster: &ClusterConfig,
) -> Mapping {
    let n_parts = g
        .n_vertices()
        .div_ceil(states_per_thread)
        .min(cluster.total_threads())
        .max(1);
    let adj = adjacency(g);
    let part_of = bisect(&adj, n_parts);
    let assign: Vec<ThreadId> = part_of.iter().map(|&p| ThreadId(p)).collect();
    Mapping::from_assignment(assign, cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::device::{Ctx, Device};

    struct Null;
    impl Device for Null {
        type Msg = u8;
        fn init(&mut self, _ctx: &mut Ctx<u8>) {}
        fn recv(&mut self, _m: &u8, _s: VertexId, _c: &mut Ctx<u8>) {}
        fn step(&mut self, _c: &mut Ctx<u8>) -> bool {
            false
        }
    }

    /// Path graph 0-1-2-...-n.
    fn path_graph(n: usize) -> Graph<Null> {
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(Null);
        }
        for v in 0..n as u32 {
            let mut d = Vec::new();
            if v > 0 {
                d.push(v - 1);
            }
            if v + 1 < n as u32 {
                d.push(v + 1);
            }
            b.add_port_to(v, d);
        }
        b.build()
    }

    #[test]
    fn bisection_balanced() {
        let g = path_graph(100);
        let adj = adjacency(&g);
        let parts = bisect(&adj, 4);
        let mut counts = [0usize; 4];
        for &p in &parts {
            counts[p as usize] += 1;
        }
        for &c in &counts {
            assert!((20..=30).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn bisection_beats_round_robin_on_path() {
        let g = path_graph(128);
        let adj = adjacency(&g);
        let parts = bisect(&adj, 8);
        let cut = edge_cut(&adj, &parts);
        // A path split into 8 contiguous chunks cuts 7 edges; round-robin
        // cuts nearly all 127. Allow slack for heuristic imperfection.
        let rr: Vec<u32> = (0..128).map(|v| (v % 8) as u32).collect();
        let rr_cut = edge_cut(&adj, &rr);
        assert!(cut <= 14, "cut={cut}");
        assert!(rr_cut > 8 * cut, "rr_cut={rr_cut} cut={cut}");
    }

    #[test]
    fn odd_part_counts_balanced() {
        let g = path_graph(90);
        let adj = adjacency(&g);
        let parts = bisect(&adj, 3);
        let mut counts = [0usize; 3];
        for &p in &parts {
            counts[p as usize] += 1;
        }
        for &c in &counts {
            assert!((25..=35).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn handles_disconnected_graphs() {
        let mut b = GraphBuilder::new();
        for _ in 0..10 {
            b.add_vertex(Null);
        }
        // no edges at all
        let g = b.build();
        let adj = adjacency(&g);
        let parts = bisect(&adj, 2);
        let ones = parts.iter().filter(|&&p| p == 1).count();
        assert!((4..=6).contains(&ones));
    }

    #[test]
    fn partition_mapping_respects_cluster() {
        let g = path_graph(64);
        let c = ClusterConfig::tiny();
        let m = partition_mapping(&g, 2, &c);
        assert_eq!(m.n_vertices(), 64);
        assert!(m.n_threads_used() <= c.total_threads());
        // Balanced: no thread over ~2x the target load.
        assert!(m.max_load() <= 4, "max_load={}", m.max_load());
    }
}
