//! The POLite-like device (vertex) abstraction — paper §4.2/§4.3.
//!
//! A device is a small state machine.  Handlers run only on event arrival (or
//! at a globally-synchronised step, driven by termination detection); they may
//! mutate device state and request sends on pre-declared output ports.  Ports
//! are multicast groups: one send request delivers the event to every
//! destination of the port (Tinsel's hardware multicast [21]).

/// Vertex identifier within an application graph.
pub type VertexId = u32;

/// Port index within a vertex (output multicast group).
pub type PortId = u8;

/// Accounting + send interface handed to every handler invocation.
///
/// `flop(n)` records floating-point work for the timing model — the
/// functional result is computed natively in the handler, but the simulated
/// cost is derived from the recorded count.
#[derive(Debug)]
pub struct Ctx<M> {
    /// This vertex's id.
    pub me: VertexId,
    /// Current global step number (target-haplotype pipelining wave).
    pub step: u64,
    flops: u64,
    sends: Vec<(PortId, M)>,
}

impl<M> Ctx<M> {
    pub fn new(me: VertexId, step: u64) -> Self {
        Ctx {
            me,
            step,
            flops: 0,
            sends: Vec::new(),
        }
    }

    /// Request a multicast send of `msg` on `port`.
    #[inline]
    pub fn send(&mut self, port: PortId, msg: M) {
        self.sends.push((port, msg));
    }

    /// Record `n` floating-point operations for the cost model.
    #[inline]
    pub fn flop(&mut self, n: u64) {
        self.flops += n;
    }

    /// Drain recorded sends (used by the simulator).
    pub fn take_sends(&mut self) -> Vec<(PortId, M)> {
        std::mem::take(&mut self.sends)
    }

    /// Drain recorded sends in place, reusing the buffer (hot path: the
    /// delivery engine calls this once per handler invocation).
    pub fn drain_sends(&mut self) -> std::vec::Drain<'_, (PortId, M)> {
        self.sends.drain(..)
    }

    /// Recorded FP-op count.
    pub fn flops(&self) -> u64 {
        self.flops
    }

    /// Reset accounting between handler invocations (simulator use).
    pub fn reset(&mut self, me: VertexId, step: u64) {
        self.me = me;
        self.step = step;
        self.flops = 0;
        debug_assert!(self.sends.is_empty(), "sends not drained");
    }
}

/// A POLite-style device.
///
/// `Msg` must be `'static + Clone` and small — the simulator asserts it fits
/// the 64-byte event budget of the Tinsel fabric.
///
/// Devices are `Send` and messages `Send + Sync`: the simulator's delivery
/// engine partitions devices into per-tile shards and fans the deliver/step
/// phases out across host threads, with each superstep's message arena shared
/// read-only between shards.  Device state itself is never shared — a shard
/// owns its resident devices exclusively — so no `Sync` bound is needed on
/// the device type.
pub trait Device: Send {
    type Msg: Clone + Send + Sync + 'static;

    /// Cluster initialisation handler (paper Algorithm 1, Initialization).
    fn init(&mut self, ctx: &mut Ctx<Self::Msg>);

    /// Received-event handler.
    fn recv(&mut self, msg: &Self::Msg, src: VertexId, ctx: &mut Ctx<Self::Msg>);

    /// Step handler, invoked when termination detection finds no active send
    /// requests (paper Algorithm 1, Step).  Return `false` to vote for halt;
    /// the run ends when *all* devices vote halt and no events are in flight.
    fn step(&mut self, ctx: &mut Ctx<Self::Msg>) -> bool;

    /// How many *lanes* (independent per-target payload slots) one message
    /// carries.  Scalar applications leave the default of 1; wave-batched
    /// applications report their SoA slab occupancy so the simulator can
    /// account delivered events and delivered lanes separately
    /// (`SimMetrics::lanes_delivered` — the quantity that shows the
    /// per-message amortisation of multi-target waves).
    fn lanes(_msg: &Self::Msg) -> u32 {
        1
    }

    /// Serialise the device's mutable state into `out` for a barrier-aligned
    /// checkpoint, returning `true` if the device supports it.  The default
    /// (`false`, nothing written) opts the device out of the fault plane's
    /// remap-and-replay: a scheduled tile failure on a graph of such devices
    /// is a hard error at the first checkpoint (`poets::fault`).
    fn snapshot(&self, _out: &mut Vec<u8>) -> bool {
        false
    }

    /// Restore state previously written by [`Device::snapshot`].  Only called
    /// with bytes this device type produced; panicking on malformed input is
    /// acceptable (it indicates a checkpoint/restore version mismatch).
    fn restore(&mut self, _bytes: &[u8]) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_records_sends_and_flops() {
        let mut ctx: Ctx<u32> = Ctx::new(7, 3);
        ctx.flop(5);
        ctx.flop(2);
        ctx.send(0, 11);
        ctx.send(1, 22);
        assert_eq!(ctx.flops(), 7);
        assert_eq!(ctx.me, 7);
        assert_eq!(ctx.step, 3);
        let sends = ctx.take_sends();
        assert_eq!(sends, vec![(0, 11), (1, 22)]);
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    fn ctx_drain_reuses_buffer() {
        let mut ctx: Ctx<u32> = Ctx::new(0, 0);
        ctx.send(0, 1);
        ctx.send(2, 3);
        let drained: Vec<_> = ctx.drain_sends().collect();
        assert_eq!(drained, vec![(0, 1), (2, 3)]);
        // Buffer empty again: reset's debug assertion must hold.
        ctx.reset(1, 1);
        assert!(ctx.take_sends().is_empty());
    }

    #[test]
    fn ctx_reset_clears_accounting() {
        let mut ctx: Ctx<u32> = Ctx::new(0, 0);
        ctx.flop(9);
        ctx.reset(1, 2);
        assert_eq!(ctx.flops(), 0);
        assert_eq!((ctx.me, ctx.step), (1, 2));
    }
}
