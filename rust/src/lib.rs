//! # poets-impute
//!
//! A full reproduction of *"An Event-Driven Approach To Genotype Imputation
//! On A Custom RISC-V FPGA Cluster"* (Morris et al., CS.DC 2023) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The paper maps the Li & Stephens imputation HMM onto POETS, an
//! event-driven RISC-V NoC FPGA cluster, and evaluates scaling,
//! soft-scheduling and a linear interpolation optimisation against a
//! single-threaded x86 baseline.
//!
//! ## The session API
//!
//! All five compute planes are driven through one typed pipeline,
//! [`session`]: build a [`session::Workload`], pick a plane with
//! [`session::EngineSpec`], and run it through a [`session::ImputeSession`]:
//!
//! ```
//! use poets_impute::session::{EngineSpec, ImputeSession, Workload};
//! use poets_impute::workload::panelgen::PanelConfig;
//!
//! let cfg = PanelConfig { n_hap: 8, n_mark: 21, annot_ratio: 0.2, seed: 1,
//!                         ..PanelConfig::default() };
//! let report = ImputeSession::new(Workload::synthetic(&cfg, 2))
//!     .engine(EngineSpec::Baseline)
//!     .run()
//!     .expect("baseline plane");
//! assert!(report.accuracy.unwrap().concordance > 0.0);
//! ```
//!
//! The CLI (`poets-impute impute|validate`), the figure/ablation benches and
//! every example run on this API (the deprecated per-plane entry points of
//! earlier revisions have been removed).
//!
//! ## Real panels
//!
//! [`genomics`] is the real-data front door: `poets-impute panel ingest
//! cohort.vcf cohort.ppnl` parses a phased bi-allelic VCF
//! ([`genomics::vcf`]) and writes it bit-packed at 1 bit/allele
//! ([`genomics::packed::PackedPanel`], the `.ppnl` format).  Anywhere a
//! panel is named — `impute --panel`, serve request lines, the
//! [`serve::PanelRegistry`] API — `vcf:<path>` and `packed:<path>` specs
//! load real panels alongside `synth:` recipes, and `impute --panel ...
//! --window W --overlap V` runs chromosome-scale inputs as overlapping
//! marker windows stitched back into one report
//! ([`genomics::window::run_windowed`]).
//!
//! ## Serving
//!
//! [`serve`] turns the pipeline into a multi-tenant service: a
//! [`serve::PanelRegistry`] shares loaded panels across requests, a bounded
//! queue coalesces concurrent same-panel requests into engine batches, and a
//! worker pool answers each request with a
//! [`serve::ServeReport`] (schema `poets-impute/serve-report/v1`).  The
//! `serve` subcommand speaks the same API as newline-delimited JSON over
//! stdin/stdout, and `bench-serve` is the closed-loop load generator that
//! archives the service throughput baseline (`BENCH_serve.json`).
//!
//! ## Layers
//!
//! * [`session`] — the unified pipeline: `Engine` trait over the five
//!   planes, target batching, accuracy scoring, serialisable reports.
//! * [`model`] — the Li & Stephens mathematics plus the paper's x86-style
//!   baseline implementation (three nested loops) and linear interpolation.
//! * [`workload`] — synthetic reference-panel / genetic-map generation
//!   following the paper's §6.2 recipe (diallelic, 5 % MAF, 1/100 or 1/10
//!   marker ratios).
//! * [`genomics`] — real-data panels: the VCF-subset parser, the bit-packed
//!   `.ppnl` panel store, and windowed chunking with dosage stitching.
//! * [`poets`] — a cycle-approximate functional + timing simulator of the
//!   POETS cluster: topology, NoC, mailboxes, hardware multicast,
//!   termination detection, discrete-event core and a calibrated cost model.
//! * [`graph`] — a POLite-like application-graph framework with manual 2-D
//!   and partitioner-based vertex→thread mapping (soft-scheduling).
//! * [`imputation`] — the paper's contribution: Algorithm 1 as event-driven
//!   vertices, wave-batched SoA multi-target deliveries (bit-identical to
//!   the per-target plane at any batch width), and linear-interpolation
//!   sections.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) used as the fast compute plane and as the
//!   oracle.
//! * [`serve`] — the multi-tenant service layer: panel registry, request
//!   coalescing (event-plane groups merge member targets into one wave
//!   sweep), deferred worker-pool target minting, admission control,
//!   worker pool, JSONL frontend and the closed-loop load generator.
//! * [`bench`] — harnesses that regenerate every figure in the paper's
//!   evaluation (Fig 11, 12, 13 plus claim checks).
//! * [`obs`] — opt-in observability: per-tile/per-superstep DES traces
//!   (`poets-impute/trace/v1`, bit-identical across thread counts and wave
//!   widths), serve request spans, and Chrome `trace_event` export.
//! * [`util`], [`cli`] — offline-friendly substrates (RNG, JSON, tables,
//!   property-testing, argument parsing) written against std only.

pub mod bench;
pub mod cli;
pub mod genomics;
pub mod graph;
pub mod imputation;
pub mod model;
pub mod obs;
pub mod poets;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod util;
pub mod workload;
