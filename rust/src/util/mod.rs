//! Offline-friendly substrates: RNG, JSON, statistics, tables, property tests.
//!
//! The build environment has no network access and only the `xla` crate's
//! dependency closure vendored, so the conveniences normally pulled from
//! crates.io (`rand`, `serde_json`, `criterion`, `proptest`, `clap`) are
//! re-implemented here at the scale this project needs.

pub mod json;
pub mod prop;
pub mod provenance;
pub mod rng;
pub mod stats;
pub mod table;

use std::time::Instant;

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Time `f` repeatedly: one warmup call plus `reps` measured calls.
pub fn timed_reps<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, Vec<f64>) {
    assert!(reps >= 1);
    let mut out = f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let start = Instant::now();
        out = f();
        times.push(start.elapsed().as_secs_f64());
    }
    (out, times)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value_and_positive_time() {
        let (v, t) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn timed_reps_counts() {
        let mut calls = 0;
        let (_, times) = timed_reps(3, || calls += 1);
        assert_eq!(calls, 4); // warmup + 3
        assert_eq!(times.len(), 3);
    }
}
