//! Deterministic RNG: splitmix64 seeding + xoshiro256** generation.
//!
//! The offline environment has no `rand` crate; this is the standard
//! public-domain xoshiro256** generator (Blackman & Vigna) with a splitmix64
//! seed expander, enough for workload generation and the property harness.
//! Determinism across runs/platforms is part of the reproducibility story:
//! every experiment records its seed.

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a single seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-entity RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        // Floyd's algorithm keeps this O(k) in memory.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_full_range() {
        let mut r = Rng::new(14);
        let s = r.sample_indices(10, 10);
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(15);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
