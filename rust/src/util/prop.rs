//! Mini property-testing harness (offline substitute for `proptest`).
//!
//! Usage:
//! ```
//! use poets_impute::util::prop::forall;
//! forall("sum is commutative", 100, |rng| {
//!     let a = rng.range(0, 1000) as i64;
//!     let b = rng.range(0, 1000) as i64;
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```
//!
//! Each case gets a fresh RNG derived from a base seed + case index, so a
//! failure is reproducible from the printed `(name, case)` pair alone. On
//! failure the harness retries the failing case once with the same seed to
//! confirm determinism, then panics with the case's seed and message.

use super::rng::Rng;

/// Base seed for all property runs; override with `POETS_PROP_SEED`.
pub fn base_seed() -> u64 {
    std::env::var("POETS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Number-of-cases multiplier; override with `POETS_PROP_CASES` (default 1x).
pub fn case_multiplier() -> usize {
    std::env::var("POETS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// Run `cases` random cases of `property`; panic on the first failure with a
/// reproducible seed.
pub fn forall<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    let total = cases * case_multiplier();
    for case in 0..total {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            // Confirm determinism before reporting.
            let mut rng2 = Rng::new(seed);
            let again = property(&mut rng2);
            panic!(
                "property '{name}' failed at case {case}/{total} (seed {seed:#x}): {msg}\n\
                 deterministic replay: {}",
                match again {
                    Err(m) => format!("reproduced ({m})"),
                    Ok(()) => "NOT reproduced — property is nondeterministic!".to_string(),
                }
            );
        }
    }
}

/// Like [`forall`] but the property builds its own case from an index too
/// (handy for sweeping structured sizes deterministically + fuzzing inside).
pub fn forall_indexed<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(usize, &mut Rng) -> Result<(), String>,
{
    let mut case_idx = 0;
    forall(name, cases, move |rng| {
        let r = property(case_idx, rng);
        case_idx += 1;
        r
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("tautology", 50, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_name() {
        forall("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn cases_see_distinct_randomness() {
        let mut seen = std::collections::HashSet::new();
        forall("distinct", 32, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 32);
    }

    #[test]
    fn indexed_variant_counts_up() {
        let mut last = None;
        forall_indexed("indexed", 10, |i, _| {
            assert_eq!(last.map_or(0, |l: usize| l + 1), i);
            last = Some(i);
            Ok(())
        });
        assert_eq!(last, Some(9));
    }
}
