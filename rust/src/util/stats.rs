//! Small statistics helpers used by the bench harness and accuracy metrics.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation between closest ranks (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "percentile out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Least-squares fit `y = a + b x`; returns `(a, b)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear_fit needs >= 2 points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    assert!(den > 0.0, "linear_fit with constant x");
    let b = num / den;
    (my - b * mx, b)
}

/// Pearson correlation; 0.0 if either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Timing summary over repeated measurements (seconds).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            p50: median(xs),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_constant() {
        let xs = [1.0, 2.0, 3.0];
        assert!((pearson(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &[6.0, 4.0, 2.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.p50 - 2.0).abs() < 1e-12);
    }
}
