//! Bench-artifact provenance: stamp archived JSON documents with the code
//! revision and run configuration that produced them.
//!
//! `BENCH_desim.json` and `BENCH_serve.json` are tracked across PRs (CI
//! uploads them as workflow artifacts), so a number without its commit and
//! sweep shape is unattributable the moment the next PR lands.  Every
//! archived bench document therefore carries:
//!
//! * `"schema"` — the document's format name/version;
//! * `"git_commit"` — `git rev-parse HEAD` of the producing tree
//!   (`"unknown"` when git is unavailable, e.g. a source tarball);
//! * `"run_config"` — the sweep parameters, so a regression can be
//!   reproduced from the artifact alone.

use crate::util::json::Json;

/// The producing tree's commit hash, or `"unknown"` outside a git checkout.
pub fn git_commit() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|out| out.status.success())
        .and_then(|out| String::from_utf8(out.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Stamp `doc` with the standard provenance triple.
pub fn stamp(doc: &mut Json, schema: &str, run_config: Json) {
    doc.set("schema", schema)
        .set("git_commit", git_commit())
        .set("run_config", run_config);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn git_commit_is_a_hash_or_unknown() {
        let c = git_commit();
        assert!(
            c == "unknown" || (c.len() == 40 && c.chars().all(|ch| ch.is_ascii_hexdigit())),
            "unexpected commit string {c:?}"
        );
    }

    #[test]
    fn stamp_sets_the_provenance_triple() {
        let mut doc = Json::obj();
        let mut cfg = Json::obj();
        cfg.set("targets", 64usize);
        stamp(&mut doc, "poets-impute/bench-test/v1", cfg);
        assert_eq!(
            doc.get("schema").unwrap().as_str(),
            Some("poets-impute/bench-test/v1")
        );
        assert!(doc.get("git_commit").unwrap().as_str().is_some());
        assert_eq!(
            doc.get("run_config").unwrap().get("targets").unwrap().as_i64(),
            Some(64)
        );
    }
}
