//! Minimal JSON writer for run reports (no serde in the offline environment).
//!
//! Only what the bench/report paths need: a tree of values rendered with
//! stable key order (insertion order), numbers via shortest-roundtrip `{}`
//! formatting, and correct string escaping.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/replace a key on an object (panics on non-objects: programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => {
                items.push(value.into());
                self
            }
            _ => panic!("Json::push on non-array"),
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Render to a compact string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Render with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close = "  ".repeat(depth);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    item.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close}]");
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close}}}");
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        // JSON has no NaN/Inf; null is the conventional stand-in.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(3i64).render(), "3");
        assert_eq!(Json::from(1.5).render(), "1.5");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::from("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{01}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).render(), "null");
        assert_eq!(Json::from(f64::INFINITY).render(), "null");
    }

    #[test]
    fn object_roundtrip_order() {
        let mut o = Json::obj();
        o.set("b", 1i64).set("a", 2i64).set("b", 3i64);
        assert_eq!(o.render(), "{\"b\":3,\"a\":2}");
        assert_eq!(o.get("a"), Some(&Json::Int(2)));
    }

    #[test]
    fn arrays_nest() {
        let mut a = Json::Arr(vec![]);
        a.push(1i64).push(vec![2i64, 3i64]);
        assert_eq!(a.render(), "[1,[2,3]]");
    }

    #[test]
    fn pretty_is_valid_shape() {
        let mut o = Json::obj();
        o.set("xs", vec![1i64, 2i64]);
        let p = o.pretty();
        assert!(p.contains("\"xs\": [\n"));
        assert!(p.ends_with('}'));
    }
}
