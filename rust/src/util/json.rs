//! Minimal JSON reader/writer for run reports and the serve protocol (no
//! serde in the offline environment).
//!
//! Writing covers what the bench/report paths need: a tree of values rendered
//! with stable key order (insertion order), numbers via shortest-roundtrip
//! `{}` formatting, and correct string escaping.  Reading ([`Json::parse`])
//! is a strict recursive-descent parser covering the full JSON grammar —
//! enough for the `serve` subcommand's newline-delimited request lines.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/replace a key on an object (panics on non-objects: programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key.to_string(), value.into()));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => {
                items.push(value.into());
                self
            }
            _ => panic!("Json::push on non-array"),
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mutable lookup on an object (`None` for non-objects/missing keys) —
    /// lets callers rewrite nested fields in place, e.g. scrubbing volatile
    /// timing fields before byte-comparing two rendered documents.
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Remove a key from an object, returning its value.  `None` when the
    /// key is absent or `self` is not an object.  Used by the streamed
    /// serve path to turn a full report into its terminal manifest (same
    /// document minus the bulky `dosages` matrix).
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(pairs) => {
                let idx = pairs.iter().position(|(k, _)| k == key)?;
                Some(pairs.remove(idx).1)
            }
            _ => None,
        }
    }

    /// Parse a complete JSON document (strict: no trailing garbage; nesting
    /// capped so untrusted input cannot overflow the parser's stack).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing input at byte {}", p.pos));
        }
        Ok(v)
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload (`Num` or `Int`), if any.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Integer payload: `Int`, or a `Num` that is exactly integral.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 2f64.powi(53) => Some(*x as i64),
            _ => None,
        }
    }

    /// Non-negative integer payload.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Render to a compact string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Render with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close = "  ".repeat(depth);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    item.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close}]");
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close}}}");
            }
            _ => self.write(out),
        }
    }
}

/// Nesting bound for [`Json::parse`]: recursion is one frame per level, and
/// a stack overflow is an abort (not an unwind), so depth from untrusted
/// input must be capped, not merely survived.
const MAX_PARSE_DEPTH: usize = 128;

/// Strict recursive-descent parser over the input bytes.
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}",
                b as char, self.pos
            ))
        }
    }

    fn eat_literal(&mut self, lit: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.eat_literal("true", Json::Bool(true)),
            Some(b'f') => self.eat_literal("false", Json::Bool(false)),
            Some(b'n') => self.eat_literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(other) => Err(format!(
                "unexpected {:?} at byte {}",
                other as char, self.pos
            )),
            None => Err("unexpected end of input".into()),
        }
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_PARSE_DEPTH {
            return Err(format!("nesting deeper than {MAX_PARSE_DEPTH} levels"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, String> {
        self.enter()?;
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes (valid UTF-8 passes through).
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| format!("invalid UTF-8 near byte {start}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.escape(&mut out)?;
                }
                Some(_) => return Err(format!("raw control byte at {}", self.pos)),
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn escape(&mut self, out: &mut String) -> Result<(), String> {
        let c = self
            .peek()
            .ok_or_else(|| "unterminated escape".to_string())?;
        self.pos += 1;
        match c {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{08}'),
            b'f' => out.push('\u{0c}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair: require \uXXXX low half.
                    if self.peek() == Some(b'\\') {
                        self.pos += 1;
                        self.expect(b'u')?;
                        let lo = self.hex4()?;
                        if !(0xDC00..0xE000).contains(&lo) {
                            return Err("invalid low surrogate".into());
                        }
                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                    } else {
                        return Err("lone high surrogate".into());
                    }
                } else {
                    hi
                };
                out.push(
                    char::from_u32(code).ok_or_else(|| "invalid \\u escape".to_string())?,
                );
            }
            other => return Err(format!("bad escape '\\{}'", other as char)),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        let s = self
            .bytes
            .get(self.pos..end)
            .ok_or_else(|| "truncated \\u escape".to_string())?;
        // Exactly four hex digits: from_str_radix would also tolerate a
        // leading '+', which JSON forbids.
        let mut v = 0u32;
        for &b in s {
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| "bad \\u escape".to_string())?;
            v = v * 16 + d;
        }
        self.pos = end;
        Ok(v)
    }

    /// The full JSON number grammar, enforced structurally: `-?` then
    /// `0 | [1-9][0-9]*` (no leading zeros), optional `.[0-9]+`, optional
    /// `[eE][+-]?[0-9]+`.  Relying on Rust's `FromStr` alone would admit
    /// forms JSON forbids (`01`, `1.`, `.5`).
    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(format!("bad number at byte {start}")),
        }
        let mut fractional = false;
        if self.peek() == Some(b'.') {
            fractional = true;
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(format!("bad number at byte {start} (digits must follow '.')"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            fractional = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(format!("bad number at byte {start} (empty exponent)"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !fractional {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        // JSON has no NaN/Inf; null is the conventional stand-in.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(3i64).render(), "3");
        assert_eq!(Json::from(1.5).render(), "1.5");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::from("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{01}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).render(), "null");
        assert_eq!(Json::from(f64::INFINITY).render(), "null");
    }

    #[test]
    fn object_roundtrip_order() {
        let mut o = Json::obj();
        o.set("b", 1i64).set("a", 2i64).set("b", 3i64);
        assert_eq!(o.render(), "{\"b\":3,\"a\":2}");
        assert_eq!(o.get("a"), Some(&Json::Int(2)));
    }

    #[test]
    fn arrays_nest() {
        let mut a = Json::Arr(vec![]);
        a.push(1i64).push(vec![2i64, 3i64]);
        assert_eq!(a.render(), "[1,[2,3]]");
    }

    #[test]
    fn pretty_is_valid_shape() {
        let mut o = Json::obj();
        o.set("xs", vec![1i64, 2i64]);
        let p = o.pretty();
        assert!(p.contains("\"xs\": [\n"));
        assert!(p.ends_with('}'));
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("1.5").unwrap(), Json::Num(1.5));
        assert_eq!(Json::parse("2e3").unwrap(), Json::Num(2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested_structure() {
        let j = Json::parse(r#"{"a": [1, -1, 0.5], "b": {"c": "x"}, "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_i64(), Some(-1));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_render_roundtrip() {
        let mut o = Json::obj();
        o.set("s", "a\"b\\c\nd\t\u{01}")
            .set("n", -7i64)
            .set("x", 0.25)
            .set("xs", vec![1i64, 2i64])
            .set("ok", true);
        let back = Json::parse(&o.render()).unwrap();
        assert_eq!(back, o);
        let pretty_back = Json::parse(&o.pretty()).unwrap();
        assert_eq!(pretty_back, o);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        assert_eq!(
            Json::parse(r#""aA\né""#).unwrap(),
            Json::Str("aA\né".into())
        );
        // Surrogate pair escape: U+1F600, both escaped and raw UTF-8.
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone surrogate");
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "1 2", "\"unterminated",
            "{\"a\":1,}", "[1]]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_enforces_number_and_escape_grammar() {
        // Forms FromStr would tolerate but JSON forbids.
        for bad in [
            "01", "1.", ".5", "-", "1e", "1e+", "+1", "[01]", "1.e3",
            "\"\\u+123\"", "\"\\u12g4\"",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
        // The strict grammar still covers every valid shape.
        assert_eq!(Json::parse("-0").unwrap(), Json::Int(0));
        assert_eq!(Json::parse("0.5").unwrap(), Json::Num(0.5));
        assert_eq!(Json::parse("-1.5e-2").unwrap(), Json::Num(-0.015));
        assert_eq!(Json::parse("1E3").unwrap(), Json::Num(1000.0));
    }

    #[test]
    fn parse_caps_nesting_depth() {
        // A stack overflow aborts the process (untrusted serve input!), so
        // pathological nesting must be an error, not a crash.
        let deep = "[".repeat(200_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        // Moderately nested documents (within the cap) still parse.
        let ok = format!("{}0{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn accessors_are_typed() {
        let j = Json::parse(r#"{"i": 3, "f": 3.0, "h": 2.5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(j.get("i").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("f").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("h").unwrap().as_i64(), None);
        assert_eq!(j.get("h").unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("s").unwrap().as_f64(), None);
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("i").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn get_mut_and_remove_edit_objects_in_place() {
        let mut j = Json::parse(r#"{"a": {"x": 1}, "b": [1, 2], "c": "keep"}"#).unwrap();
        // Rewrite a nested field in place.
        j.get_mut("a").unwrap().set("x", 9i64);
        assert_eq!(j.get("a").unwrap().get("x").unwrap().as_i64(), Some(9));
        assert!(j.get_mut("missing").is_none());
        assert!(j.get_mut("b").unwrap().get_mut("x").is_none(), "arrays have no keys");

        // Remove returns the evicted value and preserves the other keys'
        // order (rendering stays byte-stable for the survivors).
        let b = j.remove("b").unwrap();
        assert_eq!(b, Json::parse("[1, 2]").unwrap());
        assert!(j.remove("b").is_none());
        assert_eq!(j.render(), r#"{"a":{"x":9},"c":"keep"}"#);
        let mut arr = Json::parse("[1]").unwrap();
        assert!(arr.remove("0").is_none(), "remove is object-only");
    }
}
