//! ASCII table rendering for the figure harnesses (the "same rows the paper
//! reports" output format).

/// A simple right-aligned ASCII table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format seconds in engineering style (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    let abs = s.abs();
    if abs >= 1.0 {
        format!("{s:.3}s")
    } else if abs >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Format a speedup factor compactly (e.g. "270x", "1.2e5x").
pub fn fmt_speedup(x: f64) -> String {
    if x >= 1e4 {
        format!("{x:.2e}x")
    } else if x >= 10.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.2}x")
    }
}

/// Format a large count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["a", "long"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains('a') && lines[0].contains("long"));
        assert_eq!(lines[2], "  1     2");
        assert_eq!(lines[3], "100     x");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(0.0025), "2.500ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500us");
        assert_eq!(fmt_secs(2.5e-9), "2.5ns");
    }

    #[test]
    fn fmt_speedup_ranges() {
        assert_eq!(fmt_speedup(1.25), "1.25x");
        assert_eq!(fmt_speedup(270.0), "270x");
        assert_eq!(fmt_speedup(123456.0), "1.23e5x");
    }

    #[test]
    fn fmt_count_separators() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(49152), "49,152");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }
}
