//! x86-baseline cost measurement and extrapolation.
//!
//! The figures compare simulated-POETS wall-clock against the single-threaded
//! baseline's wall-clock on this host.  Full paper-scale baseline runs
//! (10,000 targets × millions of MACs each) are impractical inside a bench
//! sweep, so we measure the per-MAC throughput once on a calibration problem
//! and extrapolate linearly — the baseline is exactly linear in
//! `targets × (H²·M or H·M)` (asserted by `linearity_holds` below and the
//! calibrate bench).  Every extrapolated cell in a report is marked as such.

use crate::model::baseline::{Baseline, ImputeOut, Method};
use crate::model::interpolation;
use crate::model::panel::{ReferencePanel, TargetHaplotype};
use crate::util::timed;

/// Measured baseline throughput (MACs/second) per formulation.
#[derive(Clone, Copy, Debug)]
pub struct X86Cost {
    pub dense_macs_per_s: f64,
    pub rank1_macs_per_s: f64,
}

impl X86Cost {
    /// Measure on a calibration problem sized to run in ~a second.
    pub fn measure(panel: &ReferencePanel, target: &TargetHaplotype, reps: usize) -> X86Cost {
        let b = Baseline::default();
        let dense_flops = b.flops_per_target(panel, Method::DenseThreeLoop) as f64;
        let rank1_flops = b.flops_per_target(panel, Method::Rank1) as f64;

        let (_, t_dense) = timed(|| {
            for _ in 0..reps {
                let out: ImputeOut<f32> = b.impute(panel, target, Method::DenseThreeLoop);
                std::hint::black_box(out);
            }
        });
        let (_, t_rank1) = timed(|| {
            for _ in 0..reps {
                let out: ImputeOut<f32> = b.impute(panel, target, Method::Rank1);
                std::hint::black_box(out);
            }
        });
        X86Cost {
            dense_macs_per_s: dense_flops * reps as f64 / t_dense.max(1e-9),
            rank1_macs_per_s: rank1_flops * reps as f64 / t_rank1.max(1e-9),
        }
    }

    /// Default calibration: a mid-size panel, 3 reps.
    pub fn measure_default() -> X86Cost {
        use crate::util::rng::Rng;
        use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};
        let cfg = PanelConfig {
            n_hap: 64,
            n_mark: 512,
            annot_ratio: 0.01,
            seed: 42,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(7);
        let target = generate_targets(&panel, &cfg, 1, &mut rng)
            .into_iter()
            .next()
            .unwrap()
            .masked;
        X86Cost::measure(&panel, &target, 3)
    }

    /// Predicted baseline seconds for a raw run (dense three-loop — the
    /// paper's matched optimisation level).
    pub fn raw_seconds(&self, n_hap: usize, n_mark: usize, n_targets: usize) -> f64 {
        let b = Baseline::default();
        // flops_per_target needs a panel only for its dims; reconstruct.
        let h = n_hap as u64;
        let m = n_mark as u64;
        let _ = b;
        let flops = 2 * (m - 1) * h * (2 * h + 1) + m * 3 * h;
        n_targets as f64 * flops as f64 / self.dense_macs_per_s
    }

    /// Predicted baseline seconds with linear interpolation (matched
    /// optimisation on the x86 side, as in Fig 13).
    pub fn interp_seconds(
        &self,
        n_hap: usize,
        n_mark: usize,
        n_anchors: usize,
        n_targets: usize,
    ) -> f64 {
        let h = n_hap as u64;
        let k = n_anchors as u64;
        let m = n_mark as u64;
        let flops = 2 * (k - 1) * h * (2 * h + 1) + k * 3 * h + m * 5 * h;
        n_targets as f64 * flops as f64 / self.dense_macs_per_s
    }

    /// Directly measure a (feasible) raw batch, seconds.
    pub fn measure_raw_batch(
        panel: &ReferencePanel,
        targets: &[TargetHaplotype],
        method: Method,
    ) -> f64 {
        let b = Baseline::default();
        let (_, t) = timed(|| {
            for target in targets {
                let out: ImputeOut<f32> = b.impute(panel, target, method);
                std::hint::black_box(out);
            }
        });
        t
    }

    /// Directly measure a (feasible) interpolated batch, seconds.
    pub fn measure_interp_batch(panel: &ReferencePanel, targets: &[TargetHaplotype]) -> f64 {
        let b = Baseline::default();
        let (_, t) = timed(|| {
            for target in targets {
                let out: ImputeOut<f32> =
                    interpolation::impute_interp(&b, panel, target, Method::DenseThreeLoop);
                std::hint::black_box(out);
            }
        });
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::panelgen::{PanelConfig, generate_panel, generate_targets};

    fn small_problem() -> (ReferencePanel, Vec<TargetHaplotype>) {
        let cfg = PanelConfig {
            n_hap: 32,
            n_mark: 256,
            annot_ratio: 0.1,
            seed: 1,
            ..PanelConfig::default()
        };
        let panel = generate_panel(&cfg);
        let mut rng = Rng::new(2);
        let targets = generate_targets(&panel, &cfg, 4, &mut rng)
            .into_iter()
            .map(|c| c.masked)
            .collect();
        (panel, targets)
    }

    #[test]
    fn measurement_positive_and_ordered() {
        let (panel, targets) = small_problem();
        let cost = X86Cost::measure(&panel, &targets[0], 2);
        assert!(cost.dense_macs_per_s > 1e6, "{cost:?}");
        assert!(cost.rank1_macs_per_s > 1e6, "{cost:?}");
    }

    #[test]
    fn extrapolation_scales_linearly() {
        let c = X86Cost {
            dense_macs_per_s: 1e9,
            rank1_macs_per_s: 1e9,
        };
        let t1 = c.raw_seconds(32, 100, 10);
        let t2 = c.raw_seconds(32, 100, 20);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        let big = c.raw_seconds(64, 100, 10);
        assert!(big > 3.5 * t1 && big < 4.5 * t1, "H² scaling expected");
    }

    #[test]
    fn interp_prediction_cheaper_than_raw() {
        let c = X86Cost {
            dense_macs_per_s: 1e9,
            rank1_macs_per_s: 1e9,
        };
        let raw = c.raw_seconds(64, 1000, 10);
        let itp = c.interp_seconds(64, 1000, 100, 10);
        assert!(itp < raw / 2.0, "raw {raw} vs interp {itp}");
    }

    #[test]
    fn linearity_holds() {
        // Extrapolation premise: measured time ~ linear in target count.
        // Wall-clock under a parallel test harness is noisy — take the best
        // of several attempts before declaring nonlinearity.
        let (panel, targets) = small_problem();
        let mut last = 0.0;
        for _ in 0..5 {
            let t2 = X86Cost::measure_raw_batch(&panel, &targets[..2], Method::DenseThreeLoop);
            let t4 = X86Cost::measure_raw_batch(&panel, &targets[..4], Method::DenseThreeLoop);
            last = t4 / t2.max(1e-12);
            if (1.2..3.4).contains(&last) {
                return;
            }
        }
        panic!("nonlinear baseline? ratio {last}");
    }
}
