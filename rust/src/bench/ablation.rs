//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! * **Mapping** — the paper's manual 2-D column-packed mapping vs the
//!   POLite auto-partitioner vs a locality-blind random scatter: quantifies how
//!   much of the performance comes from keeping columns physically local
//!   (inter-board traffic and simulated time).
//! * **Multicast** — Tinsel's hardware multicast vs naive unicast fan-out:
//!   the send-request amortisation the event-driven formulation depends on.

use crate::graph::mapping::MappingStrategy;
use crate::poets::topology::ClusterConfig;
use crate::session::{EngineSpec, ImputeSession, Workload};
use crate::util::table::{Table, fmt_count, fmt_secs};
use crate::workload::panelgen::PanelConfig;

/// One ablation row.
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub name: String,
    pub sim_seconds: f64,
    pub inter_board_sends: u64,
    pub sends: u64,
    pub max_mailbox_busy: u64,
}

/// Run the mapping ablation on one panel.
pub fn mapping_ablation(
    n_hap: usize,
    n_mark: usize,
    n_targets: usize,
    boards: usize,
    states_per_thread: usize,
    seed: u64,
) -> Vec<AblationRow> {
    let cfg = PanelConfig {
        n_hap,
        n_mark,
        maf: 0.1,
        annot_ratio: 0.1,
        seed,
        ..PanelConfig::default()
    };
    let workload = Workload::synthetic(&cfg, n_targets);

    let mut rows = Vec::new();
    let mut reference: Option<Vec<Vec<f32>>> = None;
    for strategy in [
        MappingStrategy::Manual2d,
        MappingStrategy::Partitioned,
        // Locality-blind control: the manual packing, randomly permuted
        // (column neighbourhoods scatter across boards).
        MappingStrategy::Shuffled {
            seed: seed ^ 0x50F1E,
        },
    ] {
        let report = ImputeSession::new(workload.clone())
            .engine(EngineSpec::Event)
            .cluster(ClusterConfig::with_boards(boards))
            .states_per_thread(states_per_thread)
            .mapping(strategy)
            .run()
            .expect("event plane is always available");
        let name = strategy.name();
        // Mapping must not change numerics beyond f32 reassociation: message
        // arrival order (and hence accumulation order) is mapping-dependent,
        // so agreement is to tolerance, not bitwise.
        match &reference {
            None => reference = Some(report.dosages.clone()),
            Some(want) => {
                for (a, b) in want.iter().flatten().zip(report.dosages.iter().flatten()) {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "{name} changed numerics: {a} vs {b}"
                    );
                }
            }
        }
        let metrics = report.metrics.expect("event plane reports metrics");
        rows.push(AblationRow {
            name: name.into(),
            sim_seconds: report.sim_seconds.expect("event plane reports sim time"),
            inter_board_sends: metrics.inter_board_sends,
            sends: metrics.sends,
            max_mailbox_busy: metrics.max_mailbox_busy,
        });
    }
    rows
}

/// Multicast-vs-unicast send accounting (analytic: the fabric replicates one
/// send request per destination under unicast, so send requests and their
/// core cycles inflate by the mean fan-out).
pub fn multicast_ablation(n_hap: usize, n_mark: usize, n_targets: usize) -> (u64, u64) {
    let h = n_hap as u64;
    let m = n_mark as u64;
    let t = n_targets as u64;
    let mcast_sends = t * (2 * (m - 1) * h + m * (h - 1));
    let unicast_sends = t * (2 * (m - 1) * h * h + m * (h - 1));
    (mcast_sends, unicast_sends)
}

/// Render the ablation report.
pub fn report(rows: &[AblationRow], mcast: (u64, u64)) -> String {
    let mut t = Table::new(&["mapping", "sim time", "inter-board", "sends", "peak mailbox busy"]);
    for r in rows {
        t.row(vec![
            r.name.clone(),
            fmt_secs(r.sim_seconds),
            fmt_count(r.inter_board_sends),
            fmt_count(r.sends),
            fmt_count(r.max_mailbox_busy),
        ]);
    }
    format!(
        "## Mapping ablation (same numerics asserted)\n{}\n\
         ## Multicast ablation\nhardware multicast: {} send requests; \
         naive unicast fan-out: {} ({}x amplification)\n",
        t.render(),
        fmt_count(mcast.0),
        fmt_count(mcast.1),
        mcast.1 / mcast.0.max(1),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_mapping_minimises_inter_board_traffic() {
        // Panel spans >1 board (24x100 = 2400 states at 2/thread over 2
        // boards) so locality actually matters.
        let rows = mapping_ablation(24, 100, 2, 2, 2, 7);
        let by = |n: &str| rows.iter().find(|r| r.name == n).unwrap().clone();
        let manual = by("manual-2d");
        let rnd = by("shuffled");
        assert!(
            manual.inter_board_sends * 2 < rnd.inter_board_sends,
            "manual {} vs shuffled {}",
            manual.inter_board_sends,
            rnd.inter_board_sends
        );
    }

    #[test]
    fn partitioner_between_manual_and_random() {
        let rows = mapping_ablation(24, 100, 2, 2, 2, 8);
        let by = |n: &str| rows.iter().find(|r| r.name == n).unwrap().clone();
        assert!(
            by("partitioned").inter_board_sends <= by("shuffled").inter_board_sends,
            "partitioner worse than random scatter"
        );
    }

    #[test]
    fn multicast_amplification_is_fanout() {
        let (mc, uc) = multicast_ablation(16, 100, 10);
        // Unicast inflates the α/β sends by H.
        assert!(uc > 10 * mc, "mc={mc} uc={uc}");
    }

    #[test]
    fn report_renders() {
        let rows = mapping_ablation(6, 30, 2, 2, 4, 9);
        let r = report(&rows, multicast_ablation(6, 30, 2));
        assert!(r.contains("manual-2d"));
        assert!(r.contains("amplification"));
    }
}
