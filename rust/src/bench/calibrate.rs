//! Cost-model calibration report.
//!
//! Prints (a) the frozen cost-model constants, (b) the measured x86
//! throughput on this host, (c) the model's prediction at the paper's one
//! quantitative anchor — Fig 12's peak: full 48-board cluster, ~10 states
//! per thread, 10,000 targets, reported speedup ≈ 270× — and (d) a
//! per-constant sensitivity sweep.  The constants are *frozen* across all
//! experiments; this report exists so the calibration is auditable, not
//! tunable per figure.

use crate::imputation::analytic::{AppKind, Workload, predict};
use crate::poets::costmodel::CostModel;
use crate::poets::topology::ClusterConfig;
use crate::util::table::{Table, fmt_speedup};
use crate::workload::scenarios;

use super::x86::X86Cost;

/// Estimated throughput of the *paper's* x86 baseline (single-threaded C on
/// an i9-7940X, 2017-era, f32 with a branchy inner loop and DRAM-resident
/// panels).  Derived from the paper's own statement that large-panel
/// runtimes are "measured in days": the largest Fig 12 panel (≈2M states,
/// H≈140, M≈14k) costs ≈1.1e9 MACs/target; 10,000 targets over ~2 days ⇒
/// ≈6e7 MAC/s.  Used ONLY for the anchor comparison; every figure also
/// reports speedups against the (much faster) baseline measured on this
/// host.
pub const PAPER_ERA_X86_MACS_PER_S: f64 = 6e7;

/// The paper's anchor configuration (Fig 12 optimum) at a given x86
/// throughput.
pub fn anchor_speedup(cost: &CostModel, macs_per_s: f64, full_targets: usize) -> f64 {
    let full = scenarios::fig12_config(10, 0);
    let pred = predict(
        &Workload {
            n_hap: full.n_hap,
            n_mark: full.n_mark,
            n_targets: full_targets,
            states_per_thread: 10,
            lane_width: 1, // paper-anchor regime: per-target pipeline
            kind: AppKind::Raw,
        },
        &ClusterConfig::poets_48(),
        cost,
    );
    let x86 = X86Cost {
        dense_macs_per_s: macs_per_s,
        rank1_macs_per_s: macs_per_s,
    };
    x86.raw_seconds(full.n_hap, full.n_mark, full_targets) / pred.seconds
}

/// Render the full calibration report.
pub fn report(x86: &X86Cost) -> String {
    let cost = CostModel::default();
    let mut out = String::new();
    out.push_str("## Cost-model calibration\n\n");
    out.push_str(&format!(
        "constants (cycles @210MHz): handler_dispatch={} flop={} mailbox_ingress={} \
         send_request={} hop={} link_serialize={} link_latency={} barrier_base={} \
         barrier_per_level={}\n",
        cost.handler_dispatch,
        cost.flop,
        cost.mailbox_ingress,
        cost.send_request,
        cost.hop,
        cost.board_link_serialize,
        cost.board_link_latency,
        cost.step_barrier_base,
        cost.step_barrier_per_level,
    ));
    out.push_str(&format!(
        "x86 host throughput: dense {:.2e} MAC/s, rank1 {:.2e} MAC/s\n\n",
        x86.dense_macs_per_s, x86.rank1_macs_per_s
    ));

    let anchor_paper = anchor_speedup(&cost, PAPER_ERA_X86_MACS_PER_S, 10_000);
    let anchor_host = anchor_speedup(&cost, x86.dense_macs_per_s, 10_000);
    out.push_str(&format!(
        "anchor (Fig 12 peak, 48 boards, 10 states/thread, 10k targets):\n\
         \x20 vs paper-era x86 ({PAPER_ERA_X86_MACS_PER_S:.0e} MAC/s): {} — paper reports ~270x\n\
         \x20 vs this host's baseline ({:.2e} MAC/s): {}\n\n",
        fmt_speedup(anchor_paper),
        x86.dense_macs_per_s,
        fmt_speedup(anchor_host),
    ));

    // Sensitivity: halve/double each dominant constant.
    let mut t = Table::new(&["constant", "x0.5", "x1", "x2"]);
    let variants: Vec<(&str, Box<dyn Fn(u64) -> CostModel>)> = vec![
        (
            "handler_dispatch",
            Box::new(|v| CostModel {
                handler_dispatch: v,
                ..CostModel::default()
            }),
        ),
        (
            "mailbox_ingress",
            Box::new(|v| CostModel {
                mailbox_ingress: v,
                ..CostModel::default()
            }),
        ),
        (
            "flop",
            Box::new(|v| CostModel {
                flop: v,
                ..CostModel::default()
            }),
        ),
        (
            "send_request",
            Box::new(|v| CostModel {
                send_request: v,
                ..CostModel::default()
            }),
        ),
    ];
    let base_val = |name: &str| -> u64 {
        match name {
            "handler_dispatch" => cost.handler_dispatch,
            "mailbox_ingress" => cost.mailbox_ingress,
            "flop" => cost.flop,
            "send_request" => cost.send_request,
            _ => unreachable!(),
        }
    };
    for (name, make) in &variants {
        let b = base_val(name);
        let lo = anchor_speedup(&make(b / 2), PAPER_ERA_X86_MACS_PER_S, 10_000);
        let mid = anchor_speedup(&make(b), PAPER_ERA_X86_MACS_PER_S, 10_000);
        let hi = anchor_speedup(&make(b * 2), PAPER_ERA_X86_MACS_PER_S, 10_000);
        t.row(vec![
            name.to_string(),
            fmt_speedup(lo),
            fmt_speedup(mid),
            fmt_speedup(hi),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_x86() -> X86Cost {
        // The paper's i9-7940X: a scalar C loop with a branch in the inner
        // body lands in the ~1e9 MAC/s regime.
        X86Cost {
            dense_macs_per_s: 1.5e9,
            rank1_macs_per_s: 3e9,
        }
    }

    #[test]
    fn anchor_lands_in_paper_order_of_magnitude() {
        let s = anchor_speedup(&CostModel::default(), PAPER_ERA_X86_MACS_PER_S, 10_000);
        // The paper reports ≈270x at this operating point; the frozen model
        // must land in that band (not fitted per figure — see module docs).
        assert!(
            (90.0..900.0).contains(&s),
            "anchor speedup {s} out of the paper's ~270x band"
        );
    }

    #[test]
    fn report_renders() {
        let r = report(&fake_x86());
        assert!(r.contains("anchor"));
        assert!(r.contains("mailbox_ingress"));
        assert!(r.contains("270x"));
    }

    #[test]
    fn sensitivity_direction() {
        // Costlier handlers must reduce the anchor speedup.
        let base = anchor_speedup(&CostModel::default(), PAPER_ERA_X86_MACS_PER_S, 10_000);
        let slow = anchor_speedup(
            &CostModel {
                handler_dispatch: CostModel::default().handler_dispatch * 2,
                ..CostModel::default()
            },
            PAPER_ERA_X86_MACS_PER_S,
            10_000,
        );
        assert!(slow < base);
    }
}
