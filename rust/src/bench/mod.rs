//! Benchmark harnesses — one per paper figure, plus calibration.
//!
//! * [`x86`] — baseline wall-clock measurement + linear extrapolation.
//! * [`figures`] — Fig 11 / Fig 12 / Fig 13 sweeps and the E4 sync-overhead
//!   check, each printing the same series the paper plots.
//! * [`calibrate`] — the frozen cost-model constants, the 270× anchor-point
//!   comparison, and per-constant sensitivity.

pub mod ablation;
pub mod calibrate;
pub mod figures;
pub mod x86;

pub use figures::{FigOpts, FigReport, fig11, fig12, fig13, sync_overhead};
pub use x86::X86Cost;
