//! Benchmark harnesses — one per paper figure, plus calibration.
//!
//! * [`x86`] — baseline wall-clock measurement + linear extrapolation.
//! * [`figures`] — Fig 11 / Fig 12 / Fig 13 sweeps and the E4 sync-overhead
//!   check, each printing the same series the paper plots.
//! * [`calibrate`] — the frozen cost-model constants, the 270× anchor-point
//!   comparison, and per-constant sensitivity.
//! * [`topology`] — the scenario lab's workload × topology × fault-model
//!   sweep (`bench topology`), hard-gated by the analytic cross-check.

pub mod ablation;
pub mod calibrate;
pub mod figures;
pub mod topology;
pub mod x86;

pub use figures::{FigOpts, FigReport, fig11, fig12, fig13, sync_overhead};
pub use topology::{TopologyOpts, TopologyReport};
pub use x86::X86Cost;
